"""MXU-tiled Pallas matmul vs jnp.dot."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref


def rand(shape, seed=0):
    return np.random.default_rng(seed).normal(0, 1, shape).astype(np.float32)


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (8, 8, 8), (128, 128, 128), (129, 257, 65),
    (64, 784, 256), (37, 211, 150), (256, 100, 10),
])
def test_matches_ref(m, k, n):
    a, b = rand((m, k), seed=m * 7 + k), rand((k, n), seed=n * 13 + k)
    got = np.asarray(matmul(a, b))
    want = np.asarray(ref.matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300),
       seed=st.integers(0, 2**31 - 1))
def test_matches_ref_hypothesis(m, k, n, seed):
    a, b = rand((m, k), seed=seed), rand((k, n), seed=seed + 1)
    got = np.asarray(matmul(a, b))
    want = np.asarray(ref.matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 32), (128, 128, 128)])
def test_tile_shapes(bm, bn, bk):
    """Result is tile-shape independent (the schedule is a pure layout)."""
    a, b = rand((100, 90), seed=1), rand((90, 110), seed=2)
    got = np.asarray(matmul(a, b, bm=bm, bn=bn, bk=bk))
    want = np.asarray(matmul(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_identity():
    a = rand((64, 64), seed=5)
    np.testing.assert_allclose(
        np.asarray(matmul(a, np.eye(64, dtype=np.float32))), a,
        rtol=1e-5, atol=1e-5)
