"""Checkpoint format roundtrip (must stay in lockstep with the Rust reader)."""

import numpy as np
import pytest

from compile import ckpt


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.ckpt")
    tensors = [
        ("a.w", "weight", np.arange(24, dtype=np.float32).reshape(2, 3, 4)),
        ("a.b", "bias", np.zeros(7, np.float32)),
        ("bn.m", "state", np.ones(3, np.float32)),
        ("__deltas__", "deltas", np.array([0.5, 0.25], np.float32)),
    ]
    meta = {"model": "mlp", "epoch": 3}
    ckpt.write_ckpt(path, meta, tensors)
    meta2, tensors2 = ckpt.read_ckpt(path)
    assert meta2 == meta
    assert len(tensors2) == len(tensors)
    for (n1, k1, a1), (n2, k2, a2) in zip(tensors, tensors2):
        assert n1 == n2 and k1 == k2
        np.testing.assert_array_equal(a1.astype(np.float32), a2)


def test_scalarless_shapes(tmp_path):
    path = str(tmp_path / "s.ckpt")
    ckpt.write_ckpt(path, {}, [("x", "weight", np.float32(3.5).reshape(()))])
    _, [(n, k, a)] = ckpt.read_ckpt(path)
    assert a.shape == () and float(a) == 3.5


def test_bad_magic(tmp_path):
    p = tmp_path / "bad.ckpt"
    p.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        ckpt.read_ckpt(str(p))


def test_kind_codes_stable():
    """The Rust reader hard-codes these — do not renumber."""
    assert ckpt.KINDS == {"weight": 0, "bias": 1, "gamma": 2, "beta": 3,
                          "state": 4, "momentum": 5, "deltas": 6}
