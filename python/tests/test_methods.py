"""Method semantics: SYMOG vs the Table-1 comparators (BC, TWN, BR)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import methods
from compile.kernels import ref
from compile.methods import Hyper, make_transform, ternary_twn


def rand(shape, scale=1.0, seed=0):
    return np.random.default_rng(seed).normal(0, scale, shape).astype(np.float32)


HP = Hyper()
DELTAS = jnp.asarray([0.5, 0.25])


class TestTWN:
    def test_ternary_codebook(self):
        w = jnp.asarray(rand((1000,), seed=1))
        t = np.asarray(ternary_twn(w))
        vals = np.unique(t)
        assert len(vals) <= 3
        alpha = np.max(np.abs(vals))
        assert set(np.round(vals / max(alpha, 1e-9), 6)) <= {-1.0, 0.0, 1.0}

    def test_threshold_rule(self):
        """Weights below 0.7 E|w| must map to zero, others to +-alpha."""
        w = np.asarray(rand((500,), seed=2))
        thr = 0.7 * np.mean(np.abs(w))
        t = np.asarray(ternary_twn(jnp.asarray(w)))
        np.testing.assert_array_equal(t[np.abs(w) <= thr], 0.0)
        assert np.all(t[np.abs(w) > thr] != 0.0)

    def test_alpha_is_surviving_mean(self):
        w = np.asarray(rand((500,), seed=3))
        thr = 0.7 * np.mean(np.abs(w))
        mask = np.abs(w) > thr
        alpha = np.abs(w[mask]).mean()
        t = np.asarray(ternary_twn(jnp.asarray(w)))
        np.testing.assert_allclose(np.max(np.abs(t)), alpha, rtol=1e-5)

    def test_ste_gradient_is_identity(self):
        wt = make_transform("twn", DELTAS, 0.0, HP)
        w = jnp.asarray(rand((64,), seed=4))
        g = jax.grad(lambda w: jnp.sum(wt(w, 0) * 2.0))(w)
        np.testing.assert_allclose(np.asarray(g), 2.0, atol=1e-5)


class TestBC:
    def test_sign_forward(self):
        wt = make_transform("bc", DELTAS, 0.0, HP)
        w = jnp.asarray(rand((100,), seed=5))
        out = np.asarray(wt(w, 0))
        np.testing.assert_array_equal(out, np.sign(np.asarray(w)))

    def test_ste_gradient_is_identity(self):
        wt = make_transform("bc", DELTAS, 0.0, HP)
        w = jnp.asarray(rand((64,), seed=6))
        g = jax.grad(lambda w: jnp.sum(wt(w, 0) * 3.0))(w)
        np.testing.assert_allclose(np.asarray(g), 3.0, atol=1e-5)

    def test_update_clips_to_unit(self):
        p, v = [jnp.asarray(rand((50,), 2.0, 7))], [jnp.zeros(50)]
        g = [jnp.asarray(rand((50,), 2.0, 8))]
        p2, _ = methods.update_params(
            "bc", ["weight"], [0], p, v, g, DELTAS, 0.5, 0.0, HP)
        assert np.all(np.abs(np.asarray(p2[0])) <= 1.0)


class TestBR:
    def test_lambda_zero_is_identity(self):
        wt = make_transform("br", DELTAS, jnp.float32(0.0), HP)
        w = jnp.asarray(rand((100,), seed=9))
        np.testing.assert_allclose(np.asarray(wt(w, 0)), np.asarray(w), atol=1e-6)

    def test_lambda_inf_is_quantized(self):
        wt = make_transform("br", DELTAS, jnp.float32(1e6), HP)
        w = jnp.asarray(rand((100,), seed=10))
        q = ref.quantize_ref(w, DELTAS[0], HP.n_bits)
        np.testing.assert_allclose(np.asarray(wt(w, 0)), np.asarray(q), atol=1e-4)

    def test_gradient_shrinks_with_lambda(self):
        w = jnp.asarray(rand((64,), seed=11))
        for lam, expect in [(0.0, 1.0), (1.0, 0.5), (3.0, 0.25)]:
            wt = make_transform("br", DELTAS, jnp.float32(lam), HP)
            g = jax.grad(lambda w: jnp.sum(wt(w, 0)))(w)
            np.testing.assert_allclose(np.asarray(g), expect, atol=1e-5)


class TestSymogUpdate:
    def test_pallas_and_ref_paths_agree(self):
        p = [jnp.asarray(rand((300,), seed=12))]
        v = [jnp.asarray(rand((300,), 0.1, 13))]
        g = [jnp.asarray(rand((300,), 0.1, 14))]
        out_pallas = methods.update_params(
            "symog", ["weight"], [0], p, v, g, DELTAS, 0.01, 5.0,
            Hyper(use_pallas=True))
        out_ref = methods.update_params(
            "symog", ["weight"], [0], p, v, g, DELTAS, 0.01, 5.0,
            Hyper(use_pallas=False))
        np.testing.assert_allclose(
            np.asarray(out_pallas[0][0]), np.asarray(out_ref[0][0]), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(out_pallas[1][0]), np.asarray(out_ref[1][0]), atol=1e-6)

    def test_non_weight_params_not_clipped(self):
        """gamma/beta/bias follow plain Nesterov — no quantization domain."""
        p = [jnp.asarray(rand((50,), 3.0, 15))]
        v = [jnp.zeros(50)]
        g = [jnp.zeros(50)]
        p2, _ = methods.update_params(
            "symog", ["gamma"], [None], p, v, g, DELTAS, 0.01, 100.0, HP)
        np.testing.assert_allclose(np.asarray(p2[0]), np.asarray(p[0]), atol=1e-6)


class TestQuantizedTransform:
    def test_matches_ref_quantizer(self):
        wt = methods.make_quantized_transform(DELTAS, 2)
        w = jnp.asarray(rand((128,), seed=16))
        np.testing.assert_array_equal(
            np.asarray(wt(w, 1)),
            np.asarray(ref.quantize_ref(w, DELTAS[1], 2)))
