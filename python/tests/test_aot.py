"""AOT pipeline: HLO text generation + manifest integrity (fast config)."""

import json
import os

import numpy as np
import pytest

from compile import aot, ckpt
from compile.aot import Config


@pytest.fixture(scope="module")
def compiled(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = Config("mlp", "symog", "synth-mnist", width_mult=0.25, batch=8,
                 tag="aottest")
    tag = aot.compile_config(cfg, out)
    return os.path.join(out, tag)


def test_hlo_text_shape(compiled):
    for f in ("train.hlo.txt", "eval.hlo.txt", "evalq.hlo.txt"):
        text = open(os.path.join(compiled, f)).read()
        assert text.startswith("HloModule"), f
        assert "ENTRY" in text, f
        # interchange-format guard: text, not serialized proto
        assert "\x00" not in text


def test_manifest_matches_interface(compiled):
    man = json.load(open(os.path.join(compiled, "manifest.json")))
    text = open(os.path.join(compiled, "train.hlo.txt")).read()
    # train inputs: images, labels, P params, P momenta, S state, deltas, lr, lam
    P, S = len(man["params"]), len(man["state"])
    n_inputs = 2 + 2 * P + S + 3
    # count parameters of the ENTRY computation only (nested computations
    # from the Pallas while-loops have their own parameter() instructions)
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == n_inputs
    assert man["n_quant"] == sum(1 for p in man["params"] if p["kind"] == "weight")
    # qidx is dense over quantized params
    qidxs = [p["qidx"] for p in man["params"] if p["kind"] == "weight"]
    assert qidxs == list(range(man["n_quant"]))


def test_init_ckpt_covers_manifest(compiled):
    man = json.load(open(os.path.join(compiled, "manifest.json")))
    _, tensors = ckpt.read_ckpt(os.path.join(compiled, "init.ckpt"))
    by_name = {n: (k, a) for n, k, a in tensors}
    for p in man["params"]:
        kind, arr = by_name[p["name"]]
        assert list(arr.shape) == p["shape"]
        assert kind == p["kind"]
    for s in man["state"]:
        _, arr = by_name[s["name"]]
        assert list(arr.shape) == s["shape"]
    _, deltas = by_name["__deltas__"]
    assert deltas.shape == (max(man["n_quant"], 1),)
    assert np.all(deltas > 0)
    # fixed-point constraint: every delta is a power of two
    f = np.log2(deltas)
    np.testing.assert_allclose(f, np.round(f), atol=1e-6)


def test_layer_manifest_structure(compiled):
    man = json.load(open(os.path.join(compiled, "manifest.json")))
    types = [l["type"] for l in man["layers"]]
    assert types[0] == "flatten"
    assert types[-1] == "dense"
    for l in man["layers"]:
        if l["type"] in ("conv", "dense"):
            assert isinstance(l["w"], int)
