"""Fused SYMOG update kernel vs oracle (Algorithm 1, lines 14-17)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import sgd_update, ref


def rand(shape, scale=1.0, seed=0):
    return np.random.default_rng(seed).normal(0, scale, shape).astype(np.float32)


def run_both(w, v, g, delta, lr, lam, **kw):
    got = sgd_update(w, v, g, delta, lr, lam, **kw)
    want = ref.sgd_update_ref(
        jnp.asarray(w), jnp.asarray(v), jnp.asarray(g), delta, lr=lr, lam=lam,
        momentum=kw.get("momentum", 0.9), n_bits=kw.get("n_bits", 2),
        weight_decay=kw.get("weight_decay", 0.0), clip=kw.get("clip", True))
    return got, want


@pytest.mark.parametrize("shape", [(3,), (1024,), (65, 67)])
@pytest.mark.parametrize("clip", [True, False])
def test_matches_ref(shape, clip):
    seed = abs(hash((shape, clip))) % 2**31
    w, v, g = rand(shape, seed=seed), rand(shape, 0.1, seed + 1), rand(shape, 0.1, seed + 2)
    (wn, vn), (wr, vr) = run_both(w, v, g, 0.25, 0.01, 5.0, clip=clip)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(wr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vr), atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 2000), f=st.integers(-4, 4), n_bits=st.integers(2, 4),
       lr=st.floats(1e-4, 0.1), lam=st.floats(0.0, 100.0),
       wd=st.floats(0.0, 1e-2), seed=st.integers(0, 2**31 - 1))
def test_matches_ref_hypothesis(n, f, n_bits, lr, lam, wd, seed):
    delta = 2.0 ** (-f)
    w, v, g = rand((n,), seed=seed), rand((n,), 0.1, seed + 1), rand((n,), 0.1, seed + 2)
    (wn, vn), (wr, vr) = run_both(
        w, v, g, delta, lr, lam, n_bits=n_bits, weight_decay=wd)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(wr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vr), atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 1000), n_bits=st.integers(2, 4),
       f=st.integers(-3, 3), seed=st.integers(0, 2**31 - 1))
def test_clip_bounds(n, n_bits, f, seed):
    """After a clipped update every weight is within +-delta (2^{N-1}-1)."""
    delta = 2.0 ** (-f)
    w = rand((n,), scale=3 * delta, seed=seed)
    v, g = rand((n,), 1.0, seed + 1), rand((n,), 1.0, seed + 2)
    wn, _ = sgd_update(w, v, g, delta, 0.1, 10.0, n_bits=n_bits, clip=True)
    bound = delta * (2 ** (n_bits - 1) - 1)
    assert np.all(np.abs(np.asarray(wn)) <= bound + 1e-6)


def test_no_clip_can_exceed():
    """Without clipping (the Fig-4 ablation) weights may leave the domain."""
    w = np.full(100, 0.49, np.float32)
    v = np.full(100, 0.5, np.float32)   # momentum pushing outward
    g = np.full(100, -1.0, np.float32)
    wn, _ = sgd_update(w, v, g, 0.5, 0.1, 0.0, clip=False)
    assert np.any(np.abs(np.asarray(wn)) > 0.5)


def test_zero_lambda_is_plain_nesterov():
    """lam=0, wd=0 reduces to textbook Nesterov momentum."""
    w, v, g = rand((257,), seed=1), rand((257,), 0.1, 2), rand((257,), 0.1, 3)
    wn, vn = sgd_update(w, v, g, 0.5, 0.05, 0.0, clip=False)
    v_exp = 0.9 * v - 0.05 * g
    w_exp = w + 0.9 * v_exp - 0.05 * g
    np.testing.assert_allclose(np.asarray(vn), v_exp, atol=1e-6)
    np.testing.assert_allclose(np.asarray(wn), w_exp, atol=1e-6)


def test_large_lambda_converges_to_modes():
    """Iterating the update with huge lambda and zero task gradient collapses
    weights onto the fixed-point codebook — the SYMOG end state (Fig 1)."""
    # per-step contraction toward the mode is lr*lam*2/M; pick values with
    # rate ~0.16 so 200 steps shrink the residual by ~1e-15
    delta = 0.25
    w = rand((128,), scale=0.2, seed=7)
    v = np.zeros_like(w)
    g = np.zeros_like(w)
    for _ in range(200):
        w, v = (np.asarray(t) for t in sgd_update(
            w, v, g, delta, 0.01, 1000.0, momentum=0.0))
    q = np.asarray(ref.quantize_ref(jnp.asarray(w), delta, 2))
    assert np.max(np.abs(w - q)) < 1e-3
