"""End-to-end train-step semantics for every method, plus gradient checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import layers, models, train_step
from compile.kernels import ref
from compile.methods import METHODS, Hyper

RNG = np.random.default_rng(42)


_PROTOS = {}


def toy_batch(m, bs=16, seed=0):
    """Learnable toy data: FIXED class prototypes + per-batch noise."""
    key = (m.num_classes, m.input_shape)
    if key not in _PROTOS:
        _PROTOS[key] = np.random.default_rng(1234).normal(
            0, 1, (m.num_classes, *m.input_shape)).astype(np.float32)
    protos = _PROTOS[key]
    rng = np.random.default_rng(seed)
    y = rng.integers(0, m.num_classes, bs)
    x = protos[y] + rng.normal(0, 0.5, (bs, *m.input_shape)).astype(np.float32)
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def fresh(m, seed=0):
    params = [jnp.asarray(a) for a in layers.init_params(m, seed)]
    momenta = [jnp.zeros_like(p) for p in params]
    state = [jnp.asarray(a) for a in layers.init_state(m)]
    deltas = jnp.asarray(
        [ref.optimal_delta_ref(p, 2)[0]
         for p, pp in zip(params, m.params) if pp.kind == "weight"] or [1.0],
        jnp.float32)
    return params, momenta, state, deltas


MLP = models.get_model("mlp", (28, 28, 1), 10, 0.5)


@pytest.mark.parametrize("method", METHODS)
def test_loss_decreases(method):
    hp = Hyper(use_pallas=False)  # jnp path: fast tracing for the sweep
    step = jax.jit(train_step.flatten_train(MLP, method, hp))
    params, momenta, state, deltas = fresh(MLP)
    P, S = len(params), len(state)
    first = last = None
    for i in range(25):
        x, y = toy_batch(MLP, seed=i)
        lam = jnp.float32(min(0.1 * i, 1.0)) if method in ("symog", "br") else jnp.float32(0.0)
        out = step(x, y, *params, *momenta, *state, deltas, jnp.float32(0.05), lam)
        loss = float(out[0])
        params = list(out[2:2 + P])
        momenta = list(out[2 + P:2 + 2 * P])
        state = list(out[2 + 2 * P:])
        first = first if first is not None else loss
        last = loss
    assert last < first * 0.7, f"{method}: {first} -> {last}"


def test_symog_pallas_matches_ref_path():
    """The full train step with Pallas kernels == with jnp oracles."""
    hp_p, hp_r = Hyper(use_pallas=True), Hyper(use_pallas=False)
    sp = jax.jit(train_step.flatten_train(MLP, "symog", hp_p))
    sr = jax.jit(train_step.flatten_train(MLP, "symog", hp_r))
    params, momenta, state, deltas = fresh(MLP)
    x, y = toy_batch(MLP, seed=99)
    args = (x, y, *params, *momenta, *state, deltas, jnp.float32(0.01), jnp.float32(5.0))
    op, orf = sp(*args), sr(*args)
    for a, b in zip(op, orf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_symog_weights_stay_in_domain():
    hp = Hyper(use_pallas=False, clip=True)
    step = jax.jit(train_step.flatten_train(MLP, "symog", hp))
    params, momenta, state, deltas = fresh(MLP)
    P, S = len(params), len(state)
    for i in range(10):
        x, y = toy_batch(MLP, seed=i)
        out = step(x, y, *params, *momenta, *state, deltas,
                   jnp.float32(0.1), jnp.float32(10.0))
        params = list(out[2:2 + P])
        momenta = list(out[2 + P:2 + 2 * P])
        state = list(out[2 + 2 * P:])
    for p, meta in zip(params, MLP.params):
        if meta.kind == "weight":
            bound = float(deltas[meta.qidx])  # qmax = 1 for 2 bits
            assert np.all(np.abs(np.asarray(p)) <= bound + 1e-6)


def test_eval_consistency_with_train_forward():
    """eval on the same batch gives the same loss as the train forward
    (baseline method, BN batch-stats aside: use a BN-free model)."""
    hp = Hyper(use_pallas=False)
    step = jax.jit(train_step.flatten_train(MLP, "baseline", hp))
    ev = jax.jit(train_step.flatten_eval(MLP, hp, False))
    params, momenta, state, deltas = fresh(MLP)
    x, y = toy_batch(MLP, seed=5)
    out = step(x, y, *params, *momenta, *state, deltas,
               jnp.float32(0.0), jnp.float32(0.0))
    el, ec = ev(x, y, *params, *state)
    np.testing.assert_allclose(float(out[0]), float(el), rtol=1e-5)
    assert float(out[1]) == float(ec)


def test_evalq_equals_eval_on_quantized_weights():
    """evalq(params) == eval(Q(params)): the quantized-eval executable is
    exactly post-training quantization of the weight tensors."""
    hp = Hyper(use_pallas=False)
    ev = jax.jit(train_step.flatten_eval(MLP, hp, False))
    evq = jax.jit(train_step.flatten_eval(MLP, hp, True))
    params, _, state, deltas = fresh(MLP)
    x, y = toy_batch(MLP, seed=6)
    lq, cq = evq(x, y, *params, *state, deltas)
    qparams = [
        ref.quantize_ref(p, deltas[meta.qidx], 2) if meta.kind == "weight" else p
        for p, meta in zip(params, MLP.params)]
    lf, cf = ev(x, y, *qparams, *state)
    np.testing.assert_allclose(float(lq), float(lf), rtol=1e-5)
    assert float(cq) == float(cf)


def test_gradient_against_finite_differences():
    """Spot-check the fused step's task gradient with central differences on
    a few random weight coordinates (baseline method, no regularizer)."""
    hp = Hyper(use_pallas=False)
    m = models.get_model("mlp", (8, 8, 1), 4, 0.25)
    params = [jnp.asarray(a) for a in layers.init_params(m, 3)]
    state = [jnp.asarray(a) for a in layers.init_state(m)]
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (8, 8, 8, 1)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)

    def loss_of(params):
        logits, _ = layers.apply(m, params, state, x, train=False)
        return train_step.cross_entropy(logits, y)

    grads = jax.grad(lambda ps: loss_of(ps))(params)
    eps = 1e-3
    for pi in [0, 2]:
        flat = np.asarray(params[pi]).ravel()
        for ci in rng.choice(flat.size, 3, replace=False):
            delta_vec = np.zeros_like(flat)
            delta_vec[ci] = eps
            pplus = [p if i != pi else jnp.asarray(
                (flat + delta_vec).reshape(params[pi].shape)) for i, p in enumerate(params)]
            pminus = [p if i != pi else jnp.asarray(
                (flat - delta_vec).reshape(params[pi].shape)) for i, p in enumerate(params)]
            fd = (float(loss_of(pplus)) - float(loss_of(pminus))) / (2 * eps)
            an = float(np.asarray(grads[pi]).ravel()[ci])
            assert abs(fd - an) < 5e-3, (pi, ci, fd, an)


def test_correct_count_range():
    hp = Hyper(use_pallas=False)
    ev = jax.jit(train_step.flatten_eval(MLP, hp, False))
    params, _, state, _ = fresh(MLP)
    x, y = toy_batch(MLP, bs=32, seed=8)
    _, c = ev(x, y, *params, *state)
    assert 0.0 <= float(c) <= 32.0
