"""Pallas quantizer kernel vs the jnp oracle + quantizer invariants (Eq. 1)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize, ref

SHAPES = [(1,), (7,), (128,), (1024,), (65, 129), (3, 5, 7), (2, 3, 4, 5)]


def rand(shape, scale=2.0, seed=0):
    return (np.random.default_rng(seed).normal(0, scale, shape)).astype(np.float32)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("n_bits", [2, 3, 4, 8])
def test_kernel_matches_ref(shape, n_bits):
    x = rand(shape, seed=hash((shape, n_bits)) % 2**31)
    delta = 0.25
    got = quantize(x, delta, n_bits)
    want = ref.quantize_ref(jnp.asarray(x), delta, n_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 4000),
    n_bits=st.integers(2, 8),
    f=st.integers(-6, 6),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 10.0),
)
def test_kernel_matches_ref_hypothesis(n, n_bits, f, seed, scale):
    x = rand((n,), scale=scale, seed=seed)
    delta = 2.0 ** (-f)
    got = np.asarray(quantize(x, delta, n_bits))
    want = np.asarray(ref.quantize_ref(jnp.asarray(x), delta, n_bits))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 2000), n_bits=st.integers(2, 6),
       seed=st.integers(0, 2**31 - 1))
def test_idempotent(n, n_bits, seed):
    """Q(Q(x)) == Q(x): quantized values are fixed points of Q."""
    x = rand((n,), seed=seed)
    q1 = np.asarray(quantize(x, 0.5, n_bits))
    q2 = np.asarray(quantize(q1, 0.5, n_bits))
    np.testing.assert_array_equal(q1, q2)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 2000), n_bits=st.integers(2, 6),
       seed=st.integers(0, 2**31 - 1))
def test_odd_symmetry(n, n_bits, seed):
    """Q(-x) == -Q(x): the symmetric codebook of section 3.1."""
    x = rand((n,), seed=seed)
    qp = np.asarray(quantize(x, 0.25, n_bits))
    qn = np.asarray(quantize(-x, 0.25, n_bits))
    np.testing.assert_array_equal(qp, -qn)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 2000), n_bits=st.integers(2, 6),
       f=st.integers(-4, 4), seed=st.integers(0, 2**31 - 1))
def test_output_in_codebook(n, n_bits, f, seed):
    """Every output is m * delta with |m| <= 2^{N-1}-1 integer mantissa."""
    delta = 2.0 ** (-f)
    x = rand((n,), scale=5 * delta, seed=seed)
    q = np.asarray(quantize(x, delta, n_bits))
    m = q / delta
    qmax = 2 ** (n_bits - 1) - 1
    assert np.all(np.abs(m - np.round(m)) < 1e-5)
    assert np.all(np.abs(m) <= qmax + 1e-5)


def test_quantization_error_bounded():
    """|x - Q(x)| <= delta/2 inside the clip range."""
    x = rand((5000,), scale=0.3)
    delta = 0.25
    inside = np.abs(x) <= delta * 1.0  # well within the 2-bit range
    q = np.asarray(quantize(x, delta, 2))
    assert np.all(np.abs(x[inside] - q[inside]) <= delta / 2 + 1e-6)


def test_fig2_transfer_curve():
    """The 2-bit quantizer of Figure 2: ternary plateaus at {-D, 0, D}."""
    delta = 1.0
    x = np.linspace(-2, 2, 401).astype(np.float32)
    q = np.asarray(quantize(x, delta, 2))
    assert set(np.unique(q)) == {-1.0, 0.0, 1.0}
    assert q[x < -0.5][-1] == -1.0
    assert np.all(q[np.abs(x) < 0.5] == 0.0)
    assert np.all(q[x >= 0.5] == 1.0)


def test_dtype_preserved():
    x = rand((33,)).astype(np.float32)
    assert quantize(x, 0.5, 2).dtype == jnp.float32
