"""Activation quantization extension: fake_quant_act + act_bits training."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers, models, train_step
from compile.layers import fake_quant_act
from compile.methods import Hyper


def rand(shape, scale=1.0, seed=0):
    return np.random.default_rng(seed).normal(0, scale, shape).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 2000), bits=st.integers(4, 8),
       scale=st.floats(1e-3, 100.0), seed=st.integers(0, 2**31 - 1))
def test_outputs_on_power_of_two_grid(n, bits, scale, seed):
    x = jnp.asarray(np.abs(rand((n,), scale, seed)))  # post-ReLU: non-negative
    q = np.asarray(fake_quant_act(x, bits))
    qmax = 2 ** (bits - 1) - 1
    amax = float(jnp.max(jnp.abs(x)))
    # the delta the function chose: largest power of two with amax/delta <= qmax
    delta = 2.0 ** -np.floor(np.log2(qmax / amax))
    m = q / delta
    np.testing.assert_allclose(m, np.round(m), atol=1e-3)
    assert np.max(np.abs(m)) <= qmax + 0.5
    # error bounded by one step of the chosen grid
    err = np.max(np.abs(q - np.asarray(x)))
    assert err <= delta * 0.5 + 1e-6, f"err {err} delta {delta}"


def test_gradient_is_identity():
    x = jnp.asarray(np.abs(rand((128,), seed=1)))
    g = jax.grad(lambda x: jnp.sum(fake_quant_act(x, 8) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0, atol=1e-6)


def test_high_bits_near_lossless():
    x = jnp.asarray(np.abs(rand((512,), seed=2)))
    q = np.asarray(fake_quant_act(x, 16))
    np.testing.assert_allclose(q, np.asarray(x), rtol=1e-3, atol=1e-4)


def test_train_step_with_act_bits_learns():
    m = models.get_model("mlp", (8, 8, 1), 4, 0.25)
    hp = Hyper(use_pallas=False, act_bits=8)
    step = jax.jit(train_step.flatten_train(m, "symog", hp))
    params = [jnp.asarray(a) for a in layers.init_params(m, 0)]
    momenta = [jnp.zeros_like(p) for p in params]
    state = [jnp.asarray(a) for a in layers.init_state(m)]
    deltas = jnp.asarray([0.25] * m.n_quant)
    rng = np.random.default_rng(0)
    protos = rng.normal(0, 1, (4, 8, 8, 1)).astype(np.float32)
    P = len(params)
    losses = []
    for i in range(20):
        y = rng.integers(0, 4, 16)
        x = protos[y] + rng.normal(0, 0.4, (16, 8, 8, 1)).astype(np.float32)
        out = step(jnp.asarray(x), jnp.asarray(y, jnp.int32), *params, *momenta,
                   *state, deltas, jnp.float32(0.05), jnp.float32(0.5))
        losses.append(float(out[0]))
        params = list(out[2:2 + P])
        momenta = list(out[2 + P:2 + 2 * P])
    assert losses[-1] < losses[0] * 0.8, losses


def test_act_bits_changes_forward():
    m = models.get_model("mlp", (8, 8, 1), 4, 0.25)
    params = [jnp.asarray(a) for a in layers.init_params(m, 3)]
    state = [jnp.asarray(a) for a in layers.init_state(m)]
    x = jnp.asarray(rand((4, 8, 8, 1), seed=4))
    l_full, _ = layers.apply(m, params, state, x, train=False)
    l_q4, _ = layers.apply(m, params, state, x, train=False, act_bits=4)
    # 4-bit activations must perturb the logits (but not destroy them)
    assert not np.allclose(np.asarray(l_full), np.asarray(l_q4))
    assert np.all(np.isfinite(np.asarray(l_q4)))
