"""Model zoo: build, shape inference, forward shapes, param bookkeeping."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import layers, models

CASES = [
    ("mlp", (28, 28, 1), 10, 1.0),
    ("lenet5", (28, 28, 1), 10, 1.0),
    ("vgg7", (32, 32, 3), 10, 0.125),
    ("vgg11", (32, 32, 3), 100, 0.125),
    ("vgg16", (32, 32, 3), 100, 0.125),
    ("densenet", (32, 32, 3), 10, 0.25),
]


@pytest.mark.parametrize("name,shape,classes,wm", CASES)
def test_build_and_forward(name, shape, classes, wm):
    m = models.get_model(name, shape, classes, wm)
    params = [jnp.asarray(a) for a in layers.init_params(m, 0)]
    state = [jnp.asarray(a) for a in layers.init_state(m)]
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, *shape)), jnp.float32)
    logits, new_state = layers.apply(m, params, state, x, train=True)
    assert logits.shape == (2, classes)
    assert len(new_state) == len(state)
    # eval path too
    logits2, _ = layers.apply(m, params, state, x, train=False)
    assert logits2.shape == (2, classes)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name,shape,classes,wm", CASES)
def test_param_bookkeeping(name, shape, classes, wm):
    m = models.get_model(name, shape, classes, wm)
    qidxs = [p.qidx for p in m.params if p.kind == "weight"]
    assert qidxs == list(range(m.n_quant))
    assert all(p.qidx is None for p in m.params if p.kind != "weight")
    names = [p.name for p in m.params]
    assert len(names) == len(set(names)), "duplicate param names"


def test_lenet5_param_count_near_paper():
    """Paper: LeNet5 has ~60k params (Table 1)."""
    m = models.get_model("lenet5", (28, 28, 1), 10, 1.0)
    n = sum(int(np.prod(p.shape)) for p in m.params)
    assert 55_000 < n < 70_000, n


def test_vgg7_fullsize_param_count_near_paper():
    """Paper: VGG7 ~12M params. Build only (no forward — large)."""
    m = models.get_model("vgg7", (32, 32, 3), 10, 1.0)
    n = sum(int(np.prod(p.shape)) for p in m.params)
    assert 10_000_000 < n < 15_000_000, n


def test_densenet_fullsize_param_count():
    """Our DenseNet is the plain (non-bottleneck) variant: L=76 k=12 lands
    at ~2.3M params, vs the paper's 0.49M DenseNet-BC. The width_mult knob
    covers matching budgets (w=0.5 -> ~0.6M); dynamics are unaffected."""
    m = models.densenet((32, 32, 3), 10, depth=76, growth=12)
    n = sum(int(np.prod(p.shape)) for p in m.params)
    assert 1_500_000 < n < 4_000_000, n
    m_half = models.densenet((32, 32, 3), 10, depth=76, growth=12, width_mult=0.5)
    n_half = sum(int(np.prod(p.shape)) for p in m_half.params)
    assert 300_000 < n_half < 800_000, n_half


def test_bn_state_updates_in_train_mode():
    m = models.get_model("lenet5", (28, 28, 1), 10, 1.0)
    params = [jnp.asarray(a) for a in layers.init_params(m, 0)]
    state = [jnp.asarray(a) for a in layers.init_state(m)]
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (4, 28, 28, 1)), jnp.float32)
    _, new_state = layers.apply(m, params, state, x, train=True)
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(state, new_state))
    assert changed
    _, frozen = layers.apply(m, params, state, x, train=False)
    for a, b in zip(state, frozen):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_width_mult_scales_params():
    small = models.get_model("vgg7", (32, 32, 3), 10, 0.125)
    big = models.get_model("vgg7", (32, 32, 3), 10, 0.25)
    ns = sum(int(np.prod(p.shape)) for p in small.params)
    nb = sum(int(np.prod(p.shape)) for p in big.params)
    assert nb > 2 * ns


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        models.get_model("resnet", (32, 32, 3), 10)


def test_densenet_depth_validation():
    with pytest.raises(ValueError):
        models.densenet(depth=23)
