"""layers.py: shape inference, init statistics, transform routing."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as L


def build_random_cnn(rng_seed: int, n_blocks: int, input_hw: int, classes: int):
    """Deterministic pseudo-random conv stack builder (valid by construction)."""
    rng = np.random.default_rng(rng_seed)
    spec = []
    hw = input_hw
    for _ in range(n_blocks):
        ch = int(rng.integers(4, 17))
        k = int(rng.choice([1, 3, 5]))
        spec.append(L.conv(ch, k=k, padding="SAME"))
        if rng.random() < 0.5:
            spec.append(L.bn())
        spec.append(L.relu())
        if hw >= 4 and rng.random() < 0.5:
            spec.append(L.maxpool(2))
            hw //= 2
    spec += [L.flatten(), L.dense(classes)]
    return spec


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_blocks=st.integers(1, 4))
def test_random_cnn_builds_and_runs(seed, n_blocks):
    spec = build_random_cnn(seed, n_blocks, 16, 5)
    m = L.build("rand", spec, (16, 16, 3), 5)
    params = [jnp.asarray(a) for a in L.init_params(m, seed)]
    state = [jnp.asarray(a) for a in L.init_state(m)]
    x = jnp.asarray(np.random.default_rng(seed).normal(0, 1, (2, 16, 16, 3)),
                    jnp.float32)
    logits, _ = L.apply(m, params, state, x, train=True)
    assert logits.shape == (2, 5)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_valid_conv_shape_inference():
    spec = [L.conv(4, k=5, padding="VALID"), L.relu(), L.flatten(), L.dense(3)]
    m = L.build("v", spec, (12, 12, 1), 3)
    # VALID 5x5: 12 -> 8; flatten = 8*8*4
    assert m.params[0].shape == (5, 5, 1, 4)
    assert m.params[1].shape == (8 * 8 * 4, 3)


def test_strided_conv_shapes():
    spec = [L.conv(4, k=3, stride=2, padding="SAME"), L.flatten(), L.dense(2)]
    m = L.build("s", spec, (9, 9, 1), 2)
    # SAME stride 2: ceil(9/2) = 5
    assert m.params[1].shape == (5 * 5 * 4, 2)
    params = [jnp.asarray(a) for a in L.init_params(m, 0)]
    x = jnp.zeros((1, 9, 9, 1), jnp.float32)
    logits, _ = L.apply(m, params, [], x, train=False)
    assert logits.shape == (1, 2)


def test_dense_before_flatten_rejected():
    with pytest.raises(ValueError, match="dense before flatten"):
        L.build("bad", [L.dense(4)], (8, 8, 1), 4)


def test_model_must_end_in_classes():
    with pytest.raises(ValueError, match="must end"):
        L.build("bad", [L.flatten(), L.dense(7)], (8, 8, 1), 4)


def test_concat_shape_mismatch_rejected():
    spec = [
        L.conv(4), L.relu(), L.maxpool(2),
        L.concat_shortcut(0),  # 4x4 vs 8x8 -> mismatch
        L.flatten(), L.dense(2),
    ]
    with pytest.raises(ValueError, match="concat shape mismatch"):
        L.build("bad", spec, (8, 8, 1), 2)


def test_he_init_statistics():
    spec = [L.flatten(), L.dense(256, use_bias=False), L.relu(), L.dense(10)]
    m = L.build("he", spec, (16, 16, 4), 10)
    params = L.init_params(m, 0)
    w = params[0]  # (1024, 256)
    expected_std = np.sqrt(2.0 / 1024)
    assert abs(w.std() - expected_std) / expected_std < 0.05
    assert abs(w.mean()) < expected_std / 10


def test_weight_transform_applied_only_to_weights():
    calls = []

    def wt(w, qidx):
        calls.append(qidx)
        return w * 0.0  # zero out -> logits must be bias-only

    spec = [L.flatten(), L.dense(4)]
    m = L.build("wt", spec, (4, 4, 1), 4)
    params = [jnp.asarray(a) + 1.0 for a in L.init_params(m, 0)]  # bias = 1
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (3, 4, 4, 1)), jnp.float32)
    logits, _ = L.apply(m, params, [], x, train=False, wt=wt)
    assert calls == [0]
    np.testing.assert_allclose(np.asarray(logits), 1.0, atol=1e-6)


def test_avgpool_and_global_avgpool():
    spec = [L.avgpool(2), L.global_avgpool(), L.flatten(), L.dense(2)]
    m = L.build("p", spec, (8, 8, 2), 2)
    params = [jnp.asarray(a) for a in L.init_params(m, 0)]
    x = jnp.ones((1, 8, 8, 2), jnp.float32)
    logits, _ = L.apply(m, params, [], x, train=False)
    assert logits.shape == (1, 2)


def test_pallas_dense_path_matches_jnp():
    spec = [L.flatten(), L.dense(32), L.relu(), L.dense(4)]
    m = L.build("pl", spec, (8, 8, 1), 4)
    params = [jnp.asarray(a) for a in L.init_params(m, 1)]
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (4, 8, 8, 1)), jnp.float32)
    l_jnp, _ = L.apply(m, params, [], x, train=False, use_pallas=False)
    l_pal, _ = L.apply(m, params, [], x, train=False, use_pallas=True)
    np.testing.assert_allclose(np.asarray(l_jnp), np.asarray(l_pal),
                               rtol=1e-4, atol=1e-4)
