"""Mode-occupancy histogram kernel vs oracle (Fig 3/4 probe)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mode_hist, ref


def rand(shape, scale=1.0, seed=0):
    return np.random.default_rng(seed).normal(0, scale, shape).astype(np.float32)


@pytest.mark.parametrize("n", [1, 5, 1024, 4097])
@pytest.mark.parametrize("n_bits", [2, 3, 4])
def test_matches_ref(n, n_bits):
    w = rand((n,), seed=n * n_bits)
    got = np.asarray(mode_hist(w, 0.5, n_bits))
    want = np.asarray(ref.mode_hist_ref(jnp.asarray(w), 0.5, n_bits))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 5000), f=st.integers(-4, 4),
       n_bits=st.integers(2, 5), seed=st.integers(0, 2**31 - 1))
def test_matches_ref_hypothesis(n, f, n_bits, seed):
    w = rand((n,), seed=seed)
    delta = 2.0 ** (-f)
    got = np.asarray(mode_hist(w, delta, n_bits))
    want = np.asarray(ref.mode_hist_ref(jnp.asarray(w), delta, n_bits))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 3000), n_bits=st.integers(2, 5),
       seed=st.integers(0, 2**31 - 1))
def test_total_mass(n, n_bits, seed):
    """Histogram counts sum to the number of weights (padding excluded)."""
    w = rand((n,), seed=seed)
    h = np.asarray(mode_hist(w, 0.25, n_bits))
    assert h.sum() == n
    assert len(h) == 2 ** n_bits - 1


def test_known_assignment():
    delta = 1.0
    w = np.array([-3.0, -1.0, -0.4, 0.0, 0.4, 0.6, 1.2], np.float32)
    # modes for 2 bits: {-1, 0, 1}; 0.5 rounds away from zero
    h = np.asarray(mode_hist(w, delta, 2))
    np.testing.assert_array_equal(h, [2, 3, 2])


def test_ternary_distribution_shape():
    """A trained-SYMOG-like trimodal sample lands in three clean bins."""
    rng = np.random.default_rng(0)
    modes = rng.choice([-0.5, 0.0, 0.5], 3000, p=[0.3, 0.4, 0.3])
    w = (modes + rng.normal(0, 0.01, 3000)).astype(np.float32)
    h = np.asarray(mode_hist(w, 0.5, 2))
    np.testing.assert_array_equal(h, np.bincount(((modes / 0.5) + 1).astype(int), minlength=3))
