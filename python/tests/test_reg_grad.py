"""SYMOG regularizer-gradient kernel vs oracle (Eq. 4) + analytic checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import reg_grad, ref


def rand(shape, scale=1.0, seed=0):
    return np.random.default_rng(seed).normal(0, scale, shape).astype(np.float32)


@pytest.mark.parametrize("shape", [(5,), (1024,), (31, 67), (4, 4, 3, 8)])
@pytest.mark.parametrize("n_bits", [2, 3, 4])
def test_matches_ref(shape, n_bits):
    w = rand(shape, seed=abs(hash((shape, n_bits))) % 2**31)
    got = np.asarray(reg_grad(w, 0.25, n_bits))
    want = np.asarray(ref.reg_grad_ref(jnp.asarray(w), 0.25, n_bits))
    np.testing.assert_allclose(got, want, atol=1e-7)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 3000), f=st.integers(-5, 5),
       n_bits=st.integers(2, 6), seed=st.integers(0, 2**31 - 1))
def test_matches_ref_hypothesis(n, f, n_bits, seed):
    w = rand((n,), seed=seed)
    delta = 2.0 ** (-f)
    got = np.asarray(reg_grad(w, delta, n_bits))
    want = np.asarray(ref.reg_grad_ref(jnp.asarray(w), delta, n_bits))
    np.testing.assert_allclose(got, want, atol=1e-7)


def test_gradient_is_scaled_quant_error():
    """dR/dw == (2/M) * (w - Q(w)) exactly (the paper's closed form)."""
    w = rand((777,), seed=3)
    g = np.asarray(reg_grad(w, 0.5, 2))
    q = np.asarray(ref.quantize_ref(jnp.asarray(w), 0.5, 2))
    np.testing.assert_allclose(g, (2.0 / w.size) * (w - q), atol=1e-7)


def test_zero_at_modes():
    """Weights sitting exactly on a fixed-point mode get zero gradient."""
    delta = 0.25
    w = np.array([-delta, 0.0, delta], np.float32)
    g = np.asarray(reg_grad(w, delta, 2))
    np.testing.assert_array_equal(g, np.zeros_like(w))


def test_matches_autodiff_of_R():
    """The closed form equals jax.grad of R = (1/M)||w - stop_grad(Q(w))||^2.

    This validates the paper's Eq. 4 derivation (dQ/dw treated as 0)."""
    w = jnp.asarray(rand((256,), seed=9))
    delta, n_bits = 0.5, 2

    def R(w):
        q = jax.lax.stop_gradient(ref.quantize_ref(w, delta, n_bits))
        return jnp.sum((w - q) ** 2) / w.size

    auto = jax.grad(R)(w)
    closed = reg_grad(np.asarray(w), delta, n_bits)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(closed), atol=1e-7)


def test_pull_direction():
    """Gradient descent on R moves weights toward their nearest mode."""
    w = rand((512,), seed=11)
    g = np.asarray(reg_grad(w, 0.25, 2))
    q = np.asarray(ref.quantize_ref(jnp.asarray(w), 0.25, 2))
    w2 = w - 50.0 * g  # one large step
    assert np.linalg.norm(w2 - q) < np.linalg.norm(w - q)
