"""Checkpoint binary format — shared with rust/src/coordinator/checkpoint.rs.

Layout (little-endian):

    magic   8 bytes  b"SYMGCKP1"
    u32     meta_len
    bytes   meta JSON (utf-8): {"model":..., "epoch":..., ...}
    u32     n_tensors
    per tensor:
        u32   name_len
        bytes name (utf-8)
        u8    kind  (0 weight, 1 bias, 2 gamma, 3 beta, 4 state,
                     5 momentum, 6 deltas)
        u8    ndim
        u32   dims[ndim]
        f32   data[prod(dims)]

Python only *writes* init checkpoints (aot.py); Rust reads and writes them
during training. Keep the two implementations in lockstep.
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"SYMGCKP1"
KINDS = {"weight": 0, "bias": 1, "gamma": 2, "beta": 3, "state": 4,
         "momentum": 5, "deltas": 6}
KIND_NAMES = {v: k for k, v in KINDS.items()}


def write_ckpt(path: str, meta: dict,
               tensors: List[Tuple[str, str, np.ndarray]]) -> None:
    """tensors: list of (name, kind, f32 array)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        mj = json.dumps(meta).encode()
        f.write(struct.pack("<I", len(mj)))
        f.write(mj)
        f.write(struct.pack("<I", len(tensors)))
        for name, kind, arr in tensors:
            # np.asarray (not ascontiguousarray: it collapses 0-d to 1-d);
            # tobytes() always emits C order regardless of input layout
            arr = np.asarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", KINDS[kind], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_ckpt(path: str) -> Tuple[dict, List[Tuple[str, str, np.ndarray]]]:
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, f"{path}: bad magic"
        (mlen,) = struct.unpack("<I", f.read(4))
        meta = json.loads(f.read(mlen))
        (n,) = struct.unpack("<I", f.read(4))
        out = []
        for _ in range(n):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            kind, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            size = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(4 * size), np.float32).reshape(dims)
            out.append((name, KIND_NAMES[kind], arr))
        return meta, out
