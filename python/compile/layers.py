"""Functional layer primitives for the L2 model zoo.

A model is a flat list of layer dicts (see models.py). Parameters live in a
flat, deterministically-ordered list of `Param`s; BN running statistics live
in a parallel `state` list. Every `kind == "weight"` parameter is a
*quantized* parameter in the paper's sense — it owns a slot in the per-layer
step-size vector `deltas` and is routed through the active method's weight
transform before use (identity for SYMOG/baseline, sign/ternary/relaxed for
the BC/TWN/BR comparators, hard Q_N for quantized eval).

Biases, BN scale/shift are trained in float (the paper quantizes weights
only; section 5 lists full fixed-point BN as future work).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul as pallas_matmul

# ---------------------------------------------------------------------------
# parameter / state descriptors


@dataclasses.dataclass
class Param:
    """One trainable tensor. `qidx` is the index into the `deltas` vector for
    kind == "weight" parameters, else None."""

    name: str
    shape: Tuple[int, ...]
    kind: str  # "weight" | "bias" | "gamma" | "beta"
    fan_in: int = 0
    qidx: Optional[int] = None


@dataclasses.dataclass
class StateVar:
    """One non-trainable tensor (BN running mean / variance)."""

    name: str
    shape: Tuple[int, ...]
    init: float  # 0.0 for means, 1.0 for variances


# weight transform: (w, qidx) -> tensor used in the forward pass
WeightTransform = Callable[[jnp.ndarray, int], jnp.ndarray]


def identity_transform(w: jnp.ndarray, qidx: int) -> jnp.ndarray:
    return w


# ---------------------------------------------------------------------------
# pallas-backed dense matmul with a custom VJP (the Pallas call itself has no
# autodiff rule; its cotangents are two more tiled matmuls)


@jax.custom_vjp
def _pmatmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return pallas_matmul(a, b)


def _pmatmul_fwd(a, b):
    return pallas_matmul(a, b), (a, b)


def _pmatmul_bwd(res, g):
    a, b = res
    return pallas_matmul(g, b.T), pallas_matmul(a.T, g)


_pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


def dense_matmul(a: jnp.ndarray, b: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    """a @ b via the Pallas MXU-tiled kernel or plain jnp (HLO dot)."""
    if use_pallas:
        return _pmatmul(a, b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def fake_quant_act(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Dynamic per-tensor activation quantization with a power-of-two scale
    (our extension toward the paper's "pure fixed-point models" future work;
    mirrors the integer engine's runtime behaviour). Straight-through
    identity gradient."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    # largest power-of-two delta with amax/delta <= qmax
    frac = jnp.floor(jnp.log2(qmax / amax))
    delta = jnp.exp2(-frac)
    s = x / delta
    q = jnp.clip(jnp.sign(s) * jnp.floor(jnp.abs(s) + 0.5), -qmax, qmax) * delta
    return x + jax.lax.stop_gradient(q - x)


# ---------------------------------------------------------------------------
# layer constructors: each returns a dict consumed by build()/apply()


def conv(out_ch: int, k: int = 3, stride: int = 1, padding: str = "SAME",
         use_bias: bool = False) -> dict:
    return {"type": "conv", "out_ch": out_ch, "k": k, "stride": stride,
            "padding": padding, "use_bias": use_bias}


def dense(out_f: int, use_bias: bool = True) -> dict:
    return {"type": "dense", "out_f": out_f, "use_bias": use_bias}


def bn() -> dict:
    return {"type": "bn"}


def relu() -> dict:
    return {"type": "relu"}


def maxpool(k: int = 2, stride: Optional[int] = None) -> dict:
    return {"type": "maxpool", "k": k, "stride": stride or k}


def avgpool(k: int = 2, stride: Optional[int] = None) -> dict:
    return {"type": "avgpool", "k": k, "stride": stride or k}


def global_avgpool() -> dict:
    return {"type": "global_avgpool"}


def flatten() -> dict:
    return {"type": "flatten"}


def concat_shortcut(from_idx: int) -> dict:
    """DenseNet-style feature concatenation with the activation recorded at
    layer index `from_idx` (indices refer to the built layer list)."""
    return {"type": "concat", "from": from_idx}


# ---------------------------------------------------------------------------
# build: walk the layer list once with shape inference, allocating params


@dataclasses.dataclass
class BuiltModel:
    name: str
    layers: List[dict]          # layer dicts augmented with param indices
    params: List[Param]
    state: List[StateVar]
    input_shape: Tuple[int, int, int]  # HWC
    num_classes: int
    n_quant: int                # number of quantized weight tensors


def build(name: str, layer_spec: Sequence[dict], input_shape, num_classes) -> BuiltModel:
    params: List[Param] = []
    state: List[StateVar] = []
    layers: List[dict] = []
    h, w, c = input_shape
    shapes: List[Tuple[int, ...]] = []  # per-layer output shapes (HWC / F)
    qidx = 0

    def add_param(p: Param) -> int:
        params.append(p)
        return len(params) - 1

    flat_features = None
    for li, spec in enumerate(layer_spec):
        layer = dict(spec)
        t = spec["type"]
        if t == "conv":
            k, oc = spec["k"], spec["out_ch"]
            wname = f"l{li}.conv.w"
            layer["w"] = add_param(
                Param(wname, (k, k, c, oc), "weight", fan_in=k * k * c, qidx=qidx))
            qidx += 1
            if spec["use_bias"]:
                layer["b"] = add_param(Param(f"l{li}.conv.b", (oc,), "bias"))
            if spec["padding"] == "SAME":
                h = -(-h // spec["stride"])
                w = -(-w // spec["stride"])
            else:
                h = (h - k) // spec["stride"] + 1
                w = (w - k) // spec["stride"] + 1
            c = oc
        elif t == "dense":
            of = spec["out_f"]
            if flat_features is None:
                raise ValueError("dense before flatten")
            layer["w"] = add_param(
                Param(f"l{li}.dense.w", (flat_features, of), "weight",
                      fan_in=flat_features, qidx=qidx))
            qidx += 1
            if spec["use_bias"]:
                layer["b"] = add_param(Param(f"l{li}.dense.b", (of,), "bias"))
            flat_features = of
        elif t == "bn":
            layer["gamma"] = add_param(Param(f"l{li}.bn.gamma", (c,), "gamma"))
            layer["beta"] = add_param(Param(f"l{li}.bn.beta", (c,), "beta"))
            layer["mean"] = len(state)
            state.append(StateVar(f"l{li}.bn.mean", (c,), 0.0))
            layer["var"] = len(state)
            state.append(StateVar(f"l{li}.bn.var", (c,), 1.0))
        elif t in ("maxpool", "avgpool"):
            h //= spec["stride"]
            w //= spec["stride"]
        elif t == "global_avgpool":
            h, w = 1, 1
        elif t == "flatten":
            flat_features = h * w * c
        elif t == "relu":
            pass
        elif t == "concat":
            src = shapes[spec["from"]]
            if len(src) != 3 or src[0] != h or src[1] != w:
                raise ValueError(f"concat shape mismatch at layer {li}: {src} vs {(h, w, c)}")
            c += src[2]
        else:
            raise ValueError(f"unknown layer type {t}")
        shapes.append((h, w, c) if flat_features is None else (flat_features,))
        layers.append(layer)

    if flat_features is None or flat_features != num_classes:
        raise ValueError(
            f"model must end in a dense({num_classes}); got features={flat_features}")
    return BuiltModel(name, layers, params, state, tuple(input_shape),
                      num_classes, qidx)


# ---------------------------------------------------------------------------
# init


def init_params(model: BuiltModel, seed: int = 0) -> List[np.ndarray]:
    """He-normal conv/dense weights, zero biases, unit gammas. NumPy (host)
    arrays — these are written into the init checkpoint consumed by Rust."""
    rng = np.random.default_rng(seed)
    out: List[np.ndarray] = []
    for p in model.params:
        if p.kind == "weight":
            std = float(np.sqrt(2.0 / max(p.fan_in, 1)))
            out.append(rng.normal(0.0, std, p.shape).astype(np.float32))
        elif p.kind == "gamma":
            out.append(np.ones(p.shape, np.float32))
        else:
            out.append(np.zeros(p.shape, np.float32))
    return out


def init_state(model: BuiltModel) -> List[np.ndarray]:
    return [np.full(s.shape, s.init, np.float32) for s in model.state]


# ---------------------------------------------------------------------------
# apply

_BN_MOMENTUM = 0.9
_BN_EPS = 1e-5


def apply(
    model: BuiltModel,
    params: Sequence[jnp.ndarray],
    state: Sequence[jnp.ndarray],
    x: jnp.ndarray,
    *,
    train: bool,
    wt: WeightTransform = identity_transform,
    use_pallas: bool = False,
    act_bits: Optional[int] = None,
):
    """Forward pass. Returns (logits, new_state). `x` is NHWC f32.
    `act_bits` enables fake-quantized activations after every ReLU."""
    new_state = list(state)
    acts: List[jnp.ndarray] = []  # per-layer outputs, for concat shortcuts
    for layer in model.layers:
        t = layer["type"]
        if t == "conv":
            wp = model.params[layer["w"]]
            w = wt(params[layer["w"]], wp.qidx)
            x = jax.lax.conv_general_dilated(
                x, w,
                window_strides=(layer["stride"], layer["stride"]),
                padding=layer["padding"],
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            if layer.get("b") is not None:
                x = x + params[layer["b"]]
        elif t == "dense":
            wp = model.params[layer["w"]]
            w = wt(params[layer["w"]], wp.qidx)
            x = dense_matmul(x, w, use_pallas)
            if layer.get("b") is not None:
                x = x + params[layer["b"]]
        elif t == "bn":
            gamma, beta = params[layer["gamma"]], params[layer["beta"]]
            if train:
                axes = tuple(range(x.ndim - 1))
                mean = jnp.mean(x, axis=axes)
                var = jnp.var(x, axis=axes)
                new_state[layer["mean"]] = (
                    _BN_MOMENTUM * state[layer["mean"]] + (1 - _BN_MOMENTUM) * mean)
                new_state[layer["var"]] = (
                    _BN_MOMENTUM * state[layer["var"]] + (1 - _BN_MOMENTUM) * var)
            else:
                mean = state[layer["mean"]]
                var = state[layer["var"]]
            x = (x - mean) * jax.lax.rsqrt(var + _BN_EPS) * gamma + beta
        elif t == "relu":
            x = jnp.maximum(x, 0.0)
            if act_bits is not None:
                x = fake_quant_act(x, act_bits)
        elif t == "maxpool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, layer["k"], layer["k"], 1), (1, layer["stride"], layer["stride"], 1),
                "VALID")
        elif t == "avgpool":
            x = jax.lax.reduce_window(
                x, 0.0, jax.lax.add,
                (1, layer["k"], layer["k"], 1), (1, layer["stride"], layer["stride"], 1),
                "VALID") / float(layer["k"] * layer["k"])
        elif t == "global_avgpool":
            x = jnp.mean(x, axis=(1, 2), keepdims=True)
        elif t == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif t == "concat":
            x = jnp.concatenate([acts[layer["from"]], x], axis=-1)
        else:  # pragma: no cover
            raise ValueError(t)
        acts.append(x)
    return x, new_state
