"""Quantization methods: SYMOG plus every comparator in Table 1.

A method is (a) a *weight transform* applied to each quantized parameter in
the forward pass and (b) an *update rule* for quantized parameters. All
methods share the plain Nesterov-SGD update for non-quantized parameters
(bias / BN gamma / beta).

| method    | forward weights          | update of w                                  |
|-----------|--------------------------|----------------------------------------------|
| baseline  | w (float)                | Nesterov + weight decay                      |
| symog     | w (float)                | fused Pallas kernel: +lam*(2/M)(w-Q(w)), clip|
| bc        | sign(w)   (STE)          | Nesterov, clip to [-1, 1]                    |
| twn       | ternary(w) (STE)         | Nesterov                                     |
| br        | (w + lam*Q(w))/(1 + lam) | Nesterov (relaxation pulls fwd to Q)         |

BC: Courbariaux et al. 2015.  TWN: Li & Liu 2016 (threshold 0.7 E|w|, scale
alpha = mean |w| over above-threshold weights).  BR: Yin et al. 2018
(Moreau-envelope relaxation; we reuse the lam input as the relaxation
coefficient, growing over training exactly like SYMOG's lambda).
STE = straight-through estimator: the discretization contributes identity
gradient, implemented as `w + stop_gradient(f(w) - w)`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .kernels import ref, sgd_update

METHODS = ("baseline", "symog", "bc", "twn", "br")


@dataclasses.dataclass(frozen=True)
class Hyper:
    """Static hyper-parameters baked into the lowered train step."""

    n_bits: int = 2
    momentum: float = 0.9
    weight_decay: float = 0.0
    clip: bool = True          # SYMOG weight clipping (section 3.4 / Fig 4)
    use_pallas: bool = True    # L1 kernels vs pure-jnp ref path
    # fake-quantize activations after every ReLU (extension; None = off)
    act_bits: "int | None" = None


def nesterov(w, v, g, lr, momentum):
    """Nesterov momentum step; returns (w', v')."""
    v_new = momentum * v - lr * g
    w_new = w + momentum * v_new - lr * g
    return w_new, v_new


# ---------------------------------------------------------------------------
# forward weight transforms.  Each factory takes (deltas, lam, hp) and
# returns wt(w, qidx) -> tensor used by the forward pass.


def _ste(w: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    return w + jax.lax.stop_gradient(q - w)


def ternary_twn(w: jnp.ndarray) -> jnp.ndarray:
    """TWN ternarization: threshold 0.7*E|w|, scale = mean of surviving |w|."""
    absw = jnp.abs(w)
    thr = 0.7 * jnp.mean(absw)
    mask = (absw > thr).astype(w.dtype)
    alpha = jnp.sum(absw * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return alpha * jnp.sign(w) * mask


def make_transform(method: str, deltas, lam, hp: Hyper):
    if method in ("baseline", "symog"):
        return lambda w, qidx: w
    if method == "bc":
        return lambda w, qidx: _ste(w, jnp.sign(w))
    if method == "twn":
        return lambda w, qidx: _ste(w, ternary_twn(w))
    if method == "br":
        # relaxed weight (w + lam Q(w)) / (1 + lam): Q is piecewise constant
        # (zero gradient), so the relaxation is differentiable as written —
        # the gradient w.r.t. w is 1/(1+lam), matching BinaryRelax.
        return lambda w, qidx: (w + lam * jax.lax.stop_gradient(
            ref.quantize_ref(w, deltas[qidx], hp.n_bits))) / (1.0 + lam)
    raise KeyError(method)


def make_quantized_transform(deltas, n_bits: int):
    """Hard Q_N for the quantized-eval executable (post-quantization)."""
    return lambda w, qidx: ref.quantize_ref(w, deltas[qidx], n_bits)


# ---------------------------------------------------------------------------
# update rules


def update_params(
    method: str,
    kinds: Sequence[str],
    qidxs: Sequence[Optional[int]],
    params: List[jnp.ndarray],
    momenta: List[jnp.ndarray],
    grads: List[jnp.ndarray],
    deltas,
    lr,
    lam,
    hp: Hyper,
):
    """Apply the method's update to every parameter; returns (params', momenta')."""
    new_p, new_v = [], []
    for w, v, g, kind, qidx in zip(params, momenta, grads, kinds, qidxs):
        if kind != "weight":
            # float-trained auxiliaries: plain Nesterov + weight decay
            w2, v2 = nesterov(w, v, g + hp.weight_decay * w, lr, hp.momentum)
        elif method == "symog":
            if hp.use_pallas:
                w2, v2 = sgd_update(
                    w, v, g, deltas[qidx], lr, lam,
                    n_bits=hp.n_bits, momentum=hp.momentum,
                    weight_decay=hp.weight_decay, clip=hp.clip)
            else:
                w2, v2 = ref.sgd_update_ref(
                    w, v, g, deltas[qidx], lr=lr, lam=lam,
                    momentum=hp.momentum, n_bits=hp.n_bits,
                    weight_decay=hp.weight_decay, clip=hp.clip)
        elif method == "bc":
            w2, v2 = nesterov(w, v, g + hp.weight_decay * w, lr, hp.momentum)
            w2 = jnp.clip(w2, -1.0, 1.0)
        else:  # baseline, twn, br: plain Nesterov on the float shadow weights
            w2, v2 = nesterov(w, v, g + hp.weight_decay * w, lr, hp.momentum)
        new_p.append(w2)
        new_v.append(v2)
    return new_p, new_v
