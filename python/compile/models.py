"""Model zoo — the architectures the paper evaluates (section 4).

Every constructor takes `width_mult` so the benchmark harness can run
width-scaled variants that finish on CPU PJRT; `width_mult=1.0` is the
paper's full-size configuration. Channel counts are rounded up to
multiples of 4 so scaled variants stay conv-friendly.

| paper model | here | paper params | section |
|---|---|---|---|
| LeNet-5     | lenet5()                | 60k  | 4.1 |
| VGG7        | vgg7()                  | 12M  | 4.2 |
| DenseNet (L=76, k=12) | densenet(depth=76, growth=12) | 0.49M | 4.2 |
| VGG11       | vgg11()                 | 32M  | 4.3 |
| VGG16       | vgg16()                 | 34M  | 4.3 |
| (extra) MLP | mlp() — quickstart / integration tests | — | — |
"""

from __future__ import annotations

from typing import Tuple

from . import layers as L
from .layers import BuiltModel


def _ch(base: int, width_mult: float) -> int:
    c = max(int(round(base * width_mult)), 4)
    return -(-c // 4) * 4  # round up to a multiple of 4


def mlp(input_shape=(28, 28, 1), num_classes=10, width_mult: float = 1.0) -> BuiltModel:
    """Small 2-hidden-layer MLP. Quickstart + fast integration tests."""
    h1, h2 = _ch(256, width_mult), _ch(128, width_mult)
    spec = [
        L.flatten(),
        L.dense(h1), L.relu(),
        L.dense(h2), L.relu(),
        L.dense(num_classes),
    ]
    return L.build("mlp", spec, input_shape, num_classes)


def lenet5(input_shape=(28, 28, 1), num_classes=10, width_mult: float = 1.0) -> BuiltModel:
    """LeNet-5 (Lecun et al. 1998) as used in section 4.1 (60k params)."""
    c1, c2 = _ch(6, width_mult), _ch(16, width_mult)
    f1, f2 = _ch(120, width_mult), _ch(84, width_mult)
    spec = [
        L.conv(c1, k=5, padding="SAME"), L.bn(), L.relu(), L.maxpool(2),
        L.conv(c2, k=5, padding="VALID"), L.bn(), L.relu(), L.maxpool(2),
        L.flatten(),
        L.dense(f1), L.relu(),
        L.dense(f2), L.relu(),
        L.dense(num_classes),
    ]
    return L.build("lenet5", spec, input_shape, num_classes)


def vgg7(input_shape=(32, 32, 3), num_classes=10, width_mult: float = 1.0) -> BuiltModel:
    """The 7-layer VGG variant of the ternary-quantization literature
    (2x128C3 - MP2 - 2x256C3 - MP2 - 2x512C3 - MP2 - 1024FC - softmax),
    ~12M params at width_mult=1 — section 4.2."""
    c1, c2, c3 = _ch(128, width_mult), _ch(256, width_mult), _ch(512, width_mult)
    fc = _ch(1024, width_mult)
    spec = []
    for c in (c1, c1):
        spec += [L.conv(c), L.bn(), L.relu()]
    spec += [L.maxpool(2)]
    for c in (c2, c2):
        spec += [L.conv(c), L.bn(), L.relu()]
    spec += [L.maxpool(2)]
    for c in (c3, c3):
        spec += [L.conv(c), L.bn(), L.relu()]
    spec += [L.maxpool(2), L.flatten(), L.dense(fc), L.relu(), L.dense(num_classes)]
    return L.build("vgg7", spec, input_shape, num_classes)


def _vgg(name: str, cfg, input_shape, num_classes, width_mult: float) -> BuiltModel:
    spec = []
    for v in cfg:
        if v == "M":
            spec.append(L.maxpool(2))
        else:
            spec += [L.conv(_ch(v, width_mult)), L.bn(), L.relu()]
    spec += [L.flatten(), L.dense(_ch(512, width_mult)), L.relu(),
             L.dense(num_classes)]
    return L.build(name, spec, input_shape, num_classes)


def vgg11(input_shape=(32, 32, 3), num_classes=100, width_mult: float = 1.0) -> BuiltModel:
    """VGG11 (configuration A) adapted to 32x32 — section 4.3 (32M)."""
    cfg = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]
    return _vgg("vgg11", cfg, input_shape, num_classes, width_mult)


def vgg16(input_shape=(32, 32, 3), num_classes=100, width_mult: float = 1.0) -> BuiltModel:
    """VGG16 (configuration D) adapted to 32x32 — section 4.3 (34M)."""
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    return _vgg("vgg16", cfg, input_shape, num_classes, width_mult)


def densenet(input_shape=(32, 32, 3), num_classes=10, depth: int = 76,
             growth: int = 12, width_mult: float = 1.0) -> BuiltModel:
    """DenseNet (Huang et al. 2016) with 3 dense blocks — the L=76, k=12
    configuration of section 4.2 (0.49M params). `width_mult` scales the
    growth rate; `depth` must satisfy (depth - 4) % 3 == 0."""
    if (depth - 4) % 3 != 0:
        raise ValueError("densenet depth must be 3n+4")
    k = max(int(round(growth * width_mult)), 2)
    n = (depth - 4) // 3  # conv layers per dense block
    spec = [L.conv(2 * k), L.bn(), L.relu()]  # stem: idx 0..2
    for block in range(3):
        for _ in range(n):
            # pre-activation composite: BN-ReLU-Conv(k), then concat input
            src = len(spec) - 1  # index of current feature map
            spec += [L.bn(), L.relu(), L.conv(k)]
            spec += [L.concat_shortcut(src)]
        if block < 2:  # transition: BN-ReLU-Conv(1x1, compress)-AvgPool
            spec += [L.bn(), L.relu()]
            # compression 0.5 is resolved at build time via a marker conv
            spec += [L.conv(-1, k=1)]  # placeholder, patched below
            spec += [L.avgpool(2)]
    spec += [L.bn(), L.relu(), L.global_avgpool(), L.flatten(),
             L.dense(num_classes)]

    # resolve the transition 1x1 conv widths (0.5 compression) with a dry
    # channel walk mirroring build()'s shape inference
    c = 0
    chans: list = []
    out = []
    for s in spec:
        if s["type"] == "conv" and s["out_ch"] == -1:
            s = dict(s, out_ch=max(c // 2, 2))
        if s["type"] == "conv":
            c = s["out_ch"]
        elif s["type"] == "concat":
            c = chans[s["from"]] + c
        chans.append(c)
        out.append(s)
    return L.build("densenet", out, input_shape, num_classes)


def densenet40(input_shape=(32, 32, 3), num_classes=10,
               width_mult: float = 1.0) -> BuiltModel:
    """Reduced-depth DenseNet (L=40) for CPU-budget benches; same block
    structure as the paper's L=76 configuration."""
    return densenet(input_shape, num_classes, depth=40, growth=12,
                    width_mult=width_mult)


_ZOO = {
    "mlp": mlp,
    "lenet5": lenet5,
    "vgg7": vgg7,
    "vgg11": vgg11,
    "vgg16": vgg16,
    "densenet": densenet,
    "densenet40": densenet40,
}


def get_model(name: str, input_shape: Tuple[int, int, int], num_classes: int,
              width_mult: float = 1.0) -> BuiltModel:
    """Look up a zoo model by name."""
    if name not in _ZOO:
        raise KeyError(f"unknown model {name!r}; have {sorted(_ZOO)}")
    return _ZOO[name](input_shape=input_shape, num_classes=num_classes,
                      width_mult=width_mult)
