"""Pallas kernel: fixed-point mode occupancy histogram (drives Fig 3/4).

For each weight, the nearest fixed-point mode index is
clip(round(w/delta), -qmax, qmax); the kernel accumulates the count of each
of the 2*qmax+1 modes across grid steps into a single output block. The L3
tracker consumes these counts every epoch to compute the mode-switch rate
(Fig 4) and the per-mode mass (Fig 3) without streaming whole weight tensors
back to the host.

Padding note: pad_to_grid zero-pads, and zero lands exactly on mode 0, so
the wrapper subtracts the pad count from the centre bin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import util


def _mode_hist_kernel(w_ref, p_ref, o_ref, *, n_bits: int):
    qmax = 2 ** (n_bits - 1) - 1
    delta = p_ref[0, 0]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = w_ref[...] / delta
    r = jnp.sign(s) * jnp.floor(jnp.abs(s) + 0.5)
    idx = jnp.clip(r, -qmax, qmax).astype(jnp.int32) + qmax
    # one-hot reduce: counts[k] = #(idx == k) over the (BLOCK_ROWS, LANES) tile
    modes = jax.lax.broadcasted_iota(jnp.int32, (1, 2 * qmax + 1), 1)
    counts = jnp.sum(
        (idx[..., None] == modes[0]).astype(jnp.int32), axis=(0, 1)
    )
    o_ref[...] += counts.reshape(1, -1)


@functools.partial(jax.jit, static_argnames=("n_bits", "interpret"))
def mode_hist(w: jnp.ndarray, delta, n_bits: int = 2, interpret: bool = True):
    """Counts per fixed-point mode; int32 vector of length 2^{N-1}*2 - 1."""
    qmax = 2 ** (n_bits - 1) - 1
    rows, n, n_blocks = util.pad_to_grid(w.astype(jnp.float32))
    params = util.pack_params(delta)
    out = pl.pallas_call(
        functools.partial(_mode_hist_kernel, n_bits=n_bits),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((util.BLOCK_ROWS, util.LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, params.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2 * qmax + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2 * qmax + 1), jnp.int32),
        interpret=interpret,
    )(rows, params)
    pad = rows.size - n
    return out[0].at[qmax].add(-pad)
