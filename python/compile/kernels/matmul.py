"""Pallas kernel: tiled f32 matmul for the dense layers of the model zoo.

MXU-shaped schedule: the grid is (M/bm, N/bn, K/bk); each step multiplies a
(bm, bk) x (bk, bn) tile pair into a VMEM f32 accumulator, writing the
output tile once on the last K step. Tiles default to 128x128x128 — the MXU
systolic-array shape — with VMEM footprint

    bm*bk + bk*bn + 2*bm*bn   f32 = 256 KiB per step at the defaults,

leaving headroom for double buffering well under the 16 MiB VMEM budget.
Under interpret=True the same schedule runs on numpy for correctness; the
MXU-utilization estimate lives in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is importable on CPU images; used only for scratch shapes
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover - fallback if tpu module is absent
    _VMEM = None


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _pad2(x, m0, m1):
    p0 = -(-x.shape[0] // m0) * m0 - x.shape[0]
    p1 = -(-x.shape[1] // m1) * m1 - x.shape[1]
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    """f32 `a @ b` with an MXU-tiled Pallas schedule. Any (M,K)x(K,N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    a_p = _pad2(a.astype(jnp.float32), bm, bk)
    b_p = _pad2(b.astype(jnp.float32), bk, bn)
    mp, kp = a_p.shape
    _, np_ = b_p.shape
    k_steps = kp // bk

    kwargs = {}
    if _VMEM is not None:
        kwargs["scratch_shapes"] = [_VMEM((bm, bn), jnp.float32)]

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(a_p, b_p)
    return out[:m, :n]
