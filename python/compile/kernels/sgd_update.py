"""Pallas kernel: fused SYMOG update step (Algorithm 1, lines 14-17).

One VMEM round-trip performs, per weight:

    g  = dC/dw + lam * (2/M)(w - Q_N(w; delta)) + wd * w
    v' = mu * v - lr * g            (Nesterov velocity)
    w' = w + mu * v' - lr * g       (Nesterov lookahead step)
    w' = clip(w', +-delta * (2^{N-1}-1))   (weight clipping, section 3.4)

This is the L1 hot spot of SYMOG training: without fusion the update is five
elementwise passes (quantize, reg-grad, axpy, momentum, clip) each streaming
W-sized tensors through HBM; fused it reads {w, v, g} once and writes
{w', v'} once — a 10/5 -> 5/2 HBM traffic reduction (see DESIGN.md §Perf).

Runtime scalars [delta, lr, lam] travel in a params row; mu (momentum), wd
(weight decay), clip flag and n_bits are static.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import util


def _sgd_update_kernel(
    w_ref, v_ref, g_ref, p_ref, wo_ref, vo_ref,
    *, n_bits: int, inv_m2: float, momentum: float, weight_decay: float,
    clip: bool,
):
    delta = p_ref[0, 0]
    lr = p_ref[0, 1]
    lam = p_ref[0, 2]
    qmax = float(2 ** (n_bits - 1) - 1)

    w = w_ref[...]
    v = v_ref[...]

    s = w / delta
    r = jnp.sign(s) * jnp.floor(jnp.abs(s) + 0.5)
    q = jnp.clip(r, -qmax, qmax) * delta

    g = g_ref[...] + lam * (inv_m2 * (w - q)) + weight_decay * w
    v_new = momentum * v - lr * g
    w_new = w + momentum * v_new - lr * g
    if clip:
        bound = qmax * delta
        w_new = jnp.clip(w_new, -bound, bound)
    wo_ref[...] = w_new
    vo_ref[...] = v_new


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "momentum", "weight_decay", "clip", "interpret"),
)
def sgd_update(
    w: jnp.ndarray,
    v: jnp.ndarray,
    grad: jnp.ndarray,
    delta,
    lr,
    lam,
    *,
    n_bits: int = 2,
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    clip: bool = True,
    interpret: bool = True,
):
    """Fused SYMOG parameter update. Returns (w_new, v_new)."""
    orig_shape = w.shape
    w_rows, n, n_blocks = util.pad_to_grid(w.astype(jnp.float32))
    v_rows, _, _ = util.pad_to_grid(v.astype(jnp.float32))
    g_rows, _, _ = util.pad_to_grid(grad.astype(jnp.float32))
    params = util.pack_params(delta, lr, lam)

    blk = pl.BlockSpec((util.BLOCK_ROWS, util.LANES), lambda i: (i, 0))
    w_new, v_new = pl.pallas_call(
        functools.partial(
            _sgd_update_kernel,
            n_bits=n_bits,
            inv_m2=2.0 / w.size,
            momentum=momentum,
            weight_decay=weight_decay,
            clip=clip,
        ),
        grid=(n_blocks,),
        in_specs=[
            blk,
            blk,
            blk,
            pl.BlockSpec((1, params.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct(w_rows.shape, jnp.float32),
            jax.ShapeDtypeStruct(w_rows.shape, jnp.float32),
        ],
        interpret=interpret,
    )(w_rows, v_rows, g_rows, params)
    return (
        util.unpad(w_new, n, orig_shape),
        util.unpad(v_new, n, orig_shape),
    )
