"""Layer-1 Pallas kernels for SYMOG + their pure-jnp oracles (ref.py).

All kernels run with interpret=True on this image (CPU PJRT cannot execute
Mosaic custom-calls); BlockSpecs are TPU-shaped so the same code lowers to
real hardware unchanged. See DESIGN.md §Hardware-Adaptation.
"""

from . import ref  # noqa: F401
from .matmul import matmul  # noqa: F401
from .mode_hist import mode_hist  # noqa: F401
from .quantize import quantize  # noqa: F401
from .reg_grad import reg_grad  # noqa: F401
from .sgd_update import sgd_update  # noqa: F401
