"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the Pallas kernels are tested against (pytest +
hypothesis, see python/tests/). They are also what the L2 model falls back to
when `use_pallas=False` (e.g. for fast HLO lowering of the very large
configurations where interpret-mode Pallas would dominate compile time).

All functions are pure jnp, shape-polymorphic, and differentiable where the
paper requires it (the quantizer uses a straight-through zero derivative via
`lax.stop_gradient`, matching Eq. 4 of the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, delta, n_bits: int) -> jnp.ndarray:
    """Symmetric uniform N-bit fixed-point quantizer Q_N(x; delta), Eq. 1.

    q = clip(round(x / delta), -(2^{N-1} - 1), 2^{N-1} - 1) * delta

    Note the symmetric (one-value-short) integer range: the paper drops
    -2^{N-1} so the code-book is symmetric around zero (section 3.1).
    Rounding is round-half-away-from-zero to keep the quantizer odd
    (Q(-x) == -Q(x)) — jnp.round would round half-to-even and break the
    symmetry property the paper's Figure 2 depicts.
    """
    qmax = float(2 ** (n_bits - 1) - 1)
    scaled = x / delta
    # round half away from zero: sign(x) * floor(|x| + 0.5)
    rounded = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    clipped = jnp.clip(rounded, -qmax, qmax)
    return clipped * delta


def quantize_ste(x: jnp.ndarray, delta, n_bits: int) -> jnp.ndarray:
    """Quantizer with straight-through *zero* gradient (dQ/dx = 0, Eq. 4).

    SYMOG treats Q_N as piecewise-constant, so its derivative is zero a.e.;
    the regularizer gradient then reduces to (2/M)(w - Q(w)).
    """
    return jax.lax.stop_gradient(quantize_ref(x, delta, n_bits))


def reg_grad_ref(w: jnp.ndarray, delta, n_bits: int) -> jnp.ndarray:
    """SYMOG prior gradient dR/dw = (2/M) (w - Q_N(w; delta)), Eq. 4."""
    m = w.size
    return (2.0 / m) * (w - quantize_ref(w, delta, n_bits))


def clip_ref(w: jnp.ndarray, delta, n_bits: int) -> jnp.ndarray:
    """Weight clipping to the quantization domain (section 3.4)."""
    bound = delta * float(2 ** (n_bits - 1) - 1)
    return jnp.clip(w, -bound, bound)


def sgd_update_ref(
    w: jnp.ndarray,
    v: jnp.ndarray,
    grad: jnp.ndarray,
    delta,
    *,
    lr,
    lam,
    momentum: float,
    n_bits: int,
    weight_decay: float = 0.0,
    clip: bool = True,
):
    """Fused SYMOG update step (Algorithm 1, lines 14-17).

    g_total = dC/dw + lam * (2/M)(w - Q(w)) + weight_decay * w
    v'      = momentum * v - lr * g_total           (Nesterov velocity)
    w'      = w + momentum * v' - lr * g_total      (Nesterov lookahead)
    w'      = clip(w', +-delta (2^{N-1}-1))         (section 3.4)

    Returns (w', v').
    """
    g = grad + lam * reg_grad_ref(w, delta, n_bits) + weight_decay * w
    v_new = momentum * v - lr * g
    w_new = w + momentum * v_new - lr * g
    if clip:
        w_new = clip_ref(w_new, delta, n_bits)
    return w_new, v_new


def mode_hist_ref(w: jnp.ndarray, delta, n_bits: int) -> jnp.ndarray:
    """Occupancy count of each fixed-point mode (drives Fig 3/4).

    Returns an int32 vector of length 2*qmax + 1 where entry k counts
    weights whose nearest mode is (k - qmax) * delta.
    """
    qmax = 2 ** (n_bits - 1) - 1
    scaled = w / delta
    rounded = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    idx = jnp.clip(rounded, -qmax, qmax).astype(jnp.int32) + qmax
    return jnp.zeros(2 * qmax + 1, jnp.int32).at[idx.reshape(-1)].add(1)


def mode_assign_ref(w: jnp.ndarray, delta, n_bits: int) -> jnp.ndarray:
    """Per-weight signed mode index in [-qmax, qmax] (int8)."""
    qmax = 2 ** (n_bits - 1) - 1
    scaled = w / delta
    rounded = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    return jnp.clip(rounded, -qmax, qmax).astype(jnp.int8)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """f32 matmul oracle for the Pallas tiled kernel."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def quant_error_ref(w: jnp.ndarray, delta, n_bits: int) -> jnp.ndarray:
    """Mean squared quantization error (the R term for one layer, Eq. 3)."""
    return jnp.mean((w - quantize_ref(w, delta, n_bits)) ** 2)


def optimal_delta_ref(w: jnp.ndarray, n_bits: int, f_range=(-12, 12)):
    """Brute-force the fixed-point constraint: argmin over f in Z of
    ||w - Q_N(w; 2^-f)||^2 (Algorithm 1, lines 2-5). Returns (delta, f)."""
    best_f, best_err = None, None
    for f in range(f_range[0], f_range[1] + 1):
        delta = 2.0 ** (-f)
        err = float(jnp.sum((w - quantize_ref(w, delta, n_bits)) ** 2))
        if best_err is None or err < best_err:
            best_f, best_err = f, err
    return 2.0 ** (-best_f), best_f
