"""Shared plumbing for the Pallas kernels.

Every elementwise SYMOG kernel operates on a flattened weight tensor that is
padded to a (SUBLANES x LANES)-tile multiple and reshaped to rows of 128
lanes — the native TPU VREG layout. The grid walks row-blocks; each grid step
sees one (BLOCK_ROWS, LANES) VMEM tile. On real TPU hardware this maps
1:1 onto the VPU; under interpret=True (this image) the same BlockSpecs are
executed with numpy, so the layout choices are validated structurally.
"""

from __future__ import annotations

import jax.numpy as jnp

# TPU vector-register geometry: 8 sublanes x 128 lanes for f32.
LANES = 128
SUBLANES = 8
# Rows of the VMEM block each grid step processes. 64 rows x 128 lanes x 4 B
# = 32 KiB per operand — small enough that even the 3-operand fused update
# kernel stays far below VMEM (16 MiB) with double buffering.
BLOCK_ROWS = 64
BLOCK_ELEMS = BLOCK_ROWS * LANES


def pad_to_grid(x: jnp.ndarray):
    """Flatten `x`, zero-pad to a BLOCK_ELEMS multiple, reshape to rows of
    LANES. Returns (rows_2d, original_size, n_blocks)."""
    flat = x.reshape(-1)
    n = flat.size
    padded = -(-n // BLOCK_ELEMS) * BLOCK_ELEMS
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    rows = flat.reshape(padded // LANES, LANES)
    return rows, n, padded // BLOCK_ELEMS


def unpad(rows: jnp.ndarray, n: int, shape) -> jnp.ndarray:
    """Inverse of pad_to_grid: strip padding and restore `shape`."""
    return rows.reshape(-1)[:n].reshape(shape)


def pack_params(*vals) -> jnp.ndarray:
    """Pack runtime scalars (delta, lr, lam, ...) into a (1, P) f32 row that
    the kernels receive as a whole-array block. Scalars must travel as
    array operands because lr/lam change every epoch and are traced inputs
    of the AOT-lowered train step."""
    return jnp.stack([jnp.asarray(v, jnp.float32) for v in vals]).reshape(1, -1)
