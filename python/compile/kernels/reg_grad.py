"""Pallas kernel: SYMOG regularizer gradient (Eq. 4).

    dR/dw = (2 / M) * (w - Q_N(w; delta))

M is the number of weights in the layer — a static shape property, folded
into the kernel as a compile-time constant. The quantizer is re-derived
inline (cheaper than a second kernel launch and keeps the sub-expression
fused in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import util


def _reg_grad_kernel(w_ref, p_ref, o_ref, *, n_bits: int, inv_m2: float):
    delta = p_ref[0, 0]
    qmax = float(2 ** (n_bits - 1) - 1)
    w = w_ref[...]
    s = w / delta
    r = jnp.sign(s) * jnp.floor(jnp.abs(s) + 0.5)
    q = jnp.clip(r, -qmax, qmax) * delta
    o_ref[...] = inv_m2 * (w - q)


@functools.partial(jax.jit, static_argnames=("n_bits", "interpret"))
def reg_grad(w: jnp.ndarray, delta, n_bits: int = 2, interpret: bool = True):
    """(2/M)(w - Q_N(w; delta)) via Pallas; M = w.size (static)."""
    orig_shape = w.shape
    rows, n, n_blocks = util.pad_to_grid(w.astype(jnp.float32))
    params = util.pack_params(delta)
    out = pl.pallas_call(
        functools.partial(_reg_grad_kernel, n_bits=n_bits, inv_m2=2.0 / w.size),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((util.BLOCK_ROWS, util.LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, params.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((util.BLOCK_ROWS, util.LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(rows.shape, jnp.float32),
        interpret=interpret,
    )(rows, params)
    return util.unpad(out, n, orig_shape)
