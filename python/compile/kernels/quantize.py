"""Pallas kernel: symmetric uniform N-bit fixed-point quantizer (Eq. 1).

    Q_N(x; delta) = clip(round(x / delta), -qmax, qmax) * delta,
    qmax = 2^{N-1} - 1

The kernel is elementwise over VREG-shaped tiles (see util.py). `delta` is a
runtime scalar (it is a traced input of the AOT train step), `n_bits` is
static. Rounding is half-away-from-zero so the quantizer is odd — see
ref.quantize_ref for the rationale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import util


def _quantize_kernel(x_ref, p_ref, o_ref, *, n_bits: int):
    delta = p_ref[0, 0]
    qmax = float(2 ** (n_bits - 1) - 1)
    s = x_ref[...] / delta
    r = jnp.sign(s) * jnp.floor(jnp.abs(s) + 0.5)
    o_ref[...] = jnp.clip(r, -qmax, qmax) * delta


@functools.partial(jax.jit, static_argnames=("n_bits", "interpret"))
def quantize(x: jnp.ndarray, delta, n_bits: int = 2, interpret: bool = True):
    """Q_N(x; delta) via Pallas. Shape/dtype preserved; f32 compute."""
    orig_shape, orig_dtype = x.shape, x.dtype
    rows, n, n_blocks = util.pad_to_grid(x.astype(jnp.float32))
    params = util.pack_params(delta)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, n_bits=n_bits),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((util.BLOCK_ROWS, util.LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, params.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((util.BLOCK_ROWS, util.LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(rows.shape, jnp.float32),
        interpret=interpret,
    )(rows, params)
    return util.unpad(out, n, orig_shape).astype(orig_dtype)
