"""Fused train/eval step builders + the flat AOT calling convention.

The whole SYMOG step — forward, softmax-CE loss, backward, method-specific
update (with the L1 Pallas kernels inlined), weight clipping — is ONE jax
function, lowered once to a single HLO executable. The Rust coordinator then
drives it with positional literals; nothing Python survives to runtime.

Flat calling convention (manifest.json mirrors this):

  train  inputs : images, labels, params[0..P), momenta[0..P),
                  state[0..S), deltas[Q], lr, lam
  train  outputs: loss, correct, params'[0..P), momenta'[0..P), state'[0..S)

  eval   inputs : images, labels, params[0..P), state[0..S)
  eval   outputs: loss, correct

  evalq  inputs : images, labels, params[0..P), state[0..S), deltas[Q]
  evalq  outputs: loss, correct          (weights hard-quantized with Q_N)

`correct` is an f32 count of argmax hits so every tensor in the interface is
f32 (labels are i32). All hyper-parameters that change during training
(lr, lam) are runtime scalars; everything else is baked in via `Hyper`.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from . import layers, methods
from .layers import BuiltModel
from .methods import Hyper


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)
    return jnp.mean(nll)


def correct_count(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def make_train_step(model: BuiltModel, method: str, hp: Hyper):
    """Returns step(images, labels, params, momenta, state, deltas, lr, lam)
    -> (loss, correct, params', momenta', state')."""
    kinds = [p.kind for p in model.params]
    qidxs = [p.qidx for p in model.params]

    def step(images, labels, params, momenta, state, deltas, lr, lam):
        wt = methods.make_transform(method, deltas, lam, hp)

        def loss_fn(params):
            logits, new_state = layers.apply(
                model, params, state, images, train=True, wt=wt,
                use_pallas=hp.use_pallas, act_bits=hp.act_bits)
            return cross_entropy(logits, labels), (new_state, logits)

        (loss, (new_state, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(list(params))
        new_params, new_momenta = methods.update_params(
            method, kinds, qidxs, list(params), list(momenta), grads,
            deltas, lr, lam, hp)
        return loss, correct_count(logits, labels), new_params, new_momenta, new_state

    return step


def make_eval_step(model: BuiltModel, hp: Hyper):
    """Float evaluation: step(images, labels, params, state) -> (loss, correct)."""

    def step(images, labels, params, state):
        logits, _ = layers.apply(model, list(params), list(state), images,
                                 train=False, use_pallas=hp.use_pallas,
                                 act_bits=hp.act_bits)
        return cross_entropy(logits, labels), correct_count(logits, labels)

    return step


def make_evalq_step(model: BuiltModel, hp: Hyper):
    """Quantized evaluation: weights replaced by Q_N(w; delta_l) — this is
    the error rate Table 1 reports for SYMOG."""

    def step(images, labels, params, state, deltas):
        wt = methods.make_quantized_transform(deltas, hp.n_bits)
        logits, _ = layers.apply(model, list(params), list(state), images,
                                 train=False, wt=wt,
                                 use_pallas=hp.use_pallas, act_bits=hp.act_bits)
        return cross_entropy(logits, labels), correct_count(logits, labels)

    return step


# ---------------------------------------------------------------------------
# flat wrappers: jax.jit(...).lower requires a fixed positional signature


def flatten_train(model: BuiltModel, method: str, hp: Hyper):
    P, S = len(model.params), len(model.state)
    step = make_train_step(model, method, hp)

    def flat(*args):
        images, labels = args[0], args[1]
        params = list(args[2 : 2 + P])
        momenta = list(args[2 + P : 2 + 2 * P])
        state = list(args[2 + 2 * P : 2 + 2 * P + S])
        deltas, lr, lam = args[2 + 2 * P + S :]
        loss, correct, p2, v2, s2 = step(
            images, labels, params, momenta, state, deltas, lr, lam)
        return tuple([loss, correct] + p2 + v2 + s2)

    return flat


def flatten_eval(model: BuiltModel, hp: Hyper, quantized: bool):
    P, S = len(model.params), len(model.state)
    stepq = make_evalq_step(model, hp)
    stepf = make_eval_step(model, hp)

    def flat(*args):
        images, labels = args[0], args[1]
        params = list(args[2 : 2 + P])
        state = list(args[2 + P : 2 + P + S])
        if quantized:
            return tuple(stepq(images, labels, params, state, args[2 + P + S]))
        return tuple(stepf(images, labels, params, state))

    return flat


def train_input_specs(model: BuiltModel, batch: int) -> List[jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs in the flat train-input order."""
    f32, i32 = jnp.float32, jnp.int32
    img = jax.ShapeDtypeStruct((batch, *model.input_shape), f32)
    lab = jax.ShapeDtypeStruct((batch,), i32)
    ps = [jax.ShapeDtypeStruct(p.shape, f32) for p in model.params]
    ss = [jax.ShapeDtypeStruct(s.shape, f32) for s in model.state]
    deltas = jax.ShapeDtypeStruct((max(model.n_quant, 1),), f32)
    scalar = jax.ShapeDtypeStruct((), f32)
    return [img, lab] + ps + ps + ss + [deltas, scalar, scalar]


def eval_input_specs(model: BuiltModel, batch: int, quantized: bool):
    f32, i32 = jnp.float32, jnp.int32
    img = jax.ShapeDtypeStruct((batch, *model.input_shape), f32)
    lab = jax.ShapeDtypeStruct((batch,), i32)
    ps = [jax.ShapeDtypeStruct(p.shape, f32) for p in model.params]
    ss = [jax.ShapeDtypeStruct(s.shape, f32) for s in model.state]
    specs = [img, lab] + ps + ss
    if quantized:
        specs.append(jax.ShapeDtypeStruct((max(model.n_quant, 1),), jnp.float32))
    return specs
