"""AOT driver: lower (model x method) train/eval steps to HLO text.

This is the ONLY entry point of the Python side; it runs at `make artifacts`
time and never again. For each requested configuration it emits

    artifacts/<tag>/train.hlo.txt     fused train step (fwd+bwd+update+clip)
    artifacts/<tag>/eval.hlo.txt      float eval
    artifacts/<tag>/evalq.hlo.txt     eval with hard-quantized weights
    artifacts/<tag>/manifest.json     flat calling convention + layer graph
    artifacts/<tag>/init.ckpt         He-init params + BN state

HLO *text* is the interchange format (NOT lowered.compiler_ir("hlo") protos
or .serialize(): jax >= 0.5 emits 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly — see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts \
        --model lenet5 --method symog --dataset synth-mnist --batch 64
    python -m compile.aot --suite default --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import ckpt, layers, models, train_step
from .kernels import ref
from .methods import METHODS, Hyper

DATASETS = {
    # name: (input HWC, classes) — synthetic stand-ins, see DESIGN.md
    "synth-mnist": ((28, 28, 1), 10),
    "synth-cifar10": ((32, 32, 3), 10),
    "synth-cifar100": ((32, 32, 3), 100),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


@dataclasses.dataclass
class Config:
    model: str
    method: str
    dataset: str
    width_mult: float = 1.0
    batch: int = 64
    n_bits: int = 2
    momentum: float = 0.9
    weight_decay: float = 0.0
    clip: bool = True
    use_pallas: bool = True
    act_bits: "int | None" = None
    seed: int = 0
    tag: str = ""

    def resolve_tag(self) -> str:
        if self.tag:
            return self.tag
        parts = [self.model, self.method, self.dataset,
                 f"w{self.width_mult:g}", f"b{self.n_bits}"]
        if not self.clip:
            parts.append("noclip")
        if self.act_bits:
            parts.append(f"actq{self.act_bits}")
        if not self.use_pallas:
            parts.append("ref")
        return "-".join(parts)


def layer_manifest(model) -> list:
    """Serializable layer graph for the Rust integer inference engine."""
    out = []
    for layer in model.layers:
        d = {k: v for k, v in layer.items() if not callable(v)}
        out.append(d)
    return out


def compile_config(cfg: Config, out_dir: str) -> str:
    shape, classes = DATASETS[cfg.dataset]
    model = models.get_model(cfg.model, shape, classes, cfg.width_mult)
    hp = Hyper(n_bits=cfg.n_bits, momentum=cfg.momentum,
               weight_decay=cfg.weight_decay, clip=cfg.clip,
               use_pallas=cfg.use_pallas, act_bits=cfg.act_bits)
    tag = cfg.resolve_tag()
    tdir = os.path.join(out_dir, tag)
    os.makedirs(tdir, exist_ok=True)

    # --- lower the three executables
    train_fn = train_step.flatten_train(model, cfg.method, hp)
    train_specs = train_step.train_input_specs(model, cfg.batch)
    with open(os.path.join(tdir, "train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(jax.jit(train_fn, keep_unused=True).lower(*train_specs)))

    for quantized, fname in ((False, "eval.hlo.txt"), (True, "evalq.hlo.txt")):
        fn = train_step.flatten_eval(model, hp, quantized)
        specs = train_step.eval_input_specs(model, cfg.batch, quantized)
        with open(os.path.join(tdir, fname), "w") as f:
            f.write(to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs)))

    # --- init checkpoint (params + BN state; momenta are zeroed by Rust)
    init_p = layers.init_params(model, cfg.seed)
    init_s = layers.init_state(model)
    tensors = [(p.name, p.kind, a) for p, a in zip(model.params, init_p)]
    tensors += [(s.name, "state", a) for s, a in zip(model.state, init_s)]
    # suggested per-layer step sizes from the init weights (Alg. 1 l.2-5);
    # Rust recomputes these from the *pretrained* weights before SYMOG runs.
    deltas = np.array(
        [ref.optimal_delta_ref(np.asarray(a), cfg.n_bits)[0]
         for p, a in zip(model.params, init_p) if p.kind == "weight"]
        or [1.0], np.float32)
    tensors.append(("__deltas__", "deltas", deltas))
    ckpt.write_ckpt(os.path.join(tdir, "init.ckpt"),
                    {"model": cfg.model, "epoch": 0, "method": "init"}, tensors)

    # --- manifest
    manifest = {
        "tag": tag,
        "model": cfg.model,
        "method": cfg.method,
        "dataset": cfg.dataset,
        "width_mult": cfg.width_mult,
        "batch": cfg.batch,
        "n_bits": cfg.n_bits,
        "momentum": cfg.momentum,
        "weight_decay": cfg.weight_decay,
        "clip": cfg.clip,
        "use_pallas": cfg.use_pallas,
        "act_bits": cfg.act_bits,
        "input_shape": list(shape),
        "num_classes": classes,
        "n_quant": model.n_quant,
        "params": [
            {"name": p.name, "shape": list(p.shape), "kind": p.kind,
             "qidx": p.qidx, "fan_in": p.fan_in}
            for p in model.params
        ],
        "state": [{"name": s.name, "shape": list(s.shape), "init": s.init}
                  for s in model.state],
        "layers": layer_manifest(model),
        "artifacts": {"train": "train.hlo.txt", "eval": "eval.hlo.txt",
                      "evalq": "evalq.hlo.txt", "init": "init.ckpt"},
    }
    with open(os.path.join(tdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return tag


# The default suite: everything the test/bench harness loads out of the box.
# Width-scaled so the full CPU sweep stays tractable; Table-1 full-scale
# configs are produced on demand with explicit flags.
DEFAULT_SUITE = [
    Config("mlp", "symog", "synth-mnist", batch=64),
    Config("mlp", "baseline", "synth-mnist", batch=64),
    Config("lenet5", "symog", "synth-mnist", batch=64),
    Config("lenet5", "baseline", "synth-mnist", batch=64),
    Config("lenet5", "bc", "synth-mnist", batch=64),
    Config("lenet5", "twn", "synth-mnist", batch=64),
    Config("lenet5", "br", "synth-mnist", batch=64),
    Config("lenet5", "symog", "synth-mnist", batch=64, clip=False),
    # activation-quantization extension (8-bit acts after every ReLU)
    Config("lenet5", "symog", "synth-mnist", batch=64, act_bits=8),
    # N-bit ablation (A1): 3/4/8-bit symmetric codes
    Config("lenet5", "symog", "synth-mnist", batch=64, n_bits=3),
    Config("lenet5", "symog", "synth-mnist", batch=64, n_bits=4),
    Config("lenet5", "symog", "synth-mnist", batch=64, n_bits=8),
    Config("vgg7", "symog", "synth-cifar10", width_mult=0.25, batch=64),
    Config("vgg7", "baseline", "synth-cifar10", width_mult=0.25, batch=64),
    Config("vgg7", "twn", "synth-cifar10", width_mult=0.25, batch=64),
    Config("densenet", "symog", "synth-cifar10", width_mult=0.5, batch=64),
    Config("densenet", "baseline", "synth-cifar10", width_mult=0.5, batch=64),
    Config("vgg11", "symog", "synth-cifar100", width_mult=0.25, batch=64),
    Config("vgg11", "symog", "synth-cifar100", width_mult=0.25, batch=64,
           clip=False),
    Config("vgg11", "baseline", "synth-cifar100", width_mult=0.25, batch=64),
    Config("vgg11", "br", "synth-cifar100", width_mult=0.25, batch=64),
    Config("vgg16", "symog", "synth-cifar100", width_mult=0.25, batch=64),
    Config("vgg16", "baseline", "synth-cifar100", width_mult=0.25, batch=64),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--suite", choices=["default", "none"], default="none")
    ap.add_argument("--model", choices=sorted(models._ZOO))
    ap.add_argument("--method", choices=METHODS, default="symog")
    ap.add_argument("--dataset", choices=sorted(DATASETS), default="synth-mnist")
    ap.add_argument("--width-mult", type=float, default=1.0)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--no-clip", action="store_true")
    ap.add_argument("--act-bits", type=int, default=0)
    ap.add_argument("--no-pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cfgs = list(DEFAULT_SUITE) if args.suite == "default" else []
    if args.model:
        cfgs.append(Config(
            args.model, args.method, args.dataset, args.width_mult,
            args.batch, args.bits, args.momentum, args.weight_decay,
            not args.no_clip, not args.no_pallas, args.act_bits or None,
            args.seed, args.tag))
    if not cfgs:
        ap.error("nothing to do: pass --suite default and/or --model ...")
    for cfg in cfgs:
        tag = compile_config(cfg, args.out_dir)
        print(f"compiled {tag}")


if __name__ == "__main__":
    main()
