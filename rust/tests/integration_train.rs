//! End-to-end integration: load AOT artifacts, drive the full training
//! coordinator, verify learning + quantization behaviour.
//!
//! Requires `make artifacts` (the `smoke` config); tests skip if absent.

use std::path::{Path, PathBuf};

use symog::coordinator::{Checkpoint, LambdaSchedule, Trainer, TrainOptions};
use symog::data::{AugmentConfig, Preset};
use symog::runtime::Runtime;

fn smoke_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/smoke");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn train_smoke_end_to_end() {
    let Some(dir) = smoke_dir() else {
        eprintln!("skipping: artifacts/smoke not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    assert_eq!(art.manifest.model, "mlp");
    assert_eq!(art.manifest.method, "symog");

    let (train, test) = Preset::SynthMnist.load(512, 128, 42);
    let mut trainer = Trainer::from_init(&art).unwrap();

    // deltas were resolved from init weights: powers of two, positive
    assert_eq!(trainer.deltas().len(), art.manifest.deltas_len());
    for &d in trainer.deltas() {
        assert!(d > 0.0);
        let f = d.log2();
        assert!((f - f.round()).abs() < 1e-6, "delta {d} not a power of two");
    }

    let mut opts = TrainOptions::paper(4);
    opts.seed = 7;
    opts.augment = AugmentConfig::none();
    opts.track_modes = true;
    opts.hist_epochs = vec![0, 4];
    opts.hist_layers = vec![0];
    let outcome = trainer.train(&train, &test, &opts).unwrap();

    // learning happened
    let logs = &outcome.log.epochs;
    assert_eq!(logs.len(), 4);
    assert!(
        logs.last().unwrap().train_loss < logs[0].train_loss,
        "train loss did not decrease: {} -> {}",
        logs[0].train_loss,
        logs.last().unwrap().train_loss
    );
    // classifier beats chance (10 classes) on held-out data, float and quantized
    assert!(logs.last().unwrap().test_acc > 0.3);
    assert!(logs.last().unwrap().testq_acc > 0.2);

    // probes produced data
    let tracker = outcome.tracker.unwrap();
    assert_eq!(tracker.switch_rates.len(), 5); // baseline + 4 epochs
    assert_eq!(outcome.histograms[0].1.hists.len(), 2); // epochs 0 and 4

    // weights respect the clipping domain (section 3.4)
    let layers = trainer.quant_layers_host().unwrap();
    for (w, d) in &layers {
        for &x in w {
            assert!(x.abs() <= d * 1.0 + 1e-5, "weight {x} outside ±{d}");
        }
    }
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(dir) = smoke_dir() else {
        eprintln!("skipping: artifacts/smoke not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let (train, test) = Preset::SynthMnist.load(256, 64, 1);

    let mut trainer = Trainer::from_init(&art).unwrap();
    let mut opts = TrainOptions::paper(1);
    opts.steps_per_epoch = Some(4);
    trainer.train(&train, &test, &opts).unwrap();

    let tmp = std::env::temp_dir().join("symog_it_ckpt.ckpt");
    trainer.save(&tmp).unwrap();
    let ck = Checkpoint::read(&tmp).unwrap();
    assert_eq!(ck.meta_i64("epoch"), Some(1));

    // resume without re-solving deltas: state must match exactly
    let trainer2 = Trainer::from_checkpoint(&art, &ck, false).unwrap();
    assert_eq!(trainer2.deltas(), trainer.deltas());
    assert_eq!(trainer2.epoch, 1);
    let (l1, a1) = trainer.evaluate(&test, true).unwrap();
    let (l2, a2) = trainer2.evaluate(&test, true).unwrap();
    assert!((l1 - l2).abs() < 1e-6);
    assert_eq!(a1, a2);
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn lambda_off_matches_baseline_semantics() {
    // SYMOG with lambda = 0 must still learn (it degenerates to clipped SGD)
    let Some(dir) = smoke_dir() else {
        eprintln!("skipping: artifacts/smoke not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let (train, test) = Preset::SynthMnist.load(256, 64, 5);
    let mut trainer = Trainer::from_init(&art).unwrap();
    let mut opts = TrainOptions::paper(2);
    opts.lambda = LambdaSchedule::Off;
    let outcome = trainer.train(&train, &test, &opts).unwrap();
    let logs = &outcome.log.epochs;
    assert!(logs[1].train_loss < logs[0].train_loss * 1.05);
}
