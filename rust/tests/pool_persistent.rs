//! Persistent worker-pool integration suite: the process-wide pool
//! (`util::pool`) under the workloads that actually ride on it.
//!
//! The whole process is pinned to `SYMOG_WORKERS=2` before any pool use
//! (integration test binaries are their own process, so this cannot
//! leak into other suites). A cap-sized pool — one parked worker plus
//! the dispatcher — is the harshest configuration for the reentrancy
//! rule: a nested dispatch that blocked on the queue instead of running
//! inline would deadlock immediately and hang the suite.
//!
//! Covered here:
//! * serve-drain → `run_rows` → per-step fan-out nesting completes and
//!   stays bit-identical to the solo oracle;
//! * fan-out width invariance 1..=64 for dataset generation and the
//!   training fwd/bwd ops — the width is a per-call argument while the
//!   pool size is fixed at init, and neither may touch the bits;
//! * oversubscription: more concurrent dispatchers than pool threads;
//! * the acceptance proof: zero OS-thread spawns across steady-state
//!   served micro-batches, via the pool's dispatch counters.

use std::sync::Once;

use symog::data::{synth_dataset_with, SynthSpec};
use symog::inference::IntModel;
use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::train::ops::{
    conv2d_backward_with, conv2d_forward_with, dense_backward_with, dense_forward_with,
};
use symog::train::Conv2dShape;
use symog::util::pool;
use symog::util::rng::Rng;

/// Pin the pool to 2 workers (1 parked thread) and force it to spawn
/// before any test snapshots counters: `threads_spawned` is then fixed
/// for the rest of the process, whatever order the harness runs tests.
fn init() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        std::env::set_var("SYMOG_WORKERS", "2");
        assert_eq!(pool::default_workers(), 2, "env pin must be read before any pool use");
        // first multi-chunk dispatch initializes the pool
        let v = pool::par_map(8, 2, |i| i);
        assert_eq!(v, (0..8).collect::<Vec<_>>());
        assert_eq!(pool::counters().threads_spawned, 1, "2-worker pool = 1 parked thread");
    });
}

#[test]
fn run_rows_nested_inside_a_pool_fan_out_is_deadlock_free_and_bit_exact() {
    init();
    let mut rng = Rng::new(0xBEEF);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let plan = model.plan(6).unwrap();
    let (elems, out_per) = (plan.in_elems(), plan.out_per_img());
    let batch = 6usize;
    let mut img_rng = Rng::new(0x1234);
    let images: Vec<f32> = (0..batch * elems).map(|_| img_rng.normal()).collect();

    // oracle: run_rows dispatched from the test thread itself
    let mut want = vec![0f32; batch * out_per];
    let mut scr: Vec<_> = (0..2).map(|_| plan.scratch_for(1)).collect();
    plan.run_rows(&images, batch, &mut scr, &mut want).unwrap();

    // the same run_rows issued *from inside a pool fan-out*, the shape a
    // serve drain produces: each multi-scratch row scatter is a nested
    // multi-chunk dispatch. The chunks that land on the pool worker must
    // run it inline (never re-enqueue and block) or this test hangs; the
    // chunks run by the dispatcher re-enter the queue. Both paths must
    // produce the solo oracle's bits. 25 rounds so the racy chunk→thread
    // assignment visits both placements.
    for _ in 0..25 {
        let outs = pool::par_map(4, 4, |_| {
            let mut scr: Vec<_> = (0..2).map(|_| plan.scratch_for(1)).collect();
            let mut out = vec![0f32; batch * out_per];
            plan.run_rows(&images, batch, &mut scr, &mut out).unwrap();
            out
        });
        for out in outs {
            assert_eq!(out, want, "nested run_rows diverged from the solo oracle");
        }
    }
}

#[test]
fn hammered_server_on_cap_sized_pool_is_bit_exact() {
    init();
    let mut rng = Rng::new(0xC0FE);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let solo = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let key = reg
        .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(4))
        .unwrap();
    let server = Server::new(reg, ServeConfig::new().workers(2));

    // 4 client threads > 1 pool thread: drain leaders dispatch row
    // fan-outs on the pool while other clients queue up behind them
    let corpus: Vec<Vec<(Vec<f32>, Vec<f32>)>> = (0..4)
        .map(|t| {
            (0..10)
                .map(|i| {
                    let mut r = Rng::new(0x5EED ^ ((t * 10 + i) as u64).wrapping_mul(0x9E37));
                    let image: Vec<f32> = (0..elems).map(|_| r.normal()).collect();
                    let want = solo.forward(&image, 1).unwrap().0;
                    (image, want)
                })
                .collect()
        })
        .collect();
    std::thread::scope(|sc| {
        for (t, cases) in corpus.iter().enumerate() {
            let (server, key) = (&server, &key);
            sc.spawn(move || {
                for (i, (image, want)) in cases.iter().enumerate() {
                    let got = server.infer(key, image).unwrap();
                    assert_eq!(&got, want, "thread {t} request {i}: served != solo oracle");
                }
            });
        }
    });
}

#[test]
fn fan_out_width_is_bit_irrelevant_from_1_to_64() {
    init();
    // dataset generation
    let spec = SynthSpec {
        shape: [8, 8, 1],
        classes: 4,
        coarse_classes: 4,
        noise: 0.2,
        max_shift: 1,
        blob_scale: 2.0,
    };
    let base_ds = synth_dataset_with(&spec, 33, 7, 1);

    // training fwd/bwd ops (sizes chosen to not divide evenly)
    let mut rng = Rng::new(0x7777);
    let (batch, fin, fout) = (9usize, 13usize, 7usize);
    let x: Vec<f32> = (0..batch * fin).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..fin * fout).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..fout).map(|_| rng.normal()).collect();
    let dy: Vec<f32> = (0..batch * fout).map(|_| rng.normal()).collect();
    let s = Conv2dShape { h: 6, w: 5, cin: 2, k: 3, stride: 2, cout: 3 };
    let cx: Vec<f32> = (0..s.in_elems(batch)).map(|_| rng.normal()).collect();
    let cw: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal()).collect();
    let cb: Vec<f32> = (0..s.cout).map(|_| rng.normal()).collect();
    let cdy: Vec<f32> = (0..s.out_elems(batch)).map(|_| rng.normal()).collect();

    let base_df = dense_forward_with(&x, &w, &b, batch, fin, fout, 1);
    let base_db = dense_backward_with(&x, &w, &dy, batch, fin, fout, 1);
    let base_cf = conv2d_forward_with(&cx, &cw, &cb, batch, &s, 1);
    let base_cb = conv2d_backward_with(&cx, &cw, &cdy, batch, &s, 1);

    for workers in 2..=64usize {
        let ds = synth_dataset_with(&spec, 33, 7, workers);
        assert_eq!(ds.images, base_ds.images, "dataset bits moved at workers={workers}");
        assert_eq!(ds.labels, base_ds.labels, "dataset labels moved at workers={workers}");
        assert_eq!(
            dense_forward_with(&x, &w, &b, batch, fin, fout, workers),
            base_df,
            "dense forward bits moved at workers={workers}"
        );
        assert_eq!(
            dense_backward_with(&x, &w, &dy, batch, fin, fout, workers),
            base_db,
            "dense backward bits moved at workers={workers}"
        );
        assert_eq!(
            conv2d_forward_with(&cx, &cw, &cb, batch, &s, workers),
            base_cf,
            "conv forward bits moved at workers={workers}"
        );
        assert_eq!(
            conv2d_backward_with(&cx, &cw, &cdy, batch, &s, workers),
            base_cb,
            "conv backward bits moved at workers={workers}"
        );
    }
}

#[test]
fn oversubscribed_dispatchers_stay_correct() {
    init();
    // far more concurrent dispatchers than the pool's single worker:
    // caller-runs must keep every job progressing with zero free workers
    let mut rng = Rng::new(0x0D15);
    let (batch, fin, fout) = (16usize, 24usize, 10usize);
    let x: Vec<f32> = (0..batch * fin).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..fin * fout).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..fout).map(|_| rng.normal()).collect();
    let want = dense_forward_with(&x, &w, &b, batch, fin, fout, 1);

    let dispatchers = pool::default_workers() * 4 + 2;
    std::thread::scope(|sc| {
        for t in 0..dispatchers {
            let (x, w, b, want) = (&x, &w, &b, &want);
            sc.spawn(move || {
                for r in 0..10 {
                    let got = dense_forward_with(x, w, b, batch, fin, fout, 8);
                    assert_eq!(&got, want, "dispatcher {t} round {r} diverged");
                    let ids = pool::par_map(41, 8, move |i| t * 100_000 + r * 1000 + i);
                    let want_ids: Vec<usize> =
                        (0..41).map(|i| t * 100_000 + r * 1000 + i).collect();
                    assert_eq!(ids, want_ids, "dispatcher {t} round {r} par_map diverged");
                }
            });
        }
    });
}

#[test]
fn steady_state_served_micro_batches_spawn_zero_threads() {
    init();
    let mut rng = Rng::new(0xAB);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let solo = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let key = reg
        .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(4))
        .unwrap();
    let server = Server::new(reg, ServeConfig::new().workers(2));

    let corpus: Vec<Vec<(Vec<f32>, Vec<f32>)>> = (0..3)
        .map(|t| {
            (0..8)
                .map(|i| {
                    let mut r = Rng::new(0xFACE ^ ((t * 8 + i) as u64).wrapping_mul(0xA5A5));
                    let image: Vec<f32> = (0..elems).map(|_| r.normal()).collect();
                    let want = solo.forward(&image, 1).unwrap().0;
                    (image, want)
                })
                .collect()
        })
        .collect();
    let hammer = || {
        std::thread::scope(|sc| {
            for cases in &corpus {
                let (server, key) = (&server, &key);
                sc.spawn(move || {
                    for (image, want) in cases {
                        assert_eq!(&server.infer(key, image).unwrap(), want);
                    }
                });
            }
        });
    };

    hammer(); // warmup: scratch pools and plan caches fill
    let c1 = pool::counters();
    hammer(); // steady-state micro-batches
    let c2 = pool::counters();

    // the acceptance proof: `threads_spawned` only moves when the pool
    // spawns an OS thread, so a zero delta across the served round *is*
    // the zero-spawn claim (client threads above are test harness, not
    // engine). Other suites in this binary may dispatch concurrently —
    // that only adds activity, never spawns.
    assert_eq!(
        c2.threads_spawned, c1.threads_spawned,
        "steady-state serving must not create OS threads"
    );
    assert_eq!(c1.threads_spawned, 1, "pool size fixed at init (SYMOG_WORKERS=2)");
    let activity = (c2.jobs_dispatched - c1.jobs_dispatched)
        + (c2.inline_single - c1.inline_single)
        + (c2.inline_nested - c1.inline_nested);
    assert!(activity > 0, "served round must go through the pool entry points");
}
