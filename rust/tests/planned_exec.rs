//! Planned-execution test suite: races `ExecPlan` (compile-then-execute,
//! arena buffers, fused integer epilogues) against the interpreted
//! `Backend::Naive` oracle and checks the planned executor's contracts:
//!
//! * bit-identical logits and identical `OpCounts` across n_bits, worker
//!   counts, a concat/DenseNet-shaped model, and ragged final batches;
//! * analytic `OpCounts` (no dummy forward) exactly equal to the counted
//!   interpreter on LeNet5- and DenseNet-shaped models;
//! * allocation discipline: zero arena growth across steady-state runs.

use symog::inference::{Backend, IntModel};
use symog::runtime::Manifest;
use symog::testing::models;
use symog::util::rng::Rng;

type ModelFn = fn(&mut Rng, u32) -> (Manifest, symog::coordinator::Checkpoint);

const ZOO: &[(&str, ModelFn)] = &[
    ("lenet5ish", models::lenet5ish as ModelFn),
    ("densenetish", models::densenetish as ModelFn),
    // fusion-hostile placements: post-pool BN, retained flatten, BN/ReLU
    // on retained slots — covers every non-fused planned step kind
    ("oddball", models::oddball as ModelFn),
];

fn input_elems(man: &Manifest) -> usize {
    man.input_shape.iter().product()
}

#[test]
fn planned_bit_identical_to_naive_across_bits_threads_and_models() {
    for (name, build) in ZOO {
        for n_bits in [2u32, 4, 8] {
            let mut rng = Rng::new(0x9E3 ^ ((n_bits as u64) << 8));
            let (man, ck) = build(&mut rng, n_bits);
            let naive = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Naive);
            let planned = IntModel::build(&man, &ck).unwrap();
            assert_eq!(planned.backend, Backend::Planned);

            let batch = 6usize;
            let e = input_elems(&man);
            let images: Vec<f32> = (0..batch * e).map(|_| rng.normal()).collect();
            let (logits_n, counts_n) = naive.forward(&images, batch).unwrap();

            for workers in [1usize, 2, 4] {
                let plan = planned.plan(batch).unwrap().with_workers(workers);
                let mut scratch = plan.scratch();
                let logits_p = plan.run(&images, batch, &mut scratch).unwrap();
                assert_eq!(
                    logits_p, logits_n,
                    "{name} n_bits={n_bits} workers={workers}: logits diverged"
                );
                assert_eq!(
                    plan.op_counts(batch),
                    counts_n,
                    "{name} n_bits={n_bits} workers={workers}: OpCounts diverged"
                );
            }

            // the public forward() routes through the cached plan and must
            // agree too (logits AND counts)
            let (logits_f, counts_f) = planned.forward(&images, batch).unwrap();
            assert_eq!(logits_f, logits_n, "{name} n_bits={n_bits}: forward() diverged");
            assert_eq!(counts_f, counts_n);

            // and the per-call interpreted GEMM backend stays on the oracle
            let gemm = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Gemm);
            let (logits_g, counts_g) = gemm.forward(&images, batch).unwrap();
            assert_eq!(logits_g, logits_n);
            assert_eq!(counts_g, counts_n);
        }
    }
}

#[test]
fn ragged_final_batch_smaller_than_max_batch() {
    let mut rng = Rng::new(0x5EED);
    let (man, ck) = models::densenetish(&mut rng, 2);
    let planned = IntModel::build(&man, &ck).unwrap();
    let naive = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Naive);
    let e = input_elems(&man);
    let images: Vec<f32> = (0..8 * e).map(|_| rng.normal()).collect();

    let plan = planned.plan(8).unwrap();
    let mut scratch = plan.scratch();
    for batch in [8usize, 5, 1] {
        let logits_p = plan.run(&images[..batch * e], batch, &mut scratch).unwrap();
        let (logits_n, counts_n) = naive.forward(&images[..batch * e], batch).unwrap();
        assert_eq!(logits_p, logits_n, "batch={batch}");
        assert_eq!(plan.op_counts(batch), counts_n, "batch={batch}");
    }

    // through the public API: 7 images at batch 4 ends on a ragged 3
    let labels: Vec<i32> = (0..7).map(|i| i % 10).collect();
    let acc_p = planned.accuracy(&images[..7 * e], &labels, 4).unwrap();
    let acc_n = naive.accuracy(&images[..7 * e], &labels, 4).unwrap();
    assert_eq!(acc_p, acc_n);
}

#[test]
fn analytic_op_counts_match_counted_forward_exactly() {
    for (name, build) in ZOO {
        let mut rng = Rng::new(0xC057);
        let (man, ck) = build(&mut rng, 2);
        let naive = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Naive);
        let planned = IntModel::build(&man, &ck).unwrap();
        let e = input_elems(&man);
        for batch in [1usize, 4] {
            let images: Vec<f32> = (0..batch * e).map(|_| rng.normal()).collect();
            let (_, counted) = naive.forward(&images, batch).unwrap();
            // cost_report executes NO forward — its counts come from the plan
            let report = planned.cost_report(batch).unwrap();
            assert_eq!(
                report.counts, counted,
                "{name} batch={batch}: analytic OpCounts != counted forward"
            );
            assert_eq!(report.float_macs, counted.acc_adds);
        }
    }
}

#[test]
fn steady_state_runs_never_grow_the_arena() {
    let mut rng = Rng::new(0xA110C);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let plan = model.plan(8).unwrap();
    let mut scratch = plan.scratch();
    let e = input_elems(&man);
    let images: Vec<f32> = (0..8 * e).map(|_| rng.normal()).collect();

    plan.run(&images, 8, &mut scratch).unwrap();
    let fingerprint = scratch.fingerprint();
    assert!(scratch.arena_bytes() > 0);
    for batch in [8usize, 8, 3, 8, 1] {
        plan.run(&images[..batch * e], batch, &mut scratch).unwrap();
        assert_eq!(
            fingerprint,
            scratch.fingerprint(),
            "arena reallocated on a steady-state run (batch={batch})"
        );
    }
}

#[test]
fn scratch_is_bound_to_its_plan() {
    let mut rng = Rng::new(0xB0);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let plan_a = model.plan(2).unwrap();
    let plan_b = model.plan(2).unwrap();
    let e = input_elems(&man);
    let images: Vec<f32> = (0..2 * e).map(|_| rng.normal()).collect();
    let mut scratch_b = plan_b.scratch();
    assert!(plan_a.run(&images, 2, &mut scratch_b).is_err());
    assert!(plan_b.run(&images, 2, &mut scratch_b).is_ok());
}

#[test]
fn plan_metadata_reports_fusion_and_arena() {
    let mut rng = Rng::new(0xF0);
    let (man, ck) = models::vgg7ish(&mut rng, 2, 4);
    let model = IntModel::build(&man, &ck).unwrap();
    let plan = model.plan(4).unwrap();
    // 19 layers fuse into: 4 conv groups + 2 pools + 2 dense groups = 8
    assert!(plan.num_steps() < 19, "no fusion happened: {}", plan.num_steps());
    assert_eq!(plan.max_batch(), 4);
    assert!(plan.arena_bytes() > 0);
    // sparse 2-bit weights engage the ternary path; logits must still
    // match the oracle
    let mut rng = Rng::new(0xF1);
    let mut b = models::ModelBuilder::new([8, 8, 2], 10, 2);
    b.zero_frac(0.8);
    b.conv(&mut rng, 3, 2, 8, 1, true, true).relu().flatten().dense(&mut rng, 512, 10, true);
    let (man, ck) = b.finish("sparse");
    let naive = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Naive);
    let planned = IntModel::build(&man, &ck).unwrap();
    let images: Vec<f32> = (0..3 * 128).map(|_| rng.normal()).collect();
    let (ln, cn) = naive.forward(&images, 3).unwrap();
    let (lp, cp) = planned.forward(&images, 3).unwrap();
    assert_eq!(lp, ln);
    assert_eq!(cp, cn);
    assert_eq!(cn.int_mults, 0, "sparse ternary model must be multiply-free");
}
