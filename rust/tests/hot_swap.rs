//! Atomic hot-swap suite.
//!
//! The contract (see `serve/server.rs` docs, §"Versioned slots and
//! hot-swap"): installing a new model version under live traffic never
//! pauses a slot, never drops or blocks a request, and never blurs
//! versions — every response is bit-identical to a solo planned forward
//! of that request on *exactly one* version (the one the drain pinned),
//! the response says which, and per-version stats partition traffic with
//! no loss and no double counting.
//!
//! The hammer below proves it the hard way: client threads stream
//! requests while the main thread swaps v1 → v2 (in-code) → v3 (a
//! published `.fxpa` artifact), and every single response is checked
//! against the solo oracle of the version it claims. The sequential test
//! then pins down the bookkeeping exactly, where thread timing can't
//! smear the numbers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use symog::artifact::{self, PublishOpts};
use symog::inference::{IntModel, OpCounts};
use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::util::rng::Rng;

const N_IMAGES: usize = 8;

/// Three generations of the same architecture (identical geometry, fresh
/// weights each) plus a solo-oracle logits table per version.
struct Fixture {
    models: Vec<(u32, IntModel)>,
    images: Vec<Vec<f32>>,
    /// version → per-image solo logits
    oracle: BTreeMap<u32, Vec<Vec<f32>>>,
    per_row: OpCounts,
}

fn fixture() -> Fixture {
    let mut rng = Rng::new(0x5A9);
    let mut gens = Vec::new();
    for v in [1u32, 2, 3] {
        let (man, ck) = models::lenet5ish(&mut rng, 2);
        gens.push((v, man, ck));
    }
    let elems: usize = gens[0].1.input_shape.iter().product();
    let images: Vec<Vec<f32>> =
        (0..N_IMAGES).map(|_| (0..elems).map(|_| rng.normal()).collect()).collect();
    let mut oracle = BTreeMap::new();
    let mut built = Vec::new();
    let mut per_row = OpCounts::default();
    for (v, man, ck) in gens {
        let m = IntModel::build(&man, &ck).unwrap();
        let logits: Vec<Vec<f32>> = images.iter().map(|x| m.forward(x, 1).unwrap().0).collect();
        oracle.insert(v, logits);
        per_row = m.cost_report(1).unwrap().counts;
        built.push((v, m));
    }
    Fixture { models: built, images, oracle, per_row }
}

#[test]
fn hot_swap_under_concurrent_traffic_never_drops_or_blurs_versions() {
    let fx = fixture();
    let (_, m1) = &fx.models[0];
    let (_, m2) = &fx.models[1];
    // v3 travels as an artifact. The fixture consumed its
    // manifest/checkpoint, so replay the deterministic generator (same
    // seed, same draw order) to publish weights matching oracle[3].
    let mut rng = Rng::new(0x5A9);
    let _ = models::lenet5ish(&mut rng, 2);
    let _ = models::lenet5ish(&mut rng, 2);
    let (man3, ck3) = models::lenet5ish(&mut rng, 2);
    let path = std::env::temp_dir().join(format!("symog-{}-hotswap.fxpa", std::process::id()));
    artifact::publish(&man3, &ck3, &PublishOpts::new().version(3), &path).unwrap();

    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(4);
    let key = reg.add("lenet5", ModelSource::InCode(m1), &opts).unwrap();
    let server = Server::new(reg, ServeConfig::new().workers(3));

    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 40;
    let completed = AtomicU64::new(0);
    // version → responses observed with that tag (clients + main probes)
    let observed = Mutex::new(BTreeMap::<u32, u64>::new());
    let check = |img_idx: usize, logits: &[f32], v: u32| {
        let want = &fx.oracle[&v][img_idx];
        assert_eq!(logits, &want[..], "response tagged v{v} diverged from v{v}'s solo oracle");
        *observed.lock().unwrap().entry(v).or_insert(0) += 1;
    };

    std::thread::scope(|s| {
        for tid in 0..CLIENTS {
            let (server, key, fx) = (&server, &key, &fx);
            let (completed, observed) = (&completed, &observed);
            s.spawn(move || {
                for j in 0..PER_CLIENT {
                    let i = (tid * 13 + j * 7) % N_IMAGES;
                    let (logits, v) = server.infer_versioned(key, &fx.images[i]).unwrap();
                    let want = &fx.oracle[&v][i];
                    assert_eq!(
                        logits,
                        want[..],
                        "client {tid} req {j}: response tagged v{v} != v{v}'s solo oracle"
                    );
                    *observed.lock().unwrap().entry(v).or_insert(0) += 1;
                    completed.fetch_add(1, Ordering::Relaxed);
                }
            });
        }

        // main thread: swap mid-traffic, then probe until the new version
        // demonstrably serves (guarantees every version sees real traffic
        // even if the clients race ahead)
        let probe = |want_v: u32| loop {
            let (logits, v) = server.infer_versioned(&key, &fx.images[0]).unwrap();
            check(0, &logits, v);
            if v == want_v {
                break;
            }
            std::thread::yield_now();
        };
        while completed.load(Ordering::Relaxed) < 30 {
            std::thread::yield_now();
        }
        let k2 = server.swap(&key, ModelSource::InCode(m2), &opts).unwrap();
        assert_eq!(k2.version, 2);
        probe(2);
        while completed.load(Ordering::Relaxed) < 120 {
            std::thread::yield_now();
        }
        let k3 = server.swap(&key, ModelSource::Artifact(&path), &opts).unwrap();
        assert_eq!(k3.version, 3);
        probe(3);
    });
    std::fs::remove_file(&path).unwrap();

    // nothing dropped: every issued request produced exactly one response
    let observed = observed.into_inner().unwrap();
    let issued: u64 = observed.values().sum();
    assert!(issued >= (CLIENTS * PER_CLIENT) as u64);
    let total = server.stats(&key).unwrap();
    assert_eq!(total.requests, issued, "stats lost or double-counted a request");

    // stats partition exactly by the version that executed each request,
    // and op accounting stays analytic per version
    let by_version = server.stats_by_version(&key).unwrap();
    assert_eq!(by_version.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![1, 2, 3]);
    let mut sum = 0u64;
    for (v, stats) in &by_version {
        assert_eq!(
            stats.requests,
            observed[v],
            "v{v}: stats disagree with the responses tagged v{v}"
        );
        assert!(stats.requests > 0, "v{v} never served — the probe should prevent this");
        let mut want_ops = OpCounts::default();
        for _ in 0..stats.requests {
            want_ops.merge(&fx.per_row);
        }
        assert_eq!(stats.op_counts, want_ops, "v{v}: op accounting drifted");
        sum += stats.requests;
    }
    assert_eq!(sum, total.requests, "per-version stats do not partition the total");
    assert_eq!(server.current_version(&key).unwrap(), 3);
}

#[test]
fn sequential_swap_bookkeeping_is_exact() {
    let fx = fixture();
    let (_, m1) = &fx.models[0];
    let (_, m2) = &fx.models[1];
    let (_, m3) = &fx.models[2];
    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(4);
    let key = reg.add("lenet5", ModelSource::InCode(m1), &opts).unwrap();
    let server = Server::new(reg, ServeConfig::new().workers(2));

    let run = |n: usize, want_v: u32| {
        for i in 0..n {
            let (logits, v) = server.infer_versioned(&key, &fx.images[i]).unwrap();
            assert_eq!(v, want_v);
            assert_eq!(logits, fx.oracle[&want_v][i][..], "v{want_v} request {i} diverged");
        }
    };
    run(3, 1);
    // fingerprints before/after traffic on the same version: no growth
    let fp = server.pool_fingerprints(&key).unwrap();
    run(2, 1);
    assert_eq!(server.pool_fingerprints(&key).unwrap(), fp, "serving allocated steady-state");

    server.swap(&key, ModelSource::InCode(m2), &opts).unwrap();
    run(4, 2);
    // pin a far-future version explicitly
    let pin9 = RegisterOpts::new().max_batch(4).version(9);
    let k9 = server.swap(&key, ModelSource::InCode(m3), &pin9).unwrap();
    assert_eq!(k9.version, 9);
    run(2, 9);

    // keys() reports the serving version; the old key still routes
    assert_eq!(format!("{}", server.keys()[0]), "lenet5@w2#v9");
    let by_version = server.stats_by_version(&key).unwrap();
    let got: Vec<(u32, u64)> = by_version.iter().map(|(v, s)| (*v, s.requests)).collect();
    assert_eq!(got, vec![(1, 5), (2, 4), (9, 2)]);
    assert_eq!(server.stats(&key).unwrap().requests, 11);
}
