//! Serving concurrency suite: M client threads hammering one `Server`
//! must observe
//!
//! (a) per-request logits bit-identical to the solo planned oracle, no
//!     matter how the scheduler interleaves arrivals into micro-batches;
//! (b) a scratch-pool/staging-buffer fingerprint set that is *stable*
//!     across load rounds — zero steady-state allocation in the serving
//!     engine;
//! (c) exact counter accounting: request counters sum to precisely the
//!     number of `infer` calls, and analytic op totals equal
//!     requests x per-row counts (batching must never change what a
//!     request costs).
//!
//! Request images are derived from per-request seeds, so the oracle is
//! precomputed single-threaded and every thread checks its own answers.

use symog::inference::{IntModel, OpCounts};
use symog::serve::{
    Health, ModelKey, ModelSource, RegisterOpts, Registry, ServeConfig, ServeError, Server,
};
use symog::testing::models;
use symog::util::rng::Rng;

const M: usize = 4; // client threads
const K: usize = 12; // requests per thread per round
const ROUNDS: usize = 3; // one warmup + two steady-state rounds

struct Case {
    key: ModelKey,
    image: Vec<f32>,
    want: Vec<f32>,
}

/// Deterministic request image for (thread, index).
fn request_image(elems: usize, t: usize, i: usize) -> Vec<f32> {
    let mut rng = Rng::new(0x9E37 ^ ((t * K + i) as u64).wrapping_mul(0xA5A5A5A5A5A5));
    (0..elems).map(|_| rng.normal()).collect()
}

#[test]
fn hammered_server_is_bit_exact_allocation_stable_and_counts_exactly() {
    let mut rng = Rng::new(0xC0);
    let (man_a, ck_a) = models::lenet5ish(&mut rng, 2);
    let (man_b, ck_b) = models::densenetish(&mut rng, 4);
    let model_a = IntModel::build(&man_a, &ck_a).unwrap();
    let model_b = IntModel::build(&man_b, &ck_b).unwrap();
    let solo_a = IntModel::build(&man_a, &ck_a).unwrap();
    let solo_b = IntModel::build(&man_b, &ck_b).unwrap();
    let elems_a: usize = man_a.input_shape.iter().product();
    let elems_b: usize = man_b.input_shape.iter().product();

    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(4);
    let key_a = reg.add("lenet5", ModelSource::InCode(&model_a), &opts).unwrap();
    let key_b = reg.add("densenet", ModelSource::InCode(&model_b), &opts).unwrap();
    let workers = 3usize;
    let server = Server::new(reg, ServeConfig::new().workers(workers));

    // single-threaded oracle: solo planned forward per request. Threads
    // alternate between the two registered models so multi-model serving
    // is exercised *under* contention, not just sequentially.
    let corpus: Vec<Vec<Case>> = (0..M)
        .map(|t| {
            (0..K)
                .map(|i| {
                    let to_a = (t + i) % 2 == 0;
                    let (key, solo, elems) = if to_a {
                        (&key_a, &solo_a, elems_a)
                    } else {
                        (&key_b, &solo_b, elems_b)
                    };
                    let image = request_image(elems, t, i);
                    let (want, _) = solo.forward(&image, 1).unwrap();
                    Case { key: key.clone(), image, want }
                })
                .collect()
        })
        .collect();

    let hammer = |round: usize| {
        std::thread::scope(|sc| {
            for (t, cases) in corpus.iter().enumerate() {
                let server = &server;
                sc.spawn(move || {
                    for (i, case) in cases.iter().enumerate() {
                        let got = server.infer(&case.key, &case.image).unwrap();
                        assert_eq!(
                            got, case.want,
                            "round {round} thread {t} request {i} ({}): \
                             served logits != solo planned forward",
                            case.key
                        );
                    }
                });
            }
        });
    };

    // (a) bit-exactness under contention, every round
    hammer(0); // warmup: touches every pooled allocation
    let fp_a = server.pool_fingerprints(&key_a).unwrap();
    let fp_b = server.pool_fingerprints(&key_b).unwrap();
    // eager pool: `workers` row scratches + one gather/scatter entry
    assert_eq!(fp_a.len(), workers + 1);
    assert_eq!(fp_b.len(), workers + 1);
    for round in 1..ROUNDS {
        hammer(round);
    }

    // (b) zero steady-state allocation: the fingerprint *set* is unchanged
    assert_eq!(
        fp_a,
        server.pool_fingerprints(&key_a).unwrap(),
        "lenet5 scratch pool grew or reallocated under steady-state load"
    );
    assert_eq!(
        fp_b,
        server.pool_fingerprints(&key_b).unwrap(),
        "densenet scratch pool grew or reallocated under steady-state load"
    );

    // (c) exact accounting
    let sa = server.stats(&key_a).unwrap();
    let sb = server.stats(&key_b).unwrap();
    let total = (ROUNDS * M * K) as u64;
    assert_eq!(sa.requests + sb.requests, total, "request counters lost or double-counted");
    let n_a: usize = (0..M)
        .map(|t| (0..K).filter(|i| (t + i) % 2 == 0).count())
        .sum();
    assert_eq!(sa.requests, (ROUNDS * n_a) as u64);
    assert_eq!(sb.requests, (ROUNDS * (M * K - n_a)) as u64);
    for (name, s, solo) in [("lenet5", &sa, &solo_a), ("densenet", &sb, &solo_b)] {
        assert!(s.batches >= 1 && s.batches <= s.requests, "{name}: absurd batch count");
        assert!(
            s.mean_occupancy() >= 1.0 && s.max_occupancy <= 4,
            "{name}: occupancy outside [1, max_batch]"
        );
        // batching must not change what a request costs: totals are exactly
        // requests x the analytic per-row counts, whatever the partition
        let per_row = solo.cost_report(1).unwrap().counts;
        let mut want = OpCounts::default();
        for _ in 0..s.requests {
            want.merge(&per_row);
        }
        assert_eq!(s.op_counts, want, "{name}: op accounting depends on batching");
    }
}

#[test]
fn single_model_saturation_reaches_full_batches() {
    // enough same-model pressure that coalescing actually happens; the
    // invariants hold at any occupancy, this just makes sure the size
    // watermark path is exercised too (stats can't prove it fired on a
    // given scheduler, so assert only the occupancy bound + exact totals)
    let mut rng = Rng::new(0xD1);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let solo = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let cap = 3usize;
    let key = reg
        .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(cap))
        .unwrap();
    let server = Server::new(reg, ServeConfig::new().workers(2));

    let corpus: Vec<Vec<Case>> = (0..M)
        .map(|t| {
            (0..K)
                .map(|i| {
                    let image = request_image(elems, t, i);
                    let (want, _) = solo.forward(&image, 1).unwrap();
                    Case { key: key.clone(), image, want }
                })
                .collect()
        })
        .collect();
    std::thread::scope(|sc| {
        for cases in &corpus {
            let server = &server;
            sc.spawn(move || {
                for case in cases {
                    let got = server.infer(&case.key, &case.image).unwrap();
                    assert_eq!(got, case.want, "{}: diverged under saturation", case.key);
                }
            });
        }
    });
    let s = server.stats(&key).unwrap();
    assert_eq!(s.requests, (M * K) as u64);
    assert!(s.max_occupancy <= cap as u64, "micro-batch exceeded the registered cap");
    assert!(s.batches >= (M * K).div_ceil(cap) as u64, "more rows per batch than the cap allows");
}

#[test]
fn sustained_overload_sheds_but_never_loses_a_request() {
    // a queue_depth-bounded slot under 8 hammering threads: some requests
    // are shed (typed, at enqueue), every accepted one is bit-exact, and
    // nothing is ever lost — per round, requests + sheds == submissions
    // exactly, with zero timeouts/failures. Whether a given round sheds
    // depends on scheduling, so rounds repeat until one does.
    let mut rng = Rng::new(0xE2);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let solo = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let key = reg
        .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(2))
        .unwrap();
    let depth = 2usize;
    let server = Server::new(reg, ServeConfig::new().workers(2).queue_depth(depth));

    let threads = 8usize;
    let per_thread = 25usize;
    let mut total_subs = 0u64;
    for round in 0..20 {
        std::thread::scope(|sc| {
            for t in 0..threads {
                let server = &server;
                let key = &key;
                let solo = &solo;
                sc.spawn(move || {
                    for i in 0..per_thread {
                        let image = request_image(elems, t, i);
                        match server.infer(key, &image) {
                            Ok(got) => {
                                let (want, _) = solo.forward(&image, 1).unwrap();
                                assert_eq!(
                                    got, want,
                                    "round {round} thread {t} request {i}: \
                                     accepted response diverged from solo oracle"
                                );
                            }
                            Err(e) => match e.downcast_ref::<ServeError>() {
                                Some(ServeError::Shed { depth: d }) => {
                                    assert_eq!(*d, depth, "shed reports the configured depth")
                                }
                                other => panic!(
                                    "round {round}: overload produced {other:?} ({e:#}), \
                                     only Shed is a legal refusal here"
                                ),
                            },
                        }
                    }
                });
            }
        });
        total_subs += (threads * per_thread) as u64;
        let s = server.stats(&key).unwrap();
        assert_eq!(
            s.requests + s.sheds,
            total_subs,
            "terminal-outcome identity broken: a request was lost or double-counted"
        );
        assert_eq!((s.timeouts, s.failures), (0, 0), "no deadlines or faults in this test");
        if s.sheds > 0 {
            return; // overload observed and accounted for — done
        }
    }
    panic!("8 threads against queue_depth=2 never shed in 20 rounds — admission control dead?");
}

#[test]
fn manual_rollback_quarantines_and_reroutes_to_last_good() {
    // v1 -> v2 swap, manual rollback to v1: health_by_version shows v2
    // quarantined, traffic resumes on v1 bit-exactly, the per-version
    // stats partition stays exact, and a reinstall of v2's number is
    // refused while v3 is accepted.
    let mut rng = Rng::new(0xF3);
    let (man, ck1) = models::lenet5ish(&mut rng, 2);
    let (_, ck2) = models::lenet5ish(&mut rng, 2);
    let (_, ck3) = models::lenet5ish(&mut rng, 2);
    let model1 = IntModel::build(&man, &ck1).unwrap();
    let model2 = IntModel::build(&man, &ck2).unwrap();
    let model3 = IntModel::build(&man, &ck3).unwrap();
    let solo1 = IntModel::build(&man, &ck1).unwrap();
    let solo3 = IntModel::build(&man, &ck3).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(4);
    let key = reg.add("lenet5", ModelSource::InCode(&model1), &opts).unwrap();
    let server = Server::new(reg, ServeConfig::new().workers(2));

    let img = request_image(elems, 0, 0);
    let (_, served) = server.infer_versioned(&key, &img).unwrap();
    assert_eq!(served, 1);

    server.swap(&key, ModelSource::InCode(&model2), &opts).unwrap();
    assert_eq!(server.current_version(&key).unwrap(), 2);
    let (_, served) = server.infer_versioned(&key, &img).unwrap();
    assert_eq!(served, 2);

    // operator decides v2 is bad: roll back to last-good
    let now_serving = server.rollback(&key).unwrap();
    assert_eq!(now_serving, 1, "rollback must land on the newest non-quarantined version");
    assert_eq!(server.current_version(&key).unwrap(), 1);
    assert_eq!(
        server.health_by_version(&key).unwrap(),
        vec![(1, Health::Ready), (2, Health::Quarantined)]
    );

    // traffic resumes on v1, bit-identical to the v1 solo oracle
    for i in 0..5 {
        let image = request_image(elems, 1, i);
        let (got, served) = server.infer_versioned(&key, &image).unwrap();
        let (want, _) = solo1.forward(&image, 1).unwrap();
        assert_eq!(served, 1, "request {i} served by the wrong version after rollback");
        assert_eq!(got, want, "request {i} diverged from the v1 oracle after rollback");
    }

    // v2's number is burned: reinstalling it is refused, v3 is accepted
    let pin2 = RegisterOpts::new().max_batch(4).version(2);
    assert!(
        server.swap(&key, ModelSource::InCode(&model2), &pin2).is_err(),
        "a rolled-back version number must not be reinstallable"
    );
    server.swap(&key, ModelSource::InCode(&model3), &opts).unwrap();
    assert_eq!(server.current_version(&key).unwrap(), 3);
    let image = request_image(elems, 2, 0);
    let (got, served) = server.infer_versioned(&key, &image).unwrap();
    let (want, _) = solo3.forward(&image, 1).unwrap();
    assert_eq!((served, got), (3, want), "post-rollback swap must serve the new version");

    // exact per-version partition: 2 on v1 + 5 post-rollback, 1 on v2, 1 on v3
    let by_v = server.stats_by_version(&key).unwrap();
    let reqs: Vec<(u32, u64)> = by_v.iter().map(|(v, s)| (*v, s.requests)).collect();
    assert_eq!(reqs, vec![(1, 6), (2, 1), (3, 1)]);
    let total = server.stats(&key).unwrap();
    assert_eq!(total.requests, 8);
    assert_eq!((total.sheds, total.timeouts, total.failures), (0, 0, 0));
}
