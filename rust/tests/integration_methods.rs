//! Integration: comparator methods (BC / TWN / BR) train through the same
//! coordinator, and failure modes are rejected cleanly.

use std::path::{Path, PathBuf};

use symog::coordinator::{Checkpoint, Trainer, TrainOptions};
use symog::data::Preset;
use symog::runtime::Runtime;

fn artifact_dir(tag: &str) -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(tag);
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn comparator_methods_learn() {
    let rt = Runtime::cpu().unwrap();
    let (train, test) = Preset::SynthMnist.load(768, 128, 21);
    for method in ["bc", "twn", "br"] {
        let tag = format!("lenet5-{method}-synth-mnist-w1-b2");
        let Some(dir) = artifact_dir(&tag) else {
            eprintln!("skipping {tag}: not built");
            continue;
        };
        let art = rt.load_artifact(&dir).unwrap();
        assert_eq!(art.manifest.method, method);
        let mut trainer = Trainer::from_init(&art).unwrap();
        let mut opts = TrainOptions::paper(3);
        opts.seed = 21;
        opts.steps_per_epoch = Some(8);
        // BR reuses lambda as its relaxation coefficient; BC/TWN ignore it
        let outcome = trainer.train(&train, &test, &opts).unwrap();
        let logs = &outcome.log.epochs;
        assert!(
            logs.last().unwrap().train_loss < logs[0].train_loss,
            "{method}: loss {} -> {}",
            logs[0].train_loss,
            logs.last().unwrap().train_loss
        );
    }
}

#[test]
fn bits_ablation_artifacts_share_interface() {
    // the N-bit ablation artifacts must drive through the same coordinator
    let rt = Runtime::cpu().unwrap();
    let (train, test) = Preset::SynthMnist.load(512, 128, 5);
    for bits in [3u32, 4, 8] {
        let tag = format!("lenet5-symog-synth-mnist-w1-b{bits}");
        let Some(dir) = artifact_dir(&tag) else {
            eprintln!("skipping {tag}");
            continue;
        };
        let art = rt.load_artifact(&dir).unwrap();
        assert_eq!(art.manifest.n_bits, bits);
        let mut trainer = Trainer::from_init(&art).unwrap();
        let mut opts = TrainOptions::paper(1);
        opts.steps_per_epoch = Some(4);
        let outcome = trainer.train(&train, &test, &opts).unwrap();
        assert!(outcome.log.epochs[0].testq_acc > 0.05);
        // weights clipped to the wider N-bit domain
        let bound_factor = ((1i32 << (bits - 1)) - 1) as f32;
        for (w, d) in trainer.quant_layers_host().unwrap() {
            for x in w {
                assert!(x.abs() <= d * bound_factor + 1e-5);
            }
        }
    }
}

#[test]
fn checkpoint_shape_mismatch_rejected() {
    let Some(dir) = artifact_dir("smoke") else {
        eprintln!("skipping");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let mut ck = Checkpoint::read(&art.init_ckpt()).unwrap();
    // corrupt the first weight tensor's shape
    ck.tensors[0].dims = vec![1, 2];
    ck.tensors[0].data = vec![0.0; 2];
    let err = Trainer::from_checkpoint(&art, &ck, true);
    assert!(err.is_err(), "shape mismatch must be rejected");
}

#[test]
fn missing_tensor_rejected() {
    let Some(dir) = artifact_dir("smoke") else {
        eprintln!("skipping");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let mut ck = Checkpoint::read(&art.init_ckpt()).unwrap();
    ck.tensors.remove(0);
    assert!(Trainer::from_checkpoint(&art, &ck, true).is_err());
}

#[test]
fn truncated_checkpoint_rejected() {
    let Some(dir) = artifact_dir("smoke") else {
        eprintln!("skipping");
        return;
    };
    let src = std::fs::read(dir.join("init.ckpt")).unwrap();
    let tmp = std::env::temp_dir().join("symog_truncated.ckpt");
    std::fs::write(&tmp, &src[..src.len() / 2]).unwrap();
    assert!(Checkpoint::read(&tmp).is_err());
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn eval_smaller_than_batch_rejected() {
    let Some(dir) = artifact_dir("smoke") else {
        eprintln!("skipping");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let trainer = Trainer::from_init(&art).unwrap();
    let (_, mut test) = Preset::SynthMnist.load(64, 32, 0);
    let tiny = test.split_off(8); // 8 < batch(16)
    assert!(trainer.evaluate(&tiny, false).is_err());
}

#[test]
fn noclip_artifact_lets_weights_escape() {
    // the Fig-4 ablation artifact really does skip clipping
    let Some(dir) = artifact_dir("lenet5-symog-synth-mnist-w1-b2-noclip") else {
        eprintln!("skipping");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    assert!(!art.manifest.clip);
    let (train, test) = Preset::SynthMnist.load(512, 128, 9);
    let mut trainer = Trainer::from_init(&art).unwrap();
    let mut opts = TrainOptions::paper(2);
    opts.seed = 9;
    trainer.train(&train, &test, &opts).unwrap();
    let escaped = trainer
        .quant_layers_host()
        .unwrap()
        .iter()
        .any(|(w, d)| w.iter().any(|x| x.abs() > d * 1.0 + 1e-5));
    assert!(escaped, "without clipping some weight should leave ±Δ");
}
