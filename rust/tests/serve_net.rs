//! Network serving suite: the TCP front-end must be a *pure transport*
//! over the in-process serving API. Concretely:
//!
//! (a) logits served over TCP to concurrent clients are bit-identical to
//!     solo planned forwards (the same oracle `tests/serve_concurrency.rs`
//!     pins for in-process threads);
//! (b) typed failure domains cross the wire: sheds and expired deadlines
//!     arrive as their pinned error codes, and the Stats frame's
//!     terminal-outcome counters sum exactly to submissions;
//! (c) the latency histogram's sample count equals the requests that were
//!     actually enqueued (`requests + timeouts + failures`), with
//!     p50 ≤ p99 ≤ max;
//! (d) control frames work end to end: Health, Stats, version pins, and
//!     a hot-swap to a published `.fxpa` artifact over the wire;
//! (e) garbage on the socket is answered with a typed Malformed error and
//!     a closed connection — never a crash, never a guessed frame.

use std::net::TcpStream;
use std::sync::Arc;

use symog::artifact::{self, PublishOpts};
use symog::inference::IntModel;
use symog::serve::net::proto::{self, ErrCode, Frame, ProtoError};
use symog::serve::net::{Client, TcpFront, WireFail};
use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::util::rng::Rng;

const M: usize = 6; // concurrent TCP clients
const K: usize = 12; // requests per client

/// Deterministic request image for (thread, index).
fn request_image(elems: usize, t: usize, i: usize) -> Vec<f32> {
    let mut rng = Rng::new(0x7E57 ^ ((t * K + i) as u64).wrapping_mul(0xA5A5A5A5A5A5));
    (0..elems).map(|_| rng.normal()).collect()
}

#[test]
fn tcp_responses_bit_identical_across_concurrent_clients() {
    let mut rng = Rng::new(0xBEEF);
    let (man_a, ck_a) = models::lenet5ish(&mut rng, 2);
    let (man_b, ck_b) = models::densenetish(&mut rng, 4);
    let model_a = IntModel::build(&man_a, &ck_a).unwrap();
    let model_b = IntModel::build(&man_b, &ck_b).unwrap();
    let solo_a = IntModel::build(&man_a, &ck_a).unwrap();
    let solo_b = IntModel::build(&man_b, &ck_b).unwrap();
    let elems_a: usize = man_a.input_shape.iter().product();
    let elems_b: usize = man_b.input_shape.iter().product();

    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(4);
    let key_a = reg.add("lenet5", ModelSource::InCode(&model_a), &opts).unwrap();
    let key_b = reg.add("densenet", ModelSource::InCode(&model_b), &opts).unwrap();
    let server = Arc::new(Server::new(reg, ServeConfig::new().workers(2)));
    let front = TcpFront::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
    let addr = front.local_addr();

    // single-threaded oracle; clients alternate models so multi-model
    // micro-batching happens *under* network concurrency
    struct Case {
        name: &'static str,
        n_bits: u32,
        image: Vec<f32>,
        want: Vec<f32>,
    }
    let corpus: Vec<Vec<Case>> = (0..M)
        .map(|t| {
            (0..K)
                .map(|i| {
                    let to_a = (t + i) % 2 == 0;
                    let (name, n_bits, solo, elems) = if to_a {
                        ("lenet5", key_a.n_bits, &solo_a, elems_a)
                    } else {
                        ("densenet", key_b.n_bits, &solo_b, elems_b)
                    };
                    let image = request_image(elems, t, i);
                    let (want, _) = solo.forward(&image, 1).unwrap();
                    Case { name, n_bits, image, want }
                })
                .collect()
        })
        .collect();

    std::thread::scope(|sc| {
        for cases in &corpus {
            sc.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for (i, case) in cases.iter().enumerate() {
                    let reply = client.infer(case.name, case.n_bits, &case.image).unwrap();
                    // bit-identity: exact equality on the f32 bit patterns
                    assert_eq!(
                        reply.logits, case.want,
                        "request {i} for {} diverged from the solo oracle",
                        case.name
                    );
                    assert_eq!(reply.version, 1, "nothing swapped, so v1 must serve");
                }
            });
        }
    });

    // exact accounting per slot, read over the wire like a client would
    let mut client = Client::connect(addr).unwrap();
    let mut total_requests = 0;
    for (name, n_bits) in [("lenet5", key_a.n_bits), ("densenet", key_b.n_bits)] {
        let s = client.stats(name, n_bits).unwrap();
        assert_eq!(s.version, 1);
        assert_eq!((s.sheds, s.timeouts, s.failures), (0, 0, 0), "{name}: clean run");
        assert_eq!(
            s.latency_count, s.requests,
            "{name}: every enqueued request must leave exactly one latency sample"
        );
        assert!(
            s.p50_us <= s.p99_us && s.p99_us <= s.max_us,
            "{name}: quantiles must be ordered, got p50 {} p99 {} max {}",
            s.p50_us,
            s.p99_us,
            s.max_us
        );
        total_requests += s.requests;
    }
    assert_eq!(total_requests, (M * K) as u64, "every submission must be billed exactly once");
    drop(client);
    front.shutdown();
}

#[test]
fn overload_sheds_cross_the_wire_with_exact_accounting() {
    let mut rng = Rng::new(0x51ED);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let key = reg
        .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(2))
        .unwrap();
    let depth = 2usize;
    let server =
        Arc::new(Server::new(reg, ServeConfig::new().workers(2).queue_depth(depth)));
    let front = TcpFront::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
    let addr = front.local_addr();

    let threads = 8usize;
    let per_thread = 25usize;
    let mut total_subs = 0u64;
    let mut total_sheds = 0u64;
    // storm rounds until admission control visibly refuses something —
    // scheduling decides when the queue actually fills
    for round in 0..20 {
        let round_sheds: u64 = std::thread::scope(|sc| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    sc.spawn(move || {
                        let mut client = Client::connect(addr).unwrap();
                        let mut sheds = 0u64;
                        for i in 0..per_thread {
                            let image = request_image(elems, t, i);
                            match client.infer("lenet5", 2, &image) {
                                Ok(_) => {}
                                Err(e) => {
                                    let wf = e
                                        .downcast_ref::<WireFail>()
                                        .expect("refusals must be typed WireFail");
                                    assert_eq!(
                                        wf.code,
                                        ErrCode::Shed,
                                        "only sheds are legal here: {wf}"
                                    );
                                    sheds += 1;
                                }
                            }
                        }
                        sheds
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        total_subs += (threads * per_thread) as u64;
        total_sheds += round_sheds;
        if total_sheds > 0 {
            break;
        }
        assert!(round < 19, "20 storm rounds never filled a depth-{depth} queue");
    }
    assert!(total_sheds > 0);

    let mut client = Client::connect(addr).unwrap();
    let s = client.stats("lenet5", key.n_bits).unwrap();
    assert_eq!(
        s.requests + s.sheds,
        total_subs,
        "every submission must be exactly one terminal outcome"
    );
    assert_eq!(s.sheds, total_sheds, "client-observed sheds must match the server's count");
    assert_eq!(
        s.latency_count, s.requests,
        "sheds never enqueue, so they must not leave latency samples"
    );
    drop(client);
    front.shutdown();
}

#[test]
fn deadline_expiry_crosses_the_wire_and_is_billed_exactly() {
    // a wider model makes batches slow enough that a 1ms relative
    // deadline expires in the queue under an 8-client storm
    let mut rng = Rng::new(0xDEAD);
    let (man, ck) = models::vgg7ish(&mut rng, 2, 8);
    let model = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let key = reg
        .add("vgg7", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(2))
        .unwrap();
    let server = Arc::new(Server::new(reg, ServeConfig::new().workers(1)));
    let front = TcpFront::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
    let addr = front.local_addr();

    let threads = 8usize;
    let per_thread = 6usize;
    let mut total_subs = 0u64;
    let mut total_timeouts = 0u64;
    for round in 0..20 {
        let round_timeouts: u64 = std::thread::scope(|sc| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    sc.spawn(move || {
                        let mut client = Client::connect(addr).unwrap();
                        let mut timeouts = 0u64;
                        for i in 0..per_thread {
                            let image = request_image(elems, t, i);
                            match client.infer_with("vgg7", 2, &image, 1, 0) {
                                Ok(_) => {}
                                Err(e) => {
                                    let wf = e
                                        .downcast_ref::<WireFail>()
                                        .expect("refusals must be typed WireFail");
                                    assert_eq!(
                                        wf.code,
                                        ErrCode::DeadlineExceeded,
                                        "only deadline sweeps are legal here: {wf}"
                                    );
                                    timeouts += 1;
                                }
                            }
                        }
                        timeouts
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        total_subs += (threads * per_thread) as u64;
        total_timeouts += round_timeouts;
        if total_timeouts > 0 {
            break;
        }
        assert!(round < 19, "20 storm rounds never expired a 1ms deadline");
    }

    let mut client = Client::connect(addr).unwrap();
    let s = client.stats("vgg7", key.n_bits).unwrap();
    assert_eq!(s.requests + s.timeouts, total_subs);
    assert_eq!(s.timeouts, total_timeouts);
    // swept requests *were* enqueued, so they leave latency samples too
    assert_eq!(
        s.latency_count,
        s.requests + s.timeouts,
        "histogram samples must equal requests + timeouts"
    );
    drop(client);
    front.shutdown();
}

#[test]
fn control_frames_pins_and_artifact_swap_work_over_the_wire() {
    let mut rng = Rng::new(0x5A9F);
    let (man1, ck1) = models::lenet5ish(&mut rng, 2);
    let (man2, ck2) = models::lenet5ish(&mut rng, 2);
    let model1 = IntModel::build(&man1, &ck1).unwrap();
    let solo2 = IntModel::build(&man2, &ck2).unwrap();
    let elems: usize = man1.input_shape.iter().product();
    let path = std::env::temp_dir().join(format!("symog-{}-serve-net.fxpa", std::process::id()));
    artifact::publish(&man2, &ck2, &PublishOpts::new().version(2), &path).unwrap();

    let mut reg = Registry::new();
    let key = reg
        .add("lenet5", ModelSource::InCode(&model1), &RegisterOpts::new().max_batch(4))
        .unwrap();
    let server = Arc::new(Server::new(reg, ServeConfig::new().workers(2)));
    let front = TcpFront::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(front.local_addr()).unwrap();

    // health + a pinned request on the initial version
    assert_eq!(client.health("lenet5", key.n_bits).unwrap(), (0, 1));
    let image = request_image(elems, 0, 0);
    let reply = client.infer_with("lenet5", 2, &image, 0, 1).unwrap();
    assert_eq!(reply.version, 1);

    // swap refusals are typed
    let err = client.swap("nope", 2, 4, 0, path.to_str().unwrap()).unwrap_err();
    assert_eq!(err.downcast_ref::<WireFail>().unwrap().code, ErrCode::UnknownModel);
    let err = client.swap("lenet5", 2, 4, 0, "/nonexistent/v9.fxpa").unwrap_err();
    assert_eq!(err.downcast_ref::<WireFail>().unwrap().code, ErrCode::Internal);
    assert_eq!(
        client.health("lenet5", key.n_bits).unwrap(),
        (0, 1),
        "a refused swap must leave v1 serving"
    );

    // the real swap: v2 installs from the artifact and serves bit-exactly
    let installed = client.swap("lenet5", 2, 4, 0, path.to_str().unwrap()).unwrap();
    assert_eq!(installed, 2);
    let (want, _) = solo2.forward(&image, 1).unwrap();
    let reply = client.infer("lenet5", 2, &image).unwrap();
    assert_eq!(reply.version, 2);
    assert_eq!(reply.logits, want, "post-swap serving must match the v2 solo oracle");

    // a stale pin is refused; the current pin is honored
    let err = client.infer_with("lenet5", 2, &image, 0, 1).unwrap_err();
    assert_eq!(err.downcast_ref::<WireFail>().unwrap().code, ErrCode::PinMismatch);
    assert_eq!(client.infer_with("lenet5", 2, &image, 0, 2).unwrap().version, 2);

    let _ = std::fs::remove_file(&path);
    drop(client);
    front.shutdown();
}

#[test]
fn malformed_and_bad_requests_get_typed_refusals_not_crashes() {
    let mut rng = Rng::new(0xFA11);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    reg.add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(2)).unwrap();
    let server = Arc::new(Server::new(reg, ServeConfig::new().workers(1)));
    let front = TcpFront::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
    let addr = front.local_addr();

    // wrong image geometry is an in-band BadRequest; the connection
    // stays usable afterwards
    let mut client = Client::connect(addr).unwrap();
    let err = client.infer("lenet5", 2, &[1.0; 3]).unwrap_err();
    assert_eq!(err.downcast_ref::<WireFail>().unwrap().code, ErrCode::BadRequest);
    // unknown model likewise leaves the connection alive
    let err = client.infer("mystery", 2, &request_image(elems, 0, 0)).unwrap_err();
    assert_eq!(err.downcast_ref::<WireFail>().unwrap().code, ErrCode::UnknownModel);
    client.infer("lenet5", 2, &request_image(elems, 0, 1)).unwrap();
    drop(client);

    // an unknown opcode is answered with Malformed, then the server
    // closes — framing can no longer be trusted
    {
        use std::io::Write;
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(&1u32.to_le_bytes()).unwrap();
        raw.write_all(&[0x42]).unwrap();
        raw.flush().unwrap();
        let reply = proto::read_frame(&mut raw).unwrap();
        match reply {
            Frame::Error { code, .. } => assert_eq!(code, ErrCode::Malformed),
            other => panic!("expected a Malformed error frame, got {other:?}"),
        }
        assert!(
            matches!(proto::read_frame(&mut raw), Err(ProtoError::Eof)),
            "the server must close after a malformed frame"
        );
    }

    // an absurd length prefix dies at the framing layer the same way
    {
        use std::io::Write;
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(&u32::MAX.to_le_bytes()).unwrap();
        raw.flush().unwrap();
        let reply = proto::read_frame(&mut raw).unwrap();
        match reply {
            Frame::Error { code, .. } => assert_eq!(code, ErrCode::Malformed),
            other => panic!("expected a Malformed error frame, got {other:?}"),
        }
        assert!(matches!(proto::read_frame(&mut raw), Err(ProtoError::Eof)));
    }

    front.shutdown();
}
