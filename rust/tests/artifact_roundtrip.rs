//! `.fxpa` round-trip suite: publish → load → plan must be *bit-identical*
//! to the source model, and every corruption mode must be rejected with a
//! distinct, path-qualified error.
//!
//! Why bit-identity is achievable (and therefore demanded): artifacts
//! store i8 mantissas plus per-tensor power-of-two exponents, the loader
//! reconstructs `m · 2^-frac` exactly in f32, and `IntModel::build`'s
//! `QWeight::encode` re-derives the same mantissas from those codebook
//! values — so no quantization state is re-solved and no rounding can
//! drift. OpCounts are part of the contract too: a published model must
//! cost exactly what the in-code model costs.

use std::path::PathBuf;

use symog::artifact::{self, PublishOpts};
use symog::coordinator::Checkpoint;
use symog::inference::IntModel;
use symog::runtime::Manifest;
use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::train::NativeModel;
use symog::util::rng::Rng;

fn zoo(rng: &mut Rng, n_bits: u32) -> Vec<(&'static str, (Manifest, Checkpoint))> {
    vec![
        ("lenet5ish", models::lenet5ish(rng, n_bits)),
        ("densenetish", models::densenetish(rng, n_bits)),
        ("vgg7ish", models::vgg7ish(rng, n_bits, 4)),
        ("oddball", models::oddball(rng, n_bits)),
    ]
}

/// Per-test scratch path under the system temp dir (unique per process,
/// removed by each test on success).
fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("symog-{}-{name}.fxpa", std::process::id()))
}

#[test]
fn publish_load_plan_is_bit_identical_across_the_zoo() {
    for n_bits in [2u32, 4, 8] {
        let mut rng = Rng::new(0xA47F ^ ((n_bits as u64) << 20));
        for (name, (man, ck)) in zoo(&mut rng, n_bits) {
            let source = IntModel::build(&man, &ck).unwrap();
            let path = tmp_path(&format!("rt-{name}-{n_bits}"));
            let info = artifact::publish(&man, &ck, &PublishOpts::new().version(3), &path)
                .unwrap_or_else(|e| panic!("{name} w{n_bits}: publish failed: {e:#}"));
            assert_eq!(info.version, 3);
            assert!(info.quant_tensors > 0);
            assert_eq!(artifact::peek_version(&path).unwrap(), 3);

            let loaded = artifact::load(&path)
                .unwrap_or_else(|e| panic!("{name} w{n_bits}: load failed: {e:#}"));
            assert_eq!(loaded.version, 3);
            assert_eq!(loaded.manifest.n_bits, n_bits);
            assert_eq!(loaded.model.n_bits, n_bits);

            // logits bit-identical, request by request
            let e: usize = man.input_shape.iter().product();
            for i in 0..4u32 {
                let img: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
                let (want, _) = source.forward(&img, 1).unwrap();
                let (got, _) = loaded.model.forward(&img, 1).unwrap();
                assert_eq!(got, want, "{name} w{n_bits} request {i}: loaded model diverged");
            }
            // and the analytic cost is identical: same plan, same ops
            let want_counts = source.cost_report(1).unwrap().counts;
            let got_counts = loaded.model.cost_report(1).unwrap().counts;
            assert_eq!(got_counts, want_counts, "{name} w{n_bits}: OpCounts diverged");
            // plan() compiles from the loaded quantization state directly
            let plan = loaded.plan(2).unwrap();
            assert_eq!(plan.in_elems(), e);

            // the atomic publish leaves no tmp sibling behind
            assert!(!path.with_extension("fxpa.tmp").exists(), "tmp file leaked");
            std::fs::remove_file(&path).unwrap();
        }
    }
}

#[test]
fn native_model_publishes_and_round_trips() {
    // train::model export path: manifest derived from the graph, weights
    // snapshotted; the oracle is the IntModel built from the same pair
    let m = NativeModel::convnet([8, 8, 1], &[4, 8], 10, 42);
    let deltas = vec![0.25f32; m.n_quant];
    let path = tmp_path("native");
    let info = artifact::publish_native(&m, &deltas, 4, &PublishOpts::new(), &path).unwrap();
    assert_eq!(info.version, 1);

    let man = m.to_manifest(4);
    let ck = m.to_checkpoint(&deltas, 0, "symog");
    let oracle = IntModel::build(&man, &ck).unwrap();
    let loaded = artifact::load(&path).unwrap();
    let e: usize = man.input_shape.iter().product();
    let mut rng = Rng::new(7);
    for _ in 0..3 {
        let img: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
        let (want, _) = oracle.forward(&img, 1).unwrap();
        let (got, _) = loaded.model.forward(&img, 1).unwrap();
        assert_eq!(got, want, "native publish → load diverged from in-code build");
    }
    // deltas length must match the graph
    assert!(artifact::publish_native(&m, &deltas[..1], 4, &PublishOpts::new(), &path).is_err());
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn registry_and_server_accept_artifact_sources() {
    let mut rng = Rng::new(0x0A11);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let solo = IntModel::build(&man, &ck).unwrap();
    let path = tmp_path("reg");
    artifact::publish(&man, &ck, &PublishOpts::new().version(5), &path).unwrap();

    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(4);
    let key = reg.add("lenet5", ModelSource::Artifact(&path), &opts).unwrap();
    // the artifact's own model version is authoritative
    assert_eq!(key.version, 5);
    assert_eq!(format!("{key}"), "lenet5@w2#v5");
    // a disagreeing pin is a registration error, an agreeing one is fine
    let mut reg2 = Registry::new();
    let bad_pin = RegisterOpts::new().max_batch(4).version(6);
    assert!(reg2.add("lenet5", ModelSource::Artifact(&path), &bad_pin).is_err());
    let good_pin = RegisterOpts::new().max_batch(4).version(5);
    reg2.add("lenet5", ModelSource::Artifact(&path), &good_pin).unwrap();

    let server = Server::new(reg, ServeConfig::new().workers(2));
    let e: usize = man.input_shape.iter().product();
    for _ in 0..3 {
        let img: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
        let (got, v) = server.infer_versioned(&key, &img).unwrap();
        let (want, _) = solo.forward(&img, 1).unwrap();
        assert_eq!(got, want, "artifact-served logits diverged from the in-code model");
        assert_eq!(v, 5);
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn corruption_and_version_skew_are_distinct_errors() {
    let mut rng = Rng::new(0xDEAD);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let path = tmp_path("corrupt");
    artifact::publish(&man, &ck, &PublishOpts::new(), &path).unwrap();
    let good = std::fs::read(&path).unwrap();
    let emsg = |bytes: &[u8]| {
        std::fs::write(&path, bytes).unwrap();
        format!("{:#}", artifact::load(&path).unwrap_err())
    };

    // header-truncated file
    let e = emsg(&good[..10]);
    assert!(e.contains("smaller than the 28-byte header"), "{e}");

    // payload-truncated file
    let e = emsg(&good[..good.len() - 5]);
    assert!(e.contains("truncated payload"), "{e}");

    // trailing garbage
    let mut long = good.clone();
    long.extend_from_slice(b"junk");
    let e = emsg(&long);
    assert!(e.contains("trailing garbage"), "{e}");

    // flipped payload byte → checksum, not a decode error deeper in
    let mut flipped = good.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0xFF;
    let e = emsg(&flipped);
    assert!(e.contains("checksum mismatch"), "{e}");

    // newer format version → explicit forward-incompatibility
    let mut newer = good.clone();
    newer[8..12].copy_from_slice(&2u32.to_le_bytes());
    let e = emsg(&newer);
    assert!(e.contains("not forward-compatible"), "{e}");

    // a .fxpm magic gets a redirecting hint, garbage magic does not
    let mut fxpm = good.clone();
    fxpm[..8].copy_from_slice(b"SYMGFXP1");
    let e = emsg(&fxpm);
    assert!(e.contains(".fxpm packed model"), "{e}");
    let mut garbage = good.clone();
    garbage[..8].copy_from_slice(b"NOTMAGIC");
    let e = emsg(&garbage);
    assert!(e.contains("bad magic"), "{e}");

    // all errors name the offending file
    assert!(e.contains(path.file_name().unwrap().to_str().unwrap()), "{e}");

    // version 0 is unpublishable (v0 is the "never installed" sentinel)
    std::fs::write(&path, &good).unwrap();
    assert!(artifact::publish(&man, &ck, &PublishOpts::new().version(0), &path).is_err());
    // and the failed publish did not clobber the good artifact
    assert_eq!(artifact::load(&path).unwrap().version, 1);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn read_packed_errors_are_path_qualified_and_distinct() {
    // the satellite bugfix on the legacy .fxpm reader: magic / truncation
    // mismatches must name the file and the failing section
    use symog::quant::packed::{read_packed, write_packed};
    let mut rng = Rng::new(0xFACE);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let path = std::env::temp_dir().join(format!("symog-{}-legacy.fxpm", std::process::id()));
    write_packed(&man, &man.to_json(), &ck, &path).unwrap();
    read_packed(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    let emsg = |bytes: &[u8]| {
        std::fs::write(&path, bytes).unwrap();
        format!("{:#}", read_packed(&path).unwrap_err())
    };

    let e = emsg(&good[..4]);
    assert!(e.contains("truncated before the 8-byte magic") && e.contains("legacy.fxpm"), "{e}");
    let e = emsg(&good[..good.len() - 3]);
    assert!(e.contains("truncated reading") && e.contains("legacy.fxpm"), "{e}");
    let mut fxpa = good.clone();
    fxpa[..8].copy_from_slice(b"SYMOGFXA");
    let e = emsg(&fxpa);
    assert!(e.contains(".fxpa serving artifact"), "{e}");
    let mut vers = good.clone();
    vers[7] = b'9';
    let e = emsg(&vers);
    assert!(e.contains("unsupported .fxpm format version"), "{e}");
    let mut garbage = good.clone();
    garbage[..8].copy_from_slice(b"NOTMAGIC");
    let e = emsg(&garbage);
    assert!(e.contains("not a .fxpm file"), "{e}");
    std::fs::remove_file(&path).unwrap();
}
