//! End-to-end SYMOG training smoke on the pure-Rust backend — no XLA
//! artifact anywhere on disk (this is the CI `train-smoke` gate).
//!
//! A tiny MLP trains on synth-mnist through the full Algorithm 1 loop
//! (paper schedules: linear lr ramp, exponential lambda) and must show the
//! paper's three signatures:
//!   (a) the task is learned (train loss falls, mostly monotonically),
//!   (b) weight mass concentrates onto the quantization modes as lambda
//!       grows (Fig. 3's mixture collapse),
//!   (c) hard-quantized eval agrees with soft eval at the end (Table 1's
//!       "quantization for free" claim).

use symog::coordinator::{TrainBackend, Trainer, TrainOptions};
use symog::data::Preset;
use symog::train::{mean_mode_mass, NativeBackend, NativeHyper, NativeModel};

const EPOCHS: u32 = 8;

fn native_trainer(model_seed: u64) -> Trainer<NativeBackend> {
    let model = NativeModel::mlp([28, 28, 1], &[32], 10, model_seed);
    Trainer::new(NativeBackend::new(model, NativeHyper::default(), 32))
}

#[test]
fn native_symog_run_learns_and_quantizes() {
    let (train, test) = Preset::SynthMnist.load(512, 128, 42);
    let mut trainer = native_trainer(7);
    let n_bits = trainer.backend.n_bits();

    // deltas solved at init (Alg. 1 l.2-5): positive powers of two
    assert_eq!(trainer.deltas().len(), trainer.backend.n_quant());
    for &d in trainer.deltas() {
        assert!(d > 0.0);
        let f = d.log2();
        assert!((f - f.round()).abs() < 1e-6, "delta {d} not a power of two");
    }

    let init_mass = mean_mode_mass(&trainer.quant_layers_host().unwrap(), n_bits, 0.25);

    let mut opts = TrainOptions::paper(EPOCHS);
    opts.seed = 7;
    opts.track_modes = true;
    opts.hist_epochs = vec![0, EPOCHS];
    opts.hist_layers = vec![0];
    let outcome = trainer.train(&train, &test, &opts).unwrap();
    let logs = &outcome.log.epochs;
    assert_eq!(logs.len(), EPOCHS as usize);

    // (a) loss decreases, monotonically-ish: large net drop, few upticks
    let (first, last) = (logs[0].train_loss, logs.last().unwrap().train_loss);
    assert!(last < 0.5 * first, "train loss barely moved: {first} -> {last}");
    let upticks = logs
        .windows(2)
        .filter(|w| w[1].train_loss > w[0].train_loss)
        .count();
    assert!(upticks <= 2, "{upticks} loss upticks out of {}", logs.len() - 1);

    // (b) mass within delta/4 of the modes grows as lambda ramps (Fig. 3)
    let final_mass = mean_mode_mass(&trainer.quant_layers_host().unwrap(), n_bits, 0.25);
    assert!(
        final_mass > init_mass + 0.2 && final_mass > 0.8,
        "mode mass did not concentrate: {init_mass:.3} -> {final_mass:.3}"
    );

    // (c) hard-quantized eval tracks soft eval, both beating chance (0.1)
    let (_, soft_acc) = trainer.evaluate(&test, false).unwrap();
    let (_, hard_acc) = trainer.evaluate(&test, true).unwrap();
    assert!(soft_acc > 0.5, "soft accuracy {soft_acc}");
    assert!(hard_acc > 0.5, "hard-quantized accuracy {hard_acc}");
    assert!(
        (soft_acc - hard_acc).abs() <= 0.1,
        "soft {soft_acc} vs hard {hard_acc} disagree"
    );

    // weights respect the clipping domain (section 3.4)
    for (w, d) in &trainer.quant_layers_host().unwrap() {
        let bound = symog::fixedpoint::clip_bound(n_bits, *d);
        for &x in w {
            assert!(x.abs() <= bound + 1e-5, "weight {x} outside ±{bound}");
        }
    }

    // probes worked against host weights: baseline + one record per epoch
    let tracker = outcome.tracker.unwrap();
    assert_eq!(tracker.switch_rates.len(), EPOCHS as usize + 1);
    assert_eq!(outcome.histograms[0].1.hists.len(), 2); // epochs 0 and E
    // late epochs switch fewer modes than early ones (Fig. 4's trend)
    let early = tracker.switch_rates[1].iter().sum::<f32>();
    let late = tracker.switch_rates[EPOCHS as usize].iter().sum::<f32>();
    assert!(late <= early + 1e-6, "switch rate grew: {early} -> {late}");
}

#[test]
fn native_checkpoint_roundtrip_resumes_exactly() {
    let (train, test) = Preset::SynthMnist.load(256, 64, 3);
    let mut trainer = native_trainer(11);
    let mut opts = TrainOptions::paper(2);
    opts.seed = 11;
    opts.steps_per_epoch = Some(4);
    trainer.train(&train, &test, &opts).unwrap();

    let tmp = std::env::temp_dir().join("symog_native_roundtrip.ckpt");
    trainer.save(&tmp).unwrap();
    let ck = symog::coordinator::Checkpoint::read(&tmp).unwrap();
    assert_eq!(ck.meta_i64("epoch"), Some(2));
    assert_eq!(ck.meta_str("model"), Some("native-mlp"));

    let mut restored = native_trainer(999); // different init, then load
    restored.backend.load_checkpoint(&ck, false).unwrap();
    restored.epoch = ck.meta_i64("epoch").unwrap_or(0) as u32;
    assert_eq!(restored.deltas(), trainer.deltas());
    let (l1, a1) = trainer.evaluate(&test, true).unwrap();
    let (l2, a2) = restored.evaluate(&test, true).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn native_backend_without_regularizer_still_learns() {
    // lambda = Off degenerates to clipped Nesterov SGD and must still learn
    let (train, test) = Preset::SynthMnist.load(256, 64, 5);
    let mut trainer = native_trainer(13);
    let mut opts = TrainOptions::paper(3);
    opts.seed = 13;
    opts.lambda = symog::coordinator::LambdaSchedule::Off;
    let outcome = trainer.train(&train, &test, &opts).unwrap();
    let logs = &outcome.log.epochs;
    assert!(
        logs.last().unwrap().train_loss < logs[0].train_loss,
        "loss {} -> {}",
        logs[0].train_loss,
        logs.last().unwrap().train_loss
    );
    assert!(logs.last().unwrap().test_acc > 0.3);
}
