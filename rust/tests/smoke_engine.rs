//! CI smoke test: build a tiny `IntModel` from an in-code manifest +
//! checkpoint (no compiled artifacts needed), run a forward pass on a
//! synthetic batch, and assert the planned, interpreted-GEMM and naive
//! paths produce bit-identical logits and identical op counts.

use symog::coordinator::{Checkpoint, Kind, Tensor};
use symog::inference::{Backend, IntModel};
use symog::runtime::Manifest;
use symog::util::rng::Rng;

/// 8x8x2 input -> conv3x3 SAME (+bias) -> relu -> maxpool2 -> conv3x3 VALID
/// -> folded BN -> relu -> flatten -> dense 24x10 (+bias).
const MANIFEST: &str = r#"{
  "tag": "smoke-engine", "model": "smoke", "method": "symog",
  "dataset": "synth-mnist", "width_mult": 1.0, "batch": 8, "n_bits": 2,
  "momentum": 0.9, "weight_decay": 0.0, "clip": true,
  "input_shape": [8, 8, 2], "num_classes": 10, "n_quant": 3,
  "params": [
    {"name": "c1.w", "shape": [3, 3, 2, 4], "kind": "weight", "qidx": 0, "fan_in": 18},
    {"name": "c1.b", "shape": [4], "kind": "bias", "qidx": null, "fan_in": 0},
    {"name": "c2.w", "shape": [3, 3, 4, 6], "kind": "weight", "qidx": 1, "fan_in": 36},
    {"name": "bn.gamma", "shape": [6], "kind": "gamma", "qidx": null, "fan_in": 0},
    {"name": "bn.beta", "shape": [6], "kind": "beta", "qidx": null, "fan_in": 0},
    {"name": "fc.w", "shape": [24, 10], "kind": "weight", "qidx": 2, "fan_in": 24},
    {"name": "fc.b", "shape": [10], "kind": "bias", "qidx": null, "fan_in": 0}
  ],
  "state": [
    {"name": "bn.mean", "shape": [6], "init": 0.0},
    {"name": "bn.var", "shape": [6], "init": 1.0}
  ],
  "layers": [
    {"type": "conv", "w": 0, "b": 1, "stride": 1, "padding": "SAME"},
    {"type": "relu"},
    {"type": "maxpool", "k": 2, "stride": 2},
    {"type": "conv", "w": 2, "b": null, "stride": 1, "padding": "VALID"},
    {"type": "bn", "gamma": 3, "beta": 4, "mean": 0, "var": 1},
    {"type": "relu"},
    {"type": "flatten"},
    {"type": "dense", "w": 5, "b": 6}
  ]
}"#;

fn tensor(name: &str, kind: Kind, dims: &[usize], data: Vec<f32>) -> Tensor {
    Tensor { name: name.into(), kind, dims: dims.to_vec(), data }
}

/// Weights on the ternary codebook {-delta, 0, +delta}; aux params float.
fn smoke_checkpoint(rng: &mut Rng) -> Checkpoint {
    let delta = 0.5f32;
    let tern = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| (rng.below(3) as f32 - 1.0) * delta).collect()
    };
    let noise = |rng: &mut Rng, n: usize, s: f32| -> Vec<f32> {
        (0..n).map(|_| rng.normal() * s).collect()
    };
    let mut ck = Checkpoint::default();
    ck.tensors.push(tensor("c1.w", Kind::Weight, &[3, 3, 2, 4], tern(rng, 72)));
    ck.tensors.push(tensor("c1.b", Kind::Bias, &[4], noise(rng, 4, 0.1)));
    ck.tensors.push(tensor("c2.w", Kind::Weight, &[3, 3, 4, 6], tern(rng, 216)));
    let gamma: Vec<f32> = (0..6).map(|_| 1.0 + rng.normal() * 0.1).collect();
    ck.tensors.push(tensor("bn.gamma", Kind::Gamma, &[6], gamma));
    ck.tensors.push(tensor("bn.beta", Kind::Beta, &[6], noise(rng, 6, 0.1)));
    ck.tensors.push(tensor("fc.w", Kind::Weight, &[24, 10], tern(rng, 240)));
    ck.tensors.push(tensor("fc.b", Kind::Bias, &[10], noise(rng, 10, 0.1)));
    ck.tensors.push(tensor("bn.mean", Kind::State, &[6], noise(rng, 6, 0.2)));
    let var: Vec<f32> = (0..6).map(|_| 1.0 + rng.f32()).collect();
    ck.tensors.push(tensor("bn.var", Kind::State, &[6], var));
    ck.tensors.push(tensor("__deltas__", Kind::Deltas, &[3], vec![delta; 3]));
    ck
}

#[test]
fn planned_gemm_and_naive_paths_bit_identical() {
    let man = Manifest::parse(MANIFEST).unwrap();
    let mut rng = Rng::new(0xBEEF);
    let ck = smoke_checkpoint(&mut rng);

    let planned = IntModel::build(&man, &ck).unwrap();
    assert_eq!(planned.backend, Backend::Planned, "planned must be the default backend");
    assert!(planned.all_ternary, "2-bit smoke weights must be ternary");
    let gemm = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Gemm);
    let naive = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Naive);

    let batch = 8usize;
    let images: Vec<f32> = (0..batch * 8 * 8 * 2).map(|_| rng.normal()).collect();
    let (logits_p, counts_p) = planned.forward(&images, batch).unwrap();
    let (logits_g, counts_g) = gemm.forward(&images, batch).unwrap();
    let (logits_n, counts_n) = naive.forward(&images, batch).unwrap();

    assert_eq!(logits_g.len(), batch * 10);
    assert_eq!(logits_p, logits_n, "planned and naive logits must be bit-identical");
    assert_eq!(logits_g, logits_n, "GEMM and naive logits must be bit-identical");
    assert_eq!(counts_p, counts_n, "analytic op accounting must match the counted oracle");
    assert_eq!(counts_g, counts_n, "op accounting must not depend on the backend");
    // ternary conv/dense count zero multiplies; the only remaining ones
    // come from the folded-BN affine (one per activation: 8 x 2 x 2 x 6)
    assert_eq!(counts_g.int_mults, 8 * 2 * 2 * 6, "only folded BN may multiply");
    assert!(counts_g.acc_adds > 0);

    // predictions agree too (same logits => same argmax)
    let pp = planned.predict(&images, batch).unwrap();
    let pg = gemm.predict(&images, batch).unwrap();
    let pn = naive.predict(&images, batch).unwrap();
    assert_eq!(pp, pn);
    assert_eq!(pg, pn);
}

#[test]
fn smoke_model_cost_report_is_ternary_cheap() {
    let man = Manifest::parse(MANIFEST).unwrap();
    let mut rng = Rng::new(77);
    let ck = smoke_checkpoint(&mut rng);
    let model = IntModel::build(&man, &ck).unwrap();
    let report = model.cost_report(4).unwrap();
    // conv/dense are mult-free; only folded BN multiplies remain
    assert!(report.counts.int_mults < report.counts.acc_adds / 10);
    assert!(report.energy_ratio() > 18.5, "energy ratio {}", report.energy_ratio());
}
