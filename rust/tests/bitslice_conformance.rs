//! Bit-sliced popcount kernel conformance: with n_bits <= 3 every
//! conv/dense weight in the zoo is plane-eligible, so these forwards
//! execute on the AND/popcount kernel (or the ternary add/sub plan where
//! the analytic race prefers it) under whatever SIMD rung the host
//! dispatched to. The whole suite runs under each leg of CI's
//! `simd-matrix` job — AVX2, forced scalar (`SYMOG_SIMD=scalar`), and
//! aarch64 NEON — so bit-identity here proves every dispatch branch
//! against the interpreted oracle.

use symog::inference::{kernel_name, Backend, IntModel, QWeight};
use symog::kernels::bitslice::simd_level;
use symog::runtime::Manifest;
use symog::testing::models;
use symog::util::rng::Rng;

type ModelFn = fn(&mut Rng, u32) -> (Manifest, symog::coordinator::Checkpoint);

const ZOO: &[(&str, ModelFn)] = &[
    ("lenet5ish", models::lenet5ish as ModelFn),
    ("densenetish", models::densenetish as ModelFn),
    ("oddball", models::oddball as ModelFn),
];

fn input_elems(man: &Manifest) -> usize {
    man.input_shape.iter().product()
}

#[test]
fn zoo_logits_bit_identical_across_backends_for_low_bit_codes() {
    println!("dispatch level: {}", simd_level().name());
    for (name, build) in ZOO {
        for n_bits in [2u32, 3] {
            let mut rng = Rng::new(0xB17 ^ ((n_bits as u64) << 12));
            let (man, ck) = build(&mut rng, n_bits);
            let naive = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Naive);
            let planned = IntModel::build(&man, &ck).unwrap();
            let gemm = IntModel::build(&man, &ck).unwrap().with_backend(Backend::Gemm);

            let batch = 6usize;
            let e = input_elems(&man);
            let images: Vec<f32> = (0..batch * e).map(|_| rng.normal()).collect();
            let (logits_n, counts_n) = naive.forward(&images, batch).unwrap();

            let (logits_g, counts_g) = gemm.forward(&images, batch).unwrap();
            assert_eq!(logits_g, logits_n, "{name} n_bits={n_bits}: gemm logits diverged");
            assert_eq!(counts_g, counts_n, "{name} n_bits={n_bits}: gemm OpCounts diverged");

            for workers in [1usize, 2, 4] {
                let plan = planned.plan(batch).unwrap().with_workers(workers);
                let mut scratch = plan.scratch();
                let logits_p = plan.run(&images, batch, &mut scratch).unwrap();
                assert_eq!(
                    logits_p, logits_n,
                    "{name} n_bits={n_bits} workers={workers}: planned logits diverged"
                );
                assert_eq!(plan.op_counts(batch), counts_n, "{name} n_bits={n_bits}");
            }
        }
    }
}

#[test]
fn kernel_selection_engages_as_designed() {
    // uniform ternary (2-bit SYMOG, ~1/3 zeros) at a conv shape: the
    // add/sub walk loses the analytic race, popcount planes win
    let mut rng = Rng::new(0xE16);
    let (cin, cout) = (128usize, 128usize);
    let uniform: Vec<f32> = (0..3 * 3 * cin * cout)
        .map(|_| (rng.below(3) as f32 - 1.0) * 0.25)
        .collect();
    let qw = QWeight::encode(&uniform, [3, 3, cin, cout], 0.25, 2);
    assert_eq!(kernel_name(&qw, 3 * 3 * cin, cout), "bitslice");

    // sparse ternary (80% zero mode): the add/sub plan stays the winner
    let sparse: Vec<f32> = (0..512 * 10)
        .map(|_| match rng.below(10) {
            0 => 0.25,
            1 => -0.25,
            _ => 0.0,
        })
        .collect();
    let qw = QWeight::encode(&sparse, [512, 10, 1, 1], 0.25, 2);
    assert_eq!(kernel_name(&qw, 512, 10), "ternary");

    // 3-bit codes reach |m| = 3: not ternary, still plane-eligible
    let wide3: Vec<f32> = (0..256 * 32)
        .map(|_| (rng.below(7) as f32 - 3.0) * 0.25)
        .collect();
    let qw = QWeight::encode(&wide3, [256, 32, 1, 1], 0.25, 3);
    assert!(qw.mantissa.iter().any(|&m| m.abs() > 1));
    assert_eq!(kernel_name(&qw, 256, 32), "bitslice");

    // 8-bit codes overflow the decomposition: packed multiply kernel
    let wide8: Vec<f32> = (0..256 * 32).map(|_| rng.normal()).collect();
    let qw = QWeight::encode(&wide8, [256, 32, 1, 1], 0.03125, 8);
    assert!(qw.mantissa.iter().any(|&m| m.abs() > 3));
    assert_eq!(kernel_name(&qw, 256, 32), "packed");
}

#[test]
fn dispatch_honors_forced_scalar_override() {
    // under the simd-matrix forced-scalar leg this pins the whole
    // process to the oracle rung; on other hosts it just documents that
    // the decided rung is one the host can actually run
    match std::env::var("SYMOG_SIMD").as_deref() {
        Ok("scalar") => assert_eq!(simd_level().name(), "scalar"),
        _ => assert!(["scalar", "avx2", "neon"].contains(&simd_level().name())),
    }
}
