//! Chaos suite: the server under scripted fault schedules.
//!
//! Runs only with `--features fault-injection` (see `[[test]]` in
//! Cargo.toml): the suite arms `util::fault` sites — drain panics, drain
//! engine errors, artifact payload corruption, swap-probe failures — with
//! seeded probability streams, hammers the server through floods,
//! deadline storms, and bad deployments, and asserts the
//! **terminal-outcome invariant** end to end:
//!
//! * every submitted request resolves exactly once, with logits or with
//!   one typed [`ServeError`];
//! * per-version counters partition exactly —
//!   `requests + sheds + timeouts + failures` equals admitted
//!   submissions, with each component matching the client-observed
//!   outcome tallies;
//! * every *accepted* response is bit-identical to the solo planned
//!   oracle of the version that served it, no matter what was panicking,
//!   shedding, or timing out around it;
//! * a quarantined version rolls back to last-good and the slot resumes
//!   serving without a restart.
//!
//! Schedules are deterministic per `(site, prob, seed)`; CI replays the
//! suite under three pinned `SYMOG_CHAOS_SEED` values. The fault registry
//! is process-global, so every test serializes on one lock and disarms
//! all sites on entry and exit.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use symog::artifact::{self, PublishOpts};
use symog::inference::IntModel;
use symog::serve::{
    Health, InferOpts, ModelKey, ModelSource, RegisterOpts, Registry, ServeConfig, ServeError,
    Server,
};
use symog::testing::models;
use symog::util::fault;
use symog::util::rng::Rng;

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests and guarantee a clean registry on entry; the returned
/// guard disarms again on drop so a panicking test can't leak a schedule.
struct FaultGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for FaultGuard {
    fn drop(&mut self) {
        fault::disarm_all();
    }
}

fn fault_guard() -> FaultGuard {
    let g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::disarm_all();
    FaultGuard(g)
}

/// CI matrix knob: replay the whole suite under a different fault-stream
/// seed without recompiling.
fn chaos_seed() -> u64 {
    std::env::var("SYMOG_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// Deterministic request image for (thread, index).
fn request_image(elems: usize, t: usize, i: usize) -> Vec<f32> {
    let mut rng = Rng::new(0x51CA ^ ((t * 1000 + i) as u64).wrapping_mul(0x9E3779B97F4A7C15));
    (0..elems).map(|_| rng.normal()).collect()
}

struct Fixture {
    server: Server,
    key: ModelKey,
    solo: IntModel,
    elems: usize,
}

fn lenet_fixture(cfg: ServeConfig) -> Fixture {
    let mut rng = Rng::new(0xC4A0);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let solo = IntModel::build(&man, &ck).unwrap();
    let elems: usize = man.input_shape.iter().product();
    let mut reg = Registry::new();
    let key = reg
        .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(4))
        .unwrap();
    Fixture { server: Server::new(reg, cfg), key, solo, elems }
}

/// Client-observed outcome tallies, accumulated across hammer threads.
#[derive(Default)]
struct Outcomes {
    ok: AtomicU64,
    shed: AtomicU64,
    deadline: AtomicU64,
    batch_failed: AtomicU64,
    quarantined: AtomicU64,
}

impl Outcomes {
    fn record(&self, res: &anyhow::Result<(Vec<f32>, u32)>) {
        let c = match res {
            Ok(_) => &self.ok,
            Err(e) => match e.downcast_ref::<ServeError>() {
                Some(ServeError::Shed { .. }) => &self.shed,
                Some(ServeError::DeadlineExceeded) => &self.deadline,
                Some(ServeError::BatchPanicked(_)) => &self.batch_failed,
                Some(ServeError::VersionQuarantined(_)) => &self.quarantined,
                other => panic!("untyped serving failure {other:?}: {e:#}"),
            },
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    fn total(&self) -> u64 {
        self.ok.load(Ordering::Relaxed)
            + self.shed.load(Ordering::Relaxed)
            + self.deadline.load(Ordering::Relaxed)
            + self.batch_failed.load(Ordering::Relaxed)
            + self.quarantined.load(Ordering::Relaxed)
    }
}

/// Sum the failure-domain counters across every version of a slot and
/// assert they equal both the client-observed tallies and the submission
/// count — the terminal-outcome invariant, stated twice.
fn assert_exact_accounting(server: &Server, key: &ModelKey, out: &Outcomes, submissions: u64) {
    assert_eq!(out.total(), submissions, "a request vanished or resolved twice (client side)");
    let s = server.stats(key).unwrap();
    assert_eq!(s.requests, out.ok.load(Ordering::Relaxed), "requests != client-observed Oks");
    assert_eq!(s.sheds, out.shed.load(Ordering::Relaxed), "sheds != client-observed sheds");
    assert_eq!(
        s.timeouts,
        out.deadline.load(Ordering::Relaxed),
        "timeouts != client-observed deadline errors"
    );
    assert_eq!(
        s.failures,
        out.batch_failed.load(Ordering::Relaxed) + out.quarantined.load(Ordering::Relaxed),
        "failures != client-observed batch failures + quarantine refusals"
    );
    assert_eq!(
        s.requests + s.sheds + s.timeouts + s.failures,
        submissions,
        "counter identity broken: requests + sheds + timeouts + failures != submissions"
    );
}

#[test]
fn drain_panic_storm_resolves_every_request_exactly_once() {
    let _g = fault_guard();
    let seed = chaos_seed();
    // quarantine_after is set far above what a p=0.15 storm can reach in
    // a row, so this test isolates the panic-recovery path from rollback
    let f = lenet_fixture(ServeConfig::new().workers(2).quarantine_after(1_000_000));
    fault::arm(fault::SERVE_DRAIN_PANIC, 0.15, seed);
    fault::arm(fault::SERVE_DRAIN_FAIL, 0.10, seed ^ 0xDEAD);

    let threads = 6usize;
    let per_thread = 40usize;
    let out = Outcomes::default();
    std::thread::scope(|sc| {
        for t in 0..threads {
            let (server, key, solo, out) = (&f.server, &f.key, &f.solo, &out);
            sc.spawn(move || {
                for i in 0..per_thread {
                    let image = request_image(f.elems, t, i);
                    let res = server.infer_versioned(key, &image);
                    if let Ok((got, v)) = &res {
                        let (want, _) = solo.forward(&image, 1).unwrap();
                        assert_eq!(*v, 1);
                        assert_eq!(
                            got, &want,
                            "thread {t} request {i}: accepted logits diverged mid-storm"
                        );
                    }
                    out.record(&res);
                }
            });
        }
    });
    let (p_draws, p_fired) = fault::stats(fault::SERVE_DRAIN_PANIC);
    assert!(p_draws > 0, "storm never reached the drain site");
    assert!(
        p_fired > 0 || fault::stats(fault::SERVE_DRAIN_FAIL).1 > 0,
        "schedule (seed {seed}) never fired — the test proved nothing"
    );
    assert!(out.ok.load(Ordering::Relaxed) > 0, "nothing was served at p=0.15");
    assert_exact_accounting(&f.server, &f.key, &out, (threads * per_thread) as u64);
    // the slot survived the storm: disarm and serve cleanly
    fault::disarm_all();
    let image = request_image(f.elems, 99, 0);
    let (got, _) = f.server.infer_versioned(&f.key, &image).unwrap();
    let (want, _) = f.solo.forward(&image, 1).unwrap();
    assert_eq!(got, want, "slot did not recover after the storm");
}

#[test]
fn deadline_storm_sweeps_exactly_the_expired_requests() {
    let _g = fault_guard();
    let f = lenet_fixture(ServeConfig::new().workers(2));
    let threads = 4usize;
    let per_thread = 30usize;
    let out = Outcomes::default();
    std::thread::scope(|sc| {
        for t in 0..threads {
            let (server, key, solo, out) = (&f.server, &f.key, &f.solo, &out);
            sc.spawn(move || {
                for i in 0..per_thread {
                    let image = request_image(f.elems, t, i);
                    // every third request is born expired: it must be
                    // swept (never executed), the rest must serve exactly
                    let opts = if i % 3 == 0 {
                        InferOpts::new().deadline_at(Instant::now() - Duration::from_millis(1))
                    } else {
                        InferOpts::new().deadline_in(Duration::from_secs(3600))
                    };
                    let res = server.infer_with(key, &image, &opts);
                    if i % 3 == 0 {
                        let e = res.as_ref().expect_err("expired request must not serve");
                        assert_eq!(
                            e.downcast_ref::<ServeError>(),
                            Some(&ServeError::DeadlineExceeded)
                        );
                    } else if let Ok((got, _)) = &res {
                        let (want, _) = solo.forward(&image, 1).unwrap();
                        assert_eq!(got, &want, "thread {t} request {i} diverged");
                    } else {
                        panic!("live-deadline request failed: {:#}", res.unwrap_err());
                    }
                    out.record(&res);
                }
            });
        }
    });
    let expired_per_thread = (0..per_thread).filter(|i| i % 3 == 0).count();
    assert_eq!(
        out.deadline.load(Ordering::Relaxed),
        (threads * expired_per_thread) as u64,
        "sweep count != born-expired count"
    );
    assert_exact_accounting(&f.server, &f.key, &out, (threads * per_thread) as u64);
}

#[test]
fn corrupted_artifact_load_is_refused_and_clean_reload_recovers() {
    let _g = fault_guard();
    let seed = chaos_seed();
    let mut rng = Rng::new(0xA57);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let oracle = IntModel::build(&man, &ck).unwrap();
    let path = std::env::temp_dir()
        .join(format!("symog-chaos-{}-{seed}.fxpa", std::process::id()));
    artifact::publish(&man, &ck, &PublishOpts::new().version(1), &path).unwrap();

    // TOCTOU fault: the payload mutates *after* the first CRC pass; the
    // re-verify before planning must refuse the artifact
    fault::arm(fault::ARTIFACT_PAYLOAD_CORRUPT, 1.0, seed);
    let err = artifact::load(&path).expect_err("mutated payload must be refused");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("payload mutated between validation and planning"),
        "wrong refusal: {msg}"
    );
    assert!(msg.contains(&path.display().to_string()), "error lost the path: {msg}");

    // disarm: the same file loads cleanly and is bit-identical
    fault::disarm_all();
    let loaded = artifact::load(&path).unwrap();
    let elems: usize = man.input_shape.iter().product();
    for i in 0..3 {
        let image = request_image(elems, 0, i);
        let (want, _) = oracle.forward(&image, 1).unwrap();
        let (got, _) = loaded.model.forward(&image, 1).unwrap();
        assert_eq!(got, want, "clean reload diverged after the corruption storm");
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn failed_swap_probe_refuses_install_and_keeps_serving() {
    let _g = fault_guard();
    let seed = chaos_seed();
    let f = lenet_fixture(ServeConfig::new().workers(2));
    let mut rng = Rng::new(0xBEE);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let next = IntModel::build(&man, &ck).unwrap();
    let opts = RegisterOpts::new().max_batch(4);

    fault::arm(fault::SERVE_SWAP_PROBE, 1.0, seed);
    let err = f.server.swap(&f.key, ModelSource::InCode(&next), &opts).unwrap_err();
    assert!(format!("{err:#}").contains("probe row"), "wrong refusal: {err:#}");
    assert_eq!(f.server.current_version(&f.key).unwrap(), 1, "failed probe must not install");

    // v1 still serves, bit-exactly
    let image = request_image(f.elems, 0, 0);
    let (got, v) = f.server.infer_versioned(&f.key, &image).unwrap();
    let (want, _) = f.solo.forward(&image, 1).unwrap();
    assert_eq!((v, got), (1, want));

    // disarm: the same swap now installs (probe version numbers are not
    // burned by a failed probe — only installed versions are)
    fault::disarm_all();
    let k2 = f.server.swap(&f.key, ModelSource::InCode(&next), &opts).unwrap();
    assert_eq!(k2.version, 2);
    assert_eq!(f.server.current_version(&f.key).unwrap(), 2);
}

#[test]
fn combined_storm_trips_quarantine_and_rolls_back_to_last_good() {
    let _g = fault_guard();
    let seed = chaos_seed();
    // phase A: flood + deadline storm + sub-critical panic storm on v1.
    // quarantine_after(10) makes an accidental v1 trip essentially
    // impossible at p=0.15 (needs 10 consecutive failed drains).
    let f = lenet_fixture(
        ServeConfig::new().workers(2).queue_depth(6).quarantine_after(10),
    );
    let mut rng = Rng::new(0xF00D ^ seed);
    let (man, ck2) = models::lenet5ish(&mut rng, 2);
    let model2 = IntModel::build(&man, &ck2).unwrap();
    let opts = RegisterOpts::new().max_batch(4);

    fault::arm(fault::SERVE_DRAIN_PANIC, 0.15, seed.wrapping_mul(31));
    let threads = 6usize;
    let per_thread = 30usize;
    let out = Outcomes::default();
    std::thread::scope(|sc| {
        for t in 0..threads {
            let (server, key, solo, out) = (&f.server, &f.key, &f.solo, &out);
            sc.spawn(move || {
                for i in 0..per_thread {
                    let image = request_image(f.elems, t, i);
                    let opts = if i % 7 == 0 {
                        InferOpts::new().deadline_at(Instant::now() - Duration::from_millis(1))
                    } else {
                        InferOpts::new()
                    };
                    let res = server.infer_with(key, &image, &opts);
                    if let Ok((got, v)) = &res {
                        assert_eq!(*v, 1, "phase A serves v1 only");
                        let (want, _) = solo.forward(&image, 1).unwrap();
                        assert_eq!(got, &want, "accepted logits diverged in the storm");
                    }
                    out.record(&res);
                }
            });
        }
    });
    fault::disarm_all();
    assert_exact_accounting(&f.server, &f.key, &out, (threads * per_thread) as u64);
    assert_ne!(
        f.server.health(&f.key).unwrap(),
        Health::Quarantined,
        "sub-critical storm must not quarantine v1 (seed {seed})"
    );

    // phase B: deploy v2, then arm a certain drain panic — v2's breaker
    // trips on the 10th consecutive failure and the slot auto-rolls back
    // to v1 with no operator action and no restart. The fault site is
    // global (any drain would panic while armed), so send exactly the
    // tripping run and disarm before expecting v1 to serve.
    f.server.swap(&f.key, ModelSource::InCode(&model2), &opts).unwrap();
    assert_eq!(f.server.current_version(&f.key).unwrap(), 2);
    fault::arm(fault::SERVE_DRAIN_PANIC, 1.0, seed.wrapping_mul(37));
    for i in 0..10u64 {
        let image = request_image(f.elems, 40, i as usize);
        let e = f
            .server
            .infer_versioned(&f.key, &image)
            .expect_err("armed p=1.0 drain panic must fail every v2 request");
        assert!(
            matches!(e.downcast_ref::<ServeError>(), Some(ServeError::BatchPanicked(_))),
            "v2 meltdown request {i} failed with the wrong kind: {e:#}"
        );
    }
    fault::disarm_all();

    // the slot healed itself: v1 serves, v2 is quarantined, no restart
    assert_eq!(
        f.server.current_version(&f.key).unwrap(),
        1,
        "10 consecutive failures must trip the breaker and roll back to last-good"
    );
    assert_eq!(
        f.server.health_by_version(&f.key).unwrap(),
        vec![(1, Health::Ready), (2, Health::Quarantined)]
    );
    for i in 0..5 {
        let image = request_image(f.elems, 50, i);
        let (got, v) = f.server.infer_versioned(&f.key, &image).unwrap();
        let (want, _) = f.solo.forward(&image, 1).unwrap();
        assert_eq!((v, got), (1, want), "post-rollback request {i} diverged from the v1 oracle");
    }
    // the meltdown is recorded exactly: every v2 submission is a failure
    // (it never served a row), and v1's partition is phase A plus the
    // five post-rollback requests — nothing leaked across versions
    let by_v = f.server.stats_by_version(&f.key).unwrap();
    let v2 = &by_v.iter().find(|(v, _)| *v == 2).unwrap().1;
    assert_eq!((v2.requests, v2.failures), (0, 10), "v2 must record exactly the tripping run");
    let v1 = &by_v.iter().find(|(v, _)| *v == 1).unwrap().1;
    assert_eq!(
        v1.requests + v1.sheds + v1.timeouts + v1.failures,
        (threads * per_thread) as u64 + 5,
        "v1 partition != phase A submissions + post-rollback traffic"
    );
}
