//! Seeded-determinism regression suite for the randomness substrate the
//! serving tests and benches stand on: `util::rng::Rng` (xoshiro256++)
//! and the synthetic data generator.
//!
//! Two classes of guarantee are pinned:
//!
//! * **stream stability** — `Rng::new(seed)` produces a fixed, known
//!   bit-exact sequence (reference values computed independently from the
//!   published xoshiro256++/SplitMix64 recurrences), so a seed recorded in
//!   a test, bench, or serve request corpus replays identically forever;
//! * **worker invariance** — `synth_dataset` output is a pure function of
//!   `(spec, n, seed)`: the host-parallel chunking must not leak into the
//!   bits, whatever `SYMOG_WORKERS` or the machine's core count says.
//!   (Per-sample streams are seeded by index, not by chunk — this test is
//!   what keeps that property from regressing.)

use symog::data::{synth_dataset, synth_dataset_with, SynthSpec};
use symog::util::rng::Rng;

/// Reference values for the exact seeding procedure (SplitMix64 expansion
/// into xoshiro256++), computed outside this codebase. If these move, every
/// recorded seed in the repo silently means different data.
#[test]
fn xoshiro_stream_is_pinned() {
    let mut r = Rng::new(42);
    let want42: [u64; 6] = [
        0xd0764d4f4476689f,
        0x519e4174576f3791,
        0xfbe07cfb0c24ed8c,
        0xb37d9f600cd835b8,
        0xcb231c3874846a73,
        0x968d9f004e50de7d,
    ];
    for (i, &w) in want42.iter().enumerate() {
        assert_eq!(r.next_u64(), w, "seed 42, draw {i}");
    }
    let mut r = Rng::new(7);
    let want7: [u64; 3] = [0x0e2c1a002aae913d, 0x2c0fc8ddfa4e9e14, 0xb7b311b3b0d45872];
    for (i, &w) in want7.iter().enumerate() {
        assert_eq!(r.next_u64(), w, "seed 7, draw {i}");
    }
}

#[test]
fn derived_draws_are_seed_deterministic() {
    // every derived sampler (f32 / f64 / below / normal / shuffle) must be
    // a pure function of the u64 stream — same seed, same everything
    let (mut a, mut b) = (Rng::new(0xABCD), Rng::new(0xABCD));
    for _ in 0..200 {
        assert_eq!(a.f32().to_bits(), b.f32().to_bits());
        assert_eq!(a.f64().to_bits(), b.f64().to_bits());
        assert_eq!(a.below(1000), b.below(1000));
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
    }
    let mut xs: Vec<u32> = (0..64).collect();
    let mut ys = xs.clone();
    a.shuffle(&mut xs);
    b.shuffle(&mut ys);
    assert_eq!(xs, ys);
    // a cloned RNG continues the identical stream
    let mut c = a.clone();
    for _ in 0..50 {
        assert_eq!(a.next_u64(), c.next_u64());
    }
}

fn spec() -> SynthSpec {
    SynthSpec {
        shape: [12, 12, 3],
        classes: 10,
        coarse_classes: 10,
        noise: 0.4,
        max_shift: 2,
        blob_scale: 3.0,
    }
}

#[test]
fn synthetic_batches_bit_identical_across_worker_counts() {
    let s = spec();
    let base = synth_dataset_with(&s, 97, 0xDA7A, 1); // prime n: ragged chunks
    for workers in [2usize, 3, 4, 7, 16, 64] {
        let got = synth_dataset_with(&s, 97, 0xDA7A, workers);
        assert_eq!(got.labels, base.labels, "labels drifted at workers={workers}");
        let same = got
            .images
            .iter()
            .zip(&base.images)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "images not bit-identical at workers={workers}");
    }
    // the default-workers entry point is the same function
    let dflt = synth_dataset(&s, 97, 0xDA7A);
    assert_eq!(dflt.labels, base.labels);
    assert_eq!(dflt.images, base.images);
}

#[test]
fn synthetic_seeds_are_independent() {
    let s = spec();
    let a = synth_dataset_with(&s, 40, 1, 2);
    let b = synth_dataset_with(&s, 40, 2, 2);
    assert_ne!(a.images, b.images, "distinct seeds produced identical data");
    // prefix stability: the first n samples do not depend on the total count
    let long = synth_dataset_with(&s, 80, 1, 3);
    let e = a.image_elems();
    assert_eq!(
        &long.images[..40 * e],
        &a.images[..],
        "sample content depends on dataset length"
    );
    assert_eq!(&long.labels[..40], &a.labels[..]);
}
