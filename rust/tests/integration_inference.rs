//! Integration: the pure integer inference engine vs the float evalq path,
//! and the quantization toolbox on real trained checkpoints.

use std::path::{Path, PathBuf};

use symog::coordinator::{Trainer, TrainOptions};
use symog::data::Preset;
use symog::inference::IntModel;
use symog::runtime::Runtime;

fn artifact_dir(tag: &str) -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(tag);
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn integer_engine_tracks_evalq_on_trained_lenet() {
    let Some(dir) = artifact_dir("lenet5-symog-synth-mnist-w1-b2") else {
        eprintln!("skipping: lenet5 artifact not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let (train, test) = Preset::SynthMnist.load(1024, 256, 11);

    let mut trainer = Trainer::from_init(&art).unwrap();
    let mut opts = TrainOptions::paper(6);
    opts.seed = 11;
    trainer.train(&train, &test, &opts).unwrap();
    let (_, acc_q) = trainer.evaluate(&test, true).unwrap();

    let ck = trainer.to_checkpoint().unwrap();
    let model = IntModel::build(&art.manifest, &ck).unwrap();
    assert!(model.all_ternary, "2-bit SYMOG weights must be ternary");
    let usable = (test.len() / art.manifest.batch) * art.manifest.batch;
    let acc_int = model
        .accuracy(
            &test.images[..usable * test.image_elems()],
            &test.labels[..usable],
            64,
        )
        .unwrap();
    // the integer engine quantizes activations to 8 bits; allow a small gap
    assert!(
        (acc_int - acc_q).abs() < 0.08,
        "integer engine {acc_int} vs evalq {acc_q}"
    );
    assert!(acc_int > 0.3, "integer engine broken: acc {acc_int}");

    // cost model: ternary inference must clear the paper's 18.5x 8-bit claim.
    // conv/dense contribute zero multiplies; the only remaining ones come
    // from folded BN / non-power-of-two pooling — a tiny fraction of MACs.
    let report = model.cost_report(1).unwrap();
    assert!(
        report.counts.int_mults * 20 < report.counts.acc_adds,
        "multiplies not marginal: {} vs {} adds",
        report.counts.int_mults,
        report.counts.acc_adds
    );
    assert!(report.energy_ratio() > 18.5, "energy ratio {}", report.energy_ratio());
    assert!(report.compression_ratio() > 8.0);
}

#[test]
fn packed_model_roundtrip_preserves_predictions() {
    let Some(dir) = artifact_dir("lenet5-symog-synth-mnist-w1-b2") else {
        eprintln!("skipping");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let (train, test) = Preset::SynthMnist.load(512, 128, 2);
    let mut trainer = Trainer::from_init(&art).unwrap();
    let mut opts = TrainOptions::paper(2);
    opts.seed = 2;
    opts.steps_per_epoch = Some(8);
    trainer.train(&train, &test, &opts).unwrap();
    let ck = trainer.to_checkpoint().unwrap();

    let man_json = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let tmp_fxpm = std::env::temp_dir().join("symog_it.fxpm");
    let tmp_ckpt = std::env::temp_dir().join("symog_it_full.ckpt");
    symog::quant::packed::write_packed(&art.manifest, &man_json, &ck, &tmp_fxpm).unwrap();
    ck.write(&tmp_ckpt).unwrap();

    // packed file is much smaller than the float checkpoint
    let packed_size = std::fs::metadata(&tmp_fxpm).unwrap().len();
    let float_size = std::fs::metadata(&tmp_ckpt).unwrap().len();
    assert!(
        (float_size as f64 / packed_size as f64) > 6.0,
        "packed {packed_size} vs float {float_size}"
    );

    // predictions identical between direct-ckpt engine and packed engine
    let direct = IntModel::build(&art.manifest, &ck).unwrap();
    let (man2, ck2) = symog::quant::packed::read_packed(&tmp_fxpm).unwrap();
    let packed = IntModel::build(&man2, &ck2).unwrap();
    let e = test.image_elems();
    let pd = direct.predict(&test.images[..32 * e], 32).unwrap();
    let pp = packed.predict(&test.images[..32 * e], 32).unwrap();
    assert_eq!(pd, pp, "packed model must predict identically");
    std::fs::remove_file(&tmp_fxpm).ok();
    std::fs::remove_file(&tmp_ckpt).ok();
}

#[test]
fn naive_ptq_is_worse_than_symog_training() {
    // section 2.1's point: post-quantizing a float model loses accuracy;
    // SYMOG training closes that gap. Verified end-to-end on the baseline
    // vs symog lenet artifacts.
    let (Some(bdir), Some(sdir)) = (
        artifact_dir("lenet5-baseline-synth-mnist-w1-b2"),
        artifact_dir("lenet5-symog-synth-mnist-w1-b2"),
    ) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let base_art = rt.load_artifact(&bdir).unwrap();
    let symog_art = rt.load_artifact(&sdir).unwrap();
    let (train, test) = Preset::SynthMnist.load(1024, 256, 3);

    // float pretrain
    let mut base = Trainer::from_init(&base_art).unwrap();
    let mut opts = TrainOptions::paper(5);
    opts.seed = 3;
    base.train(&train, &test, &opts).unwrap();
    let (_, base_float_acc) = base.evaluate(&test, false).unwrap();
    // naive PTQ = evalq on the float-trained weights
    let (_, ptq_acc) = base.evaluate(&test, true).unwrap();

    // SYMOG continue-training from the same pretrained weights
    let ck = base.to_checkpoint().unwrap();
    let mut symog = Trainer::from_checkpoint(&symog_art, &ck, true).unwrap();
    let mut sopts = TrainOptions::paper(6);
    sopts.seed = 3;
    symog.train(&train, &test, &sopts).unwrap();
    let (_, symog_q_acc) = symog.evaluate(&test, true).unwrap();

    assert!(
        symog_q_acc > ptq_acc + 0.02,
        "SYMOG {symog_q_acc} must beat naive PTQ {ptq_acc} (float was {base_float_acc})"
    );
}

#[test]
fn quantize_ckpt_produces_codebook_weights() {
    let Some(dir) = artifact_dir("lenet5-baseline-synth-mnist-w1-b2") else {
        eprintln!("skipping");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let ck = symog::coordinator::Checkpoint::read(&art.init_ckpt()).unwrap();
    let qck = symog::quant::quantize_ckpt(&art.manifest, &ck).unwrap();
    let deltas = &qck.find("__deltas__").unwrap().data;
    for p in &art.manifest.params {
        let Some(qidx) = p.qidx else { continue };
        let t = qck.find(&p.name).unwrap();
        let delta = deltas[qidx];
        for &w in &t.data {
            let m = w / delta;
            assert!((m - m.round()).abs() < 1e-5, "{} not on codebook: {w}", p.name);
            assert!(m.abs() <= 1.0 + 1e-5);
        }
    }
    // stats on the quantized ckpt: zero quantization error
    let stats = symog::quant::layer_stats(&art.manifest, &qck).unwrap();
    for s in &stats {
        assert!(s.mse < 1e-12, "{}: mse {}", s.name, s.mse);
        let total: f32 = s.occupancy.iter().sum();
        assert!((total - 1.0).abs() < 1e-4);
    }
}
