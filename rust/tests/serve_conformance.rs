//! Serving conformance suite: batching invariance of the serve execution
//! path.
//!
//! The contract under test (see `serve/` module docs and DESIGN.md §"The
//! serving layer"): for every zoo model and bit width, **any** partition
//! of K requests into micro-batches — ragged tails, batch-of-1, the whole
//! set at once — produces per-request logits bit-identical to running
//! each request through a solo `Backend::Planned` forward. This is what
//! makes dynamic batching an invisible implementation detail to clients:
//! the engine's requantization statistics are batch-global, so the
//! serving path must (and does) execute coalesced rows with per-request
//! isolation (`ExecPlan::run_rows`) instead of one whole-batch forward.

use symog::coordinator::Checkpoint;
use symog::inference::IntModel;
use symog::runtime::Manifest;
use symog::serve::{ModelKey, ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::util::rng::Rng;

/// The full zoo: every architecture shape the planned executor supports,
/// including the fusion-hostile `oddball` and the concat-heavy
/// `densenetish` (retained slots are where batching bugs would hide).
fn zoo(rng: &mut Rng, n_bits: u32) -> Vec<(&'static str, (Manifest, Checkpoint))> {
    vec![
        ("lenet5ish", models::lenet5ish(rng, n_bits)),
        ("densenetish", models::densenetish(rng, n_bits)),
        ("vgg7ish", models::vgg7ish(rng, n_bits, 4)),
        ("oddball", models::oddball(rng, n_bits)),
    ]
}

/// Representative arrival patterns for 7 requests: one full drain, ragged
/// splits, pure batch-of-1 traffic, and mixed tails.
const PARTITIONS: &[&[usize]] = &[
    &[7],
    &[4, 3],
    &[1, 1, 1, 1, 1, 1, 1],
    &[2, 2, 2, 1],
    &[6, 1],
    &[5, 1, 1],
];

#[test]
fn any_partition_into_micro_batches_matches_solo_forwards() {
    const K: usize = 7;
    for n_bits in [2u32, 4, 8] {
        let mut rng = Rng::new(0x5EC0 ^ ((n_bits as u64) << 16));
        for (name, (man, ck)) in zoo(&mut rng, n_bits) {
            let model = IntModel::build(&man, &ck).unwrap();
            let plan = model.shared_plan(8).unwrap();
            let (e, o) = (plan.in_elems(), plan.out_per_img());
            let images: Vec<f32> = (0..K * e).map(|_| rng.normal()).collect();

            // solo oracle: each request through a batch-1 planned forward
            let solo: Vec<Vec<f32>> = (0..K)
                .map(|r| model.forward(&images[r * e..(r + 1) * e], 1).unwrap().0)
                .collect();

            // scatter-pool width must be bit-irrelevant too
            for n_scratch in [1usize, 3] {
                let mut scratches: Vec<_> = (0..n_scratch).map(|_| plan.scratch_for(1)).collect();
                for parts in PARTITIONS {
                    assert_eq!(parts.iter().sum::<usize>(), K);
                    let mut off = 0usize;
                    for &k in *parts {
                        let mut out = vec![0f32; k * o];
                        plan.run_rows(
                            &images[off * e..(off + k) * e],
                            k,
                            &mut scratches,
                            &mut out,
                        )
                        .unwrap();
                        for r in 0..k {
                            assert_eq!(
                                &out[r * o..(r + 1) * o],
                                &solo[off + r][..],
                                "{name} n_bits={n_bits} partition {parts:?} \
                                 scratches={n_scratch}: row {} diverged from solo",
                                off + r
                            );
                        }
                        off += k;
                    }
                }
            }
        }
    }
}

#[test]
fn server_serves_whole_zoo_bit_identical_to_solo() {
    // one server, all 12 (model, n_bits) combinations registered side by
    // side — the multi-model registry path end to end
    let mut build_rng = Rng::new(0xCAFE);
    let mut reg = Registry::new();
    let mut oracles: Vec<(ModelKey, IntModel, usize)> = Vec::new();
    for n_bits in [2u32, 4, 8] {
        for (name, (man, ck)) in zoo(&mut build_rng, n_bits) {
            let model = IntModel::build(&man, &ck).unwrap();
            let solo = IntModel::build(&man, &ck).unwrap();
            let opts = RegisterOpts::new().max_batch(4);
            let key = reg.add(name, ModelSource::InCode(&model), &opts).unwrap();
            let elems: usize = man.input_shape.iter().product();
            oracles.push((key, solo, elems));
        }
    }
    assert_eq!(reg.len(), 12);
    let server = Server::new(reg, ServeConfig::new().workers(2));
    assert_eq!(server.keys().len(), 12);

    let mut rng = Rng::new(0xBEEF);
    for (key, solo, elems) in &oracles {
        for i in 0..3u32 {
            let img: Vec<f32> = (0..*elems).map(|_| rng.normal()).collect();
            let got = server.infer(key, &img).unwrap();
            let (want, _) = solo.forward(&img, 1).unwrap();
            assert_eq!(got, want, "{key} request {i}: served logits diverged");
        }
        let stats = server.stats(key).unwrap();
        assert_eq!(stats.requests, 3, "{key}: request counter drifted");
        assert_eq!(stats.batches, 3, "{key}: a lone caller never queues");
    }
}

#[test]
fn run_rows_rejects_misuse() {
    let mut rng = Rng::new(0xBAD);
    let (man, ck) = models::lenet5ish(&mut rng, 2);
    let model = IntModel::build(&man, &ck).unwrap();
    let plan_a = model.plan(4).unwrap();
    let plan_b = model.plan(4).unwrap();
    let (e, o) = (plan_a.in_elems(), plan_a.out_per_img());
    let images: Vec<f32> = (0..2 * e).map(|_| rng.normal()).collect();
    let mut out = vec![0f32; 2 * o];

    // scratch bound to a different plan
    let mut wrong = vec![plan_b.scratch_for(1)];
    assert!(plan_a.run_rows(&images, 2, &mut wrong, &mut out).is_err());

    let mut ok = vec![plan_a.scratch_for(1)];
    // output buffer of the wrong size
    assert!(plan_a
        .run_rows(&images, 2, &mut ok, &mut out[..o])
        .is_err());
    // input slice of the wrong size
    assert!(plan_a
        .run_rows(&images[..e - 1], 1, &mut ok, &mut out[..o])
        .is_err());
    // no scratches at all
    assert!(plan_a
        .run_rows(&images, 2, &mut [], &mut out)
        .is_err());
    // a row scratch cannot hold a multi-image batch
    let mut row = plan_a.scratch_for(1);
    assert!(plan_a.run_into(&images, 2, &mut row, &mut out).is_err());
    // and the well-formed call still works after all the rejections
    plan_a.run_rows(&images, 2, &mut ok, &mut out).unwrap();
}

#[test]
fn row_scratch_is_fraction_of_full_arena_and_reusable() {
    let mut rng = Rng::new(0xF00D);
    let (man, ck) = models::vgg7ish(&mut rng, 2, 4);
    let model = IntModel::build(&man, &ck).unwrap();
    let plan = model.plan(8).unwrap();
    let full = plan.scratch();
    let row = plan.scratch_for(1);
    assert_eq!(
        row.arena_bytes() * 8,
        full.arena_bytes(),
        "row scratch should hold exactly 1/max_batch of the activation arena"
    );
    // a row scratch sized mid-way also works and is batch-capped
    let mut mid = plan.scratch_for(3);
    let e = plan.in_elems();
    let images: Vec<f32> = (0..3 * e).map(|_| rng.normal()).collect();
    let got = plan.run(&images, 3, &mut mid).unwrap();
    let (want, _) = model.forward(&images, 3).unwrap();
    assert_eq!(got, want, "mid-capacity scratch diverged from the shared-plan forward");
    assert!(plan.run(&images, 3, &mut plan.scratch_for(2)).is_err());
}
