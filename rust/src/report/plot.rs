//! Self-contained SVG plot writer — regenerates the paper's figures as
//! actual graphics (no plotting library is vendored).
//!
//! Two chart types cover everything the paper shows:
//! * `LineChart`  — Figure 4's switch-rate-vs-epoch curves
//! * `HistogramGrid` — Figure 1/3's weight-distribution panels

use std::fmt::Write as _;

/// Map a data point into pixel space.
#[derive(Clone, Copy, Debug)]
struct Frame {
    x0: f32,
    x1: f32,
    y0: f32,
    y1: f32,
    // pixel box
    px: f32,
    py: f32,
    pw: f32,
    ph: f32,
}

impl Frame {
    fn x(&self, v: f32) -> f32 {
        self.px + (v - self.x0) / (self.x1 - self.x0).max(1e-9) * self.pw
    }

    fn y(&self, v: f32) -> f32 {
        // SVG y grows downward
        self.py + self.ph - (v - self.y0) / (self.y1 - self.y0).max(1e-9) * self.ph
    }
}

const GRID: &str = "#ddd";
const AXIS: &str = "#333";
const BAR: &str = "#4878a8";

const PALETTE: &[&str] = &[
    "#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b", "#e377c2",
];

/// A multi-series line chart.
pub struct LineChart {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<(String, Vec<(f32, f32)>)>,
    pub width: u32,
    pub height: u32,
}

impl LineChart {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> LineChart {
        LineChart {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
            width: 720,
            height: 420,
        }
    }

    pub fn series(&mut self, name: &str, points: Vec<(f32, f32)>) -> &mut Self {
        self.series.push((name.into(), points));
        self
    }

    pub fn to_svg(&self) -> String {
        let (w, h) = (self.width as f32, self.height as f32);
        let frame = {
            let pts: Vec<(f32, f32)> =
                self.series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
            let (mut x0, mut x1) = min_max(pts.iter().map(|p| p.0));
            let (mut y0, mut y1) = min_max(pts.iter().map(|p| p.1));
            if x0 == x1 {
                x1 += 1.0;
            }
            if y0 == y1 {
                y1 += 1.0;
            }
            // pad the y range 5%
            let pad = (y1 - y0) * 0.05;
            y0 -= pad;
            y1 += pad;
            let _ = (&mut x0, &mut y0);
            Frame { x0, x1, y0, y1, px: 64.0, py: 40.0, pw: w - 96.0, ph: h - 104.0 }
        };
        let mut s = String::new();
        let _ = write!(
            s,
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" font-family="sans-serif">"#
        );
        let _ = write!(s, r#"<rect width="{w}" height="{h}" fill="white"/>"#);
        let _ = write!(
            s,
            r#"<text x="{}" y="24" text-anchor="middle" font-size="15">{}</text>"#,
            w / 2.0,
            esc(&self.title)
        );
        // axes + gridlines + tick labels
        for k in 0..=4 {
            let fy = frame.y0 + (frame.y1 - frame.y0) * k as f32 / 4.0;
            let y = frame.y(fy);
            let _ = write!(
                s,
                r#"<line x1="{}" y1="{y}" x2="{}" y2="{y}" stroke="{GRID}"/>"#,
                frame.px,
                frame.px + frame.pw
            );
            let _ = write!(
                s,
                r#"<text x="{}" y="{}" text-anchor="end" font-size="11">{}</text>"#,
                frame.px - 6.0,
                y + 4.0,
                fmt_tick(fy)
            );
            let fx = frame.x0 + (frame.x1 - frame.x0) * k as f32 / 4.0;
            let x = frame.x(fx);
            let _ = write!(
                s,
                r#"<text x="{x}" y="{}" text-anchor="middle" font-size="11">{}</text>"#,
                frame.py + frame.ph + 16.0,
                fmt_tick(fx)
            );
        }
        let _ = write!(
            s,
            r#"<rect x="{}" y="{}" width="{}" height="{}" fill="none" stroke="{AXIS}"/>"#,
            frame.px, frame.py, frame.pw, frame.ph
        );
        // axis labels
        let _ = write!(
            s,
            r#"<text x="{}" y="{}" text-anchor="middle" font-size="12">{}</text>"#,
            frame.px + frame.pw / 2.0,
            h - 8.0,
            esc(&self.x_label)
        );
        let _ = write!(
            s,
            r#"<text x="14" y="{}" text-anchor="middle" font-size="12" transform="rotate(-90 14 {})">{}</text>"#,
            frame.py + frame.ph / 2.0,
            frame.py + frame.ph / 2.0,
            esc(&self.y_label)
        );
        // series
        for (i, (name, pts)) in self.series.iter().enumerate() {
            let color = PALETTE[i % PALETTE.len()];
            let path: Vec<String> = pts
                .iter()
                .map(|&(x, y)| format!("{:.1},{:.1}", frame.x(x), frame.y(y)))
                .collect();
            let _ = write!(
                s,
                r#"<polyline points="{}" fill="none" stroke="{color}" stroke-width="1.8"/>"#,
                path.join(" ")
            );
            // legend
            let ly = frame.py + 14.0 + i as f32 * 16.0;
            let lx = frame.px + frame.pw - 150.0;
            let _ = write!(
                s,
                r#"<line x1="{lx}" y1="{ly}" x2="{}" y2="{ly}" stroke="{color}" stroke-width="2"/>"#,
                lx + 22.0
            );
            let _ = write!(
                s,
                r#"<text x="{}" y="{}" font-size="11">{}</text>"#,
                lx + 28.0,
                ly + 4.0,
                esc(name)
            );
        }
        s.push_str("</svg>");
        s
    }
}

/// A grid of histogram panels (one row per epoch) — Figure 3's layout.
pub struct HistogramGrid {
    pub title: String,
    /// (label, bin lo, bin hi, counts)
    pub panels: Vec<(String, f32, f32, Vec<u32>)>,
    pub width: u32,
    pub panel_height: u32,
}

impl HistogramGrid {
    pub fn new(title: &str) -> HistogramGrid {
        HistogramGrid { title: title.into(), panels: Vec::new(), width: 560, panel_height: 96 }
    }

    pub fn panel(&mut self, label: &str, lo: f32, hi: f32, counts: &[u32]) -> &mut Self {
        self.panels.push((label.into(), lo, hi, counts.to_vec()));
        self
    }

    pub fn to_svg(&self) -> String {
        let w = self.width as f32;
        let ph = self.panel_height as f32;
        let h = 40.0 + self.panels.len() as f32 * (ph + 28.0);
        let mut s = String::new();
        let _ = write!(
            s,
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" font-family="sans-serif">"#
        );
        let _ = write!(s, r#"<rect width="{w}" height="{h}" fill="white"/>"#);
        let _ = write!(
            s,
            r#"<text x="{}" y="22" text-anchor="middle" font-size="14">{}</text>"#,
            w / 2.0,
            esc(&self.title)
        );
        for (pi, (label, lo, hi, counts)) in self.panels.iter().enumerate() {
            let top = 36.0 + pi as f32 * (ph + 28.0);
            let px = 50.0;
            let pw = w - 80.0;
            let max = counts.iter().copied().max().unwrap_or(1).max(1) as f32;
            let bw = pw / counts.len() as f32;
            for (bi, &c) in counts.iter().enumerate() {
                let bh = c as f32 / max * ph;
                let _ = write!(
                    s,
                    r#"<rect x="{:.1}" y="{:.1}" width="{:.2}" height="{:.1}" fill="{BAR}"/>"#,
                    px + bi as f32 * bw,
                    top + ph - bh,
                    bw.max(0.5),
                    bh
                );
            }
            let _ = write!(
                s,
                r#"<rect x="{px}" y="{top}" width="{pw}" height="{ph}" fill="none" stroke="{AXIS}"/>"#
            );
            let _ = write!(
                s,
                r#"<text x="{}" y="{}" font-size="11">{}</text>"#,
                px,
                top + ph + 14.0,
                fmt_tick(*lo)
            );
            let _ = write!(
                s,
                r#"<text x="{}" y="{}" text-anchor="end" font-size="11">{}</text>"#,
                px + pw,
                top + ph + 14.0,
                fmt_tick(*hi)
            );
            let _ = write!(
                s,
                r#"<text x="{}" y="{}" font-size="12">{}</text>"#,
                px + pw + 6.0,
                top + ph / 2.0,
                esc(label)
            );
        }
        s.push_str("</svg>");
        s
    }
}

fn min_max(vals: impl Iterator<Item = f32>) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for v in vals {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() {
        (0.0, 1.0)
    } else {
        (lo, hi)
    }
}

fn fmt_tick(v: f32) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_valid_svg() {
        let mut c = LineChart::new("Fig 4", "epoch", "switch %");
        c.series("layer 1", vec![(0.0, 10.0), (1.0, 22.0), (2.0, 8.0)]);
        c.series("layer 7", vec![(0.0, 5.0), (1.0, 12.0), (2.0, 3.0)]);
        let svg = c.to_svg();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert!(svg.contains("layer 7"));
    }

    #[test]
    fn histogram_grid_panels() {
        let mut g = HistogramGrid::new("Fig 3 — layer 1");
        g.panel("epoch 0", -1.0, 1.0, &[1, 5, 9, 5, 1]);
        g.panel("epoch 80", -1.0, 1.0, &[9, 1, 9, 1, 9]);
        let svg = g.to_svg();
        assert!(svg.contains("epoch 80"));
        // 10 bars + 2 frames + 1 background
        assert_eq!(svg.matches("<rect").count(), 13);
    }

    #[test]
    fn escapes_labels() {
        let mut c = LineChart::new("a<b&c", "x", "y");
        c.series("s", vec![(0.0, 0.0), (1.0, 1.0)]);
        let svg = c.to_svg();
        assert!(svg.contains("a&lt;b&amp;c"));
        assert!(!svg.contains("a<b"));
    }

    #[test]
    fn degenerate_ranges_handled() {
        let mut c = LineChart::new("flat", "x", "y");
        c.series("s", vec![(0.0, 5.0), (1.0, 5.0)]);
        let svg = c.to_svg();
        assert!(svg.contains("polyline"));
        // no NaNs leaked into coordinates
        assert!(!svg.contains("NaN"));
    }
}
