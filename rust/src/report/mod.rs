//! Report rendering: markdown tables matching the paper's layout.

pub mod plot;

/// A simple aligned markdown table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Table {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// One Table-1-style result row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub dataset: String,
    pub method: String,
    pub model: String,
    pub params: usize,
    pub bits: String,
    pub fixed_point: bool,
    pub epochs: u32,
    pub error: f32,
}

/// Render rows in the paper's Table 1 format.
pub fn render_table1(rows: &[Table1Row]) -> String {
    let mut t = Table::new([
        "Data set", "Method", "Model", "Param.", "Bits", "Fixed-Point", "Epochs", "Error",
    ]);
    for r in rows {
        t.row([
            r.dataset.clone(),
            r.method.clone(),
            r.model.clone(),
            human_count(r.params),
            r.bits.clone(),
            if r.fixed_point { "yes" } else { "no" }.into(),
            r.epochs.to_string(),
            format!("{:.2}%", r.error * 100.0),
        ]);
    }
    t.render()
}

/// 62582 -> "62.6k", 12_300_000 -> "12.3M"
pub fn human_count(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}k", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(["a", "long header"]);
        t.row(["xxxxxxx", "y"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[1].starts_with("|---"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new(["a", "b"]).row(["only one"]);
    }

    #[test]
    fn human_counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(62_582), "62.6k");
        assert_eq!(human_count(12_300_000), "12.3M");
    }

    #[test]
    fn table1_render() {
        let rows = vec![Table1Row {
            dataset: "synth-mnist".into(),
            method: "SYMOG".into(),
            model: "lenet5".into(),
            params: 62582,
            bits: "2".into(),
            fixed_point: true,
            epochs: 25,
            error: 0.0063,
        }];
        let s = render_table1(&rows);
        assert!(s.contains("0.63%"));
        assert!(s.contains("yes"));
    }
}
