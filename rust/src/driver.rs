//! High-level experiment driver shared by the CLI, examples and benches.
//!
//! Encapsulates the full pipeline of the paper's protocol:
//!   1. (optionally) pretrain a float baseline,
//!   2. initialize the quantized run from it (solving the step sizes),
//!   3. train with the method's schedule,
//!   4. report float + quantized error.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::Experiment;
use crate::coordinator::{Checkpoint, Trainer, TrainOutcome};
use crate::data::Dataset;
use crate::runtime::{Runtime, XlaArtifact};

/// Default artifacts root: $SYMOG_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("SYMOG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Everything `run_experiment` hands back.
pub struct RunResult {
    pub outcome: TrainOutcome,
    pub final_ckpt: Checkpoint,
    /// best quantized test error over the run (Table 1 metric)
    pub best_q_error: f32,
    pub best_f_error: f32,
}

/// Load the experiment's artifact.
pub fn load_artifact(rt: &Runtime, exp: &Experiment, root: &Path) -> Result<XlaArtifact> {
    let dir = exp.artifact_dir(root);
    rt.load_artifact(&dir)
        .with_context(|| format!("loading artifact {} (run `make artifacts`?)", dir.display()))
}

/// Run one experiment end to end on the given data.
pub fn run_experiment(
    artifact: &XlaArtifact,
    exp: &Experiment,
    train: &Dataset,
    test: &Dataset,
) -> Result<RunResult> {
    let mut trainer = match &exp.init_from {
        Some(path) => {
            let ck = Checkpoint::read(path)?;
            Trainer::from_checkpoint(artifact, &ck, exp.resolve_deltas)?
        }
        None => Trainer::from_init(artifact)?,
    };
    let opts = exp.train_options();
    let outcome = trainer.train(train, test, &opts)?;
    let final_ckpt = trainer.to_checkpoint()?;
    let best_q_error = outcome.log.best_quantized_error();
    let best_f_error = outcome.log.best_float_error();
    Ok(RunResult { outcome, final_ckpt, best_q_error, best_f_error })
}

/// The paper's two-phase protocol: pretrain the float baseline artifact,
/// then run the quantized method initialized from the pretrained weights.
/// Returns (baseline result, method result).
pub fn pretrain_then_run(
    rt: &Runtime,
    baseline_exp: &Experiment,
    method_exp: &Experiment,
    root: &Path,
    train: &Dataset,
    test: &Dataset,
) -> Result<(RunResult, RunResult)> {
    let base_art = load_artifact(rt, baseline_exp, root)?;
    let base = run_experiment(&base_art, baseline_exp, train, test)?;

    // hand the pretrained weights to the method run via a temp checkpoint
    let tmp = std::env::temp_dir().join(format!(
        "symog_pretrain_{}_{}.ckpt",
        baseline_exp.name,
        std::process::id()
    ));
    base.final_ckpt.write(&tmp)?;
    let mut mexp = method_exp.clone();
    mexp.init_from = Some(tmp.clone());
    mexp.resolve_deltas = true; // Alg. 1 lines 2-5 on the pretrained weights
    let meth_art = load_artifact(rt, &mexp, root)?;
    let out = run_experiment(&meth_art, &mexp, train, test);
    std::fs::remove_file(&tmp).ok();
    Ok((base, out?))
}
