//! Minimal TOML-subset parser for experiment configs.
//!
//! Supported grammar (all configs/*.toml stay within it):
//!   [section] and [section.sub] headers
//!   key = "string" | 123 | 1.5 | true | false | [1, 2, "x"]
//!   # comments, blank lines
//!
//! Values surface as `util::json::Json` so downstream code shares one
//! dynamic-value type.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// A parsed TOML document: section path -> (key -> value). Root keys live
/// under the "" section.
#[derive(Clone, Debug, Default)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, Json>>,
}

impl Toml {
    pub fn parse(src: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut current = String::new();
        doc.sections.entry(current.clone()).or_default();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", ln + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", ln + 1);
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
            } else if let Some(eq) = find_eq(line) {
                let key = line[..eq].trim();
                let val = line[eq + 1..].trim();
                if key.is_empty() {
                    bail!("line {}: empty key", ln + 1);
                }
                let parsed = parse_value(val)
                    .with_context(|| format!("line {}: bad value {val:?}", ln + 1))?;
                doc.sections.get_mut(&current).unwrap().insert(key.to_string(), parsed);
            } else {
                bail!("line {}: expected `key = value` or `[section]`", ln + 1);
            }
        }
        Ok(doc)
    }

    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, Json>> {
        self.sections.get(name)
    }

    /// Look up `key` in `section`, falling back to the root section.
    pub fn get(&self, section: &str, key: &str) -> Option<&Json> {
        self.sections
            .get(section)
            .and_then(|s| s.get(key))
            .or_else(|| self.sections.get("").and_then(|s| s.get(key)))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|j| j.str().ok())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|j| j.num().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|j| j.usize().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|j| j.boolean().ok()).unwrap_or(default)
    }
}

/// Strip a # comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Find the first `=` outside of strings.
fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(v: &str) -> Result<Json> {
    if v.starts_with('"') {
        // reuse the JSON string parser
        return Json::parse(v);
    }
    if v == "true" {
        return Ok(Json::Bool(true));
    }
    if v == "false" {
        return Ok(Json::Bool(false));
    }
    if v.starts_with('[') {
        let inner = v
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .context("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Json::Arr(items));
    }
    Ok(Json::Num(v.parse::<f64>().map_err(|e| anyhow::anyhow!("{e}"))?))
}

/// Split on commas outside strings (arrays of scalars only — no nesting).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment preset
name = "lenet-mnist"
epochs = 25

[train]
lr0 = 0.01          # start
lr_end = 0.001
lambda0 = 10
clip = true
hist_epochs = [0, 10, 25]

[data]
dataset = "synth-mnist"
train_n = 2048
"#;

    #[test]
    fn parses_sample() {
        let t = Toml::parse(SAMPLE).unwrap();
        assert_eq!(t.str_or("", "name", "?"), "lenet-mnist");
        assert_eq!(t.usize_or("", "epochs", 0), 25);
        assert_eq!(t.f64_or("train", "lr0", 0.0), 0.01);
        assert!(t.bool_or("train", "clip", false));
        assert_eq!(t.str_or("data", "dataset", "?"), "synth-mnist");
        let he = t.get("train", "hist_epochs").unwrap().usize_vec().unwrap();
        assert_eq!(he, vec![0, 10, 25]);
    }

    #[test]
    fn root_fallback() {
        let t = Toml::parse("x = 5\n[a]\ny = 6\n").unwrap();
        assert_eq!(t.usize_or("a", "x", 0), 5); // falls back to root
        assert_eq!(t.usize_or("a", "y", 0), 6);
        assert_eq!(t.usize_or("", "y", 0), 0); // no reverse fallback
    }

    #[test]
    fn comments_in_strings() {
        let t = Toml::parse(r##"s = "a # not comment" # real comment"##).unwrap();
        assert_eq!(t.str_or("", "s", ""), "a # not comment");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Toml::parse("just words").is_err());
        assert!(Toml::parse("[unclosed").is_err());
        assert!(Toml::parse("k = ").is_err());
    }

    #[test]
    fn empty_array() {
        let t = Toml::parse("xs = []").unwrap();
        assert_eq!(t.get("", "xs").unwrap().arr().unwrap().len(), 0);
    }
}
