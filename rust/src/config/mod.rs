//! Experiment configuration: TOML presets + programmatic construction.
//!
//! An `Experiment` fully determines one training run: which artifact to
//! load, which synthetic dataset to generate at what size, the schedules,
//! and the probes. `configs/*.toml` ship the presets used by the benches
//! and examples; the CLI can override any field.

mod toml;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use toml::Toml;

use crate::coordinator::{LambdaSchedule, LrSchedule, TrainOptions};
use crate::data::{AugmentConfig, Preset};

/// One fully-specified training run.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    /// artifact directory (relative to artifacts root unless absolute)
    pub artifact: String,
    pub dataset: Preset,
    pub train_n: usize,
    pub test_n: usize,
    pub epochs: u32,
    pub lr0: f32,
    pub lr_end: f32,
    /// lambda schedule kind: "exp" (paper), "linear", "const", "off"
    pub lambda_kind: String,
    pub lambda0: f32,
    /// growth exponent: alpha = growth / epochs for "exp" (paper uses 9)
    pub lambda_growth: f32,
    pub augment: bool,
    pub seed: u64,
    pub steps_per_epoch: Option<usize>,
    pub track_modes: bool,
    pub hist_epochs: Vec<u32>,
    pub hist_layers: Vec<usize>,
    /// initialize from this checkpoint instead of the artifact's init.ckpt
    pub init_from: Option<PathBuf>,
    /// re-solve per-layer step sizes from the initial weights
    pub resolve_deltas: bool,
    pub verbose: bool,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment {
            name: "unnamed".into(),
            artifact: "smoke".into(),
            dataset: Preset::SynthMnist,
            train_n: 2048,
            test_n: 512,
            epochs: 10,
            lr0: 0.01,
            lr_end: 0.001,
            lambda_kind: "exp".into(),
            lambda0: 10.0,
            lambda_growth: 9.0,
            augment: false,
            seed: 0,
            steps_per_epoch: None,
            track_modes: false,
            hist_epochs: Vec::new(),
            hist_layers: Vec::new(),
            init_from: None,
            resolve_deltas: true,
            verbose: true,
        }
    }
}

impl Experiment {
    /// Parse a TOML preset file.
    pub fn from_toml_file(path: &Path) -> Result<Experiment> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Experiment::from_toml(&src)
    }

    pub fn from_toml(src: &str) -> Result<Experiment> {
        let t = Toml::parse(src)?;
        let d = Experiment::default();
        let dataset_name = t.str_or("data", "dataset", "synth-mnist");
        let dataset = Preset::parse(&dataset_name)
            .with_context(|| format!("unknown dataset {dataset_name:?}"))?;
        Ok(Experiment {
            name: t.str_or("", "name", &d.name),
            artifact: t.str_or("", "artifact", &d.artifact),
            dataset,
            train_n: t.usize_or("data", "train_n", d.train_n),
            test_n: t.usize_or("data", "test_n", d.test_n),
            epochs: t.usize_or("train", "epochs", d.epochs as usize) as u32,
            lr0: t.f64_or("train", "lr0", d.lr0 as f64) as f32,
            lr_end: t.f64_or("train", "lr_end", d.lr_end as f64) as f32,
            lambda_kind: t.str_or("train", "lambda_kind", &d.lambda_kind),
            lambda0: t.f64_or("train", "lambda0", d.lambda0 as f64) as f32,
            lambda_growth: t.f64_or("train", "lambda_growth", d.lambda_growth as f64) as f32,
            augment: t.bool_or("data", "augment", d.augment),
            seed: t.usize_or("", "seed", d.seed as usize) as u64,
            steps_per_epoch: match t.usize_or("train", "steps_per_epoch", 0) {
                0 => None,
                n => Some(n),
            },
            track_modes: t.bool_or("probe", "track_modes", d.track_modes),
            hist_epochs: t
                .get("probe", "hist_epochs")
                .and_then(|j| j.usize_vec().ok())
                .map(|v| v.into_iter().map(|x| x as u32).collect())
                .unwrap_or_default(),
            hist_layers: t
                .get("probe", "hist_layers")
                .and_then(|j| j.usize_vec().ok())
                .unwrap_or_default(),
            init_from: {
                let s = t.str_or("", "init_from", "");
                (!s.is_empty()).then(|| PathBuf::from(s))
            },
            resolve_deltas: t.bool_or("", "resolve_deltas", d.resolve_deltas),
            verbose: t.bool_or("", "verbose", d.verbose),
        })
    }

    /// Resolve the artifact directory against an artifacts root.
    pub fn artifact_dir(&self, root: &Path) -> PathBuf {
        let p = Path::new(&self.artifact);
        if p.is_absolute() {
            p.to_path_buf()
        } else {
            root.join(p)
        }
    }

    pub fn lambda_schedule(&self) -> LambdaSchedule {
        match self.lambda_kind.as_str() {
            "exp" => LambdaSchedule::Exponential {
                lambda0: self.lambda0,
                alpha: self.lambda_growth / self.epochs.max(1) as f32,
            },
            "linear" => LambdaSchedule::Linear {
                lambda0: self.lambda0,
                growth: self.lambda_growth.exp(), // match exp's endpoint
                epochs: self.epochs,
            },
            "const" => LambdaSchedule::Constant { lambda0: self.lambda0 },
            _ => LambdaSchedule::Off,
        }
    }

    /// Materialize `TrainOptions` for the coordinator.
    pub fn train_options(&self) -> TrainOptions {
        TrainOptions {
            epochs: self.epochs,
            lr: LrSchedule { eta0: self.lr0, eta_e: self.lr_end, epochs: self.epochs },
            lambda: self.lambda_schedule(),
            seed: self.seed,
            augment: if self.augment { AugmentConfig::cifar() } else { AugmentConfig::none() },
            steps_per_epoch: self.steps_per_epoch,
            track_modes: self.track_modes,
            hist_epochs: self.hist_epochs.clone(),
            hist_layers: self.hist_layers.clone(),
            hist_bins: 61,
            verbose: self.verbose,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name = "vgg7-cifar10"
artifact = "vgg7-symog-synth-cifar10-w0.25-b2"
seed = 3

[data]
dataset = "synth-cifar10"
train_n = 4096
test_n = 1024
augment = true

[train]
epochs = 30
lr0 = 0.01
lr_end = 0.001
lambda_kind = "exp"
lambda0 = 10
lambda_growth = 9

[probe]
track_modes = true
hist_epochs = [0, 10, 30]
hist_layers = [0, 3, 6]
"#;

    #[test]
    fn full_preset_parses() {
        let e = Experiment::from_toml(SAMPLE).unwrap();
        assert_eq!(e.name, "vgg7-cifar10");
        assert_eq!(e.dataset, Preset::SynthCifar10);
        assert!(e.augment);
        assert_eq!(e.epochs, 30);
        assert_eq!(e.hist_layers, vec![0, 3, 6]);
        assert_eq!(e.seed, 3);
        let opts = e.train_options();
        assert_eq!(opts.epochs, 30);
        assert!(opts.track_modes);
        // paper schedule: lambda grows e^9 over the run
        let s = e.lambda_schedule();
        assert!((s.at(30) / s.at(0) - (9f32).exp()).abs() / (9f32).exp() < 1e-3);
    }

    #[test]
    fn defaults_fill_gaps() {
        let e = Experiment::from_toml("name = \"x\"").unwrap();
        assert_eq!(e.epochs, 10);
        assert_eq!(e.dataset, Preset::SynthMnist);
        assert!(e.resolve_deltas);
        assert!(e.init_from.is_none());
    }

    #[test]
    fn lambda_kinds() {
        for (kind, expect0) in [("exp", 10.0f32), ("const", 10.0), ("off", 0.0)] {
            let src = format!("[train]\nlambda_kind = \"{kind}\"\nlambda0 = 10\n");
            let e = Experiment::from_toml(&src).unwrap();
            assert_eq!(e.lambda_schedule().at(0), expect0, "{kind}");
        }
    }

    #[test]
    fn unknown_dataset_rejected() {
        assert!(Experiment::from_toml("[data]\ndataset = \"imagenet\"").is_err());
    }

    #[test]
    fn artifact_dir_resolution() {
        let e = Experiment { artifact: "foo".into(), ..Default::default() };
        assert_eq!(e.artifact_dir(Path::new("/a")), PathBuf::from("/a/foo"));
        let e2 = Experiment { artifact: "/abs/foo".into(), ..Default::default() };
        assert_eq!(e2.artifact_dir(Path::new("/a")), PathBuf::from("/abs/foo"));
    }
}
