// Probe: does PJRT untuple multi-output roots into result[0][k]?
use anyhow::Result;
fn main() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("/tmp/multi_nt.hlo.txt")?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]);
    let y = xla::Literal::vec1(&[10f32, 20., 30., 40.]);
    let out = exe.execute::<xla::Literal>(&[x, y])?;
    println!("replicas={} outputs_per_replica={}", out.len(), out[0].len());
    for (i, b) in out[0].iter().enumerate() {
        let lit = b.to_literal_sync()?;
        println!("out[{}] shape={:?}", i, lit.shape()?);
    }
    // chain: feed out buffers back via execute_b
    let out2 = exe.execute_b(&[&out[0][0], &out[0][1]])?;
    let l = out2[0][0].to_literal_sync()?;
    println!("chained out0 = {:?}", l.to_vec::<f32>()?);
    Ok(())
}
