//! CI regression gate for the integer inference hot path.
//!
//! Compares the `BENCH_hotpath.json` that `cargo bench --bench hotpath`
//! just wrote against the committed `BENCH_baseline.json` and exits
//! non-zero if any case's *speedup ratio* regressed more than the
//! tolerance (default 30%). Four ratio families are gated side by side:
//! naive-vs-GEMM kernel speedups, interpret-vs-planned whole-model
//! forwards (`kind: "planned_forward"` — the `ExecPlan` arena + fused
//! epilogue path must stay ahead of the per-call GEMM walk), serving
//! throughput (`kind: "serve_throughput"` — N closed-loop client threads
//! through `serve::Server` vs solo batch-1 planned forwards of the same
//! corpus), and fan-out dispatch (`kind: "pool_dispatch"` — the
//! persistent parked pool vs spawn-per-call scoped threads on
//! dispatch-dominated chunk sizes; run the bench with
//! `SYMOG_HOTPATH=gemm,serve,bitslice,pool` so every gated family lands
//! in one report). Ratios are compared — not wall-clock seconds — so the
//! gate is machine-speed-invariant: both numbers of a ratio come from
//! the same host.
//!
//!     bench_check [--current PATH] [--baseline PATH] [--tolerance 0.30]

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use symog::util::json::Json;

struct Case {
    name: String,
    speedup: f64,
    bit_identical: bool,
}

fn load_cases(path: &Path) -> Result<Vec<Case>> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&src).with_context(|| format!("parsing {}", path.display()))?;
    j.get("cases")?
        .arr()?
        .iter()
        .map(|c| {
            Ok(Case {
                name: c.get("name")?.str()?.to_string(),
                speedup: c.get("speedup")?.num()?,
                bit_identical: c
                    .opt("bit_identical")
                    .map(|b| b.boolean())
                    .transpose()?
                    .unwrap_or(true),
            })
        })
        .collect()
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("bench_check: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut current = PathBuf::from("../BENCH_hotpath.json");
    let mut baseline = PathBuf::from("../BENCH_baseline.json");
    let mut tolerance = 0.30f64;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut val = |name: &str| {
            args.next().with_context(|| format!("{name} needs a value"))
        };
        match a.as_str() {
            "--current" => current = PathBuf::from(val("--current")?),
            "--baseline" => baseline = PathBuf::from(val("--baseline")?),
            "--tolerance" => {
                tolerance = val("--tolerance")?
                    .parse()
                    .context("--tolerance must be a float")?
            }
            other => bail!("unknown flag {other:?}"),
        }
    }
    // also accept repo-root-relative paths when invoked from the repo root
    for p in [&mut current, &mut baseline] {
        if !p.exists() {
            if let Some(name) = p.file_name() {
                let flat = PathBuf::from(name);
                if flat.exists() {
                    *p = flat;
                }
            }
        }
    }

    let cur = load_cases(&current).context(
        "no current bench report — run `cargo bench --bench hotpath` first \
         (SYMOG_HOTPATH=gemm,serve,bitslice,pool covers every gated case)",
    )?;
    let base = load_cases(&baseline)?;
    anyhow::ensure!(!base.is_empty(), "baseline has no cases");

    println!(
        "{:<32} {:>10} {:>10} {:>8}  verdict (tolerance {:.0}%)",
        "kernel", "baseline", "current", "ratio", tolerance * 100.0
    );
    let mut failures = Vec::new();
    for b in &base {
        let Some(c) = cur.iter().find(|c| c.name == b.name) else {
            failures.push(format!("{}: missing from current report", b.name));
            continue;
        };
        if !c.bit_identical {
            failures.push(format!("{}: GEMM output no longer bit-identical", b.name));
        }
        let floor = b.speedup * (1.0 - tolerance);
        let ratio = c.speedup / b.speedup;
        let ok = c.speedup >= floor;
        println!(
            "{:<32} {:>9.2}x {:>9.2}x {:>7.2}x  {}",
            b.name,
            b.speedup,
            c.speedup,
            ratio,
            if ok { "ok" } else { "REGRESSED" }
        );
        if !ok {
            failures.push(format!(
                "{}: speedup {:.2}x < floor {:.2}x (baseline {:.2}x)",
                b.name, c.speedup, floor, b.speedup
            ));
        }
    }
    if !failures.is_empty() {
        bail!("{} kernel(s) regressed:\n  {}", failures.len(), failures.join("\n  "));
    }
    println!("all {} kernels within tolerance", base.len());
    Ok(())
}
