//! Manifest: the flat calling convention + layer graph emitted by aot.py.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Metadata for one trainable tensor.
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// "weight" | "bias" | "gamma" | "beta"
    pub kind: String,
    /// index into the deltas vector for quantized weights
    pub qidx: Option<usize>,
    pub fan_in: usize,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn is_quantized(&self) -> bool {
        self.kind == "weight"
    }
}

/// Metadata for one non-trainable tensor (BN running stats).
#[derive(Clone, Debug)]
pub struct StateMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: f32,
}

impl StateMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One layer of the model graph (consumed by the integer inference engine).
/// Kept as raw JSON plus typed accessors — layer dicts are heterogeneous.
#[derive(Clone, Debug)]
pub struct LayerDesc(pub Json);

impl LayerDesc {
    pub fn ty(&self) -> &str {
        self.0.get("type").and_then(|j| j.str()).unwrap_or("?")
    }

    pub fn usize_field(&self, key: &str) -> Option<usize> {
        self.0.opt(key).and_then(|j| j.usize().ok())
    }

    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.0.opt(key).and_then(|j| j.str().ok())
    }

    /// Param index fields ("w", "b", "gamma", "beta") — absent or null -> None.
    pub fn param_idx(&self, key: &str) -> Option<usize> {
        match self.0.opt(key) {
            Some(j) if !j.is_null() => j.usize().ok(),
            _ => None,
        }
    }
}

/// The parsed manifest of one compiled configuration.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tag: String,
    pub model: String,
    pub method: String,
    pub dataset: String,
    pub width_mult: f64,
    pub batch: usize,
    pub n_bits: u32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub clip: bool,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    pub n_quant: usize,
    pub params: Vec<ParamMeta>,
    pub state: Vec<StateMeta>,
    pub layers: Vec<LayerDesc>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).context("parsing manifest JSON")?;
        let params = j
            .get("params")?
            .arr()?
            .iter()
            .map(|p| {
                Ok(ParamMeta {
                    name: p.get("name")?.str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                    kind: p.get("kind")?.str()?.to_string(),
                    qidx: match p.get("qidx")? {
                        Json::Null => None,
                        q => Some(q.usize()?),
                    },
                    fan_in: p.get("fan_in")?.usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let state = j
            .get("state")?
            .arr()?
            .iter()
            .map(|s| {
                Ok(StateMeta {
                    name: s.get("name")?.str()?.to_string(),
                    shape: s.get("shape")?.usize_vec()?,
                    init: s.get("init")?.num()? as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let layers = j
            .get("layers")?
            .arr()?
            .iter()
            .map(|l| LayerDesc(l.clone()))
            .collect();
        let ishape = j.get("input_shape")?.usize_vec()?;
        anyhow::ensure!(ishape.len() == 3, "input_shape must be HWC");
        Ok(Manifest {
            tag: j.get("tag")?.str()?.to_string(),
            model: j.get("model")?.str()?.to_string(),
            method: j.get("method")?.str()?.to_string(),
            dataset: j.get("dataset")?.str()?.to_string(),
            width_mult: j.get("width_mult")?.num()?,
            batch: j.get("batch")?.usize()?,
            n_bits: j.get("n_bits")?.usize()? as u32,
            momentum: j.get("momentum")?.num()? as f32,
            weight_decay: j.get("weight_decay")?.num()? as f32,
            clip: j.get("clip")?.boolean()?,
            input_shape: [ishape[0], ishape[1], ishape[2]],
            num_classes: j.get("num_classes")?.usize()?,
            n_quant: j.get("n_quant")?.usize()?,
            params,
            state,
            layers,
        })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&src)
    }

    /// Serialize back to manifest JSON — the inverse of [`Manifest::parse`],
    /// used to embed manifests in on-disk formats (`.fxpm`, `.fxpa`).
    /// Numbers are written with `f64`'s round-trip `Display`, so
    /// `parse(&m.to_json())` reconstructs every field exactly.
    pub fn to_json(&self) -> String {
        fn shape(s: &[usize]) -> Json {
            Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect())
        }
        fn obj(fields: Vec<(&str, Json)>) -> Json {
            Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
        }
        let params = self
            .params
            .iter()
            .map(|p| {
                obj(vec![
                    ("name", Json::Str(p.name.clone())),
                    ("shape", shape(&p.shape)),
                    ("kind", Json::Str(p.kind.clone())),
                    ("qidx", p.qidx.map_or(Json::Null, |q| Json::Num(q as f64))),
                    ("fan_in", Json::Num(p.fan_in as f64)),
                ])
            })
            .collect();
        let state = self
            .state
            .iter()
            .map(|s| {
                obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    ("shape", shape(&s.shape)),
                    ("init", Json::Num(s.init as f64)),
                ])
            })
            .collect();
        let layers = self.layers.iter().map(|l| l.0.clone()).collect();
        obj(vec![
            ("tag", Json::Str(self.tag.clone())),
            ("model", Json::Str(self.model.clone())),
            ("method", Json::Str(self.method.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("width_mult", Json::Num(self.width_mult)),
            ("batch", Json::Num(self.batch as f64)),
            ("n_bits", Json::Num(self.n_bits as f64)),
            ("momentum", Json::Num(self.momentum as f64)),
            ("weight_decay", Json::Num(self.weight_decay as f64)),
            ("clip", Json::Bool(self.clip)),
            ("input_shape", shape(&self.input_shape)),
            ("num_classes", Json::Num(self.num_classes as f64)),
            ("n_quant", Json::Num(self.n_quant as f64)),
            ("params", Json::Arr(params)),
            ("state", Json::Arr(state)),
            ("layers", Json::Arr(layers)),
        ])
        .to_string()
    }

    /// Total trainable parameters.
    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Length of the deltas vector ((max(n_quant, 1),) in aot.py).
    pub fn deltas_len(&self) -> usize {
        self.n_quant.max(1)
    }

    /// Number of inputs of the train executable.
    pub fn train_arity(&self) -> usize {
        2 + 2 * self.params.len() + self.state.len() + 3
    }

    /// Number of outputs of the train executable.
    pub fn train_outputs(&self) -> usize {
        2 + 2 * self.params.len() + self.state.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "tag":"t","model":"mlp","method":"symog","dataset":"synth-mnist",
      "width_mult":1.0,"batch":8,"n_bits":2,"momentum":0.9,
      "weight_decay":0.0,"clip":true,"use_pallas":true,
      "input_shape":[28,28,1],"num_classes":10,"n_quant":2,
      "params":[
        {"name":"l1.dense.w","shape":[784,16],"kind":"weight","qidx":0,"fan_in":784},
        {"name":"l1.dense.b","shape":[16],"kind":"bias","qidx":null,"fan_in":0},
        {"name":"l2.dense.w","shape":[16,10],"kind":"weight","qidx":1,"fan_in":16}
      ],
      "state":[{"name":"bn.m","shape":[16],"init":0.0}],
      "layers":[{"type":"flatten"},{"type":"dense","out_f":16,"w":0,"b":1,"use_bias":true}],
      "artifacts":{"train":"train.hlo.txt"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "mlp");
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.params[0].qidx, Some(0));
        assert_eq!(m.params[1].qidx, None);
        assert_eq!(m.num_params(), 784 * 16 + 16 + 160);
        assert_eq!(m.train_arity(), 2 + 6 + 1 + 3);
        assert_eq!(m.train_outputs(), 2 + 6 + 1);
        assert_eq!(m.input_shape, [28, 28, 1]);
        assert_eq!(m.layers[1].ty(), "dense");
        assert_eq!(m.layers[1].param_idx("w"), Some(0));
        assert_eq!(m.layers[0].param_idx("w"), None);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"tag":"x"}"#).is_err());
    }

    #[test]
    fn to_json_roundtrips_exactly() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let m2 = Manifest::parse(&m.to_json()).unwrap();
        assert_eq!(m2.tag, m.tag);
        assert_eq!(m2.width_mult, m.width_mult);
        assert_eq!(m2.momentum, m.momentum);
        assert_eq!(m2.n_bits, m.n_bits);
        assert_eq!(m2.clip, m.clip);
        assert_eq!(m2.input_shape, m.input_shape);
        assert_eq!(m2.n_quant, m.n_quant);
        assert_eq!(m2.params.len(), m.params.len());
        for (a, b) in m2.params.iter().zip(&m.params) {
            assert_eq!((&a.name, &a.shape, &a.kind), (&b.name, &b.shape, &b.kind));
            assert_eq!((a.qidx, a.fan_in), (b.qidx, b.fan_in));
        }
        assert_eq!(m2.state.len(), m.state.len());
        assert_eq!(m2.state[0].init, m.state[0].init);
        assert_eq!(m2.layers.len(), m.layers.len());
        assert_eq!(m2.layers[1].param_idx("w"), m.layers[1].param_idx("w"));
        // a second round trip is a fixed point: the writer is deterministic
        assert_eq!(m2.to_json(), Manifest::parse(&m2.to_json()).unwrap().to_json());
    }
}
