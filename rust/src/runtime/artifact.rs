//! XlaArtifact: a compiled XLA training configuration — the manifest plus
//! the three PJRT executables (train / eval / evalq) aot.py emitted as HLO
//! text. Not to be confused with the *serving* artifact (`.fxpa`,
//! `crate::artifact`), which holds packed fixed-point weights and no
//! executables.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::{Manifest, Runtime};

/// A loaded AOT artifact directory. Executables are compiled eagerly at
/// load.
pub struct XlaArtifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub train: xla::PjRtLoadedExecutable,
    pub eval: xla::PjRtLoadedExecutable,
    pub evalq: xla::PjRtLoadedExecutable,
}

impl XlaArtifact {
    pub fn load(rt: &Runtime, dir: &Path) -> Result<XlaArtifact> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest in {}", dir.display()))?;
        let train = rt.load_hlo(&dir.join("train.hlo.txt"))?;
        let eval = rt.load_hlo(&dir.join("eval.hlo.txt"))?;
        let evalq = rt.load_hlo(&dir.join("evalq.hlo.txt"))?;
        Ok(XlaArtifact { dir: dir.to_path_buf(), manifest, train, eval, evalq })
    }

    /// Path of the init checkpoint written by aot.py.
    pub fn init_ckpt(&self) -> PathBuf {
        self.dir.join("init.ckpt")
    }
}
