//! Artifact: one compiled configuration (manifest + train/eval/evalq).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::{Manifest, Runtime};

/// A loaded artifact directory. Executables are compiled eagerly at load.
pub struct Artifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub train: xla::PjRtLoadedExecutable,
    pub eval: xla::PjRtLoadedExecutable,
    pub evalq: xla::PjRtLoadedExecutable,
}

impl Artifact {
    pub fn load(rt: &Runtime, dir: &Path) -> Result<Artifact> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest in {}", dir.display()))?;
        let train = rt.load_hlo(&dir.join("train.hlo.txt"))?;
        let eval = rt.load_hlo(&dir.join("eval.hlo.txt"))?;
        let evalq = rt.load_hlo(&dir.join("evalq.hlo.txt"))?;
        Ok(Artifact { dir: dir.to_path_buf(), manifest, train, eval, evalq })
    }

    /// Path of the init checkpoint written by aot.py.
    pub fn init_ckpt(&self) -> PathBuf {
        self.dir.join("init.ckpt")
    }
}
