//! Runtime: load AOT artifacts (HLO text) and execute them on PJRT.
//!
//! This wraps the `xla` crate's PJRT CPU client. One `XlaArtifact` bundles
//! the three executables of a compiled configuration (train / eval / evalq)
//! with its manifest. Interchange is HLO *text* — see aot.py for why.
//! (The *serving* artifact — packed fixed-point weights, no executables —
//! is `crate::artifact`; the XLA prefix keeps the two apart.)

mod artifact;
mod manifest;
mod tensor;

pub use artifact::XlaArtifact;

/// Pre-rename alias for [`XlaArtifact`] (this type held PJRT executables
/// and collided with the `.fxpa` serving artifact in `crate::artifact`).
#[deprecated(note = "renamed to XlaArtifact; `Artifact` now means the .fxpa serving artifact")]
pub type Artifact = XlaArtifact;
pub use manifest::{LayerDesc, Manifest, ParamMeta, StateMeta};
pub use tensor::{literal_f32, literal_i32, literal_scalar_f32, to_f32_vec};

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client + executable loader. Create once, share everywhere.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this image).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it into an executable.
    pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Load a full artifact directory (manifest + 3 executables).
    pub fn load_artifact(&self, dir: &Path) -> Result<XlaArtifact> {
        XlaArtifact::load(self, dir)
    }
}

/// Execute with literal inputs and untuple the single tuple output into a
/// flat literal vector (aot.py lowers with return_tuple=True).
pub fn run<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[L],
) -> Result<Vec<xla::Literal>> {
    let out = exe.execute(args).context("PJRT execute")?;
    let lit = out[0][0].to_literal_sync().context("download result")?;
    lit.to_tuple().context("untuple result")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_hlo_is_error() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
