//! Literal <-> host-buffer helpers.

use anyhow::{Context, Result};

/// Build an f32 literal of the given dimensions from a host slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "data len {} != prod(dims {:?})", data.len(), dims);
    let lit = xla::Literal::vec1(data);
    if dims.is_empty() {
        // () scalar: reshape the 1-element vector
        return lit.reshape(&[]).context("reshape to scalar");
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).context("reshape literal")
}

/// Build an i32 literal (labels) of the given dimensions.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "data len {} != prod(dims {:?})", data.len(), dims);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims_i64).context("reshape literal")
}

/// f32 scalar literal.
pub fn literal_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Download an f32 literal to a host vector.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to_vec<f32>")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
    }

    #[test]
    fn scalar_literal() {
        let lit = literal_scalar_f32(3.5);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![3.5]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn labels_i32() {
        let lit = literal_i32(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }
}
