//! Operation counting + energy cost model.
//!
//! Energy-per-op numbers follow the 45 nm measurements popularized by
//! Horowitz (ISSCC 2014) and used by the survey the paper cites (Sze et
//! al. 2017) — the source of the intro's "8-bit fixed-point multiplication
//! requires 18.5x less energy than 32-bit floating-point" motivation.

/// Raw operation counts accumulated by the integer engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// integer accumulator adds (the MACs' add half; for ternary weights
    /// this is the *entire* MAC)
    pub acc_adds: u64,
    /// integer multiplies that could not be reduced to add/sub/skip
    pub int_mults: u64,
    /// rounding bit shifts (requantization, pooling divides)
    pub shifts: u64,
    /// comparisons (ReLU, max-pool)
    pub compares: u64,
}

impl OpCounts {
    pub fn total(&self) -> u64 {
        self.acc_adds + self.int_mults + self.shifts + self.compares
    }

    /// Accumulate another count set into this one.
    pub fn merge(&mut self, other: &OpCounts) {
        self.acc_adds += other.acc_adds;
        self.int_mults += other.int_mults;
        self.shifts += other.shifts;
        self.compares += other.compares;
    }
}

/// Energy per operation in picojoules (45 nm, Horowitz ISSCC 2014).
#[derive(Clone, Copy, Debug)]
pub struct EnergyTable {
    pub f32_mult: f64,
    pub f32_add: f64,
    pub i32_mult: f64,
    pub i32_add: f64,
    pub i8_mult: f64,
    pub i8_add: f64,
    /// shift / compare are modeled at the 8-bit-add scale
    pub misc: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        EnergyTable {
            f32_mult: 3.7,
            f32_add: 0.9,
            i32_mult: 3.1,
            i32_add: 0.1,
            i8_mult: 0.2,
            i8_add: 0.03,
            misc: 0.03,
        }
    }
}

/// The summary the `cost-report` command prints.
#[derive(Clone, Debug)]
pub struct CostReport {
    /// MAC count of the float reference model (one f32 mult + add each)
    pub float_macs: u64,
    pub counts: OpCounts,
    pub float_energy_pj: f64,
    pub fixed_energy_pj: f64,
    /// model size in bytes at 32-bit float vs N-bit fixed point
    pub float_bytes: u64,
    pub fixed_bytes: u64,
}

impl CostReport {
    pub fn energy_ratio(&self) -> f64 {
        self.float_energy_pj / self.fixed_energy_pj.max(1e-12)
    }

    pub fn compression_ratio(&self) -> f64 {
        self.float_bytes as f64 / self.fixed_bytes.max(1) as f64
    }

    pub fn render(&self) -> String {
        format!(
            "float model : {} MACs, {:.3} uJ, {} KiB\n\
             fixed model : {} adds + {} mults + {} shifts + {} cmps, {:.3} uJ, {} KiB\n\
             energy ratio: {:.1}x    model size ratio: {:.1}x",
            self.float_macs,
            self.float_energy_pj / 1e6,
            self.float_bytes / 1024,
            self.counts.acc_adds,
            self.counts.int_mults,
            self.counts.shifts,
            self.counts.compares,
            self.fixed_energy_pj / 1e6,
            self.fixed_bytes / 1024,
            self.energy_ratio(),
            self.compression_ratio(),
        )
    }
}

/// Builds cost reports from op counts + model metadata.
pub struct CostModel {
    pub table: EnergyTable,
    pub n_bits: u32,
}

impl CostModel {
    pub fn new(n_bits: u32) -> CostModel {
        CostModel { table: EnergyTable::default(), n_bits }
    }

    /// `float_macs`: MACs of the float model (== acc_adds of the integer
    /// engine's conv/dense). `param_count`: weights in quantized layers.
    /// `other_params`: float-kept parameters (bias/BN).
    pub fn report(
        &self,
        counts: OpCounts,
        float_macs: u64,
        param_count: u64,
        other_params: u64,
    ) -> CostReport {
        let t = &self.table;
        let float_energy = float_macs as f64 * (t.f32_mult + t.f32_add);
        // fixed energy: accumulator adds at i32-add cost, residual mults at
        // i8-mult cost (mantissas are narrow), shifts/compares at misc cost
        let fixed_energy = counts.acc_adds as f64 * t.i32_add
            + counts.int_mults as f64 * t.i8_mult
            + (counts.shifts + counts.compares) as f64 * t.misc;
        CostReport {
            float_macs,
            counts,
            float_energy_pj: float_energy,
            fixed_energy_pj: fixed_energy,
            float_bytes: (param_count + other_params) * 4,
            // N-bit weights packed + fp32 auxiliaries kept
            fixed_bytes: (param_count * self.n_bits as u64).div_ceil(8) + other_params * 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ternary_energy_advantage_exceeds_paper_8bit_claim() {
        // ternary conv: all MACs become i32 adds; the paper's 8-bit claim
        // is 18.5x, ternary should beat it comfortably
        let counts = OpCounts { acc_adds: 1_000_000, ..Default::default() };
        let report = CostModel::new(2).report(counts, 1_000_000, 100_000, 1_000);
        assert!(report.energy_ratio() > 18.5, "ratio {}", report.energy_ratio());
    }

    #[test]
    fn compression_near_16x_for_2bit() {
        let report =
            CostModel::new(2).report(OpCounts::default(), 0, 1_000_000, 0);
        assert!((report.compression_ratio() - 16.0).abs() < 0.1);
    }

    #[test]
    fn aux_params_reduce_compression() {
        let with_aux = CostModel::new(2).report(OpCounts::default(), 0, 1_000_000, 100_000);
        assert!(with_aux.compression_ratio() < 16.0);
        assert!(with_aux.compression_ratio() > 5.0);
    }

    #[test]
    fn counts_add() {
        let mut a = OpCounts { acc_adds: 1, int_mults: 2, shifts: 3, compares: 4 };
        a.merge(&OpCounts { acc_adds: 10, int_mults: 20, shifts: 30, compares: 40 });
        assert_eq!(a.total(), 110);
    }

    #[test]
    fn render_contains_ratio() {
        let counts = OpCounts { acc_adds: 100, ..Default::default() };
        let r = CostModel::new(2).report(counts, 100, 1000, 10);
        assert!(r.render().contains("energy ratio"));
    }
}
