//! Arena buffers for planned execution.
//!
//! An `ExecPlan` (see `plan.rs`) resolves every intermediate shape once, so
//! all activation storage for a whole forward pass can be preallocated:
//!
//! * two **ping-pong slots** that transient layer outputs alternate
//!   between (each sized to the largest tensor that ever lands in it);
//! * one **retained slot** per concat source, so skip/concat tensors are
//!   written once and read in place — no per-forward clone;
//! * flat **side scratch**: per-worker im2col patch panels, per-worker
//!   amax reduction cells, i64 pooling accumulators, and the per-layer
//!   bias/BN constant encodings (which depend on the runtime exponent).
//!
//! Everything lives in one `Scratch` value. A `Scratch` is cheap relative
//! to the shared `ExecPlan` (it is just buffers — no weights), is built
//! for exactly one plan (checked via `plan_id`), and after the first
//! `ExecPlan::run` never grows again: steady-state forwards perform zero
//! allocation inside the arena (asserted by `Scratch::fingerprint` in the
//! allocation-discipline test).
//!
//! `ScratchPool` is the one checkout/return implementation sitting on top:
//! both `IntModel`'s internal forward pooling and the serving layer
//! (`serve::Server`) draw warm scratches from it. The pool is bounded (it
//! never holds, nor creates through [`ScratchPool::checkout`], more than
//! `cap` scratches over its lifetime), so a warmed pool is a *fixed set*
//! of allocations — `ScratchPool::fingerprints` exposes that set and the
//! serve concurrency test asserts it is stable under load.

/// Index of one preallocated activation buffer in the arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Slot(pub(crate) usize);

/// Per-thread mutable state for `ExecPlan::run`: the activation arena plus
/// all side scratch. One `Scratch` per concurrently-running forward; the
/// plan itself stays shared and immutable.
pub struct Scratch {
    /// activation buffers, indexed by `Slot`
    pub(crate) bufs: Vec<Vec<i32>>,
    /// current binary-point position of each slot's contents
    pub(crate) fracs: Vec<i32>,
    /// im2col patch panels, `workers` contiguous regions of `patch_len`
    pub(crate) patches: Vec<i32>,
    pub(crate) patch_len: usize,
    /// per-worker |mantissa| maxima for requantization reductions
    pub(crate) amax: Vec<i64>,
    /// i64 accumulators for average pooling
    pub(crate) wide: Vec<i64>,
    /// bias mantissas encoded at the runtime exponent (len = max cout)
    pub(crate) bias_enc: Vec<i64>,
    /// folded-BN offsets aligned to the runtime product exponent
    pub(crate) bn_enc: Vec<i64>,
    /// the plan this scratch was sized for
    pub(crate) plan_id: u64,
    /// largest batch the activation slots can hold (full-size scratches
    /// carry the plan's `max_batch`; serving row scratches carry 1)
    pub(crate) cap_batch: usize,
}

impl Scratch {
    /// Allocate a scratch sized by the plan's capacity table. All buffers
    /// get their final length here; `run` only ever writes into them.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn sized(
        plan_id: u64,
        slot_caps: &[usize],
        cap_batch: usize,
        workers: usize,
        patch_len: usize,
        wide_len: usize,
        chan_len: usize,
    ) -> Scratch {
        Scratch {
            bufs: slot_caps.iter().map(|&c| vec![0i32; c]).collect(),
            fracs: vec![0; slot_caps.len()],
            patches: vec![0i32; workers * patch_len],
            patch_len,
            amax: vec![0i64; workers],
            wide: vec![0i64; wide_len],
            bias_enc: vec![0i64; chan_len],
            bn_enc: vec![0i64; chan_len],
            plan_id,
            cap_batch,
        }
    }

    /// (pointer, capacity) of every arena-owned allocation — stable across
    /// steady-state runs. The allocation-discipline test snapshots this
    /// after the first forward and asserts it never changes.
    pub fn fingerprint(&self) -> Vec<(usize, usize)> {
        let mut fp: Vec<(usize, usize)> = self
            .bufs
            .iter()
            .map(|b| (b.as_ptr() as usize, b.capacity()))
            .collect();
        fp.push((self.fracs.as_ptr() as usize, self.fracs.capacity()));
        fp.push((self.patches.as_ptr() as usize, self.patches.capacity()));
        fp.push((self.amax.as_ptr() as usize, self.amax.capacity()));
        fp.push((self.wide.as_ptr() as usize, self.wide.capacity()));
        fp.push((self.bias_enc.as_ptr() as usize, self.bias_enc.capacity()));
        fp.push((self.bn_enc.as_ptr() as usize, self.bn_enc.capacity()));
        fp
    }

    /// Total bytes held by the activation slots (reported by examples/docs).
    pub fn arena_bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.capacity() * std::mem::size_of::<i32>()).sum()
    }
}

/// Bounded checkout/return pool of warm `Scratch` values for one plan.
///
/// Two usage styles share this type:
/// * `IntModel::forward` pops with [`try_take`](ScratchPool::try_take) and
///   falls back to a transient scratch when the pool runs dry (unbounded
///   concurrency, bounded *pooling*);
/// * the serving layer checks out with [`checkout`](ScratchPool::checkout),
///   which lazily creates scratches until the lifetime bound `cap` is
///   reached and never past it — so after warmup the pool is a fixed,
///   fingerprint-stable set of allocations (zero steady-state growth).
pub struct ScratchPool {
    inner: std::sync::Mutex<PoolInner>,
    cap: usize,
}

struct PoolInner {
    free: Vec<Scratch>,
    /// scratches ever created *through* `checkout` (the serve-side bound)
    created: usize,
}

impl ScratchPool {
    pub fn new(cap: usize) -> ScratchPool {
        ScratchPool {
            inner: std::sync::Mutex::new(PoolInner { free: Vec::new(), created: 0 }),
            cap: cap.max(1),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Pop one warm scratch if any is free (never creates).
    pub fn try_take(&self) -> Option<Scratch> {
        self.lock().free.pop()
    }

    /// Check out up to `want` scratches: pops free ones first, then creates
    /// via `mk` while the lifetime-created count is below the pool bound.
    /// May return fewer than `want` (even zero) when the pool is saturated.
    pub fn checkout(&self, want: usize, mk: &mut dyn FnMut() -> Scratch) -> Vec<Scratch> {
        let mut g = self.lock();
        let mut out = Vec::with_capacity(want.min(self.cap));
        while out.len() < want {
            if let Some(s) = g.free.pop() {
                out.push(s);
            } else if g.created < self.cap {
                g.created += 1;
                out.push(mk());
            } else {
                break;
            }
        }
        out
    }

    /// Return one scratch; dropped silently once `cap` are already free.
    pub fn put(&self, s: Scratch) {
        let mut g = self.lock();
        if g.free.len() < self.cap {
            g.free.push(s);
        }
    }

    /// Return a batch of scratches (see [`put`](ScratchPool::put)).
    pub fn put_all(&self, scratches: impl IntoIterator<Item = Scratch>) {
        let mut g = self.lock();
        for s in scratches {
            if g.free.len() < self.cap {
                g.free.push(s);
            }
        }
    }

    /// Scratches created through `checkout` over the pool's lifetime.
    pub fn created(&self) -> usize {
        self.lock().created
    }

    /// Fingerprints of every currently-free scratch, sorted so the result
    /// is a canonical *set* snapshot: if no scratch is in flight, two equal
    /// snapshots mean the pool neither grew nor reallocated in between.
    pub fn fingerprints(&self) -> Vec<Vec<(usize, usize)>> {
        let g = self.lock();
        let mut fps: Vec<Vec<(usize, usize)>> =
            g.free.iter().map(|s| s.fingerprint()).collect();
        fps.sort();
        fps
    }
}

/// Two disjoint `&mut` borrows out of one slice (stable-Rust split_at_mut
/// dance; `slice::get_disjoint_mut` postdates our MSRV).
pub(crate) fn two_mut<T>(v: &mut [T], i: usize, j: usize) -> (&mut T, &mut T) {
    assert_ne!(i, j, "arena slots must be distinct");
    if i < j {
        let (lo, hi) = v.split_at_mut(j);
        (&mut lo[i], &mut hi[0])
    } else {
        let (lo, hi) = v.split_at_mut(i);
        (&mut hi[0], &mut lo[j])
    }
}

/// Three disjoint `&mut` borrows out of one slice.
pub(crate) fn three_mut<T>(v: &mut [T], i: usize, j: usize, k: usize) -> (&mut T, &mut T, &mut T) {
    assert!(i != j && j != k && i != k, "arena slots must be distinct");
    // sort the indices, split twice, then hand the parts back in call order
    let mut order = [(i, 0usize), (j, 1), (k, 2)];
    order.sort_unstable();
    let (lo, rest) = v.split_at_mut(order[1].0);
    let (mid, hi) = rest.split_at_mut(order[2].0 - order[1].0);
    let parts = [&mut lo[order[0].0], &mut mid[0], &mut hi[0]];
    let mut out: [Option<&mut T>; 3] = [None, None, None];
    for (part, (_, rank)) in parts.into_iter().zip(order) {
        out[rank] = Some(part);
    }
    let [a, b, c] = out;
    (a.unwrap(), b.unwrap(), c.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_mut_disjoint_both_orders() {
        let mut v = vec![10, 20, 30];
        let (a, b) = two_mut(&mut v, 0, 2);
        assert_eq!((*a, *b), (10, 30));
        let (a, b) = two_mut(&mut v, 2, 0);
        assert_eq!((*a, *b), (30, 10));
    }

    #[test]
    fn three_mut_all_permutations() {
        let mut v = vec![1, 2, 3, 4];
        for (i, j, k) in [(0, 1, 2), (2, 0, 3), (3, 1, 0), (1, 3, 2)] {
            let (a, b, c) = three_mut(&mut v, i, j, k);
            assert_eq!((*a, *b, *c), (v_at(i), v_at(j), v_at(k)));
        }
        fn v_at(i: usize) -> i32 {
            [1, 2, 3, 4][i]
        }
    }

    #[test]
    fn fingerprint_stable_without_growth() {
        let mut s = Scratch::sized(1, &[16, 8], 4, 2, 4, 4, 4);
        let fp = s.fingerprint();
        s.bufs[0][..16].fill(7);
        s.patches.fill(3);
        assert_eq!(fp, s.fingerprint());
    }

    #[test]
    fn scratch_pool_bounds_creation_and_is_fingerprint_stable() {
        let pool = ScratchPool::new(2);
        let mut mk = || Scratch::sized(9, &[8], 1, 1, 2, 2, 2);
        // saturating checkout: creation stops at the bound
        let got = pool.checkout(5, &mut mk);
        assert_eq!(got.len(), 2);
        assert_eq!(pool.created(), 2);
        assert!(pool.try_take().is_none());
        pool.put_all(got);
        let fp = pool.fingerprints();
        assert_eq!(fp.len(), 2);
        // steady state: checkout/return cycles reuse the same allocations
        for want in [1usize, 2, 2, 1] {
            let got = pool.checkout(want, &mut mk);
            assert_eq!(got.len(), want);
            pool.put_all(got);
        }
        assert_eq!(pool.created(), 2, "pool grew past its bound");
        assert_eq!(fp, pool.fingerprints(), "pool reallocated in steady state");
    }

    #[test]
    #[should_panic]
    fn two_mut_rejects_aliasing() {
        let mut v = vec![1, 2];
        let _ = two_mut(&mut v, 1, 1);
    }
}
