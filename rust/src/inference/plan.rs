//! Compile-then-execute inference: `ExecPlan`.
//!
//! The interpreted engine (`engine.rs`) re-derives everything on every
//! forward: shapes, concat retention, im2col scratch, and it runs
//! requantize / bias / folded-BN / ReLU as separate full-tensor passes
//! with a fresh allocation per op. A hard-quantized SYMOG net is a
//! *static* artifact though (§3.1: fixed-point weights, shift-only
//! rescaling), so all of that is knowable once:
//!
//! `IntModel::plan(max_batch)` walks the layer program a single time and
//! emits an immutable, shareable `ExecPlan`:
//!
//! * every intermediate shape is resolved and each step is assigned a slot
//!   in a preallocated ping-pong arena (`arena.rs`); concat sources get
//!   dedicated retained slots, so skip tensors are written once and read
//!   in place — no per-forward `needed`-set rebuild, no clone;
//! * im2col geometry is precomputed and the ternary add/sub plans are
//!   warmed at plan time;
//! * bias + folded-BN + ReLU + requantize are **fused into the matmul
//!   epilogue**: one elementwise pass (two when BN's exponent must be
//!   re-centered — the shift amount depends on the batch-global |max|,
//!   which is itself reduced inside the GEMM workers) instead of four
//!   interpreted passes, and the epilogue runs batch-parallel where the
//!   interpreter was serial;
//! * `op_counts` is an analytic function of the plan — `cost_report`
//!   prices a forward without executing one.
//!
//! Execution state lives in a per-thread `Scratch`; the plan itself is
//! `Sync` and meant to be shared behind an `Arc` — that split is the seam
//! the serving layer (`serve::Server`) sits on: it coalesces requests into
//! micro-batches and drives them through [`ExecPlan::run_rows`], which
//! fans gathered rows over pooled `scratch_for(1)` row scratches with
//! per-request requantization isolation (see `run_rows` for why serving
//! must not share batch-global shift statistics between requests).
//!
//! Everything here replays the interpreter's integer arithmetic
//! *bit-for-bit* (same kernels, same requantize decisions, same rounding),
//! which `tests/planned_exec.rs` enforces against `Backend::Naive`.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::fixedpoint::fxp_round_shift;
use crate::util::pool;

use super::arena::{self, Scratch, Slot};
use super::engine::IntLayer;
use super::ops::{self, QAffine, QWeight};
use super::{gemm, OpCounts};

static NEXT_PLAN_ID: AtomicU64 = AtomicU64::new(1);

fn numel3(d: [usize; 3]) -> usize {
    d[0] * d[1] * d[2]
}

fn clamp_i32(v: i64) -> i32 {
    v.clamp(i32::MIN as i64, i32::MAX as i64) as i32
}

/// Precomputed conv geometry (resolved once at plan time).
#[derive(Clone, Copy, Debug)]
struct ConvGeom {
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    oh: usize,
    ow: usize,
}

/// One executable step of the plan. A `MatMul` step is a *fusion group*:
/// the conv/dense layer plus any immediately-following BN/ReLU absorbed
/// into its epilogue (fusion never crosses a concat-retention boundary,
/// so retained tensors keep the interpreter's exact per-layer values).
#[derive(Clone, Debug)]
enum StepKind {
    MatMul {
        li: usize,
        geom: Option<ConvGeom>,
        bn: Option<usize>,
        relu: bool,
        bias: bool,
        ternary: bool,
        macs_per_img: u64,
    },
    Affine { li: usize },
    Relu,
    MaxPool { k: usize, stride: usize },
    AvgPool { k: usize, stride: usize },
    GlobalAvgPool,
    Concat { a: Slot, a_dim: [usize; 3] },
    /// materialize a shape-only layer (retained flatten) into its own slot
    Copy,
}

#[derive(Clone, Debug)]
struct Step {
    kind: StepKind,
    src: Slot,
    dst: Slot,
    /// per-image HWC dims at the step input / output (batch dim implicit)
    in_dim: [usize; 3],
    out_dim: [usize; 3],
}

/// A compiled forward pass: immutable, cheap to share across threads.
pub struct ExecPlan {
    id: u64,
    layers: Arc<Vec<IntLayer>>,
    steps: Vec<Step>,
    max_batch: usize,
    workers: usize,
    in_dim: [usize; 3],
    in_slot: Slot,
    out_slot: Slot,
    out_per_img: usize,
    /// capacity (in i32 elements, `max_batch`-scaled) of each arena slot
    slot_caps: Vec<usize>,
    /// per-worker im2col panel length (max over conv steps)
    patch_len: usize,
    /// i64 pooling-accumulator length
    wide_len: usize,
    /// max channel count needing per-call bias/BN constant encoding
    chan_len: usize,
}

impl ExecPlan {
    /// Compile the layer program for batches up to `max_batch`.
    pub(crate) fn build(
        layers: Arc<Vec<IntLayer>>,
        retained: &BTreeSet<usize>,
        input_shape: [usize; 3],
        max_batch: usize,
    ) -> Result<ExecPlan> {
        ensure!(max_batch >= 1, "ExecPlan needs max_batch >= 1");
        let mut slot_caps = vec![0usize; 2];
        slot_caps[0] = max_batch * numel3(input_shape);
        let mut retained_slots: BTreeMap<usize, (Slot, [usize; 3])> = BTreeMap::new();
        let mut steps: Vec<Step> = Vec::new();
        let mut cur = Slot(0);
        let mut cur_dim = input_shape;
        let (mut patch_len, mut wide_len, mut chan_len) = (0usize, 0usize, 0usize);

        let mut li = 0usize;
        while li < layers.len() {
            // (kind, out_dim, group_end, in_place_ok); None = shape-only
            let planned: Option<(StepKind, [usize; 3], usize, bool)> = match &layers[li] {
                IntLayer::Conv { w, bias, stride, pad_same } => {
                    let [h, ww, c] = cur_dim;
                    let [kh, kw, wcin, cout] = w.dims;
                    ensure!(c == wcin, "plan: conv channel mismatch at layer {li}");
                    let (oh, ow, pad_h, pad_w) =
                        gemm::conv_geometry(h, ww, kh, kw, *stride, *pad_same);
                    let geom =
                        ConvGeom { kh, kw, cin: c, cout, stride: *stride, pad_h, pad_w, oh, ow };
                    patch_len = patch_len.max(oh * ow * kh * kw * c);
                    // resolve + warm the kernel (ternary / bitslice /
                    // packed race) at plan time
                    let _ = gemm::select_kernel(w, kh * kw * c, cout);
                    let (bn, relu, group_end) = absorb(&layers, retained, li);
                    check_bn(&layers, bn, cout, li)?;
                    if bias.is_some() || bn.is_some() {
                        chan_len = chan_len.max(cout);
                    }
                    let kind = StepKind::MatMul {
                        li,
                        geom: Some(geom),
                        bn,
                        relu,
                        bias: bias.is_some(),
                        ternary: w.is_ternary(),
                        macs_per_img: (oh * ow * cout * kh * kw * c) as u64,
                    };
                    Some((kind, [oh, ow, cout], group_end, false))
                }
                IntLayer::Dense { w, bias } => {
                    let f_in = numel3(cur_dim);
                    ensure!(f_in == w.dims[0], "plan: dense shape mismatch at layer {li}");
                    let f_out = w.dims[1];
                    // resolve + warm the kernel (ternary / bitslice /
                    // packed race) at plan time
                    let _ = gemm::select_kernel(w, f_in, f_out);
                    let (bn, relu, group_end) = absorb(&layers, retained, li);
                    check_bn(&layers, bn, f_out, li)?;
                    if bias.is_some() || bn.is_some() {
                        chan_len = chan_len.max(f_out);
                    }
                    let kind = StepKind::MatMul {
                        li,
                        geom: None,
                        bn,
                        relu,
                        bias: bias.is_some(),
                        ternary: w.is_ternary(),
                        macs_per_img: (f_in * f_out) as u64,
                    };
                    Some((kind, [1, 1, f_out], group_end, false))
                }
                IntLayer::Bn(a) => {
                    ensure!(
                        a.a_mant.len() == cur_dim[2],
                        "plan: BN channel mismatch at layer {li}"
                    );
                    chan_len = chan_len.max(cur_dim[2]);
                    Some((StepKind::Affine { li }, cur_dim, li, true))
                }
                IntLayer::Relu => Some((StepKind::Relu, cur_dim, li, true)),
                IntLayer::MaxPool { k, stride } => {
                    let [h, ww, c] = cur_dim;
                    let out = [h / stride, ww / stride, c];
                    Some((StepKind::MaxPool { k: *k, stride: *stride }, out, li, false))
                }
                IntLayer::AvgPool { k, stride } => {
                    let [h, ww, c] = cur_dim;
                    let out = [h / stride, ww / stride, c];
                    wide_len = wide_len.max(max_batch * numel3(out));
                    Some((StepKind::AvgPool { k: *k, stride: *stride }, out, li, false))
                }
                IntLayer::GlobalAvgPool => {
                    let out = [1, 1, cur_dim[2]];
                    wide_len = wide_len.max(max_batch * cur_dim[2]);
                    Some((StepKind::GlobalAvgPool, out, li, false))
                }
                IntLayer::Flatten => {
                    let out = [1, 1, numel3(cur_dim)];
                    if retained.contains(&li) {
                        // shape-only layer whose output must outlive the
                        // stream: materialize it into a retained slot
                        Some((StepKind::Copy, out, li, false))
                    } else {
                        cur_dim = out;
                        li += 1;
                        None
                    }
                }
                IntLayer::Concat { from } => {
                    let (a_slot, a_dim) = *retained_slots
                        .get(from)
                        .with_context(|| format!("plan: concat source {from} not retained"))?;
                    let [h, ww, c] = cur_dim;
                    ensure!(
                        a_dim[0] == h && a_dim[1] == ww,
                        "plan: concat spatial mismatch at layer {li}"
                    );
                    let out = [h, ww, a_dim[2] + c];
                    Some((StepKind::Concat { a: a_slot, a_dim }, out, li, false))
                }
            };
            let Some((kind, out_dim, group_end, in_place_ok)) = planned else { continue };
            let total = max_batch * numel3(out_dim);
            let dst = if retained.contains(&group_end) {
                slot_caps.push(total);
                let s = Slot(slot_caps.len() - 1);
                retained_slots.insert(group_end, (s, out_dim));
                s
            } else if in_place_ok && cur.0 < 2 {
                slot_caps[cur.0] = slot_caps[cur.0].max(total);
                cur
            } else {
                let s = if cur.0 == 0 { Slot(1) } else { Slot(0) };
                slot_caps[s.0] = slot_caps[s.0].max(total);
                s
            };
            steps.push(Step { kind, src: cur, dst, in_dim: cur_dim, out_dim });
            cur = dst;
            cur_dim = out_dim;
            li = group_end + 1;
        }

        Ok(ExecPlan {
            id: NEXT_PLAN_ID.fetch_add(1, Ordering::Relaxed),
            layers,
            steps,
            max_batch,
            workers: pool::default_workers(),
            in_dim: input_shape,
            in_slot: Slot(0),
            out_slot: cur,
            out_per_img: numel3(cur_dim),
            slot_caps,
            patch_len,
            wide_len,
            chan_len,
        })
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Number of fused execution steps (< layer count when epilogues fused).
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    /// Total activation-arena footprint in bytes.
    pub fn arena_bytes(&self) -> usize {
        self.slot_caps.iter().sum::<usize>() * std::mem::size_of::<i32>()
    }

    /// Override the worker-thread count (results are bit-identical for any
    /// value; this tunes wall-clock only). Returns a new plan identity, so
    /// existing `Scratch` values cannot be mixed in by accident.
    pub fn with_workers(mut self, workers: usize) -> ExecPlan {
        self.workers = workers.clamp(1, 64);
        self.id = NEXT_PLAN_ID.fetch_add(1, Ordering::Relaxed);
        self
    }

    /// Allocate the mutable per-thread state for `run`. Steady-state runs
    /// never grow it (see `Scratch::fingerprint`).
    pub fn scratch(&self) -> Scratch {
        self.scratch_for(self.max_batch)
    }

    /// Allocate a scratch whose activation slots hold at most `cap_batch`
    /// images (clamped to `1..=max_batch`). The serving layer pools
    /// `scratch_for(1)` row scratches: per-request isolation executes every
    /// request at batch 1, so sizing each pooled scratch for `max_batch`
    /// would multiply the arena footprint by the micro-batch cap for no
    /// benefit. `run` rejects batches larger than the scratch's capacity.
    pub fn scratch_for(&self, cap_batch: usize) -> Scratch {
        let cb = cap_batch.clamp(1, self.max_batch);
        // every capacity in the table is an exact max_batch multiple (they
        // are all computed as max_batch * per-image numel), so per-image
        // rescaling is lossless
        let scale = |c: usize| c / self.max_batch * cb;
        let caps: Vec<usize> = self.slot_caps.iter().map(|&c| scale(c)).collect();
        Scratch::sized(
            self.id,
            &caps,
            cb,
            self.workers.clamp(1, cb),
            self.patch_len,
            scale(self.wide_len),
            self.chan_len,
        )
    }

    /// Elements of one input image (H*W*C at the plan's input shape).
    pub fn in_elems(&self) -> usize {
        numel3(self.in_dim)
    }

    /// Logits per image produced by `run` / `run_rows`.
    pub fn out_per_img(&self) -> usize {
        self.out_per_img
    }

    /// Analytic operation counts for one forward of `batch` images —
    /// exactly what the counted interpreter reports, computed from shapes
    /// alone (shift accounting is deterministic; see `ops::finish_matmul`).
    pub fn op_counts(&self, batch: usize) -> OpCounts {
        let mut c = OpCounts::default();
        let b = batch as u64;
        for step in &self.steps {
            let out = (numel3(step.out_dim) * batch) as u64;
            match &step.kind {
                StepKind::MatMul { bn, relu, bias, ternary, macs_per_img, .. } => {
                    let macs = macs_per_img * b;
                    c.acc_adds += macs;
                    if !ternary {
                        c.int_mults += macs;
                    }
                    c.shifts += out; // matmul requantize
                    if *bias {
                        c.acc_adds += out;
                    }
                    if bn.is_some() {
                        c.int_mults += out;
                        c.acc_adds += out;
                        c.shifts += out; // BN requantize
                    }
                    if *relu {
                        c.compares += out;
                    }
                }
                StepKind::Affine { .. } => {
                    c.int_mults += out;
                    c.acc_adds += out;
                    c.shifts += out;
                }
                StepKind::Relu => c.compares += out,
                StepKind::MaxPool { k, .. } => c.compares += out * (k * k) as u64,
                StepKind::AvgPool { k, .. } => {
                    c.acc_adds += out * (k * k) as u64;
                    if !((k * k) as u32).is_power_of_two() {
                        c.int_mults += out;
                    }
                    c.shifts += out;
                }
                StepKind::GlobalAvgPool => {
                    c.acc_adds += (numel3(step.in_dim) * batch) as u64;
                    if !((step.in_dim[0] * step.in_dim[1]) as u32).is_power_of_two() {
                        c.int_mults += out;
                    }
                    c.shifts += out;
                }
                StepKind::Concat { .. } => c.shifts += out,
                StepKind::Copy => {}
            }
        }
        c
    }

    /// Execute the plan on a float batch (encoded to 8-bit fixed point at
    /// the input, like the interpreter). `batch` may be smaller than
    /// `max_batch` (ragged final batch); logits come back as f32.
    pub fn run(&self, images: &[f32], batch: usize, s: &mut Scratch) -> Result<Vec<f32>> {
        let mut out = vec![0f32; batch * self.out_per_img];
        self.run_into(images, batch, s, &mut out)?;
        Ok(out)
    }

    /// `run` writing logits into a caller-owned buffer (`batch *
    /// out_per_img` long) — the allocation-free serving entry point.
    pub fn run_into(
        &self,
        images: &[f32],
        batch: usize,
        s: &mut Scratch,
        out: &mut [f32],
    ) -> Result<()> {
        ensure!(s.plan_id == self.id, "Scratch was built for a different ExecPlan");
        ensure!(
            batch >= 1 && batch <= self.max_batch,
            "batch {batch} outside 1..={}",
            self.max_batch
        );
        ensure!(
            batch <= s.cap_batch,
            "batch {batch} exceeds this Scratch's capacity {} (see scratch_for)",
            s.cap_batch
        );
        let in_elems = numel3(self.in_dim);
        ensure!(images.len() == batch * in_elems, "bad input size");
        ensure!(out.len() == batch * self.out_per_img, "bad output size");
        let frac_in =
            ops::encode_f32_into(images, 8, &mut s.bufs[self.in_slot.0][..batch * in_elems]);
        s.fracs[self.in_slot.0] = frac_in;
        for step in &self.steps {
            self.exec_step(step, batch, s)?;
        }
        let scale = (2f32).powi(-s.fracs[self.out_slot.0]);
        for (o, &m) in out.iter_mut().zip(&s.bufs[self.out_slot.0][..batch * self.out_per_img]) {
            *o = m as f32 * scale;
        }
        Ok(())
    }

    /// Serving gather/scatter entry: execute `batch` single-request rows
    /// (`images` is the caller-assembled gather, row-major) with
    /// **per-request requantization isolation** — row `r`'s logits land at
    /// `out[r * out_per_img ..]` and are bit-identical to
    /// `run(&images[r * in_elems ..][..in_elems], 1, ..)`, i.e. to a solo
    /// forward of that request, *whatever the batch composition*.
    ///
    /// This is deliberately not `run(images, batch, ..)`: the engine's
    /// requantization statistics (input exponent, every matmul/BN shift)
    /// are batch-global, so a whole-batch forward lets one outlier request
    /// coarsen its batchmates' shift decisions — results would depend on
    /// which requests happened to be coalesced together. Serving instead
    /// runs each row through the identical batch-1 path and takes its
    /// parallelism *across* rows: `scratches` (each from `scratch_for` on
    /// this plan) defines the worker fan-out, and any count yields the
    /// same bits.
    ///
    /// The row fan-out dispatches on the process-wide persistent pool;
    /// any per-step fan-out *inside* a row then runs inline on that pool
    /// worker (`util::pool`'s inline-when-nested rule), so serve-drain →
    /// `run_rows` → step nesting cannot deadlock the pool.
    pub fn run_rows(
        &self,
        images: &[f32],
        batch: usize,
        scratches: &mut [Scratch],
        out: &mut [f32],
    ) -> Result<()> {
        ensure!(batch >= 1, "run_rows needs at least one row");
        ensure!(!scratches.is_empty(), "run_rows needs at least one scratch");
        let in_elems = numel3(self.in_dim);
        ensure!(images.len() == batch * in_elems, "bad input size");
        ensure!(out.len() == batch * self.out_per_img, "bad output size");
        for s in scratches.iter() {
            ensure!(s.plan_id == self.id, "Scratch was built for a different ExecPlan");
        }
        let workers = scratches.len().min(batch);
        let per = batch.div_ceil(workers);
        struct RowItem<'a> {
            rows: &'a [f32],
            out: &'a mut [f32],
            scratch: &'a mut Scratch,
            err: Option<anyhow::Error>,
        }
        let mut items: Vec<RowItem> = images
            .chunks(per * in_elems)
            .zip(out.chunks_mut(per * self.out_per_img))
            .zip(scratches.iter_mut())
            .map(|((rows, out), scratch)| RowItem { rows, out, scratch, err: None })
            .collect();
        let k = items.len();
        pool::par_chunks_mut(&mut items, k, |_, its| {
            for it in its.iter_mut() {
                for (row, row_out) in
                    it.rows.chunks(in_elems).zip(it.out.chunks_mut(self.out_per_img))
                {
                    if let Err(e) = self.run_into(row, 1, it.scratch, row_out) {
                        it.err = Some(e);
                        break;
                    }
                }
            }
        });
        for it in items {
            if let Some(e) = it.err {
                return Err(e);
            }
        }
        Ok(())
    }

    fn exec_step(&self, step: &Step, batch: usize, s: &mut Scratch) -> Result<()> {
        let in_total = batch * numel3(step.in_dim);
        let out_total = batch * numel3(step.out_dim);
        match &step.kind {
            StepKind::MatMul { .. } => self.exec_matmul(step, batch, s),
            StepKind::Affine { li } => {
                let IntLayer::Bn(a) = &self.layers[*li] else {
                    unreachable!("affine step on non-BN layer")
                };
                let Scratch { bufs, fracs, amax, bn_enc, .. } = s;
                if step.src != step.dst {
                    let (sv, dv) = arena::two_mut(bufs, step.src.0, step.dst.0);
                    dv[..out_total].copy_from_slice(&sv[..in_total]);
                    fracs[step.dst.0] = fracs[step.src.0];
                }
                let c = step.out_dim[2];
                let x_frac = fracs[step.dst.0];
                let prod_frac = a.a_frac + x_frac;
                for (e, &bm) in bn_enc.iter_mut().zip(a.b_mant.iter()) {
                    *e = ops::shift_to(bm, a.b_frac, prod_frac);
                }
                let data = &mut bufs[step.dst.0][..out_total];
                let (a_m, bn_b): (&[i32], &[i64]) = (&a.a_mant, &bn_enc[..c]);
                // clamp like exec_matmul so batch-1 serving rows stay on
                // the single-chunk inline path (no pool dispatch per step
                // per row — the persistent pool is only engaged when the
                // fan-out has more than one chunk)
                let workers = self.workers.clamp(1, batch);
                let amax2 = par_map_amax(data, amax, workers, |i, v| {
                    let ch = i % c;
                    clamp_i32(v as i64 * a_m[ch] as i64 + bn_b[ch])
                });
                let shift = ops::shift_for_amax(amax2, 16);
                if shift > 0 {
                    par_map_elems(data, workers, |_, v| {
                        fxp_round_shift(v as i64, shift) as i32
                    });
                }
                fracs[step.dst.0] = prod_frac - shift;
                Ok(())
            }
            StepKind::Relu => {
                let Scratch { bufs, fracs, .. } = s;
                if step.src == step.dst {
                    for v in &mut bufs[step.dst.0][..out_total] {
                        if *v < 0 {
                            *v = 0;
                        }
                    }
                } else {
                    let (sv, dv) = arena::two_mut(bufs, step.src.0, step.dst.0);
                    for (o, &v) in dv[..out_total].iter_mut().zip(&sv[..in_total]) {
                        *o = v.max(0);
                    }
                    fracs[step.dst.0] = fracs[step.src.0];
                }
                Ok(())
            }
            StepKind::MaxPool { k, stride } => {
                let Scratch { bufs, fracs, .. } = s;
                let (sv, dv) = arena::two_mut(bufs, step.src.0, step.dst.0);
                let (src, dst) = (&sv[..in_total], &mut dv[..out_total]);
                let [h, w, c] = step.in_dim;
                let [oh, ow, _] = step.out_dim;
                ops::maxpool_slice(src, (batch, h, w, c), *k, *stride, (oh, ow), dst);
                fracs[step.dst.0] = fracs[step.src.0];
                Ok(())
            }
            StepKind::AvgPool { k, stride } => {
                let Scratch { bufs, fracs, wide, .. } = s;
                let (sv, dv) = arena::two_mut(bufs, step.src.0, step.dst.0);
                let (src, dst) = (&sv[..in_total], &mut dv[..out_total]);
                let acc = &mut wide[..out_total];
                let [h, w, c] = step.in_dim;
                let [oh, ow, _] = step.out_dim;
                ops::avgpool_acc_slice(src, (batch, h, w, c), *k, *stride, (oh, ow), acc);
                ops::divide_slice(acc, (k * k) as u32, dst);
                fracs[step.dst.0] = fracs[step.src.0];
                Ok(())
            }
            StepKind::GlobalAvgPool => {
                let Scratch { bufs, fracs, wide, .. } = s;
                let (sv, dv) = arena::two_mut(bufs, step.src.0, step.dst.0);
                let (src, dst) = (&sv[..in_total], &mut dv[..out_total]);
                let acc = &mut wide[..out_total];
                let [h, w, c] = step.in_dim;
                ops::global_avg_acc_slice(src, (batch, h, w, c), acc);
                ops::divide_slice(acc, (h * w) as u32, dst);
                fracs[step.dst.0] = fracs[step.src.0];
                Ok(())
            }
            StepKind::Concat { a: a_slot, a_dim } => {
                let Scratch { bufs, fracs, .. } = s;
                let (fa, fb) = (fracs[a_slot.0], fracs[step.src.0]);
                let frac = fa.min(fb);
                let [h, w, cb] = step.in_dim;
                let ca = a_dim[2];
                let rows = batch * h * w;
                if *a_slot == step.src {
                    // self-concat: both halves read the same slot
                    let (sv, dv) = arena::two_mut(bufs, step.src.0, step.dst.0);
                    let both = &sv[..in_total];
                    ops::concat_rows(both, fa, both, fb, frac, ca, cb, rows, dv);
                } else {
                    let (av, sv, dv) =
                        arena::three_mut(bufs, a_slot.0, step.src.0, step.dst.0);
                    let a_total = batch * numel3(*a_dim);
                    let (a, b) = (&av[..a_total], &sv[..in_total]);
                    ops::concat_rows(a, fa, b, fb, frac, ca, cb, rows, dv);
                }
                fracs[step.dst.0] = frac;
                Ok(())
            }
            StepKind::Copy => {
                let Scratch { bufs, fracs, .. } = s;
                let (sv, dv) = arena::two_mut(bufs, step.src.0, step.dst.0);
                dv[..out_total].copy_from_slice(&sv[..in_total]);
                fracs[step.dst.0] = fracs[step.src.0];
                Ok(())
            }
        }
    }

    /// Matmul step: GEMM workers accumulate into the arena and co-reduce
    /// the batch-global |max|, then the fused epilogue (requantize + bias +
    /// folded BN + ReLU) sweeps the output in at most two parallel passes.
    fn exec_matmul(&self, step: &Step, batch: usize, s: &mut Scratch) -> Result<()> {
        let StepKind::MatMul { li, geom, bn, relu, bias: has_bias, .. } = &step.kind else {
            unreachable!("exec_matmul on non-matmul step")
        };
        let (w, bias): (&QWeight, Option<&Vec<f32>>) = match &self.layers[*li] {
            IntLayer::Conv { w, bias, .. } => (w, bias.as_ref()),
            IntLayer::Dense { w, bias } => (w, bias.as_ref()),
            _ => unreachable!("matmul step on non-matmul layer"),
        };
        let Scratch { bufs, fracs, patches, amax, bias_enc, bn_enc, .. } = s;
        let (src_v, dst_v) = arena::two_mut(bufs, step.src.0, step.dst.0);
        let in_total = batch * numel3(step.in_dim);
        let out_total = batch * numel3(step.out_dim);
        let src_buf: &[i32] = &src_v[..in_total];
        let dst_buf: &mut [i32] = &mut dst_v[..out_total];
        let workers = self.workers.clamp(1, batch);
        let per = batch.div_ceil(workers);

        // --- phase 1: integer GEMM + per-worker |max| reduction ----------
        struct Item<'a> {
            img0: usize,
            out: &'a mut [i32],
            patches: &'a mut [i32],
            amax: &'a mut i64,
        }
        let n_cells;
        match geom {
            Some(g) => {
                let m_dim = g.oh * g.ow;
                let k_dim = g.kh * g.kw * g.cin;
                let img_out = m_dim * g.cout;
                let kern = gemm::select_kernel(w, k_dim, g.cout);
                let hwc = (step.in_dim[0], step.in_dim[1], g.cin);
                let mut items: Vec<Item> = dst_buf
                    .chunks_mut(per * img_out)
                    .zip(patches.chunks_mut(self.patch_len))
                    .zip(amax.iter_mut())
                    .enumerate()
                    .map(|(wi, ((out, p), m))| {
                        let (panel, _) = p.split_at_mut(m_dim * k_dim);
                        Item { img0: wi * per, out, patches: panel, amax: m }
                    })
                    .collect();
                n_cells = items.len();
                pool::par_chunks_mut(&mut items, n_cells, |_, its| {
                    for it in its.iter_mut() {
                        let mut lm = 0i64;
                        for (i, out_img) in it.out.chunks_mut(img_out).enumerate() {
                            out_img.fill(0);
                            gemm::im2col(
                                src_buf,
                                hwc,
                                it.img0 + i,
                                g.kh,
                                g.kw,
                                g.stride,
                                g.pad_h,
                                g.pad_w,
                                g.oh,
                                g.ow,
                                it.patches,
                            );
                            kern.run(it.patches, out_img, m_dim, k_dim, g.cout);
                            for &v in out_img.iter() {
                                lm = lm.max((v as i64).abs());
                            }
                        }
                        *it.amax = lm;
                    }
                });
            }
            None => {
                let f_in = numel3(step.in_dim);
                let f_out = step.out_dim[2];
                let kern = gemm::select_kernel(w, f_in, f_out);
                let mut items: Vec<Item> = dst_buf
                    .chunks_mut(per * f_out)
                    .zip(amax.iter_mut())
                    .enumerate()
                    .map(|(wi, (out, m))| Item { img0: wi * per, out, patches: &mut [], amax: m })
                    .collect();
                n_cells = items.len();
                pool::par_chunks_mut(&mut items, n_cells, |_, its| {
                    for it in its.iter_mut() {
                        it.out.fill(0);
                        let rows = it.out.len() / f_out;
                        let a = &src_buf[it.img0 * f_in..(it.img0 + rows) * f_in];
                        kern.run(a, it.out, rows, f_in, f_out);
                        let mut lm = 0i64;
                        for &v in it.out.iter() {
                            lm = lm.max((v as i64).abs());
                        }
                        *it.amax = lm;
                    }
                });
            }
        }
        let amax1 = amax[..n_cells].iter().copied().max().unwrap_or(0);

        // --- fused epilogue ---------------------------------------------
        let cout = step.out_dim[2];
        let shift1 = ops::shift_for_amax(amax1, 16);
        let frac1 = fracs[step.src.0] + w.frac - shift1;
        if let Some(b) = bias {
            for (e, &v) in bias_enc.iter_mut().zip(b.iter()) {
                *e = ops::enc32(v, frac1) as i64;
            }
        }
        let bias_s: Option<&[i64]> = has_bias.then(|| &bias_enc[..cout]);
        let bn_aff: Option<&QAffine> = bn.map(|bi| match &self.layers[bi] {
            IntLayer::Bn(a) => a,
            _ => unreachable!("absorbed BN index is not a BN layer"),
        });
        let rl = *relu;
        let final_frac = if let Some(a) = bn_aff {
            let prod_frac = a.a_frac + frac1;
            for (e, &bm) in bn_enc.iter_mut().zip(a.b_mant.iter()) {
                *e = ops::shift_to(bm, a.b_frac, prod_frac);
            }
            let (a_m, bn_b): (&[i32], &[i64]) = (&a.a_mant, &bn_enc[..cout]);
            let amax2 = par_map_amax(dst_buf, amax, workers, |i, v| {
                let ch = i % cout;
                let mut t = fxp_round_shift(v as i64, shift1) as i32;
                if let Some(bs) = bias_s {
                    t = clamp_i32(t as i64 + bs[ch]);
                }
                clamp_i32(t as i64 * a_m[ch] as i64 + bn_b[ch])
            });
            let shift2 = ops::shift_for_amax(amax2, 16);
            if shift2 > 0 || rl {
                par_map_elems(dst_buf, workers, |_, v| {
                    let t = fxp_round_shift(v as i64, shift2) as i32;
                    if rl {
                        t.max(0)
                    } else {
                        t
                    }
                });
            }
            prod_frac - shift2
        } else {
            if shift1 > 0 || *has_bias || rl {
                par_map_elems(dst_buf, workers, |i, v| {
                    let mut t = fxp_round_shift(v as i64, shift1) as i32;
                    if let Some(bs) = bias_s {
                        t = clamp_i32(t as i64 + bs[i % cout]);
                    }
                    if rl {
                        t.max(0)
                    } else {
                        t
                    }
                });
            }
            frac1
        };
        fracs[step.dst.0] = final_frac;
        Ok(())
    }
}

/// Greedily absorb a BN and/or ReLU immediately following `li` into its
/// fusion group. Absorption stops at a retention boundary: if the group's
/// current tail must be kept for a later concat, its exact per-layer value
/// is the contract, so nothing more may fuse past it.
fn absorb(
    layers: &[IntLayer],
    retained: &BTreeSet<usize>,
    li: usize,
) -> (Option<usize>, bool, usize) {
    let (mut bn, mut relu, mut last) = (None, false, li);
    loop {
        if retained.contains(&last) {
            break;
        }
        match layers.get(last + 1) {
            Some(IntLayer::Bn(_)) if bn.is_none() && !relu => {
                bn = Some(last + 1);
                last += 1;
            }
            Some(IntLayer::Relu) if !relu => {
                relu = true;
                last += 1;
            }
            _ => break,
        }
    }
    (bn, relu, last)
}

fn check_bn(layers: &[IntLayer], bn: Option<usize>, cout: usize, li: usize) -> Result<()> {
    if let Some(bi) = bn {
        let IntLayer::Bn(a) = &layers[bi] else { unreachable!() };
        ensure!(
            a.a_mant.len() == cout,
            "plan: BN channel mismatch after matmul layer {li}"
        );
    }
    Ok(())
}

/// Parallel elementwise map over `data` (global element index passed so
/// per-channel constants can be looked up with `idx % c`).
fn par_map_elems<F>(data: &mut [i32], workers: usize, f: F)
where
    F: Fn(usize, i32) -> i32 + Sync,
{
    if data.is_empty() {
        return;
    }
    pool::par_chunks_mut(data, workers.clamp(1, data.len()), |off, chunk| {
        for (i, v) in chunk.iter_mut().enumerate() {
            *v = f(off + i, *v);
        }
    });
}

/// Parallel elementwise map that also reduces the |max| of the mapped
/// values (the requantization statistic) through per-worker cells.
fn par_map_amax<F>(data: &mut [i32], cells: &mut [i64], workers: usize, f: F) -> i64
where
    F: Fn(usize, i32) -> i32 + Sync,
{
    let n = data.len();
    if n == 0 {
        return 0;
    }
    let workers = workers.clamp(1, n).min(cells.len().max(1));
    let chunk = n.div_ceil(workers);
    struct Cell<'a> {
        off: usize,
        d: &'a mut [i32],
        m: &'a mut i64,
    }
    let mut items: Vec<Cell> = data
        .chunks_mut(chunk)
        .zip(cells.iter_mut())
        .enumerate()
        .map(|(wi, (d, m))| Cell { off: wi * chunk, d, m })
        .collect();
    let k = items.len();
    pool::par_chunks_mut(&mut items, k, |_, its| {
        for it in its.iter_mut() {
            let mut lm = 0i64;
            for (i, v) in it.d.iter_mut().enumerate() {
                let t = f(it.off + i, *v);
                *v = t;
                lm = lm.max((t as i64).abs());
            }
            *it.m = lm;
        }
    });
    drop(items);
    cells[..k].iter().copied().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_amax_matches_serial_any_worker_count() {
        let base: Vec<i32> = (-40..60).collect();
        let mut want = base.clone();
        let mut cells = vec![0i64; 8];
        let want_max = {
            let mut m = 0i64;
            for v in &mut want {
                *v *= 3;
                m = m.max((*v as i64).abs());
            }
            m
        };
        for workers in [1, 2, 3, 7] {
            let mut got = base.clone();
            let m = par_map_amax(&mut got, &mut cells, workers, |_, v| v * 3);
            assert_eq!(got, want, "workers={workers}");
            assert_eq!(m, want_max, "workers={workers}");
        }
    }

    #[test]
    fn par_map_elems_uses_global_indices() {
        let mut data = vec![0i32; 100];
        par_map_elems(&mut data, 7, |i, _| i as i32);
        assert_eq!(data, (0..100).collect::<Vec<i32>>());
    }

    #[test]
    fn shared_divide_core_matches_shift_and_reciprocal() {
        let acc: Vec<i64> = vec![0, 3, -3, 100, -101, 1 << 20];
        let mut shifted = vec![0i32; acc.len()];
        ops::divide_slice(&acc, 4, &mut shifted);
        assert_eq!(shifted[1], 1); // 3/4 rounds half away -> 1
        assert_eq!(shifted[2], -1);
        let mut recip = vec![0i32; acc.len()];
        ops::divide_slice(&acc, 9, &mut recip);
        assert_eq!(recip[3], 11); // 100/9 = 11.1
    }
}
