//! Integer tensor ops for the fixed-point engine.
//!
//! All activations are `QTensor`s: i32 mantissas + a shared exponent
//! (`frac`), value = mantissa * 2^-frac, laid out NHWC like the float model.

use crate::fixedpoint::fxp_round_shift;

/// Integer activation tensor: value = data[i] * 2^-frac.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub data: Vec<i32>,
    pub frac: i32,
    /// NHWC dims; dense activations use [n, 1, 1, features]
    pub dims: [usize; 4],
}

impl QTensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Encode a float tensor: choose the largest frac with max |mantissa|
    /// <= 2^{bits-1}-1 (8-bit activations by default). Integer hardware
    /// derives this from a leading-zero count of the running max.
    pub fn from_f32(x: &[f32], dims: [usize; 4], bits: u32) -> QTensor {
        let mut data = vec![0i32; x.len()];
        let frac = encode_f32_into(x, bits, &mut data);
        QTensor { data, frac, dims }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        let s = (2f32).powi(-self.frac);
        self.data.iter().map(|&m| m as f32 * s).collect()
    }

    /// Requantize mantissas down to `bits` dynamic range (shift right until
    /// max |mantissa| fits). Pure integer: max-abs + shift.
    pub fn requantize(&mut self, bits: u32) -> i32 {
        let amax = self.data.iter().fold(0i64, |m, &v| m.max((v as i64).abs()));
        let shift = shift_for_amax(amax, bits);
        if shift > 0 {
            for v in &mut self.data {
                *v = fxp_round_shift(*v as i64, shift) as i32;
            }
            self.frac -= shift;
        }
        shift
    }
}

/// Smallest right-shift that brings `amax` within the signed `bits` range —
/// the requantization decision shared by the interpreted ops and the
/// planned executor (both must agree bit-for-bit).
pub(crate) fn shift_for_amax(amax: i64, bits: u32) -> i32 {
    let qmax = (1i64 << (bits - 1)) - 1;
    let mut shift = 0;
    while (amax >> shift) > qmax {
        shift += 1;
    }
    shift
}

/// Encode floats to i32 mantissas at the largest frac keeping max
/// |mantissa| within `bits`; returns the chosen frac. Shared by
/// `QTensor::from_f32` and the planned executor's input stage.
pub(crate) fn encode_f32_into(x: &[f32], bits: u32, out: &mut [i32]) -> i32 {
    debug_assert_eq!(x.len(), out.len());
    let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-12);
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    // delta = 2^-frac such that amax/delta <= qmax
    let frac = (qmax / amax).log2().floor() as i32;
    let scale = (2f64).powi(frac);
    for (o, &v) in out.iter_mut().zip(x) {
        let s = v as f64 * scale;
        *o = (s.abs() + 0.5).floor().copysign(s) as i32;
    }
    frac
}

/// Quantized weight tensor: i8 mantissas + power-of-two step 2^-frac.
#[derive(Clone, Debug)]
pub struct QWeight {
    pub mantissa: Vec<i8>,
    /// mantissas pre-widened to i32 — lets the conv/dense inner loops
    /// auto-vectorize (i8 -> i32 conversion inside the loop defeats SIMD)
    pub mantissa_i32: Vec<i32>,
    pub frac: i32,
    /// conv: HWIO dims; dense: [in, out, 1, 1]
    pub dims: [usize; 4],
    /// lazily-built sign-separated index plan for the ternary add/sub
    /// GEMM kernel (None once built = "use the multiply kernel")
    pub(crate) ternary_plan: std::sync::OnceLock<Option<super::gemm::TernaryPlan>>,
    /// lazily-built packed B panels for the multiply kernel — weights are
    /// immutable, so the pack cost is paid at most once (ExecPlan warms it)
    pub(crate) packed_b: std::sync::OnceLock<crate::kernels::PackedB<i32>>,
    /// lazily-built bit-plane decomposition for the AND/popcount kernel
    /// (None once built = ineligible |mantissa| > 3, or lost the cost race)
    pub(crate) bit_plan: std::sync::OnceLock<Option<crate::kernels::bitslice::BitslicePlan>>,
}

impl QWeight {
    /// Encode trained float weights with the layer's delta = 2^-frac; every
    /// weight must already sit within the N-bit code range (SYMOG-trained
    /// weights do — they were clipped during training).
    pub fn encode(w: &[f32], dims: [usize; 4], delta: f32, n_bits: u32) -> QWeight {
        let frac = (-delta.log2()).round() as i32;
        let qmax = ((1i32 << (n_bits - 1)) - 1) as f32;
        let mantissa: Vec<i8> = w
            .iter()
            .map(|&x| {
                let s = x / delta;
                ((s.abs() + 0.5).floor().copysign(s)).clamp(-qmax, qmax) as i8
            })
            .collect();
        let mantissa_i32 = mantissa.iter().map(|&m| m as i32).collect();
        QWeight {
            mantissa,
            mantissa_i32,
            frac,
            dims,
            ternary_plan: std::sync::OnceLock::new(),
            packed_b: std::sync::OnceLock::new(),
            bit_plan: std::sync::OnceLock::new(),
        }
    }

    /// Are all mantissas in {-1, 0, 1}? (True for 2-bit SYMOG — multiplies
    /// degenerate to add/sub/skip.)
    pub fn is_ternary(&self) -> bool {
        self.mantissa.iter().all(|&m| (-1..=1).contains(&m))
    }
}

/// Fixed-point affine (folded batch-norm): y = (a*x + b), a/b as 16-bit
/// mantissas with shared exponents.
#[derive(Clone, Debug)]
pub struct QAffine {
    pub a_mant: Vec<i32>,
    pub a_frac: i32,
    pub b_mant: Vec<i64>,
    pub b_frac: i32,
}

impl QAffine {
    /// Fold BN params (gamma, beta, mean, var) into fixed point.
    pub fn fold_bn(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32], eps: f32) -> QAffine {
        let a: Vec<f32> = gamma
            .iter()
            .zip(var)
            .map(|(&g, &v)| g / (v + eps).sqrt())
            .collect();
        let b: Vec<f32> = beta
            .iter()
            .zip(&a)
            .zip(mean)
            .map(|((&bt, &ai), &m)| bt - ai * m)
            .collect();
        let amax = a.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-12);
        let a_frac = ((32767.0 / amax).log2().floor() as i32).min(24);
        let bmax = b.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-12);
        let b_frac = ((32767.0 / bmax).log2().floor() as i32).min(24);
        QAffine {
            a_mant: a.iter().map(|&v| enc32(v, a_frac)).collect(),
            a_frac,
            b_mant: b.iter().map(|&v| enc32(v, b_frac) as i64).collect(),
            b_frac,
        }
    }
}

pub(crate) fn enc32(v: f32, frac: i32) -> i32 {
    let s = v as f64 * (2f64).powi(frac);
    (s.abs() + 0.5).floor().copysign(s) as i32
}

// ---------------------------------------------------------------------------
// layer kernels (all integer)

/// Shared conv/dense epilogue: exact op accounting (one MAC per output
/// position x kernel elem x cin x cout, counted in full whichever backend
/// produced the sums) + requantization. Keeping this in one place is what
/// guarantees `OpCounts` never depends on the compute backend.
///
/// Shift accounting is deterministic: every requantization point bills one
/// shift per element whether or not the resolved shift is zero (the barrel
/// shifter sits on the datapath either way). This makes `OpCounts` a pure
/// function of network shape, which is what lets `ExecPlan::op_counts`
/// price a forward pass analytically without executing it.
fn finish_matmul(
    acc: Vec<i32>,
    dims: [usize; 4],
    frac: i32,
    macs: u64,
    ternary: bool,
    counts: &mut super::OpCounts,
) -> QTensor {
    counts.acc_adds += macs;
    if !ternary {
        counts.int_mults += macs;
    }
    let mut out = QTensor { data: acc, frac, dims };
    out.requantize(16);
    counts.shifts += out.numel() as u64;
    out
}

/// Integer conv2d, NHWC x HWIO -> NHWC. `pad_same` selects SAME (TF-style)
/// vs VALID padding.
///
/// The hot path: im2col + blocked i32 GEMM, parallel over the batch
/// dimension (see `gemm.rs`). Bit-identical to [`conv2d_naive`].
///
/// i32 accumulation is safe: activations are requantized to <= 16 bits
/// between layers and weight mantissas are <= 2^{N-1}-1 <= 127, so the
/// accumulator bound is K * 2^15 * 127 < 2^31 for every K < 2^9 at 8-bit
/// weights and K < 2^16 ternary — far above any layer in the zoo.
pub fn conv2d(
    x: &QTensor,
    w: &QWeight,
    stride: usize,
    pad_same: bool,
    counts: &mut super::OpCounts,
) -> QTensor {
    let [n, h, wd, cin] = x.dims;
    let [kh, kw, wcin, cout] = w.dims;
    assert_eq!(cin, wcin, "conv channel mismatch");
    let (oh, ow, pad_h, pad_w) = super::gemm::conv_geometry(h, wd, kh, kw, stride, pad_same);
    let acc = super::gemm::conv2d_acc(x, w, stride, pad_h, pad_w, oh, ow);
    let macs = (n * oh * ow * cout * kh * kw * cin) as u64;
    finish_matmul(acc, [n, oh, ow, cout], x.frac + w.frac, macs, w.is_ternary(), counts)
}

/// Reference integer conv2d: the direct nested loops the GEMM path is
/// checked against (and benchmarked against in `benches/hotpath.rs`).
pub fn conv2d_naive(
    x: &QTensor,
    w: &QWeight,
    stride: usize,
    pad_same: bool,
    counts: &mut super::OpCounts,
) -> QTensor {
    let [n, h, wd, cin] = x.dims;
    let [kh, kw, wcin, cout] = w.dims;
    assert_eq!(cin, wcin, "conv channel mismatch");
    let (oh, ow, pad_h, pad_w) = super::gemm::conv_geometry(h, wd, kh, kw, stride, pad_same);
    let mut acc = vec![0i32; n * oh * ow * cout];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let out_off = ((b * oh + oy) * ow + ox) * cout;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad_h as isize;
                    if !(0..h as isize).contains(&iy) {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad_w as isize;
                        if !(0..wd as isize).contains(&ix) {
                            continue;
                        }
                        let in_off = ((b * h + iy as usize) * wd + ix as usize) * cin;
                        let w_off = (ky * kw + kx) * cin * cout;
                        let acc_row = &mut acc[out_off..out_off + cout];
                        for ic in 0..cin {
                            let xv = x.data[in_off + ic];
                            if xv == 0 {
                                continue;
                            }
                            let w_row =
                                &w.mantissa_i32[w_off + ic * cout..w_off + (ic + 1) * cout];
                            // branchless: xv * m vectorizes; on real ternary
                            // hardware this is an add/sub/skip (the cost
                            // model accounts it as such)
                            for (a, &m) in acc_row.iter_mut().zip(w_row) {
                                *a += xv * m;
                            }
                        }
                    }
                }
            }
        }
    }
    let macs = (n * oh * ow * cout * kh * kw * cin) as u64;
    finish_matmul(acc, [n, oh, ow, cout], x.frac + w.frac, macs, w.is_ternary(), counts)
}

/// Integer dense: [n, f_in] x [f_in, f_out], blocked GEMM parallel over
/// batch-row blocks. Bit-identical to [`dense_naive`].
pub fn dense(x: &QTensor, w: &QWeight, counts: &mut super::OpCounts) -> QTensor {
    let n = x.dims[0];
    let f_in = x.numel() / n.max(1);
    let [wi, wo, _, _] = w.dims;
    assert_eq!(f_in, wi, "dense shape mismatch");
    let acc = super::gemm::dense_acc(x, w);
    let macs = (n * f_in * wo) as u64;
    finish_matmul(acc, [n, 1, 1, wo], x.frac + w.frac, macs, w.is_ternary(), counts)
}

/// Reference integer dense: direct loops (see [`dense`]).
pub fn dense_naive(x: &QTensor, w: &QWeight, counts: &mut super::OpCounts) -> QTensor {
    let n = x.dims[0];
    let f_in = x.numel() / n.max(1);
    let [wi, wo, _, _] = w.dims;
    assert_eq!(f_in, wi, "dense shape mismatch");
    let mut acc = vec![0i32; n * wo];
    for b in 0..n {
        let out_row = &mut acc[b * wo..(b + 1) * wo];
        for i in 0..f_in {
            let xv = x.data[b * f_in + i];
            if xv == 0 {
                continue;
            }
            let w_row = &w.mantissa_i32[i * wo..(i + 1) * wo];
            for (a, &m) in out_row.iter_mut().zip(w_row) {
                *a += xv * m;
            }
        }
    }
    let macs = (n * f_in * wo) as u64;
    finish_matmul(acc, [n, 1, 1, wo], x.frac + w.frac, macs, w.is_ternary(), counts)
}

/// Add a per-feature bias (stored as fixed point at the activation's frac).
pub fn add_bias(x: &mut QTensor, bias: &[f32], counts: &mut super::OpCounts) {
    let c = x.dims[3];
    assert_eq!(bias.len(), c);
    let enc: Vec<i64> = bias.iter().map(|&b| enc32(b, x.frac) as i64).collect();
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = (*v as i64 + enc[i % c]).clamp(i32::MIN as i64, i32::MAX as i64) as i32;
    }
    counts.acc_adds += x.numel() as u64;
}

/// Apply a folded-BN fixed-point affine per channel.
pub fn affine(x: &mut QTensor, a: &QAffine, counts: &mut super::OpCounts) {
    let c = x.dims[3];
    assert_eq!(a.a_mant.len(), c);
    // y = (a_m * x_m) * 2^-(a_frac + x_frac) + b_m * 2^-b_frac.
    // align b to the product's exponent
    let prod_frac = a.a_frac + x.frac;
    for (i, v) in x.data.iter_mut().enumerate() {
        let ch = i % c;
        let prod = *v as i64 * a.a_mant[ch] as i64;
        let b_aligned = shift_to(a.b_mant[ch], a.b_frac, prod_frac);
        *v = (prod + b_aligned).clamp(i32::MIN as i64, i32::MAX as i64) as i32;
    }
    x.frac = prod_frac;
    counts.int_mults += x.numel() as u64;
    counts.acc_adds += x.numel() as u64;
    x.requantize(16);
    // deterministic shift accounting (see finish_matmul)
    counts.shifts += x.numel() as u64;
}

pub(crate) fn shift_to(m: i64, from_frac: i32, to_frac: i32) -> i64 {
    if to_frac >= from_frac {
        m << (to_frac - from_frac)
    } else {
        fxp_round_shift(m, from_frac - to_frac)
    }
}

/// Integer ReLU.
pub fn relu(x: &mut QTensor, counts: &mut super::OpCounts) {
    for v in &mut x.data {
        if *v < 0 {
            *v = 0;
        }
    }
    counts.compares += x.numel() as u64;
}

/// Shared max-pool core (also driven by the planned executor): NHWC,
/// square window clamped at the lower-right edge. One definition so the
/// boundary rule can never drift between the interpreted and planned
/// paths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn maxpool_slice(
    src: &[i32],
    (n, h, w, c): (usize, usize, usize, usize),
    k: usize,
    stride: usize,
    (oh, ow): (usize, usize),
    dst: &mut [i32],
) {
    dst[..n * oh * ow * c].fill(i32::MIN);
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..k.min(h - oy * stride) {
                    for kx in 0..k.min(w - ox * stride) {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        let si = ((b * h + iy) * w + ix) * c;
                        let di = ((b * oh + oy) * ow + ox) * c;
                        for ch in 0..c {
                            let v = src[si + ch];
                            if v > dst[di + ch] {
                                dst[di + ch] = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Shared average-pool accumulation core (see [`maxpool_slice`]): sums
/// window values into i64 accumulators; the caller divides via
/// [`divide_slice`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn avgpool_acc_slice(
    src: &[i32],
    (n, h, w, c): (usize, usize, usize, usize),
    k: usize,
    stride: usize,
    (oh, ow): (usize, usize),
    acc: &mut [i64],
) {
    acc[..n * oh * ow * c].fill(0);
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..k.min(h - oy * stride) {
                    for kx in 0..k.min(w - ox * stride) {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        let si = ((b * h + iy) * w + ix) * c;
                        let di = ((b * oh + oy) * ow + ox) * c;
                        for ch in 0..c {
                            acc[di + ch] += src[si + ch] as i64;
                        }
                    }
                }
            }
        }
    }
}

/// Shared global-average accumulation core: per-image per-channel sums.
pub(crate) fn global_avg_acc_slice(
    src: &[i32],
    (n, h, w, c): (usize, usize, usize, usize),
    acc: &mut [i64],
) {
    acc[..n * c].fill(0);
    for b in 0..n {
        for i in 0..h * w {
            let si = (b * h * w + i) * c;
            for ch in 0..c {
                acc[b * c + ch] += src[si + ch] as i64;
            }
        }
    }
}

/// Integer max-pool (VALID, square window).
pub fn maxpool(x: &QTensor, k: usize, stride: usize, counts: &mut super::OpCounts) -> QTensor {
    let [n, h, w, c] = x.dims;
    let (oh, ow) = (h / stride, w / stride);
    let mut out = vec![0i32; n * oh * ow * c];
    maxpool_slice(&x.data, (n, h, w, c), k, stride, (oh, ow), &mut out);
    counts.compares += (n * oh * ow * c * k * k) as u64;
    QTensor { data: out, frac: x.frac, dims: [n, oh, ow, c] }
}

/// Integer average pool: sum + shift (k power of two) or reciprocal multiply.
pub fn avgpool(x: &QTensor, k: usize, stride: usize, counts: &mut super::OpCounts) -> QTensor {
    let [n, h, w, c] = x.dims;
    let (oh, ow) = (h / stride, w / stride);
    let mut acc = vec![0i64; n * oh * ow * c];
    avgpool_acc_slice(&x.data, (n, h, w, c), k, stride, (oh, ow), &mut acc);
    counts.acc_adds += (n * oh * ow * c * k * k) as u64;
    let area = (k * k) as u32;
    let div = divide_out(&acc, area, counts);
    QTensor { data: div, frac: x.frac, dims: [n, oh, ow, c] }
}

/// Global average pool -> [n, 1, 1, c].
pub fn global_avgpool(x: &QTensor, counts: &mut super::OpCounts) -> QTensor {
    let [n, h, w, c] = x.dims;
    let mut acc = vec![0i64; n * c];
    global_avg_acc_slice(&x.data, (n, h, w, c), &mut acc);
    counts.acc_adds += (n * h * w * c) as u64;
    let div = divide_out(&acc, (h * w) as u32, counts);
    QTensor { data: div, frac: x.frac, dims: [n, 1, 1, c] }
}

/// Shared pooling-divide core (also driven by the planned executor): pure
/// shift when `area` is a power of two, Q16 reciprocal multiply + shift
/// otherwise. One definition so the rounding rule can never drift between
/// the interpreted and planned paths.
pub(crate) fn divide_slice(acc: &[i64], area: u32, out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    if area.is_power_of_two() {
        let s = area.trailing_zeros() as i32;
        for (o, &v) in out.iter_mut().zip(acc) {
            *o = fxp_round_shift(v, s) as i32;
        }
    } else {
        // reciprocal in Q16: round(2^16 / area)
        let recip = ((1u64 << 16) + (area as u64 / 2)) / area as u64;
        for (o, &v) in out.iter_mut().zip(acc) {
            *o = fxp_round_shift(v * recip as i64, 16) as i32;
        }
    }
}

/// Divide accumulators by `area` into a fresh vector, with op accounting.
fn divide_out(acc: &[i64], area: u32, counts: &mut super::OpCounts) -> Vec<i32> {
    if !area.is_power_of_two() {
        counts.int_mults += acc.len() as u64;
    }
    counts.shifts += acc.len() as u64;
    let mut out = vec![0i32; acc.len()];
    divide_slice(acc, area, &mut out);
    out
}

/// Shared concat core (also driven by the planned executor): interleave
/// two NHWC sources channel-wise, shifting the finer exponent down to
/// `frac`. One definition so the alignment rule can never drift between
/// the interpreted and planned paths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn concat_rows(
    av: &[i32],
    fa: i32,
    bv: &[i32],
    fb: i32,
    frac: i32,
    ca: usize,
    cb: usize,
    rows: usize,
    dv: &mut [i32],
) {
    let fix = |v: i32, f: i32| -> i32 {
        if f == frac {
            v
        } else {
            fxp_round_shift(v as i64, f - frac) as i32
        }
    };
    let mut o = 0usize;
    for i in 0..rows {
        for &v in &av[i * ca..(i + 1) * ca] {
            dv[o] = fix(v, fa);
            o += 1;
        }
        for &v in &bv[i * cb..(i + 1) * cb] {
            dv[o] = fix(v, fb);
            o += 1;
        }
    }
}

/// Channel-concat two NHWC tensors (aligning exponents by shifting the
/// finer one down — integer shift only).
pub fn concat(a: &QTensor, b: &QTensor, counts: &mut super::OpCounts) -> QTensor {
    assert_eq!(a.dims[0], b.dims[0]);
    assert_eq!(a.dims[1], b.dims[1]);
    assert_eq!(a.dims[2], b.dims[2]);
    let frac = a.frac.min(b.frac);
    let [n, h, w, ca] = a.dims;
    let cb = b.dims[3];
    let rows = n * h * w;
    let mut out = vec![0i32; rows * (ca + cb)];
    concat_rows(&a.data, a.frac, &b.data, b.frac, frac, ca, cb, rows, &mut out);
    // deterministic shift accounting (see finish_matmul): the alignment
    // shifter is billed whether or not the exponents happened to agree
    counts.shifts += out.len() as u64;
    QTensor { data: out, frac, dims: [n, h, w, ca + cb] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::OpCounts;

    fn qt(vals: &[f32], dims: [usize; 4]) -> QTensor {
        QTensor::from_f32(vals, dims, 8)
    }

    #[test]
    fn qtensor_roundtrip_precision() {
        let x = [0.5f32, -0.25, 0.125, 1.0];
        let q = qt(&x, [1, 2, 2, 1]);
        let back = q.to_f32();
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1.0 / 127.0, "{a} vs {b}");
        }
    }

    #[test]
    fn ternary_conv_matches_float() {
        // 1x3x3x1 input, 2x2 ternary kernel, stride 1 VALID
        let x = [1.0f32, 2., 3., 4., 5., 6., 7., 8., 9.];
        let w = [1.0f32, 0., -1., 1.]; // HWIO 2x2x1x1
        let qx = qt(&x, [1, 3, 3, 1]);
        let qw = QWeight::encode(&w, [2, 2, 1, 1], 1.0, 2);
        assert!(qw.is_ternary());
        let mut c = OpCounts::default();
        let out = conv2d(&qx, &qw, 1, false, &mut c);
        assert_eq!(out.dims, [1, 2, 2, 1]);
        let f = out.to_f32();
        // float conv: x00*1 + x01*0 + x10*(-1) + x11*1
        let expect = [1. - 4. + 5., 2. - 5. + 6., 4. - 7. + 8., 5. - 8. + 9.];
        for (g, e) in f.iter().zip(&expect) {
            assert!((g - e).abs() < 0.1, "{g} vs {e}");
        }
        assert_eq!(c.int_mults, 0, "ternary conv must not multiply");
        assert!(c.acc_adds > 0);
    }

    #[test]
    fn same_padding_shape() {
        let x = vec![1.0f32; 8 * 8];
        let w = vec![1.0f32; 3 * 3];
        let qx = qt(&x, [1, 8, 8, 1]);
        let qw = QWeight::encode(&w, [3, 3, 1, 1], 1.0, 2);
        let mut c = OpCounts::default();
        let out = conv2d(&qx, &qw, 1, true, &mut c);
        assert_eq!(out.dims, [1, 8, 8, 1]);
        // interior pixel: 9 contributions of 1.0
        let f = out.to_f32();
        assert!((f[3 * 8 + 3] - 9.0).abs() < 0.5);
    }

    #[test]
    fn dense_matches_float() {
        let x = [0.5f32, -1.0, 2.0];
        let w = [1.0f32, -1., 0., 1., 1., 0.]; // [3 in, 2 out]
        let qx = qt(&x, [1, 1, 1, 3]);
        let qw = QWeight::encode(&w, [3, 2, 1, 1], 1.0, 2);
        let mut c = OpCounts::default();
        let out = dense(&qx, &qw, &mut c);
        let f = out.to_f32();
        // out0 = 0.5*1 + (-1)*0 + 2*1 = 2.5 ; out1 = 0.5*(-1) + (-1)*1 + 0 = -1.5
        assert!((f[0] - 2.5).abs() < 0.1, "{f:?}");
        assert!((f[1] + 1.5).abs() < 0.1, "{f:?}");
    }

    #[test]
    fn relu_and_maxpool() {
        let mut q = qt(&[-1.0, 2.0, -3.0, 4.0], [1, 2, 2, 1]);
        let mut c = OpCounts::default();
        relu(&mut q, &mut c);
        let f = q.to_f32();
        assert!(f[0] == 0.0 && f[2] == 0.0);
        let p = maxpool(&q, 2, 2, &mut c);
        assert_eq!(p.dims, [1, 1, 1, 1]);
        assert!((p.to_f32()[0] - 4.0).abs() < 0.1);
    }

    #[test]
    fn avgpool_power_of_two_is_shift() {
        let q = qt(&[1.0, 2.0, 3.0, 4.0], [1, 2, 2, 1]);
        let mut c = OpCounts::default();
        let p = avgpool(&q, 2, 2, &mut c);
        assert!((p.to_f32()[0] - 2.5).abs() < 0.1);
        assert_eq!(c.int_mults, 0); // power-of-two divide: shift only
    }

    #[test]
    fn global_avgpool_non_power_of_two() {
        let q = qt(&[1.0; 9], [1, 3, 3, 1]);
        let mut c = OpCounts::default();
        let p = global_avgpool(&q, &mut c);
        assert!((p.to_f32()[0] - 1.0).abs() < 0.05);
        assert!(c.int_mults > 0); // reciprocal multiply path
    }

    #[test]
    fn bn_fold_matches_float() {
        let gamma = [2.0f32];
        let beta = [1.0f32];
        let mean = [0.5f32];
        let var = [4.0f32];
        let a = QAffine::fold_bn(&gamma, &beta, &mean, &var, 1e-5);
        let mut q = qt(&[1.5f32, -0.5], [1, 1, 2, 1]);
        let mut c = OpCounts::default();
        affine(&mut q, &a, &mut c);
        let f = q.to_f32();
        // y = 2*(x-0.5)/2 + 1 = x + 0.5
        assert!((f[0] - 2.0).abs() < 0.02, "{f:?}");
        assert!((f[1] - 0.0).abs() < 0.02, "{f:?}");
    }

    #[test]
    fn concat_aligns_exponents() {
        let a = QTensor { data: vec![4], frac: 2, dims: [1, 1, 1, 1] }; // 1.0
        let b = QTensor { data: vec![16], frac: 4, dims: [1, 1, 1, 1] }; // 1.0
        let mut c = OpCounts::default();
        let out = concat(&a, &b, &mut c);
        assert_eq!(out.frac, 2);
        let f = out.to_f32();
        assert_eq!(f, vec![1.0, 1.0]);
    }

    #[test]
    fn bias_add() {
        let mut q = qt(&[1.0, 2.0], [1, 1, 1, 2]);
        let mut c = OpCounts::default();
        add_bias(&mut q, &[0.5, -0.5], &mut c);
        let f = q.to_f32();
        assert!((f[0] - 1.5).abs() < 0.02 && (f[1] - 1.5).abs() < 0.02);
    }

    /// Naive float conv reference (VALID or SAME), NHWC x HWIO.
    fn conv_f32_ref(
        x: &[f32],
        xd: [usize; 4],
        w: &[f32],
        wd: [usize; 4],
        stride: usize,
        pad_same: bool,
    ) -> (Vec<f32>, [usize; 4]) {
        let [n, h, wid, cin] = xd;
        let [kh, kw, _, cout] = wd;
        let (oh, ow, ph, pw) = if pad_same {
            let oh = h.div_ceil(stride);
            let ow = wid.div_ceil(stride);
            (oh, ow,
             (((oh - 1) * stride + kh).saturating_sub(h)) / 2,
             (((ow - 1) * stride + kw).saturating_sub(wid)) / 2)
        } else {
            ((h - kh) / stride + 1, (wid - kw) / stride + 1, 0, 0)
        };
        let mut out = vec![0f32; n * oh * ow * cout];
        for b in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * stride + ky) as isize - ph as isize;
                            let ix = (ox * stride + kx) as isize - pw as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= wid as isize {
                                continue;
                            }
                            for ic in 0..cin {
                                let xv = x[((b * h + iy as usize) * wid + ix as usize) * cin + ic];
                                for oc in 0..cout {
                                    out[((b * oh + oy) * ow + ox) * cout + oc] +=
                                        xv * w[((ky * kw + kx) * cin + ic) * cout + oc];
                                }
                            }
                        }
                    }
                }
            }
        }
        (out, [n, oh, ow, cout])
    }

    #[test]
    fn prop_conv_matches_float_reference() {
        crate::testing::forall(12, |rng: &mut crate::util::rng::Rng| {
            let (h, wid) = (3 + rng.below(8), 3 + rng.below(8));
            let cin = 1 + rng.below(4);
            let cout = 1 + rng.below(4);
            let k = [1, 3].into_iter().nth(rng.below(2)).unwrap().min(h).min(wid);
            let stride = 1 + rng.below(2);
            let pad_same = rng.bool(0.5);
            let x: Vec<f32> = (0..h * wid * cin).map(|_| rng.normal()).collect();
            // ternary weights on an exact grid: integer conv is then exact
            // up to activation-input quantization
            let w: Vec<f32> = (0..k * k * cin * cout)
                .map(|_| [-1.0f32, 0.0, 1.0][rng.below(3)])
                .collect();
            let qx = QTensor::from_f32(&x, [1, h, wid, cin], 8);
            let qw = QWeight::encode(&w, [k, k, cin, cout], 1.0, 2);
            let mut c = crate::inference::OpCounts::default();
            let got = conv2d(&qx, &qw, stride, pad_same, &mut c);
            // reference on the *quantized* input so rounding cancels out
            let (want, wd2) = conv_f32_ref(
                &qx.to_f32(),
                [1, h, wid, cin],
                &w,
                [k, k, cin, cout],
                stride,
                pad_same,
            );
            assert_eq!(got.dims, wd2);
            let gf = got.to_f32();
            for (g, e) in gf.iter().zip(&want) {
                assert!(
                    (g - e).abs() <= 2e-2 * e.abs().max(1.0),
                    "{g} vs {e} (h={h} w={wid} k={k} s={stride} same={pad_same})"
                );
            }
        });
    }

    #[test]
    fn prop_dense_matches_float_reference() {
        crate::testing::forall(16, |rng: &mut crate::util::rng::Rng| {
            let fi = 1 + rng.below(64);
            let fo = 1 + rng.below(16);
            let x: Vec<f32> = (0..fi).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..fi * fo)
                .map(|_| [-1.0f32, 0.0, 1.0][rng.below(3)])
                .collect();
            let qx = QTensor::from_f32(&x, [1, 1, 1, fi], 8);
            let qw = QWeight::encode(&w, [fi, fo, 1, 1], 1.0, 2);
            let mut c = crate::inference::OpCounts::default();
            let got = dense(&qx, &qw, &mut c).to_f32();
            let xq = qx.to_f32();
            for o in 0..fo {
                let want: f32 = (0..fi).map(|i| xq[i] * w[i * fo + o]).sum();
                assert!((got[o] - want).abs() <= 2e-2 * want.abs().max(1.0));
            }
        });
    }
}
