//! im2col + blocked integer GEMM — the inference engine's hot path.
//!
//! The direct convolution loops (kept as `ops::conv2d_naive` for
//! cross-checking and benchmarking) walk the input once per kernel tap and
//! re-stream the whole weight tensor for every output pixel. This module
//! restructures conv/dense as matrix multiplication on top of the shared
//! scalar-generic core in [`crate::kernels`]:
//!
//! * **im2col**: each image's receptive fields are gathered into a dense
//!   patch matrix `A[oh*ow, kh*kw*cin]` (`kernels::im2col` — padding
//!   becomes literal zeros, and the memset is skipped entirely for
//!   unpadded geometries);
//! * **packed-panel GEMM**: the HWIO mantissas reshaped to
//!   `[kh*kw*cin, cout]` are packed once per weight into `NR`-column
//!   panels ([`cached_packed`], warmed at `ExecPlan` build time) and
//!   `kernels::gemm_packed` runs the `MR x NR` register-blocked,
//!   depth-blocked kernel over them;
//! * **ternary fast path**: when every mantissa is in {-1, 0, +1} *and* the
//!   zero mode is well occupied, the weight matrix is transposed once into
//!   sign-separated index lists and each MAC degenerates to a pure integer
//!   add or subtract — the paper's fixed-point hardware claim, executed
//!   literally. The add/sub kernel is register-blocked over `MR` rows too,
//!   so each walk of the index lists feeds four images' worth of output;
//! * **bit-sliced popcount path**: any weight with |mantissa| <= 3
//!   (every 2-/3-bit code) can instead run on
//!   [`kernels::bitslice::gemm_bitsliced`] — AND + popcount over sign-
//!   magnitude bit planes, SIMD-dispatched at runtime. [`select_kernel`]
//!   races the three kernels analytically once per weight: ternary when
//!   its nonzero count beats the estimated plane cost (the old >= 50%-
//!   zeros rule at large depth), bit-sliced for the rest of the eligible
//!   range, packed-panel multiply otherwise;
//! * **batch parallelism**: images are independent, so the batch dimension
//!   is fanned out over `util::pool::par_chunks_mut`, which dispatches to
//!   the process-wide persistent worker pool (no thread spawn per call —
//!   see the threading-model notes in `util::pool`).
//!
//! Everything is exact i32 arithmetic in every path, so naive and GEMM
//! results are bit-identical (asserted by property tests here and the
//! `smoke_engine` integration test).

pub(crate) use crate::kernels::{conv_geometry, im2col};

use crate::kernels::bitslice::{self, BitslicePlan};
use crate::kernels::{self, MR, PackedB};
use crate::util::pool;

use super::ops::{QTensor, QWeight};

/// Sign-separated sparse view of a ternary weight matrix: per depth row,
/// the column indices holding +1 and -1. A MAC against it is an add or a
/// subtract — no multiplier anywhere.
#[derive(Clone, Debug)]
pub(crate) struct TernaryPlan {
    plus: Vec<u32>,
    minus: Vec<u32>,
    /// CSR offsets, length depth + 1 each
    plus_off: Vec<u32>,
    minus_off: Vec<u32>,
}

impl TernaryPlan {
    /// Build from a row-major `[depth, cols]` ternary matrix.
    pub(crate) fn build(b: &[i32], depth: usize, cols: usize) -> TernaryPlan {
        debug_assert_eq!(b.len(), depth * cols);
        let mut plan = TernaryPlan {
            plus: Vec::new(),
            minus: Vec::new(),
            plus_off: Vec::with_capacity(depth + 1),
            minus_off: Vec::with_capacity(depth + 1),
        };
        plan.plus_off.push(0);
        plan.minus_off.push(0);
        for row in b.chunks(cols) {
            for (j, &m) in row.iter().enumerate() {
                debug_assert!((-1..=1).contains(&m));
                match m {
                    1 => plan.plus.push(j as u32),
                    -1 => plan.minus.push(j as u32),
                    _ => {}
                }
            }
            plan.plus_off.push(plan.plus.len() as u32);
            plan.minus_off.push(plan.minus.len() as u32);
        }
        plan
    }

    fn nonzeros(&self) -> usize {
        self.plus.len() + self.minus.len()
    }
}

/// `C += A * B` where `B` is ternary, as pure adds/subtracts. Register-
/// blocked over `MR = 4` A-rows: the +1/-1 index lists of a depth row are
/// walked once and applied to four output rows, instead of re-walked per
/// row. Adding `xv = 0` is the integer identity, so no per-row zero test
/// is needed inside the list walk.
pub(crate) fn gemm_ternary(
    a: &[i32],
    plan: &TernaryPlan,
    c: &mut [i32],
    rows: usize,
    depth: usize,
    cols: usize,
) {
    debug_assert_eq!(a.len(), rows * depth);
    debug_assert_eq!(c.len(), rows * cols);
    for (ab, cb) in a.chunks(MR * depth).zip(c.chunks_mut(MR * cols)) {
        if ab.len() == MR * depth {
            ternary_kernel_4(ab, plan, cb, depth, cols);
        } else {
            // remainder rows (< MR)
            for (a_row, c_row) in ab.chunks(depth).zip(cb.chunks_mut(cols)) {
                ternary_row(a_row, plan, c_row);
            }
        }
    }
}

/// Four output rows per index-list walk.
#[inline]
fn ternary_kernel_4(ab: &[i32], plan: &TernaryPlan, cb: &mut [i32], depth: usize, cols: usize) {
    let (a0, rest) = ab.split_at(depth);
    let (a1, rest) = rest.split_at(depth);
    let (a2, a3) = rest.split_at(depth);
    let (c0, rest) = cb.split_at_mut(cols);
    let (c1, rest) = rest.split_at_mut(cols);
    let (c2, c3) = rest.split_at_mut(cols);
    for kk in 0..depth {
        let (x0, x1, x2, x3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
        if (x0 | x1 | x2 | x3) == 0 {
            continue;
        }
        let p = plan.plus_off[kk] as usize..plan.plus_off[kk + 1] as usize;
        for &j in &plan.plus[p] {
            let j = j as usize;
            c0[j] += x0;
            c1[j] += x1;
            c2[j] += x2;
            c3[j] += x3;
        }
        let m = plan.minus_off[kk] as usize..plan.minus_off[kk + 1] as usize;
        for &j in &plan.minus[m] {
            let j = j as usize;
            c0[j] -= x0;
            c1[j] -= x1;
            c2[j] -= x2;
            c3[j] -= x3;
        }
    }
}

/// Single-row add/sub walk (remainder rows).
#[inline]
fn ternary_row(a_row: &[i32], plan: &TernaryPlan, c_row: &mut [i32]) {
    for (kk, &xv) in a_row.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        let p = plan.plus_off[kk] as usize..plan.plus_off[kk + 1] as usize;
        for &j in &plan.plus[p] {
            c_row[j as usize] += xv;
        }
        let m = plan.minus_off[kk] as usize..plan.minus_off[kk + 1] as usize;
        for &j in &plan.minus[m] {
            c_row[j as usize] -= xv;
        }
    }
}

/// Should a ternary weight use the add/sub kernel? The analytic race:
/// the index-list walk costs one add per nonzero mantissa per A-row,
/// the bit-sliced alternative costs `bitslice::estimated_row_cost`
/// scalar-op equivalents per A-row (one magnitude plane for ternary).
/// At large depth this degenerates to the old >= 50%-zeros rule; ties
/// go to ternary, which is also multiply-free in the `OpCounts` ledger.
fn use_ternary_plan(w: &QWeight, depth: usize, cols: usize) -> bool {
    if !w.is_ternary() {
        return false;
    }
    let nnz = w.mantissa.iter().filter(|&&m| m != 0).count() as u64;
    nnz <= bitslice::estimated_row_cost(depth, cols, 1)
}

/// The weight's ternary plan, built once per `QWeight` and cached (the
/// decision and the index lists only depend on the immutable mantissas).
/// `ExecPlan` warms this at plan-build time so no forward ever pays for it.
pub(crate) fn cached_plan(w: &QWeight, depth: usize, cols: usize) -> Option<&TernaryPlan> {
    w.ternary_plan
        .get_or_init(|| {
            use_ternary_plan(w, depth, cols)
                .then(|| TernaryPlan::build(&w.mantissa_i32, depth, cols))
        })
        .as_ref()
}

/// The weight's bit-plane decomposition, built once per `QWeight` and
/// cached. Consulted only after the ternary race is lost — a weight with
/// |mantissa| <= 3 that didn't take the add/sub path runs AND/popcount
/// instead of the multiply kernel.
pub(crate) fn cached_bitplan(w: &QWeight, depth: usize, cols: usize) -> Option<&BitslicePlan> {
    w.bit_plan
        .get_or_init(|| {
            bitslice::eligible(&w.mantissa)
                .then(|| BitslicePlan::build(&w.mantissa_i32, depth, cols))
        })
        .as_ref()
}

/// The weight's packed `B` panels, built once per `QWeight` and cached —
/// inference weights are immutable, so the pack happens at most once per
/// process (`ExecPlan` warms it at plan-build time for every non-ternary
/// matmul so no forward ever pays for it).
pub(crate) fn cached_packed(w: &QWeight, depth: usize, cols: usize) -> &PackedB<i32> {
    w.packed_b.get_or_init(|| kernels::pack_b(&w.mantissa_i32, depth, cols))
}

/// The GEMM kernel a weight resolved to. Copy (it's three borrows), so
/// the batch-parallel closures capture it by value.
#[derive(Clone, Copy)]
pub(crate) enum Kernel<'a> {
    Ternary(&'a TernaryPlan),
    Bitslice(&'a BitslicePlan),
    Packed(&'a PackedB<i32>),
}

impl Kernel<'_> {
    /// `C += A * B` through whichever kernel was selected — all three are
    /// bit-identical, only the arithmetic (add/sub, popcount, multiply)
    /// differs.
    pub(crate) fn run(self, a: &[i32], c: &mut [i32], rows: usize, depth: usize, cols: usize) {
        match self {
            Kernel::Ternary(p) => gemm_ternary(a, p, c, rows, depth, cols),
            Kernel::Bitslice(p) => bitslice::gemm_bitsliced(a, p, c, rows, depth, cols),
            Kernel::Packed(p) => {
                debug_assert_eq!((p.depth, p.cols), (depth, cols));
                kernels::gemm_packed(a, p, c, rows)
            }
        }
    }

    pub(crate) fn name(self) -> &'static str {
        match self {
            Kernel::Ternary(_) => "ternary",
            Kernel::Bitslice(_) => "bitslice",
            Kernel::Packed(_) => "packed",
        }
    }
}

/// Resolve the cheapest kernel for a `[depth, cols]` weight (cached —
/// the first call per weight runs the analytic race and builds the
/// winner's data structure; `ExecPlan` warms it at plan-build time).
pub(crate) fn select_kernel(w: &QWeight, depth: usize, cols: usize) -> Kernel<'_> {
    if let Some(p) = cached_plan(w, depth, cols) {
        return Kernel::Ternary(p);
    }
    if let Some(p) = cached_bitplan(w, depth, cols) {
        return Kernel::Bitslice(p);
    }
    Kernel::Packed(cached_packed(w, depth, cols))
}

/// Which kernel [`select_kernel`] routes this weight to — `"ternary"`,
/// `"bitslice"`, or `"packed"`. Observability for benches and the
/// engagement assertions in the conformance tests.
pub fn kernel_name(w: &QWeight, depth: usize, cols: usize) -> &'static str {
    select_kernel(w, depth, cols).name()
}

/// Raw conv accumulators via im2col + packed-panel GEMM, parallel over the
/// batch. Returns `[n, oh, ow, cout]` i32 sums — bit-identical to the
/// naive loops.
pub(crate) fn conv2d_acc(
    x: &QTensor,
    w: &QWeight,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    oh: usize,
    ow: usize,
) -> Vec<i32> {
    let [n, _, _, cin] = x.dims;
    let [kh, kw, _, cout] = w.dims;
    let k_dim = kh * kw * cin;
    let m_dim = oh * ow;
    let mut acc = vec![0i32; n * m_dim * cout];
    if n == 0 || m_dim == 0 {
        return acc;
    }
    let kern = select_kernel(w, k_dim, cout);
    let mut views: Vec<&mut [i32]> = acc.chunks_mut(m_dim * cout).collect();
    let workers = pool::default_workers().clamp(1, views.len());
    pool::par_chunks_mut(&mut views, workers, |offset, chunk| {
        let mut patches = vec![0i32; m_dim * k_dim];
        for (bi, out_img) in chunk.iter_mut().enumerate() {
            let b = offset + bi;
            let hwc = (x.dims[1], x.dims[2], cin);
            im2col(&x.data, hwc, b, kh, kw, stride, pad_h, pad_w, oh, ow, &mut patches);
            kern.run(&patches, out_img, m_dim, k_dim, cout);
        }
    });
    acc
}

/// Raw dense accumulators `[n, f_out]` via packed-panel GEMM, parallel
/// over batch-row blocks. Bit-identical to the naive loops.
pub(crate) fn dense_acc(x: &QTensor, w: &QWeight) -> Vec<i32> {
    let n = x.dims[0];
    let f_in = x.numel() / n.max(1);
    let [_, f_out, _, _] = w.dims;
    let mut acc = vec![0i32; n * f_out];
    if n == 0 {
        return acc;
    }
    let kern = select_kernel(w, f_in, f_out);
    let workers = pool::default_workers().clamp(1, n);
    let rows_per_block = n.div_ceil(workers);
    let mut views: Vec<&mut [i32]> = acc.chunks_mut(rows_per_block * f_out).collect();
    pool::par_chunks_mut(&mut views, workers, |offset, chunk| {
        for (bi, out_block) in chunk.iter_mut().enumerate() {
            let row0 = (offset + bi) * rows_per_block;
            let rows = out_block.len() / f_out;
            let a = &x.data[row0 * f_in..(row0 + rows) * f_in];
            kern.run(a, out_block, rows, f_in, f_out);
        }
    });
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::OpCounts;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    /// Schoolbook reference for the raw GEMM kernels.
    fn gemm_ref(a: &[i32], b: &[i32], rows: usize, depth: usize, cols: usize) -> Vec<i32> {
        let mut c = vec![0i32; rows * cols];
        for i in 0..rows {
            for kk in 0..depth {
                for j in 0..cols {
                    c[i * cols + j] += a[i * depth + kk] * b[kk * cols + j];
                }
            }
        }
        c
    }

    #[test]
    fn prop_ternary_plan_matches_dense() {
        forall(24, |rng: &mut Rng| {
            let rows = 1 + rng.below(11);
            let depth = 1 + rng.below(120);
            let cols = 1 + rng.below(33);
            let a: Vec<i32> = (0..rows * depth).map(|_| rng.below(31) as i32 - 15).collect();
            let b: Vec<i32> = (0..depth * cols).map(|_| rng.below(3) as i32 - 1).collect();
            let plan = TernaryPlan::build(&b, depth, cols);
            assert_eq!(plan.nonzeros(), b.iter().filter(|&&m| m != 0).count());
            let mut c = vec![0i32; rows * cols];
            gemm_ternary(&a, &plan, &mut c, rows, depth, cols);
            assert_eq!(c, gemm_ref(&a, &b, rows, depth, cols), "{rows}x{depth}x{cols}");
        });
    }

    #[test]
    fn prop_conv_gemm_bit_identical_to_naive() {
        forall(16, |rng: &mut Rng| {
            let (h, w) = (3 + rng.below(10), 3 + rng.below(10));
            let n = 1 + rng.below(5);
            let cin = 1 + rng.below(5);
            let cout = 1 + rng.below(9);
            let k = (1 + 2 * rng.below(2)).min(h).min(w); // 1 or 3
            let stride = 1 + rng.below(2);
            let pad_same = rng.bool(0.5);
            let n_bits = [2u32, 4, 8][rng.below(3)];
            let xs: Vec<f32> = (0..n * h * w * cin).map(|_| rng.normal()).collect();
            let ws: Vec<f32> = (0..k * k * cin * cout).map(|_| rng.normal() * 0.4).collect();
            let qx = QTensor::from_f32(&xs, [n, h, w, cin], 8);
            let qw = QWeight::encode(&ws, [k, k, cin, cout], 0.25, n_bits);
            let mut cg = OpCounts::default();
            let mut cn = OpCounts::default();
            let got = super::super::ops::conv2d(&qx, &qw, stride, pad_same, &mut cg);
            let want = super::super::ops::conv2d_naive(&qx, &qw, stride, pad_same, &mut cn);
            assert_eq!(got.dims, want.dims);
            assert_eq!(got.frac, want.frac);
            assert_eq!(got.data, want.data, "k={k} s={stride} same={pad_same}");
            assert_eq!(cg, cn, "op accounting must not depend on the backend");
        });
    }

    #[test]
    fn prop_dense_gemm_bit_identical_to_naive() {
        forall(16, |rng: &mut Rng| {
            let n = 1 + rng.below(9);
            let f_in = 1 + rng.below(200);
            let f_out = 1 + rng.below(40);
            let n_bits = [2u32, 3, 8][rng.below(3)];
            let xs: Vec<f32> = (0..n * f_in).map(|_| rng.normal()).collect();
            let ws: Vec<f32> = (0..f_in * f_out).map(|_| rng.normal() * 0.4).collect();
            let qx = QTensor::from_f32(&xs, [n, 1, 1, f_in], 8);
            let qw = QWeight::encode(&ws, [f_in, f_out, 1, 1], 0.25, n_bits);
            let mut cg = OpCounts::default();
            let mut cn = OpCounts::default();
            let got = super::super::ops::dense(&qx, &qw, &mut cg);
            let want = super::super::ops::dense_naive(&qx, &qw, &mut cn);
            assert_eq!(got.data, want.data);
            assert_eq!(got.frac, want.frac);
            assert_eq!(cg, cn);
        });
    }

    #[test]
    fn sparse_ternary_engages_add_sub_plan() {
        // 80% zeros: the plan must engage and still agree with naive
        let mut rng = Rng::new(7);
        let cin = 8;
        let cout = 16;
        let ws: Vec<f32> = (0..3 * 3 * cin * cout)
            .map(|_| match rng.below(10) {
                0 => 0.25,
                1 => -0.25,
                _ => 0.0,
            })
            .collect();
        let qw = QWeight::encode(&ws, [3, 3, cin, cout], 0.25, 2);
        assert!(qw.is_ternary());
        assert!(use_ternary_plan(&qw, 3 * 3 * cin, cout));
        assert_eq!(kernel_name(&qw, 3 * 3 * cin, cout), "ternary");
        let xs: Vec<f32> = (0..2 * 6 * 6 * cin).map(|_| rng.normal()).collect();
        let qx = QTensor::from_f32(&xs, [2, 6, 6, cin], 8);
        let mut cg = OpCounts::default();
        let mut cn = OpCounts::default();
        let got = super::super::ops::conv2d(&qx, &qw, 1, true, &mut cg);
        let want = super::super::ops::conv2d_naive(&qx, &qw, 1, true, &mut cn);
        assert_eq!(got.data, want.data);
        assert_eq!(cg.int_mults, 0, "ternary conv must not count multiplies");
    }

    #[test]
    fn dense_uniform_ternary_routes_to_bitslice() {
        // uniform ternary is only ~1/3 zeros: the add/sub walk loses the
        // analytic race, and the popcount kernel (eligible for every
        // ternary weight) takes the slot the multiply kernel used to win
        let mut rng = Rng::new(3);
        let ws: Vec<f32> = (0..64 * 10).map(|_| (rng.below(3) as f32 - 1.0) * 0.5).collect();
        let qw = QWeight::encode(&ws, [64, 10, 1, 1], 0.5, 2);
        if qw.mantissa.iter().filter(|&&m| m == 0).count() * 2 < qw.mantissa.len() {
            assert!(!use_ternary_plan(&qw, 64, 10));
            assert_eq!(kernel_name(&qw, 64, 10), "bitslice");
        }
    }

    #[test]
    fn kernel_selection_covers_all_three_kernels() {
        let mut rng = Rng::new(11);
        // 3-bit codes reach |mantissa| = 3: never ternary, always
        // popcount-eligible
        let ws: Vec<f32> = (0..128 * 16).map(|_| rng.normal()).collect();
        let qw3 = QWeight::encode(&ws, [128, 16, 1, 1], 0.25, 3);
        assert!(qw3.mantissa.iter().any(|&m| m.abs() > 1), "want a wide code");
        assert_eq!(kernel_name(&qw3, 128, 16), "bitslice");
        // 8-bit codes overflow the plane decomposition: multiply kernel
        let qw8 = QWeight::encode(&ws, [128, 16, 1, 1], 0.03125, 8);
        assert!(qw8.mantissa.iter().any(|&m| m.abs() > 3), "want a wide code");
        assert_eq!(kernel_name(&qw8, 128, 16), "packed");
        // the resolved kernel is cached: same selection on every call
        assert_eq!(kernel_name(&qw8, 128, 16), "packed");
    }

    #[test]
    fn prop_all_kernels_bit_identical_on_shared_shapes() {
        // race whatever kernel selection picks against the schoolbook
        // reference, across the eligibility boundary (max |m| 1..=4)
        forall(24, |rng: &mut Rng| {
            let rows = 1 + rng.below(7);
            let depth = 1 + rng.below(150);
            let cols = 1 + rng.below(24);
            let max_mag = 1 + rng.below(4) as i32;
            let wf: Vec<f32> = (0..depth * cols)
                .map(|_| (rng.below(2 * max_mag as usize + 1) as i32 - max_mag) as f32)
                .collect();
            let qw = QWeight::encode(&wf, [depth, cols, 1, 1], 1.0, 4);
            let a: Vec<i32> = (0..rows * depth).map(|_| rng.below(61) as i32 - 30).collect();
            let want = gemm_ref(&a, &qw.mantissa_i32, rows, depth, cols);
            let mut c = vec![0i32; rows * cols];
            select_kernel(&qw, depth, cols).run(&a, &mut c, rows, depth, cols);
            let name = kernel_name(&qw, depth, cols);
            assert_eq!(c, want, "{name} {rows}x{depth}x{cols} max_mag={max_mag}");
            if qw.mantissa.iter().any(|&m| m.abs() > 3) {
                assert_eq!(name, "packed");
            } else {
                assert_ne!(name, "packed", "eligible weights never multiply");
            }
        });
    }

    #[test]
    fn packed_panels_cached_once_per_weight() {
        let mut rng = Rng::new(9);
        let ws: Vec<f32> = (0..32 * 20).map(|_| rng.normal() * 0.4).collect();
        let qw = QWeight::encode(&ws, [32, 20, 1, 1], 0.25, 8);
        let p1 = cached_packed(&qw, 32, 20) as *const PackedB<i32>;
        let p2 = cached_packed(&qw, 32, 20) as *const PackedB<i32>;
        assert_eq!(p1, p2, "pack must happen once and be cached");
        assert_eq!(cached_packed(&qw, 32, 20).cols, 20);
    }
}
