//! Pure fixed-point inference engine.
//!
//! Section 3.1's motivation — "a multiplication by a power of two is
//! equivalent to moving the decimal point … which significantly accelerates
//! computations on fixed-point hardware" — is demonstrated here for real:
//! the engine executes a whole forward pass with integer arithmetic only:
//!
//! * weights: i8 mantissas m (|m| <= 2^{N-1}-1) with a per-layer power-of-two
//!   step size delta = 2^-f — for N=2 the mantissas are ternary {-1,0,1}, so
//!   every "multiplication" in a conv/dense is an add, a subtract, or a skip;
//! * activations: i32 mantissas with a shared per-tensor exponent; layer
//!   outputs are rescaled by *bit shifts* (round-half-away, matching Q_N);
//! * batch-norm: folded to a fixed-point affine (16-bit mantissa multiply +
//!   shift) — our extension toward the paper's "pure fixed-point models"
//!   future-work item, documented in DESIGN.md;
//! * pooling / ReLU / concat: integer comparisons and adds.
//!
//! The engine reconstructs the network from the artifact manifest's layer
//! graph and a trained checkpoint, and its accuracy is validated against
//! the float `evalq` executable in the integration tests.
//!
//! Execution is compile-then-execute by default: `IntModel::plan` lowers
//! the layer program once into an [`ExecPlan`] (preallocated ping-pong
//! arena, plan-time concat retention, fused bias/BN/ReLU/requantize
//! epilogues, analytic op counting) which `forward` reuses across calls —
//! see `plan.rs` and DESIGN.md §"Planned execution".

mod arena;
mod cost;
mod engine;
pub(crate) mod gemm;
mod ops;
mod plan;

pub use arena::{Scratch, ScratchPool};
pub use cost::{CostModel, CostReport, EnergyTable, OpCounts};
pub use engine::{Backend, IntModel, QTensor};
pub use gemm::kernel_name;
pub use ops::{conv2d, conv2d_naive, dense, dense_naive, QWeight};
pub use plan::ExecPlan;
