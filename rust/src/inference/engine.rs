//! Graph executor: rebuild the network from the artifact manifest and run
//! it with integer arithmetic only.
//!
//! Since the compile-then-execute refactor the default path is *planned*:
//! `forward` lazily compiles the layer program into an [`ExecPlan`]
//! (preallocated arena, fused epilogues, plan-time concat retention) and
//! reuses it — plus a pooled `Scratch` — across calls. The interpreted
//! walk below survives as the bit-exact oracle (`Backend::Naive`) and the
//! per-call GEMM comparison point (`Backend::Gemm`).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::Checkpoint;
use crate::runtime::Manifest;

use super::arena::{Scratch, ScratchPool};
use super::ops::{self, QAffine, QWeight};
use super::plan::ExecPlan;
use super::{CostModel, CostReport, OpCounts};

pub use super::ops::QTensor;

const BN_EPS: f32 = 1e-5;

/// Scratches kept warm per model; beyond this, extras are dropped (they
/// only pile up when more threads than this share one `IntModel`).
const MAX_POOLED_SCRATCH: usize = 8;

/// One compiled layer of the integer network.
pub(crate) enum IntLayer {
    Conv { w: QWeight, bias: Option<Vec<f32>>, stride: usize, pad_same: bool },
    Dense { w: QWeight, bias: Option<Vec<f32>> },
    Bn(QAffine),
    Relu,
    MaxPool { k: usize, stride: usize },
    AvgPool { k: usize, stride: usize },
    GlobalAvgPool,
    Flatten,
    Concat { from: usize },
}

/// Which execution strategy `forward` drives.
///
/// `Planned` (the default) compiles the layer program once into an
/// [`ExecPlan`] — arena buffers, fused integer epilogues — and executes
/// that. `Gemm` interprets the layer list per call on the im2col + blocked
/// GEMM kernels; `Naive` interprets on the direct-loop reference kernels.
/// All three are exact integer arithmetic and produce bit-identical
/// activations and identical `OpCounts` — the interpreted modes exist for
/// cross-checking and benchmarking, not as fallbacks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    #[default]
    Planned,
    Gemm,
    Naive,
}

/// A compiled plan plus its pool of reusable per-call scratches (the same
/// checkout/return `ScratchPool` the serving layer uses).
struct PlanCache {
    plan: Arc<ExecPlan>,
    pool: ScratchPool,
}

/// The integer model: quantized weights + the layer program.
pub struct IntModel {
    layers: Arc<Vec<IntLayer>>,
    /// concat-source layer indices, resolved once at build time
    retained: BTreeSet<usize>,
    pub n_bits: u32,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    /// quantized-weight parameter count (for the cost model)
    pub quant_params: u64,
    /// float-kept auxiliary parameter count
    pub aux_params: u64,
    /// whether every quantized layer is ternary (pure add/sub inference)
    pub all_ternary: bool,
    /// execution strategy (planned by default)
    pub backend: Backend,
    /// lazily-built plan + scratch pool for the planned backend
    cache: Mutex<Option<PlanCache>>,
}

impl IntModel {
    /// Build from a manifest + trained checkpoint (float weights + deltas).
    /// Weights are hard-quantized here — this IS the paper's final
    /// quantization step (Alg. 1 lines 21-23) materialized for hardware.
    pub fn build(man: &Manifest, ckpt: &Checkpoint) -> Result<IntModel> {
        let deltas = &ckpt
            .find("__deltas__")
            .context("checkpoint has no __deltas__")?
            .data;
        let tensor = |idx: usize| -> Result<&crate::coordinator::Tensor> {
            let meta = &man.params[idx];
            ckpt.find(&meta.name)
                .with_context(|| format!("missing tensor {}", meta.name))
        };
        let mut layers = Vec::new();
        let mut quant_params = 0u64;
        let mut aux_params = 0u64;
        let mut all_ternary = true;
        for l in &man.layers {
            match l.ty() {
                "conv" => {
                    let widx = l.param_idx("w").context("conv without w")?;
                    let meta = &man.params[widx];
                    let t = tensor(widx)?;
                    let qidx = meta.qidx.context("conv weight not quantized")?;
                    let dims = [t.dims[0], t.dims[1], t.dims[2], t.dims[3]];
                    let w = QWeight::encode(&t.data, dims, deltas[qidx], man.n_bits);
                    all_ternary &= w.is_ternary();
                    quant_params += t.data.len() as u64;
                    let bias = match l.param_idx("b") {
                        Some(bi) => {
                            let bt = tensor(bi)?;
                            aux_params += bt.data.len() as u64;
                            Some(bt.data.clone())
                        }
                        None => None,
                    };
                    layers.push(IntLayer::Conv {
                        w,
                        bias,
                        stride: l.usize_field("stride").unwrap_or(1),
                        pad_same: l.str_field("padding") == Some("SAME"),
                    });
                }
                "dense" => {
                    let widx = l.param_idx("w").context("dense without w")?;
                    let meta = &man.params[widx];
                    let t = tensor(widx)?;
                    let qidx = meta.qidx.context("dense weight not quantized")?;
                    let dims = [t.dims[0], t.dims[1], 1, 1];
                    let w = QWeight::encode(&t.data, dims, deltas[qidx], man.n_bits);
                    all_ternary &= w.is_ternary();
                    quant_params += t.data.len() as u64;
                    let bias = match l.param_idx("b") {
                        Some(bi) => {
                            let bt = tensor(bi)?;
                            aux_params += bt.data.len() as u64;
                            Some(bt.data.clone())
                        }
                        None => None,
                    };
                    layers.push(IntLayer::Dense { w, bias });
                }
                "bn" => {
                    let g = tensor(l.param_idx("gamma").context("bn gamma")?)?;
                    let b = tensor(l.param_idx("beta").context("bn beta")?)?;
                    let mi = l.usize_field("mean").context("bn mean idx")?;
                    let vi = l.usize_field("var").context("bn var idx")?;
                    let mean = ckpt
                        .find(&man.state[mi].name)
                        .with_context(|| format!("missing state {}", man.state[mi].name))?;
                    let var = ckpt
                        .find(&man.state[vi].name)
                        .with_context(|| format!("missing state {}", man.state[vi].name))?;
                    aux_params += (g.data.len() + b.data.len()) as u64;
                    layers.push(IntLayer::Bn(QAffine::fold_bn(
                        &g.data, &b.data, &mean.data, &var.data, BN_EPS,
                    )));
                }
                "relu" => layers.push(IntLayer::Relu),
                "maxpool" => layers.push(IntLayer::MaxPool {
                    k: l.usize_field("k").unwrap_or(2),
                    stride: l.usize_field("stride").unwrap_or(2),
                }),
                "avgpool" => layers.push(IntLayer::AvgPool {
                    k: l.usize_field("k").unwrap_or(2),
                    stride: l.usize_field("stride").unwrap_or(2),
                }),
                "global_avgpool" => layers.push(IntLayer::GlobalAvgPool),
                "flatten" => layers.push(IntLayer::Flatten),
                "concat" => layers.push(IntLayer::Concat {
                    from: l.usize_field("from").context("concat from")?,
                }),
                other => bail!("integer engine: unsupported layer type {other:?}"),
            }
        }
        // concat retention is a property of the (immutable) program — decide
        // it once here, not per forward
        let retained: BTreeSet<usize> = layers
            .iter()
            .filter_map(|l| match l {
                IntLayer::Concat { from } => Some(*from),
                _ => None,
            })
            .collect();
        Ok(IntModel {
            layers: Arc::new(layers),
            retained,
            n_bits: man.n_bits,
            input_shape: man.input_shape,
            num_classes: man.num_classes,
            quant_params,
            aux_params,
            all_ternary,
            backend: Backend::default(),
            cache: Mutex::new(None),
        })
    }

    /// Builder-style backend override (used by the planned/GEMM/naive
    /// cross-checks).
    pub fn with_backend(mut self, backend: Backend) -> IntModel {
        self.backend = backend;
        self
    }

    /// Compile the layer program for batches up to `max_batch`. The plan is
    /// immutable and `Sync`: share it behind an `Arc` and give each worker
    /// thread its own [`ExecPlan::scratch`] — that pairing is the serving
    /// seam. Returns a fresh, unshared plan (e.g. to retune
    /// [`ExecPlan::with_workers`]); use [`IntModel::shared_plan`] to get
    /// the cached instance `forward` itself runs on.
    pub fn plan(&self, max_batch: usize) -> Result<ExecPlan> {
        ExecPlan::build(
            Arc::clone(&self.layers),
            &self.retained,
            self.input_shape,
            max_batch,
        )
    }

    /// The cache-backed shared plan — the exact instance `forward`/
    /// `predict`/`accuracy` execute on (compiled at most once per
    /// `max_batch` high-water mark). `serve::Registry::register` draws its
    /// per-model plan from here, so a served model and its direct
    /// `forward()` path share one compiled artifact.
    pub fn shared_plan(&self, max_batch: usize) -> Result<Arc<ExecPlan>> {
        self.plan_for(max_batch)
    }

    /// The cached shared plan, (re)built if the requested batch outgrows it.
    fn plan_for(&self, batch: usize) -> Result<Arc<ExecPlan>> {
        let mut guard = self.cache.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(c) = guard.as_ref() {
            if c.plan.max_batch() >= batch {
                return Ok(Arc::clone(&c.plan));
            }
        }
        let plan = Arc::new(self.plan(batch)?);
        *guard = Some(PlanCache {
            plan: Arc::clone(&plan),
            pool: ScratchPool::new(MAX_POOLED_SCRATCH),
        });
        Ok(plan)
    }

    fn take_scratch(&self, plan: &Arc<ExecPlan>) -> Option<Scratch> {
        let guard = self.cache.lock().unwrap_or_else(|e| e.into_inner());
        match guard.as_ref() {
            Some(c) if Arc::ptr_eq(&c.plan, plan) => c.pool.try_take(),
            _ => None,
        }
    }

    fn put_scratch(&self, plan: &Arc<ExecPlan>, scratch: Scratch) {
        let guard = self.cache.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(c) = guard.as_ref() {
            if Arc::ptr_eq(&c.plan, plan) {
                c.pool.put(scratch);
            }
        }
    }

    /// Forward pass on a float batch (encoded to 8-bit fixed point at the
    /// input). Returns (logits, op counts). Routes through the lazily-built
    /// plan unless an interpreted backend was selected.
    pub fn forward(&self, images: &[f32], batch: usize) -> Result<(Vec<f32>, OpCounts)> {
        match self.backend {
            Backend::Planned => self.forward_planned(images, batch),
            Backend::Gemm | Backend::Naive => self.forward_interpreted(images, batch),
        }
    }

    fn forward_planned(&self, images: &[f32], batch: usize) -> Result<(Vec<f32>, OpCounts)> {
        let plan = self.plan_for(batch)?;
        let mut scratch = self
            .take_scratch(&plan)
            .unwrap_or_else(|| plan.scratch());
        let logits = plan.run(images, batch, &mut scratch)?;
        self.put_scratch(&plan, scratch);
        Ok((logits, plan.op_counts(batch)))
    }

    /// The interpreted walk: per-call allocation, one op at a time. Kept as
    /// the oracle the planned executor is raced against (`Backend::Naive`)
    /// and as the per-call GEMM baseline (`Backend::Gemm`).
    fn forward_interpreted(&self, images: &[f32], batch: usize) -> Result<(Vec<f32>, OpCounts)> {
        let [h, w, c] = self.input_shape;
        anyhow::ensure!(images.len() == batch * h * w * c, "bad input size");
        let naive = self.backend == Backend::Naive;
        let mut counts = OpCounts::default();
        let mut x = QTensor::from_f32(images, [batch, h, w, c], 8);
        // Retained concat sources are *moved* into `stored` (no clone);
        // while the stream is parked there, out-of-place ops read it in
        // place and only an in-place op has to copy it back out.
        let mut stored: BTreeMap<usize, QTensor> = BTreeMap::new();
        let mut parked: Option<usize> = None;
        for (li, layer) in self.layers.iter().enumerate() {
            match layer {
                IntLayer::Conv { w, bias, stride, pad_same } => {
                    let src = parked.map_or(&x, |i| &stored[&i]);
                    let mut out = if naive {
                        ops::conv2d_naive(src, w, *stride, *pad_same, &mut counts)
                    } else {
                        ops::conv2d(src, w, *stride, *pad_same, &mut counts)
                    };
                    if let Some(b) = bias {
                        ops::add_bias(&mut out, b, &mut counts);
                    }
                    x = out;
                    parked = None;
                }
                IntLayer::Dense { w, bias } => {
                    let src = parked.map_or(&x, |i| &stored[&i]);
                    let mut out = if naive {
                        ops::dense_naive(src, w, &mut counts)
                    } else {
                        ops::dense(src, w, &mut counts)
                    };
                    if let Some(b) = bias {
                        ops::add_bias(&mut out, b, &mut counts);
                    }
                    x = out;
                    parked = None;
                }
                IntLayer::Bn(a) => {
                    unpark(&mut x, &mut parked, &stored);
                    ops::affine(&mut x, a, &mut counts);
                }
                IntLayer::Relu => {
                    unpark(&mut x, &mut parked, &stored);
                    ops::relu(&mut x, &mut counts);
                }
                IntLayer::MaxPool { k, stride } => {
                    let src = parked.map_or(&x, |i| &stored[&i]);
                    x = ops::maxpool(src, *k, *stride, &mut counts);
                    parked = None;
                }
                IntLayer::AvgPool { k, stride } => {
                    let src = parked.map_or(&x, |i| &stored[&i]);
                    x = ops::avgpool(src, *k, *stride, &mut counts);
                    parked = None;
                }
                IntLayer::GlobalAvgPool => {
                    let src = parked.map_or(&x, |i| &stored[&i]);
                    x = ops::global_avgpool(src, &mut counts);
                    parked = None;
                }
                IntLayer::Flatten => {
                    unpark(&mut x, &mut parked, &stored);
                    let n = x.dims[0];
                    let f = x.numel() / n;
                    x.dims = [n, 1, 1, f];
                }
                IntLayer::Concat { from } => {
                    let a = stored
                        .get(from)
                        .context("concat source not retained")?;
                    let b = parked.map_or(&x, |i| &stored[&i]);
                    x = ops::concat(a, b, &mut counts);
                    parked = None;
                }
            }
            if self.retained.contains(&li) {
                let t = std::mem::replace(
                    &mut x,
                    QTensor { data: Vec::new(), frac: 0, dims: [0; 4] },
                );
                stored.insert(li, t);
                parked = Some(li);
            }
        }
        let out = parked.map_or(&x, |i| &stored[&i]);
        Ok((out.to_f32(), counts))
    }

    /// Classify a float batch: returns predicted class ids.
    pub fn predict(&self, images: &[f32], batch: usize) -> Result<Vec<i32>> {
        let (logits, _) = self.forward(images, batch)?;
        let k = self.num_classes;
        Ok((0..batch)
            .map(|b| {
                let row = &logits[b * k..(b + 1) * k];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// Accuracy over a dataset slice.
    pub fn accuracy(&self, images: &[f32], labels: &[i32], batch: usize) -> Result<f32> {
        let [h, w, c] = self.input_shape;
        let e = h * w * c;
        let n = labels.len();
        let mut correct = 0usize;
        for start in (0..n).step_by(batch) {
            let bs = batch.min(n - start);
            let preds = self.predict(&images[start * e..(start + bs) * e], bs)?;
            correct += preds
                .iter()
                .zip(&labels[start..start + bs])
                .filter(|(p, l)| p == l)
                .count();
        }
        Ok(correct as f32 / n as f32)
    }

    /// Cost report for one forward pass of `batch` images — analytic since
    /// the compile-then-execute refactor: `OpCounts` comes straight from
    /// the plan (shapes x per-layer ternary flags), no dummy forward runs.
    pub fn cost_report(&self, batch: usize) -> Result<CostReport> {
        let counts = self.plan_for(batch)?.op_counts(batch);
        // float MACs == integer accumulator adds from conv/dense (bias adds
        // and BN excluded on both sides for a like-for-like core count)
        let model = CostModel::new(self.n_bits);
        Ok(model.report(counts, counts.acc_adds, self.quant_params, self.aux_params))
    }
}

/// Copy a parked (retained) stream back into the working tensor so an
/// in-place op can mutate it without corrupting the retained value.
fn unpark(x: &mut QTensor, parked: &mut Option<usize>, stored: &BTreeMap<usize, QTensor>) {
    if let Some(i) = parked.take() {
        *x = stored[&i].clone();
    }
}
