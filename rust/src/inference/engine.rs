//! Graph executor: rebuild the network from the artifact manifest and run
//! it with integer arithmetic only.

use anyhow::{bail, Context, Result};

use crate::coordinator::Checkpoint;
use crate::runtime::Manifest;

use super::ops::{self, QAffine, QWeight};
use super::{CostModel, CostReport, OpCounts};

pub use super::ops::QTensor;

const BN_EPS: f32 = 1e-5;

/// One compiled layer of the integer network.
enum IntLayer {
    Conv { w: QWeight, bias: Option<Vec<f32>>, stride: usize, pad_same: bool },
    Dense { w: QWeight, bias: Option<Vec<f32>> },
    Bn(QAffine),
    Relu,
    MaxPool { k: usize, stride: usize },
    AvgPool { k: usize, stride: usize },
    GlobalAvgPool,
    Flatten,
    Concat { from: usize },
}

/// Which conv/dense implementation the engine drives.
///
/// `Gemm` (the default) is the im2col + blocked-GEMM hot path, parallel
/// over the batch; `Naive` is the direct-loop reference. Both are exact
/// integer arithmetic and produce bit-identical activations — `Naive`
/// exists for cross-checking and benchmarking, not as a fallback.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    #[default]
    Gemm,
    Naive,
}

/// The integer model: quantized weights + the layer program.
pub struct IntModel {
    layers: Vec<IntLayer>,
    pub n_bits: u32,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    /// quantized-weight parameter count (for the cost model)
    pub quant_params: u64,
    /// float-kept auxiliary parameter count
    pub aux_params: u64,
    /// whether every quantized layer is ternary (pure add/sub inference)
    pub all_ternary: bool,
    /// conv/dense implementation (GEMM hot path by default)
    pub backend: Backend,
}

impl IntModel {
    /// Build from a manifest + trained checkpoint (float weights + deltas).
    /// Weights are hard-quantized here — this IS the paper's final
    /// quantization step (Alg. 1 lines 21-23) materialized for hardware.
    pub fn build(man: &Manifest, ckpt: &Checkpoint) -> Result<IntModel> {
        let deltas = &ckpt
            .find("__deltas__")
            .context("checkpoint has no __deltas__")?
            .data;
        let tensor = |idx: usize| -> Result<&crate::coordinator::Tensor> {
            let meta = &man.params[idx];
            ckpt.find(&meta.name)
                .with_context(|| format!("missing tensor {}", meta.name))
        };
        let mut layers = Vec::new();
        let mut quant_params = 0u64;
        let mut aux_params = 0u64;
        let mut all_ternary = true;
        for l in &man.layers {
            match l.ty() {
                "conv" => {
                    let widx = l.param_idx("w").context("conv without w")?;
                    let meta = &man.params[widx];
                    let t = tensor(widx)?;
                    let qidx = meta.qidx.context("conv weight not quantized")?;
                    let dims = [t.dims[0], t.dims[1], t.dims[2], t.dims[3]];
                    let w = QWeight::encode(&t.data, dims, deltas[qidx], man.n_bits);
                    all_ternary &= w.is_ternary();
                    quant_params += t.data.len() as u64;
                    let bias = match l.param_idx("b") {
                        Some(bi) => {
                            let bt = tensor(bi)?;
                            aux_params += bt.data.len() as u64;
                            Some(bt.data.clone())
                        }
                        None => None,
                    };
                    layers.push(IntLayer::Conv {
                        w,
                        bias,
                        stride: l.usize_field("stride").unwrap_or(1),
                        pad_same: l.str_field("padding") == Some("SAME"),
                    });
                }
                "dense" => {
                    let widx = l.param_idx("w").context("dense without w")?;
                    let meta = &man.params[widx];
                    let t = tensor(widx)?;
                    let qidx = meta.qidx.context("dense weight not quantized")?;
                    let dims = [t.dims[0], t.dims[1], 1, 1];
                    let w = QWeight::encode(&t.data, dims, deltas[qidx], man.n_bits);
                    all_ternary &= w.is_ternary();
                    quant_params += t.data.len() as u64;
                    let bias = match l.param_idx("b") {
                        Some(bi) => {
                            let bt = tensor(bi)?;
                            aux_params += bt.data.len() as u64;
                            Some(bt.data.clone())
                        }
                        None => None,
                    };
                    layers.push(IntLayer::Dense { w, bias });
                }
                "bn" => {
                    let g = tensor(l.param_idx("gamma").context("bn gamma")?)?;
                    let b = tensor(l.param_idx("beta").context("bn beta")?)?;
                    let mi = l.usize_field("mean").context("bn mean idx")?;
                    let vi = l.usize_field("var").context("bn var idx")?;
                    let mean = ckpt
                        .find(&man.state[mi].name)
                        .with_context(|| format!("missing state {}", man.state[mi].name))?;
                    let var = ckpt
                        .find(&man.state[vi].name)
                        .with_context(|| format!("missing state {}", man.state[vi].name))?;
                    aux_params += (g.data.len() + b.data.len()) as u64;
                    layers.push(IntLayer::Bn(QAffine::fold_bn(
                        &g.data, &b.data, &mean.data, &var.data, BN_EPS,
                    )));
                }
                "relu" => layers.push(IntLayer::Relu),
                "maxpool" => layers.push(IntLayer::MaxPool {
                    k: l.usize_field("k").unwrap_or(2),
                    stride: l.usize_field("stride").unwrap_or(2),
                }),
                "avgpool" => layers.push(IntLayer::AvgPool {
                    k: l.usize_field("k").unwrap_or(2),
                    stride: l.usize_field("stride").unwrap_or(2),
                }),
                "global_avgpool" => layers.push(IntLayer::GlobalAvgPool),
                "flatten" => layers.push(IntLayer::Flatten),
                "concat" => layers.push(IntLayer::Concat {
                    from: l.usize_field("from").context("concat from")?,
                }),
                other => bail!("integer engine: unsupported layer type {other:?}"),
            }
        }
        Ok(IntModel {
            layers,
            n_bits: man.n_bits,
            input_shape: man.input_shape,
            num_classes: man.num_classes,
            quant_params,
            aux_params,
            all_ternary,
            backend: Backend::default(),
        })
    }

    /// Builder-style backend override (used by the naive-vs-GEMM checks).
    pub fn with_backend(mut self, backend: Backend) -> IntModel {
        self.backend = backend;
        self
    }

    /// Forward pass on a float batch (encoded to 8-bit fixed point at the
    /// input). Returns (logits, op counts).
    pub fn forward(&self, images: &[f32], batch: usize) -> Result<(Vec<f32>, OpCounts)> {
        let [h, w, c] = self.input_shape;
        anyhow::ensure!(images.len() == batch * h * w * c, "bad input size");
        let mut x = QTensor::from_f32(images, [batch, h, w, c], 8);
        let mut counts = OpCounts::default();
        let mut acts: Vec<Option<QTensor>> = Vec::with_capacity(self.layers.len());
        let needed: std::collections::BTreeSet<usize> = self
            .layers
            .iter()
            .filter_map(|l| match l {
                IntLayer::Concat { from } => Some(*from),
                _ => None,
            })
            .collect();
        for (li, layer) in self.layers.iter().enumerate() {
            match layer {
                IntLayer::Conv { w, bias, stride, pad_same } => {
                    x = match self.backend {
                        Backend::Gemm => ops::conv2d(&x, w, *stride, *pad_same, &mut counts),
                        Backend::Naive => {
                            ops::conv2d_naive(&x, w, *stride, *pad_same, &mut counts)
                        }
                    };
                    if let Some(b) = bias {
                        ops::add_bias(&mut x, b, &mut counts);
                    }
                }
                IntLayer::Dense { w, bias } => {
                    x = match self.backend {
                        Backend::Gemm => ops::dense(&x, w, &mut counts),
                        Backend::Naive => ops::dense_naive(&x, w, &mut counts),
                    };
                    if let Some(b) = bias {
                        ops::add_bias(&mut x, b, &mut counts);
                    }
                }
                IntLayer::Bn(a) => ops::affine(&mut x, a, &mut counts),
                IntLayer::Relu => ops::relu(&mut x, &mut counts),
                IntLayer::MaxPool { k, stride } => x = ops::maxpool(&x, *k, *stride, &mut counts),
                IntLayer::AvgPool { k, stride } => x = ops::avgpool(&x, *k, *stride, &mut counts),
                IntLayer::GlobalAvgPool => x = ops::global_avgpool(&x, &mut counts),
                IntLayer::Flatten => {
                    let n = x.dims[0];
                    let f = x.numel() / n;
                    x.dims = [n, 1, 1, f];
                }
                IntLayer::Concat { from } => {
                    let src = acts[*from]
                        .as_ref()
                        .context("concat source not retained")?;
                    x = ops::concat(src, &x, &mut counts);
                }
            }
            acts.push(needed.contains(&li).then(|| x.clone()));
        }
        Ok((x.to_f32(), counts))
    }

    /// Classify a float batch: returns predicted class ids.
    pub fn predict(&self, images: &[f32], batch: usize) -> Result<Vec<i32>> {
        let (logits, _) = self.forward(images, batch)?;
        let k = self.num_classes;
        Ok((0..batch)
            .map(|b| {
                let row = &logits[b * k..(b + 1) * k];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// Accuracy over a dataset slice.
    pub fn accuracy(&self, images: &[f32], labels: &[i32], batch: usize) -> Result<f32> {
        let [h, w, c] = self.input_shape;
        let e = h * w * c;
        let n = labels.len();
        let mut correct = 0usize;
        for start in (0..n).step_by(batch) {
            let bs = batch.min(n - start);
            let preds = self.predict(&images[start * e..(start + bs) * e], bs)?;
            correct += preds
                .iter()
                .zip(&labels[start..start + bs])
                .filter(|(p, l)| p == l)
                .count();
        }
        Ok(correct as f32 / n as f32)
    }

    /// Cost report for one forward pass of `batch` images.
    pub fn cost_report(&self, batch: usize) -> Result<CostReport> {
        let [h, w, c] = self.input_shape;
        let images = vec![0.1f32; batch * h * w * c];
        let (_, counts) = self.forward(&images, batch)?;
        // float MACs == integer accumulator adds from conv/dense (bias adds
        // and BN excluded on both sides for a like-for-like core count)
        let model = CostModel::new(self.n_bits);
        Ok(model.report(counts, counts.acc_adds, self.quant_params, self.aux_params))
    }
}
