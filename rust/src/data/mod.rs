//! Data pipeline: synthetic dataset generators, normalization, augmentation,
//! and deterministic shuffled batch iteration.
//!
//! The paper evaluates on MNIST / CIFAR-10 / CIFAR-100, which are not
//! available in this offline environment. `synthetic.rs` builds procedural
//! class-conditional image distributions with the same shapes, sizes and
//! normalization pipeline, so every training / quantization code path is
//! exercised identically — see DESIGN.md §Substitutions.

mod augment;
mod batch;
mod synthetic;

pub use augment::{augment_batch, AugmentConfig};
pub use batch::{Batch, BatchIter};
pub use synthetic::{synth_dataset, synth_dataset_with, SynthSpec};

/// An in-memory image-classification dataset, NHWC f32 + i32 labels.
#[derive(Clone)]
pub struct Dataset {
    /// Flattened images, `n * h * w * c` values, already normalized.
    pub images: Vec<f32>,
    /// Class ids, length `n`.
    pub labels: Vec<i32>,
    pub shape: [usize; 3], // H, W, C
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image_elems(&self) -> usize {
        self.shape[0] * self.shape[1] * self.shape[2]
    }

    /// Borrow image `i` as a slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let e = self.image_elems();
        &self.images[i * e..(i + 1) * e]
    }

    /// Per-dataset mean/std over all pixels (used to normalize in-place).
    pub fn normalize(&mut self) -> (f32, f32) {
        let mean = crate::util::mean(&self.images);
        let std = crate::util::std_dev(&self.images).max(1e-6);
        for v in &mut self.images {
            *v = (*v - mean) / std;
        }
        (mean, std)
    }

    /// Split off the last `n` examples as a held-out set.
    pub fn split_off(&mut self, n: usize) -> Dataset {
        assert!(n <= self.len());
        let keep = self.len() - n;
        let e = self.image_elems();
        let images = self.images.split_off(keep * e);
        let labels = self.labels.split_off(keep);
        Dataset { images, labels, shape: self.shape, classes: self.classes }
    }
}

/// Named dataset presets matching the paper's benchmarks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    SynthMnist,
    SynthCifar10,
    SynthCifar100,
}

impl Preset {
    pub fn parse(name: &str) -> Option<Preset> {
        match name {
            "synth-mnist" => Some(Preset::SynthMnist),
            "synth-cifar10" => Some(Preset::SynthCifar10),
            "synth-cifar100" => Some(Preset::SynthCifar100),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Preset::SynthMnist => "synth-mnist",
            Preset::SynthCifar10 => "synth-cifar10",
            Preset::SynthCifar100 => "synth-cifar100",
        }
    }

    pub fn spec(self) -> SynthSpec {
        match self {
            Preset::SynthMnist => SynthSpec {
                shape: [28, 28, 1],
                classes: 10,
                coarse_classes: 10,
                noise: 0.45,
                max_shift: 2,
                blob_scale: 5.0,
            },
            Preset::SynthCifar10 => SynthSpec {
                shape: [32, 32, 3],
                classes: 10,
                coarse_classes: 10,
                noise: 0.55,
                max_shift: 3,
                blob_scale: 6.0,
            },
            Preset::SynthCifar100 => SynthSpec {
                shape: [32, 32, 3],
                classes: 100,
                coarse_classes: 10,
                noise: 0.5,
                max_shift: 3,
                blob_scale: 6.0,
            },
        }
    }

    /// Generate a normalized (train, test) pair.
    pub fn load(self, train_n: usize, test_n: usize, seed: u64) -> (Dataset, Dataset) {
        let mut train = synth_dataset(&self.spec(), train_n + test_n, seed);
        train.normalize();
        let test = train.split_off(test_n);
        (train, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_roundtrip() {
        for p in [Preset::SynthMnist, Preset::SynthCifar10, Preset::SynthCifar100] {
            assert_eq!(Preset::parse(p.name()), Some(p));
        }
        assert_eq!(Preset::parse("mnist"), None);
    }

    #[test]
    fn load_shapes() {
        let (train, test) = Preset::SynthMnist.load(128, 32, 0);
        assert_eq!(train.len(), 128);
        assert_eq!(test.len(), 32);
        assert_eq!(train.shape, [28, 28, 1]);
        assert_eq!(train.images.len(), 128 * 28 * 28);
        assert!(test.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn normalization_zero_mean_unit_std() {
        let (train, _) = Preset::SynthCifar10.load(256, 16, 1);
        let m = crate::util::mean(&train.images);
        // mean/std were computed before the split; tolerate the tail effect
        assert!(m.abs() < 0.1, "mean {m}");
        let s = crate::util::std_dev(&train.images);
        assert!((s - 1.0).abs() < 0.1, "std {s}");
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _) = Preset::SynthMnist.load(64, 8, 7);
        let (b, _) = Preset::SynthMnist.load(64, 8, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let (c, _) = Preset::SynthMnist.load(64, 8, 8);
        assert_ne!(a.images, c.images);
    }
}
