//! Procedural class-conditional image generator.
//!
//! Each class owns a prototype built from a small number of smooth Gaussian
//! blobs plus an oriented sinusoidal texture — enough spatial structure that
//! convnets have real features to learn, while leaving a controllable noise
//! floor so error rates land in a realistic band (a few percent, like the
//! paper's benchmarks) rather than collapsing to zero.
//!
//! CIFAR-100's 10-coarse x 10-fine hierarchy is mimicked: a fine class's
//! prototype = its coarse prototype + a half-amplitude fine residual, so
//! classes within a coarse group are genuinely confusable — this is what
//! makes synth-cifar100 "hard" in the same relative sense as the paper.

use super::Dataset;
use crate::util::pool;
use crate::util::rng::Rng;

/// Parameters of a synthetic dataset family.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub shape: [usize; 3],
    pub classes: usize,
    /// number of coarse groups (== classes for the 10-way sets)
    pub coarse_classes: usize,
    /// per-pixel Gaussian noise sigma added to every sample
    pub noise: f32,
    /// max |translation| in pixels applied per sample
    pub max_shift: i32,
    /// spatial scale of the prototype blobs, in pixels
    pub blob_scale: f32,
}

/// One additive Gaussian blob / sinusoid component of a prototype.
struct Component {
    cx: f32,
    cy: f32,
    sx: f32,
    sy: f32,
    amp: [f32; 3],
    freq: f32,
    phase: f32,
    angle: f32,
}

fn render_prototype(rng: &mut Rng, spec: &SynthSpec) -> Vec<f32> {
    let [h, w, c] = spec.shape;
    let n_blobs = 3 + rng.below(3);
    let comps: Vec<Component> = (0..n_blobs)
        .map(|_| Component {
            cx: rng.range_f32(0.2, 0.8) * w as f32,
            cy: rng.range_f32(0.2, 0.8) * h as f32,
            sx: rng.range_f32(0.5, 1.5) * spec.blob_scale,
            sy: rng.range_f32(0.5, 1.5) * spec.blob_scale,
            amp: [
                rng.range_f32(-1.5, 1.5),
                rng.range_f32(-1.5, 1.5),
                rng.range_f32(-1.5, 1.5),
            ],
            freq: rng.range_f32(0.15, 0.7),
            phase: rng.range_f32(0.0, std::f32::consts::TAU),
            angle: rng.range_f32(0.0, std::f32::consts::PI),
        })
        .collect();
    let mut img = vec![0f32; h * w * c];
    for y in 0..h {
        for x in 0..w {
            for comp in &comps {
                let dx = x as f32 - comp.cx;
                let dy = y as f32 - comp.cy;
                let env = (-(dx * dx) / (2.0 * comp.sx * comp.sx)
                    - (dy * dy) / (2.0 * comp.sy * comp.sy))
                    .exp();
                let u = dx * comp.angle.cos() + dy * comp.angle.sin();
                let tex = (comp.freq * u + comp.phase).sin();
                for ch in 0..c {
                    img[(y * w + x) * c + ch] += comp.amp[ch % 3] * env * (0.6 + 0.4 * tex);
                }
            }
        }
    }
    img
}

/// Generate `n` samples of the synthetic distribution with root `seed`.
/// Prototypes depend only on (seed, class); samples add translation jitter,
/// per-sample gain, and pixel noise. Generation is host-parallel with the
/// default worker count.
pub fn synth_dataset(spec: &SynthSpec, n: usize, seed: u64) -> Dataset {
    synth_dataset_with(spec, n, seed, pool::default_workers())
}

/// [`synth_dataset`] with an explicit worker count. Output is a pure
/// function of `(spec, n, seed)`: every sample draws from its own
/// `Rng::new(seed ^ f(i))` stream, so the chunking — and therefore the
/// worker count — is bit-irrelevant. `tests/determinism.rs` pins this
/// (serving benches and the serve test suites rely on reproducible
/// request data whatever `SYMOG_WORKERS` says).
pub fn synth_dataset_with(spec: &SynthSpec, n: usize, seed: u64, workers: usize) -> Dataset {
    let [h, w, c] = spec.shape;
    let elems = h * w * c;

    // --- prototypes: coarse + fine residual hierarchy
    let mut proto_rng = Rng::new(seed ^ 0x50524F54); // "PROT"
    let coarse: Vec<Vec<f32>> = (0..spec.coarse_classes)
        .map(|_| render_prototype(&mut proto_rng, spec))
        .collect();
    let protos: Vec<Vec<f32>> = (0..spec.classes)
        .map(|k| {
            if spec.classes == spec.coarse_classes {
                coarse[k].clone()
            } else {
                // fine residual at half amplitude on top of the coarse parent
                let parent = &coarse[k % spec.coarse_classes];
                let fine = render_prototype(&mut proto_rng, spec);
                parent.iter().zip(fine).map(|(p, f)| p + 0.5 * f).collect()
            }
        })
        .collect();

    // --- labels: balanced-ish via uniform draw
    let mut lab_rng = Rng::new(seed ^ 0x4C414245); // "LABE"
    let labels: Vec<i32> = (0..n).map(|_| lab_rng.below(spec.classes) as i32).collect();

    // --- samples (persistent-pool fan-out over per-image views of one
    // contiguous buffer; every sample seeds its own RNG stream, so the
    // chunk layout is bit-irrelevant and no staging vector is needed)
    let mut images = vec![0f32; n * elems];
    let mut views: Vec<&mut [f32]> = images.chunks_mut(elems).collect();
    pool::par_chunks_mut(&mut views, workers, |offset, chunk| {
        for (pos, slot) in chunk.iter_mut().enumerate() {
            let i = offset + pos;
            let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            sample_into(slot, &protos[labels[i] as usize], spec, &mut rng);
        }
    });

    Dataset { images, labels, shape: spec.shape, classes: spec.classes }
}

fn sample_into(out: &mut [f32], proto: &[f32], spec: &SynthSpec, rng: &mut Rng) {
    let [h, w, c] = spec.shape;
    let dx = rng.below(2 * spec.max_shift as usize + 1) as i32 - spec.max_shift;
    let dy = rng.below(2 * spec.max_shift as usize + 1) as i32 - spec.max_shift;
    let gain = rng.range_f32(0.85, 1.15);
    for y in 0..h as i32 {
        for x in 0..w as i32 {
            let sy = (y + dy).clamp(0, h as i32 - 1) as usize;
            let sx = (x + dx).clamp(0, w as i32 - 1) as usize;
            for ch in 0..c {
                let v = proto[(sy * w + sx) * c + ch] * gain + spec.noise * rng.normal();
                out[(y as usize * w + x as usize) * c + ch] = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec10() -> SynthSpec {
        SynthSpec {
            shape: [16, 16, 1],
            classes: 10,
            coarse_classes: 10,
            noise: 0.3,
            max_shift: 2,
            blob_scale: 3.0,
        }
    }

    #[test]
    fn shapes_and_labels() {
        let ds = synth_dataset(&spec10(), 100, 0);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.images.len(), 100 * 16 * 16);
        assert!(ds.labels.iter().all(|&l| (0..10).contains(&l)));
        // all classes present in 100 draws (prob of miss is negligible)
        let mut seen = [false; 10];
        for &l in &ds.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 8);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = synth_dataset(&spec10(), 50, 3);
        let b = synth_dataset(&spec10(), 50, 3);
        let c = synth_dataset(&spec10(), 50, 4);
        assert_eq!(a.images, b.images);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn class_structure_is_learnable() {
        // nearest-prototype classification on noiseless prototypes must beat
        // chance by a wide margin: same-class samples are closer to their
        // own prototype than to others.
        let spec = spec10();
        let ds = synth_dataset(&spec, 200, 9);
        // re-derive prototypes through the same seeded path
        let mut proto_rng = Rng::new(9u64 ^ 0x50524F54);
        let protos: Vec<Vec<f32>> =
            (0..10).map(|_| render_prototype(&mut proto_rng, &spec)).collect();
        let mut correct = 0;
        for i in 0..ds.len() {
            let img = ds.image(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = img.iter().zip(&protos[a]).map(|(x, p)| (x - p).powi(2)).sum();
                    let db: f32 = img.iter().zip(&protos[b]).map(|(x, p)| (x - p).powi(2)).sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if best as i32 == ds.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f32 / ds.len() as f32;
        assert!(acc > 0.6, "nearest-prototype acc {acc} — structure too weak");
    }

    #[test]
    fn hierarchy_increases_confusability() {
        // fine classes within a coarse group are closer to each other than
        // to other groups' prototypes (CIFAR-100-style difficulty)
        let spec = SynthSpec { classes: 100, coarse_classes: 10, ..spec10() };
        let mut proto_rng = Rng::new(5u64 ^ 0x50524F54);
        let coarse: Vec<Vec<f32>> =
            (0..10).map(|_| render_prototype(&mut proto_rng, &spec)).collect();
        let fine: Vec<Vec<f32>> = (0..100usize)
            .map(|k| {
                let parent = &coarse[k % 10];
                let f = render_prototype(&mut proto_rng, &spec);
                parent.iter().zip(f).map(|(p, q)| p + 0.5 * q).collect()
            })
            .collect();
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
        };
        // fine 0 and fine 10 share coarse parent 0; fine 1 does not
        let same = d(&fine[0], &fine[10]);
        let diff = d(&fine[0], &fine[1]);
        assert!(same < diff, "same-group {same} !< cross-group {diff}");
    }
}
