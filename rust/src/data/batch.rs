//! Deterministic shuffled batch iteration with fixed-size batches.
//!
//! The AOT train executable is compiled for a static batch size, so the
//! final short batch of an epoch wraps around to the epoch's start
//! (standard practice for static-shape runtimes).

use super::{augment_batch, AugmentConfig, Dataset};
use crate::util::rng::Rng;

/// One assembled batch, ready to upload.
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

/// Epoch iterator: yields `ceil(n / batch)` batches per epoch, reshuffling
/// with a per-epoch seed derived from (base seed, epoch).
pub struct BatchIter<'a> {
    data: &'a Dataset,
    batch: usize,
    perm: Vec<u32>,
    cursor: usize,
    augment: AugmentConfig,
    rng: Rng,
}

impl<'a> BatchIter<'a> {
    pub fn new(
        data: &'a Dataset,
        batch: usize,
        seed: u64,
        epoch: u64,
        augment: AugmentConfig,
    ) -> Self {
        assert!(batch > 0 && !data.is_empty());
        let mut shuffle_rng = Rng::new(seed ^ epoch.wrapping_mul(0x5851F42D4C957F2D));
        let perm = shuffle_rng.permutation(data.len());
        BatchIter { data, batch, perm, cursor: 0, augment, rng: shuffle_rng }
    }

    /// Number of batches this epoch.
    pub fn num_batches(&self) -> usize {
        self.data.len().div_ceil(self.batch)
    }

    /// Assemble the next batch into reusable buffers; returns false at epoch
    /// end. Buffers are resized as needed (no per-step allocation once warm).
    pub fn next_into(&mut self, images: &mut Vec<f32>, labels: &mut Vec<i32>) -> bool {
        if self.cursor >= self.data.len() {
            return false;
        }
        let e = self.data.image_elems();
        images.clear();
        images.reserve(self.batch * e);
        labels.clear();
        for k in 0..self.batch {
            // wrap around for the final short batch
            let idx = self.perm[(self.cursor + k) % self.data.len()] as usize;
            images.extend_from_slice(self.data.image(idx));
            labels.push(self.data.labels[idx]);
        }
        self.cursor += self.batch;
        augment_batch(images, self.data.shape, &self.augment, &mut self.rng);
        true
    }
}

impl Iterator for BatchIter<'_> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let mut images = Vec::new();
        let mut labels = Vec::new();
        if self.next_into(&mut images, &mut labels) {
            Some(Batch { images, labels })
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Preset;

    #[test]
    fn covers_every_example_once() {
        let (train, _) = Preset::SynthMnist.load(100, 10, 0);
        let batches: Vec<Batch> =
            BatchIter::new(&train, 10, 42, 0, AugmentConfig::none()).collect();
        assert_eq!(batches.len(), 10);
        let mut label_counts = vec![0usize; 10];
        for b in &batches {
            assert_eq!(b.labels.len(), 10);
            assert_eq!(b.images.len(), 10 * 28 * 28);
            for &l in &b.labels {
                label_counts[l as usize] += 1;
            }
        }
        // 100 examples, each exactly once
        let train_counts = {
            let mut c = vec![0usize; 10];
            for &l in &train.labels {
                c[l as usize] += 1;
            }
            c
        };
        assert_eq!(label_counts, train_counts);
    }

    #[test]
    fn short_batch_wraps() {
        let (train, _) = Preset::SynthMnist.load(25, 5, 0);
        let batches: Vec<Batch> =
            BatchIter::new(&train, 10, 1, 0, AugmentConfig::none()).collect();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2].labels.len(), 10); // padded to full size by wrap
    }

    #[test]
    fn epochs_shuffle_differently() {
        let (train, _) = Preset::SynthMnist.load(64, 8, 0);
        let b0: Vec<i32> = BatchIter::new(&train, 64, 7, 0, AugmentConfig::none())
            .next()
            .unwrap()
            .labels;
        let b1: Vec<i32> = BatchIter::new(&train, 64, 7, 1, AugmentConfig::none())
            .next()
            .unwrap()
            .labels;
        assert_ne!(b0, b1);
        // same epoch: identical
        let b0b: Vec<i32> = BatchIter::new(&train, 64, 7, 0, AugmentConfig::none())
            .next()
            .unwrap()
            .labels;
        assert_eq!(b0, b0b);
    }

    #[test]
    fn next_into_reuses_buffers() {
        let (train, _) = Preset::SynthMnist.load(32, 4, 0);
        let mut it = BatchIter::new(&train, 8, 1, 0, AugmentConfig::none());
        let mut images = Vec::new();
        let mut labels = Vec::new();
        let mut n = 0;
        while it.next_into(&mut images, &mut labels) {
            assert_eq!(labels.len(), 8);
            n += 1;
        }
        assert_eq!(n, 4);
    }
}
