//! Train-time augmentation: pad-4 random crop + horizontal flip — the
//! standard CIFAR recipe of Huang et al. 2016 the paper follows (§4.2/4.3).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct AugmentConfig {
    /// padding for the random crop (0 disables cropping)
    pub pad: usize,
    /// enable horizontal flips (CIFAR yes, MNIST no)
    pub flip: bool,
}

impl AugmentConfig {
    pub fn none() -> Self {
        AugmentConfig { pad: 0, flip: false }
    }

    pub fn cifar() -> Self {
        AugmentConfig { pad: 4, flip: true }
    }

    pub fn is_noop(&self) -> bool {
        self.pad == 0 && !self.flip
    }
}

/// Augment one image in place (shape HWC) using scratch storage.
fn augment_one(
    img: &mut [f32],
    scratch: &mut Vec<f32>,
    shape: [usize; 3],
    cfg: &AugmentConfig,
    rng: &mut Rng,
) {
    let [h, w, c] = shape;
    if cfg.pad > 0 {
        // zero-pad to (h+2p, w+2p), then crop a random (h, w) window
        let p = cfg.pad;
        let (ph, pw) = (h + 2 * p, w + 2 * p);
        scratch.clear();
        scratch.resize(ph * pw * c, 0.0);
        for y in 0..h {
            let src = &img[y * w * c..(y + 1) * w * c];
            let dst_off = ((y + p) * pw + p) * c;
            scratch[dst_off..dst_off + w * c].copy_from_slice(src);
        }
        let oy = rng.below(2 * p + 1);
        let ox = rng.below(2 * p + 1);
        for y in 0..h {
            let src_off = ((y + oy) * pw + ox) * c;
            let dst = &mut img[y * w * c..(y + 1) * w * c];
            dst.copy_from_slice(&scratch[src_off..src_off + w * c]);
        }
    }
    if cfg.flip && rng.bool(0.5) {
        for y in 0..h {
            let row = &mut img[y * w * c..(y + 1) * w * c];
            for x in 0..w / 2 {
                for ch in 0..c {
                    row.swap(x * c + ch, (w - 1 - x) * c + ch);
                }
            }
        }
    }
}

/// Augment a batch buffer (`bs` images of `shape`) in place.
pub fn augment_batch(
    batch: &mut [f32],
    shape: [usize; 3],
    cfg: &AugmentConfig,
    rng: &mut Rng,
) {
    if cfg.is_noop() {
        return;
    }
    let elems = shape[0] * shape[1] * shape[2];
    let mut scratch = Vec::new();
    for img in batch.chunks_mut(elems) {
        augment_one(img, &mut scratch, shape, cfg, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(h: usize, w: usize, c: usize) -> Vec<f32> {
        (0..h * w * c).map(|i| i as f32).collect()
    }

    #[test]
    fn noop_config_leaves_data() {
        let mut img = ramp(8, 8, 3);
        let orig = img.clone();
        let mut rng = Rng::new(0);
        augment_batch(&mut img, [8, 8, 3], &AugmentConfig::none(), &mut rng);
        assert_eq!(img, orig);
    }

    #[test]
    fn flip_is_involution() {
        // flipping twice with forced flips restores the image
        let mut img = ramp(4, 6, 2);
        let orig = img.clone();
        let cfg = AugmentConfig { pad: 0, flip: true };
        let mut rng = Rng::new(1);
        // find a seed whose first two draws both flip
        loop {
            let mut probe = rng.clone();
            if probe.bool(0.5) && probe.bool(0.5) {
                break;
            }
            rng.next_u64();
        }
        augment_batch(&mut img, [4, 6, 2], &cfg, &mut rng.clone());
        let mut rng2 = rng.clone();
        rng2.bool(0.5); // consume the first flip decision
        augment_batch(&mut img, [4, 6, 2], &cfg, &mut rng2);
        assert_eq!(img, orig);
    }

    #[test]
    fn crop_preserves_shape_and_center_mass() {
        let mut img = vec![1.0f32; 8 * 8];
        let mut rng = Rng::new(2);
        augment_batch(&mut img, [8, 8, 1], &AugmentConfig { pad: 2, flip: false }, &mut rng);
        assert_eq!(img.len(), 64);
        // after a shift of at most 2 with zero padding, the 4x4 center
        // can lose at most... nothing: center pixels always covered
        for y in 2..6 {
            for x in 2..6 {
                assert_eq!(img[y * 8 + x], 1.0, "center pixel moved to zero");
            }
        }
    }

    #[test]
    fn zero_shift_crop_is_identity() {
        // when the random offsets equal pad, the crop is centered = identity
        let img0 = ramp(6, 6, 1);
        let p = 2usize;
        // run many seeds; at least one must produce the identity offsets,
        // and identity offsets must reproduce the input exactly
        let mut found = false;
        for seed in 0..200 {
            let mut rng = Rng::new(seed);
            let (oy, ox) = (rng.below(2 * p + 1), rng.below(2 * p + 1));
            if (oy, ox) == (p, p) {
                let mut img = img0.clone();
                let mut rng = Rng::new(seed);
                let cfg = AugmentConfig { pad: p, flip: false };
                augment_batch(&mut img, [6, 6, 1], &cfg, &mut rng);
                assert_eq!(img, img0);
                found = true;
                break;
            }
        }
        assert!(found, "no identity-offset seed in 200 tries");
    }

    #[test]
    fn deterministic_given_rng_state() {
        let mut a = ramp(8, 8, 3);
        let mut b = a.clone();
        augment_batch(&mut a, [8, 8, 3], &AugmentConfig::cifar(), &mut Rng::new(9));
        augment_batch(&mut b, [8, 8, 3], &AugmentConfig::cifar(), &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
