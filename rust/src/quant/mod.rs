//! Quantization toolbox: post-training quantization, per-layer analysis,
//! and bit-packing (the storage story behind the paper's model-size claims).

pub mod packed;

use anyhow::{Context, Result};

use crate::coordinator::{Checkpoint, Kind};
use crate::fixedpoint::{self, mode_indices, quantize_slice};
use crate::runtime::Manifest;

/// Naive post-training quantization (section 2.1's strawman): solve the
/// per-layer step size on the given checkpoint's weights and replace them
/// with Q_N(w). Returns a new checkpoint with updated __deltas__.
pub fn quantize_ckpt(man: &Manifest, ckpt: &Checkpoint) -> Result<Checkpoint> {
    let mut out = ckpt.clone();
    let mut deltas = vec![1.0f32; man.deltas_len()];
    for p in &man.params {
        if !p.is_quantized() {
            continue;
        }
        let qidx = p.qidx.unwrap();
        let t = out
            .tensors
            .iter_mut()
            .find(|t| t.name == p.name)
            .with_context(|| format!("missing {}", p.name))?;
        let (delta, _) = fixedpoint::optimal_delta_refined(&t.data, man.n_bits);
        deltas[qidx] = delta;
        let src = t.data.clone();
        quantize_slice(&src, delta, man.n_bits, &mut t.data);
    }
    match out.tensors.iter_mut().find(|t| t.name == "__deltas__") {
        Some(t) => {
            t.dims = vec![deltas.len()];
            t.data = deltas;
        }
        None => out.tensors.push(crate::coordinator::Tensor {
            name: "__deltas__".into(),
            kind: Kind::Deltas,
            dims: vec![deltas.len()],
            data: deltas,
        }),
    }
    Ok(out)
}

/// Per-layer quantization statistics (the numbers behind Fig 1's narrative).
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub name: String,
    pub numel: usize,
    pub delta: f32,
    pub std: f32,
    /// mean squared quantization error (1/M)||w - Q(w)||^2
    pub mse: f64,
    /// fraction of weights per mode, centered (len 2^N - 1)
    pub occupancy: Vec<f32>,
}

/// Analyze every quantized layer of a checkpoint.
pub fn layer_stats(man: &Manifest, ckpt: &Checkpoint) -> Result<Vec<LayerStats>> {
    let deltas = &ckpt.find("__deltas__").context("no __deltas__")?.data;
    let mut out = Vec::new();
    for p in &man.params {
        let Some(qidx) = p.qidx else { continue };
        let t = ckpt.find(&p.name).with_context(|| format!("missing {}", p.name))?;
        let delta = deltas[qidx];
        let mse = fixedpoint::quant_error(&t.data, delta, man.n_bits) / t.data.len() as f64;
        let modes = mode_indices(&t.data, delta, man.n_bits);
        let qmax = (1i32 << (man.n_bits - 1)) - 1;
        let mut occ = vec![0f32; (2 * qmax + 1) as usize];
        for m in modes {
            occ[(m as i32 + qmax) as usize] += 1.0;
        }
        for o in &mut occ {
            *o /= t.data.len() as f32;
        }
        out.push(LayerStats {
            name: p.name.clone(),
            numel: t.data.len(),
            delta,
            std: crate::util::std_dev(&t.data),
            mse,
            occupancy: occ,
        });
    }
    Ok(out)
}

/// Pack 2-bit weight mantissas (-1/0/1 -> 2-bit codes) into bytes: the
/// 16x storage reduction the paper's fixed-point format enables.
pub fn pack_ternary(mantissas: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; mantissas.len().div_ceil(4)];
    for (i, &m) in mantissas.iter().enumerate() {
        debug_assert!((-1..=1).contains(&m));
        let code = (m + 1) as u8; // -1,0,1 -> 0,1,2
        out[i / 4] |= code << ((i % 4) * 2);
    }
    out
}

/// Inverse of `pack_ternary`.
pub fn unpack_ternary(packed: &[u8], n: usize) -> Vec<i8> {
    (0..n)
        .map(|i| (((packed[i / 4] >> ((i % 4) * 2)) & 0b11) as i8) - 1)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    #[test]
    fn prop_pack_roundtrip() {
        forall(32, |rng: &mut Rng| {
            let n = 1 + rng.below(1000);
            let m: Vec<i8> = (0..n).map(|_| rng.below(3) as i8 - 1).collect();
            let packed = pack_ternary(&m);
            assert_eq!(packed.len(), n.div_ceil(4));
            assert_eq!(unpack_ternary(&packed, n), m);
        });
    }

    #[test]
    fn pack_is_quarter_size() {
        let m = vec![0i8; 1000];
        assert_eq!(pack_ternary(&m).len(), 250);
    }
}
