//! `.fxpm` — packed fixed-point model format for deployment.
//!
//! This materializes the paper's model-size claim: 2-bit SYMOG weights are
//! stored as packed 2-bit codes (4 weights/byte) plus one power-of-two
//! exponent per layer; float-kept auxiliaries (bias/BN) stay f32. The
//! integer inference engine loads this file directly — no float weight
//! tensor ever exists at inference time.
//!
//! Layout (little-endian):
//!   magic  8 bytes  b"SYMGFXP1"
//!   u32    manifest_len, manifest JSON (the artifact manifest, embedded)
//!   u32    n_quant; per quantized tensor (qidx order):
//!          u32 numel, i32 frac, packed codes ceil(numel * n_bits / 8)
//!   u32    n_aux; per aux tensor:
//!          u32 name_len + name, u8 ndim, u32 dims[], f32 data
//!
//! For n_bits = 2 the code is (mantissa + 1) in 2 bits; for wider codes the
//! mantissa is stored sign-magnitude in n_bits bits.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Checkpoint, Kind, Tensor};
use crate::runtime::Manifest;

const MAGIC: &[u8; 8] = b"SYMGFXP1";

/// Pack signed mantissas (|m| <= 2^{n_bits-1}-1) into n_bits-wide codes.
pub fn pack_codes(mantissas: &[i8], n_bits: u32) -> Vec<u8> {
    let qmax = (1i16 << (n_bits - 1)) - 1;
    let nb = n_bits as usize;
    let mut out = vec![0u8; (mantissas.len() * nb).div_ceil(8)];
    for (i, &m) in mantissas.iter().enumerate() {
        debug_assert!((m as i16).abs() <= qmax);
        let code = (m as i16 + qmax) as u16; // bias to unsigned
        let bit = i * nb;
        // codes never straddle more than 2 bytes for n_bits <= 8
        out[bit / 8] |= (code << (bit % 8)) as u8;
        if bit % 8 + nb > 8 {
            out[bit / 8 + 1] |= (code >> (8 - bit % 8)) as u8;
        }
    }
    out
}

/// Decode the `i`-th mantissa from a packed code stream without
/// unpacking the rest — plane builders (`kernels::bitslice`) stream
/// codes straight out of `.fxpm` payloads through this.
#[inline]
pub fn mantissa_at(packed: &[u8], i: usize, n_bits: u32) -> i8 {
    let qmax = (1i16 << (n_bits - 1)) - 1;
    let nb = n_bits as usize;
    let mask = (1u16 << nb) - 1;
    let bit = i * nb;
    debug_assert!(
        (bit + nb - 1) / 8 < packed.len(),
        "mantissa_at: code {i} ({n_bits}-bit) ends at byte {}, packed stream holds {}",
        (bit + nb - 1) / 8,
        packed.len()
    );
    let mut v = (packed[bit / 8] >> (bit % 8)) as u16;
    if bit % 8 + nb > 8 {
        v |= (packed[bit / 8 + 1] as u16) << (8 - bit % 8);
    }
    ((v & mask) as i16 - qmax) as i8
}

/// Inverse of `pack_codes`.
pub fn unpack_codes(packed: &[u8], n: usize, n_bits: u32) -> Vec<i8> {
    (0..n).map(|i| mantissa_at(packed, i, n_bits)).collect()
}

/// Write a packed model from a trained checkpoint (weights are quantized
/// with the checkpoint's deltas during packing).
pub fn write_packed(man: &Manifest, man_json: &str, ckpt: &Checkpoint, path: &Path) -> Result<()> {
    let deltas = &ckpt.find("__deltas__").context("no __deltas__")?.data;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(man_json.len() as u32).to_le_bytes())?;
    f.write_all(man_json.as_bytes())?;
    // quantized tensors in qidx order
    let mut quant: Vec<(&crate::runtime::ParamMeta, usize)> = man
        .params
        .iter()
        .filter_map(|p| p.qidx.map(|q| (p, q)))
        .collect();
    quant.sort_by_key(|(_, q)| *q);
    f.write_all(&(quant.len() as u32).to_le_bytes())?;
    let qmax = ((1i32 << (man.n_bits - 1)) - 1) as f32;
    for (p, qidx) in &quant {
        let t = ckpt.find(&p.name).with_context(|| format!("missing {}", p.name))?;
        let delta = deltas[*qidx];
        let frac = (-delta.log2()).round() as i32;
        let mantissas: Vec<i8> = t
            .data
            .iter()
            .map(|&w| {
                let s = w / delta;
                (s.abs() + 0.5).floor().copysign(s).clamp(-qmax, qmax) as i8
            })
            .collect();
        f.write_all(&(t.data.len() as u32).to_le_bytes())?;
        f.write_all(&frac.to_le_bytes())?;
        f.write_all(&pack_codes(&mantissas, man.n_bits))?;
    }
    // aux tensors: everything non-quantized the engine needs
    let aux: Vec<&Tensor> = ckpt
        .tensors
        .iter()
        .filter(|t| {
            t.name != "__deltas__"
                && !t.name.ends_with("#m")
                && !man
                    .params
                    .iter()
                    .any(|p| p.qidx.is_some() && p.name == t.name)
        })
        .collect();
    f.write_all(&(aux.len() as u32).to_le_bytes())?;
    for t in aux {
        f.write_all(&(t.name.len() as u32).to_le_bytes())?;
        f.write_all(t.name.as_bytes())?;
        f.write_all(&[t.dims.len() as u8])?;
        for &d in &t.dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read a packed model back into (manifest, checkpoint-with-quantized-
/// weights) — ready for `IntModel::build`.
pub fn read_packed(path: &Path) -> Result<(Manifest, Checkpoint)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated before the 8-byte magic", path.display()))?;
    if &magic != MAGIC {
        if &magic == b"SYMOGFXA" {
            bail!(
                "{}: this is a .fxpa serving artifact, not a .fxpm packed model — \
                 load it with artifact::load",
                path.display()
            );
        }
        if magic[..7] == MAGIC[..7] {
            bail!(
                "{}: unsupported .fxpm format version byte {:?} (this build reads '1')",
                path.display(),
                magic[7] as char
            );
        }
        bail!("{}: not a .fxpm file (bad magic {magic:02x?})", path.display());
    }
    let mlen = read_u32(&mut f)
        .with_context(|| format!("{}: truncated reading the manifest length", path.display()))?
        as usize;
    let mut mbuf = vec![0u8; mlen];
    f.read_exact(&mut mbuf).with_context(|| {
        format!("{}: truncated reading the {mlen}-byte embedded manifest", path.display())
    })?;
    let man = Manifest::parse(std::str::from_utf8(&mbuf)?)
        .with_context(|| format!("{}: parsing the embedded manifest", path.display()))?;

    let mut ck = Checkpoint::default();
    let n_quant = read_u32(&mut f).with_context(|| {
        format!("{}: truncated reading the quantized tensor count", path.display())
    })? as usize;
    let mut quant: Vec<(&crate::runtime::ParamMeta, usize)> = man
        .params
        .iter()
        .filter_map(|p| p.qidx.map(|q| (p, q)))
        .collect();
    quant.sort_by_key(|(_, q)| *q);
    anyhow::ensure!(
        quant.len() == n_quant,
        "{}: payload declares {n_quant} quantized tensors, the embedded manifest has {}",
        path.display(),
        quant.len()
    );
    let mut deltas = vec![1.0f32; man.deltas_len()];
    for (p, qidx) in &quant {
        let numel = read_u32(&mut f).with_context(|| {
            format!("{}: truncated reading the numel of {}", path.display(), p.name)
        })? as usize;
        anyhow::ensure!(
            numel == p.numel(),
            "{}: {} has {numel} elements in the payload, the manifest says {}",
            path.display(),
            p.name,
            p.numel()
        );
        let mut fb = [0u8; 4];
        f.read_exact(&mut fb).with_context(|| {
            format!("{}: truncated reading the frac exponent of {}", path.display(), p.name)
        })?;
        let frac = i32::from_le_bytes(fb);
        let delta = (2.0f32).powi(-frac);
        deltas[*qidx] = delta;
        let mut packed = vec![0u8; (numel * man.n_bits as usize).div_ceil(8)];
        f.read_exact(&mut packed).with_context(|| {
            format!("{}: truncated reading the packed codes of {}", path.display(), p.name)
        })?;
        let data = unpack_codes(&packed, numel, man.n_bits)
            .into_iter()
            .map(|m| m as f32 * delta)
            .collect();
        ck.tensors.push(Tensor {
            name: p.name.clone(),
            kind: Kind::Weight,
            dims: p.shape.clone(),
            data,
        });
    }
    let n_aux = read_u32(&mut f)
        .with_context(|| format!("{}: truncated reading the aux tensor count", path.display()))?
        as usize;
    for i in 0..n_aux {
        let nlen = read_u32(&mut f).with_context(|| {
            format!("{}: truncated reading the name of aux tensor {i}", path.display())
        })? as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb).with_context(|| {
            format!("{}: truncated reading the name of aux tensor {i}", path.display())
        })?;
        let name = String::from_utf8(nb)
            .with_context(|| format!("{}: aux tensor {i} name is not UTF-8", path.display()))?;
        let mut db = [0u8; 1];
        f.read_exact(&mut db)
            .with_context(|| format!("{}: truncated reading the rank of {name}", path.display()))?;
        let ndim = db[0] as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut f).with_context(|| {
                format!("{}: truncated reading the dims of {name}", path.display())
            })? as usize);
        }
        let numel: usize = dims.iter().product::<usize>().max(1);
        let mut raw = vec![0u8; numel * 4];
        f.read_exact(&mut raw).with_context(|| {
            format!("{}: truncated reading the data of {name}", path.display())
        })?;
        ck.tensors.push(Tensor {
            name,
            kind: Kind::State,
            dims,
            data: raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        });
    }
    ck.tensors.push(Tensor {
        name: "__deltas__".into(),
        kind: Kind::Deltas,
        dims: vec![deltas.len()],
        data: deltas,
    });
    Ok((man, ck))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    #[test]
    fn prop_codes_roundtrip_all_widths() {
        forall(48, |rng: &mut Rng| {
            let n_bits = 2 + rng.below(7) as u32;
            let qmax = (1i16 << (n_bits - 1)) - 1;
            let n = 1 + rng.below(500);
            let m: Vec<i8> = (0..n)
                .map(|_| (rng.below(2 * qmax as usize + 1) as i16 - qmax) as i8)
                .collect();
            let packed = pack_codes(&m, n_bits);
            assert_eq!(packed.len(), (n * n_bits as usize).div_ceil(8));
            assert_eq!(unpack_codes(&packed, n, n_bits), m);
        });
    }

    #[test]
    fn two_bit_density() {
        let m = vec![1i8; 4000];
        assert_eq!(pack_codes(&m, 2).len(), 1000);
    }

    #[test]
    fn codes_roundtrip_fixed_widths_with_straddle() {
        for n_bits in [2u32, 3, 4, 6, 8] {
            let qmax = (1i16 << (n_bits - 1)) - 1;
            // full symmetric codebook plus a tail whose length is not a
            // multiple of 8 bits, so codes straddle byte boundaries
            let mut m: Vec<i8> = (-qmax..=qmax).map(|v| v as i8).collect();
            m.extend([qmax as i8, -(qmax as i8), 0, 1, -1, 0, qmax as i8]);
            let packed = pack_codes(&m, n_bits);
            assert_eq!(packed.len(), (m.len() * n_bits as usize).div_ceil(8));
            assert_eq!(unpack_codes(&packed, m.len(), n_bits), m, "n_bits={n_bits}");
        }
    }

    #[test]
    fn bias_to_unsigned_encoding_at_extremes() {
        // the stored code is mantissa + qmax: -qmax -> 0, 0 -> qmax,
        // +qmax -> 2*qmax — always within n_bits unsigned
        for n_bits in [2u32, 3, 4, 6, 8] {
            let qmax = ((1i16 << (n_bits - 1)) - 1) as i8;
            assert_eq!(pack_codes(&[-qmax], n_bits)[0], 0, "n_bits={n_bits}");
            assert_eq!(pack_codes(&[0], n_bits)[0], qmax as u8);
            assert_eq!(pack_codes(&[qmax], n_bits)[0], 2 * qmax as u8);
            assert_eq!(unpack_codes(&[2 * qmax as u8], 1, n_bits), vec![qmax]);
        }
    }

    #[test]
    fn three_bit_codes_straddle_exact_bytes() {
        // 3 codes x 3 bits = 9 bits: the third code crosses the byte edge.
        // mantissas [3, -3, 1] -> codes [6, 0, 4] -> 110 000 1|00
        let packed = pack_codes(&[3, -3, 1], 3);
        assert_eq!(packed, vec![0b0000_0110, 0b0000_0001]);
        assert_eq!(unpack_codes(&packed, 3, 3), vec![3, -3, 1]);
    }

    #[test]
    fn six_bit_codes_straddle_exact_bytes() {
        // codes are 6 wide: the second code occupies bits 6..12
        let qmax = 31i8; // 6-bit qmax
        let packed = pack_codes(&[-qmax, qmax, 0], 6);
        // codes [0, 62, 31]: byte0 = 62<<6 truncated, byte1 = 62>>2 | 31<<4
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_codes(&packed, 3, 6), vec![-qmax, qmax, 0]);
    }
}
