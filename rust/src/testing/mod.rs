//! Property-testing helpers (proptest is not vendored; this is a focused
//! replacement: seeded random-case generation with failure reporting) and
//! the in-code model zoo (`models`) shared by engine tests and benches.

pub mod models;

use crate::util::rng::Rng;

/// Run `body` for `cases` independently seeded RNGs. On panic, the failing
/// seed is reported so the case replays deterministically with
/// `forall_seed(seed, body)`.
pub fn forall<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, body: F) {
    // base seed can be pinned via SYMOG_PROP_SEED for replay
    let base = std::env::var("SYMOG_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            body(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed: case {case}, replay with SYMOG_PROP_SEED and forall_seed({seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing case.
pub fn forall_seed<F: FnOnce(&mut Rng)>(seed: u64, body: F) {
    let mut rng = Rng::new(seed);
    body(&mut rng);
}

/// Assert two f32 slices agree within `atol` element-wise.
#[track_caller]
pub fn assert_allclose(got: &[f32], want: &[f32], atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= atol || (g.is_nan() && w.is_nan()),
            "index {i}: got {g}, want {w} (atol {atol})"
        );
    }
}

/// Relative+absolute tolerance comparison (numpy allclose semantics).
#[track_caller]
pub fn assert_allclose_rel(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!((g - w).abs() <= tol, "index {i}: got {g}, want {w} (tol {tol})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let counter = std::sync::atomic::AtomicU64::new(0);
        forall(10, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 10);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(5, |rng| {
            assert!(rng.f32() < 0.0, "always fails");
        });
    }

    #[test]
    fn allclose_passes_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 0.0);
        assert_allclose_rel(&[100.1], &[100.0], 1e-2, 0.0);
    }

    #[test]
    #[should_panic]
    fn allclose_catches_mismatch() {
        assert_allclose(&[1.0], &[2.0], 0.5);
    }
}
