//! In-code model zoo for engine tests and benchmarks.
//!
//! Builds (Manifest, Checkpoint) pairs directly — no compiled artifacts,
//! no JSON files — with weights drawn from the exact N-bit codebook
//! {-qmax..qmax} x delta, so `IntModel::build` round-trips them losslessly.
//! Used by `tests/planned_exec.rs` and the interpret-vs-planned section of
//! `benches/hotpath.rs`.

use std::collections::BTreeMap;

use crate::coordinator::{Checkpoint, Kind, Tensor};
use crate::runtime::{LayerDesc, Manifest, ParamMeta, StateMeta};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Incremental (Manifest, Checkpoint) builder. Layer methods append both
/// the manifest graph entry and the backing checkpoint tensors.
pub struct ModelBuilder {
    n_bits: u32,
    delta: f32,
    /// probability of the zero code per weight (None = uniform codebook)
    zero_frac: Option<f32>,
    input_shape: [usize; 3],
    num_classes: usize,
    params: Vec<ParamMeta>,
    state: Vec<StateMeta>,
    layers: Vec<LayerDesc>,
    tensors: Vec<Tensor>,
    n_quant: usize,
}

fn obj(fields: Vec<(&str, Json)>) -> LayerDesc {
    let map: BTreeMap<String, Json> =
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    LayerDesc(Json::Obj(map))
}

fn num(v: usize) -> Json {
    Json::Num(v as f64)
}

impl ModelBuilder {
    pub fn new(input_shape: [usize; 3], num_classes: usize, n_bits: u32) -> ModelBuilder {
        ModelBuilder {
            n_bits,
            delta: 0.25,
            zero_frac: None,
            input_shape,
            num_classes,
            params: Vec::new(),
            state: Vec::new(),
            layers: Vec::new(),
            tensors: Vec::new(),
            n_quant: 0,
        }
    }

    /// Force a given zero-code occupancy (e.g. 0.8 to engage the sparse
    /// ternary add/sub kernel at 2 bits).
    pub fn zero_frac(&mut self, f: f32) -> &mut Self {
        self.zero_frac = Some(f);
        self
    }

    /// Index of the layer the next `conv`/`relu`/... call will create —
    /// capture it before the call to wire a later `concat` to it.
    pub fn next_layer_idx(&self) -> usize {
        self.layers.len()
    }

    fn codebook_weights(&self, rng: &mut Rng, n: usize) -> Vec<f32> {
        let qmax = (1i32 << (self.n_bits - 1)) - 1;
        (0..n)
            .map(|_| {
                if let Some(zf) = self.zero_frac {
                    if rng.bool(zf) {
                        return 0.0;
                    }
                    let m = 1 + rng.below(qmax as usize) as i32;
                    let signed = if rng.bool(0.5) { m } else { -m };
                    return signed as f32 * self.delta;
                }
                (rng.below((2 * qmax + 1) as usize) as i32 - qmax) as f32 * self.delta
            })
            .collect()
    }

    fn add_weight(&mut self, shape: &[usize], fan_in: usize, data: Vec<f32>) -> usize {
        let idx = self.params.len();
        let name = format!("p{idx}.w");
        self.params.push(ParamMeta {
            name: name.clone(),
            shape: shape.to_vec(),
            kind: "weight".into(),
            qidx: Some(self.n_quant),
            fan_in,
        });
        self.n_quant += 1;
        self.tensors.push(Tensor { name, kind: Kind::Weight, dims: shape.to_vec(), data });
        idx
    }

    fn add_aux(&mut self, kind: &str, ck_kind: Kind, shape: &[usize], data: Vec<f32>) -> usize {
        let idx = self.params.len();
        let name = format!("p{idx}.{kind}");
        self.params.push(ParamMeta {
            name: name.clone(),
            shape: shape.to_vec(),
            kind: kind.into(),
            qidx: None,
            fan_in: 0,
        });
        self.tensors.push(Tensor { name, kind: ck_kind, dims: shape.to_vec(), data });
        idx
    }

    fn add_state(&mut self, tag: &str, c: usize, data: Vec<f32>) -> usize {
        let idx = self.state.len();
        let name = format!("s{idx}.{tag}");
        self.state.push(StateMeta { name: name.clone(), shape: vec![c], init: 0.0 });
        self.tensors.push(Tensor { name, kind: Kind::State, dims: vec![c], data });
        idx
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        rng: &mut Rng,
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        same: bool,
        bias: bool,
    ) -> &mut Self {
        let data = self.codebook_weights(rng, k * k * cin * cout);
        let w = self.add_weight(&[k, k, cin, cout], k * k * cin, data);
        let b = bias.then(|| {
            let data = (0..cout).map(|_| rng.normal() * 0.1).collect();
            self.add_aux("bias", Kind::Bias, &[cout], data)
        });
        self.layers.push(obj(vec![
            ("type", Json::Str("conv".into())),
            ("w", num(w)),
            ("b", b.map_or(Json::Null, num)),
            ("stride", num(stride)),
            ("padding", Json::Str(if same { "SAME" } else { "VALID" }.into())),
        ]));
        self
    }

    pub fn dense(&mut self, rng: &mut Rng, f_in: usize, f_out: usize, bias: bool) -> &mut Self {
        let data = self.codebook_weights(rng, f_in * f_out);
        let w = self.add_weight(&[f_in, f_out], f_in, data);
        let b = bias.then(|| {
            let data = (0..f_out).map(|_| rng.normal() * 0.1).collect();
            self.add_aux("bias", Kind::Bias, &[f_out], data)
        });
        self.layers.push(obj(vec![
            ("type", Json::Str("dense".into())),
            ("w", num(w)),
            ("b", b.map_or(Json::Null, num)),
        ]));
        self
    }

    pub fn bn(&mut self, rng: &mut Rng, c: usize) -> &mut Self {
        let gamma: Vec<f32> = (0..c).map(|_| 1.0 + rng.normal() * 0.1).collect();
        let beta: Vec<f32> = (0..c).map(|_| rng.normal() * 0.1).collect();
        let mean: Vec<f32> = (0..c).map(|_| rng.normal() * 0.2).collect();
        let var: Vec<f32> = (0..c).map(|_| 1.0 + rng.f32()).collect();
        let g = self.add_aux("gamma", Kind::Gamma, &[c], gamma);
        let b = self.add_aux("beta", Kind::Beta, &[c], beta);
        let m = self.add_state("mean", c, mean);
        let v = self.add_state("var", c, var);
        self.layers.push(obj(vec![
            ("type", Json::Str("bn".into())),
            ("gamma", num(g)),
            ("beta", num(b)),
            ("mean", num(m)),
            ("var", num(v)),
        ]));
        self
    }

    pub fn relu(&mut self) -> &mut Self {
        self.layers.push(obj(vec![("type", Json::Str("relu".into()))]));
        self
    }

    pub fn maxpool(&mut self, k: usize, stride: usize) -> &mut Self {
        self.layers.push(obj(vec![
            ("type", Json::Str("maxpool".into())),
            ("k", num(k)),
            ("stride", num(stride)),
        ]));
        self
    }

    pub fn avgpool(&mut self, k: usize, stride: usize) -> &mut Self {
        self.layers.push(obj(vec![
            ("type", Json::Str("avgpool".into())),
            ("k", num(k)),
            ("stride", num(stride)),
        ]));
        self
    }

    pub fn global_avgpool(&mut self) -> &mut Self {
        self.layers.push(obj(vec![("type", Json::Str("global_avgpool".into()))]));
        self
    }

    pub fn flatten(&mut self) -> &mut Self {
        self.layers.push(obj(vec![("type", Json::Str("flatten".into()))]));
        self
    }

    pub fn concat(&mut self, from: usize) -> &mut Self {
        self.layers.push(obj(vec![
            ("type", Json::Str("concat".into())),
            ("from", num(from)),
        ]));
        self
    }

    pub fn finish(self, tag: &str) -> (Manifest, Checkpoint) {
        let n_quant = self.n_quant.max(1);
        let man = Manifest {
            tag: tag.into(),
            model: tag.into(),
            method: "symog".into(),
            dataset: "synth-mnist".into(),
            width_mult: 1.0,
            batch: 8,
            n_bits: self.n_bits,
            momentum: 0.9,
            weight_decay: 0.0,
            clip: true,
            input_shape: self.input_shape,
            num_classes: self.num_classes,
            n_quant,
            params: self.params,
            state: self.state,
            layers: self.layers,
        };
        let mut ck = Checkpoint { meta: BTreeMap::new(), tensors: self.tensors };
        ck.tensors.push(Tensor {
            name: "__deltas__".into(),
            kind: Kind::Deltas,
            dims: vec![n_quant],
            data: vec![self.delta; n_quant],
        });
        (man, ck)
    }
}

/// LeNet5-shaped stack on a 16x16x1 input: conv5(SAME)+bias / relu /
/// maxpool / conv5(VALID) / bn / relu / maxpool / flatten / dense / relu /
/// dense. Exercises both paddings, bias, BN fusion and the dense head.
pub fn lenet5ish(rng: &mut Rng, n_bits: u32) -> (Manifest, Checkpoint) {
    let mut b = ModelBuilder::new([16, 16, 1], 10, n_bits);
    b.conv(rng, 5, 1, 6, 1, true, true)
        .relu()
        .maxpool(2, 2)
        .conv(rng, 5, 6, 16, 1, false, false)
        .bn(rng, 16)
        .relu()
        .maxpool(2, 2)
        .flatten()
        .dense(rng, 2 * 2 * 16, 32, true)
        .relu()
        .dense(rng, 32, 10, true);
    b.finish("lenet5ish")
}

/// DenseNet-shaped growth block on a 6x6x4 input: two channel concats
/// (one chained off the other), retained relu/concat sources, avg pooling
/// with a non-power-of-two global area (reciprocal divide path).
pub fn densenetish(rng: &mut Rng, n_bits: u32) -> (Manifest, Checkpoint) {
    let mut b = ModelBuilder::new([6, 6, 4], 10, n_bits);
    b.conv(rng, 3, 4, 8, 1, true, false).bn(rng, 8);
    let skip1 = b.next_layer_idx();
    b.relu(); // layer `skip1`: first concat source
    b.conv(rng, 3, 8, 8, 1, true, true).relu();
    let skip2 = b.next_layer_idx();
    b.concat(skip1); // layer `skip2`: 6x6x16, itself a concat source
    b.conv(rng, 3, 16, 8, 1, true, false).bn(rng, 8).relu();
    b.concat(skip2); // 6x6x24
    b.avgpool(2, 2); // 3x3x24
    b.global_avgpool(); // area 9: non-power-of-two reciprocal divide
    b.flatten();
    b.dense(rng, 24, 10, true);
    b.finish("densenetish")
}

/// Deliberately awkward layer placements that defeat epilogue fusion:
/// BN after a pool (standalone affine, in place), a *retained* flatten
/// (concat source with no compute of its own), BN reading a retained
/// concat output (standalone affine via copy), and ReLUs after BN and
/// after concat (standalone, in-place and out-of-place). Exercises every
/// non-fused step kind of the planned executor.
pub fn oddball(rng: &mut Rng, n_bits: u32) -> (Manifest, Checkpoint) {
    let mut b = ModelBuilder::new([6, 6, 4], 10, n_bits);
    b.conv(rng, 3, 4, 6, 1, true, true); // 6x6x6
    b.maxpool(2, 2); // 3x3x6
    b.bn(rng, 6); // standalone affine after a pool
    b.relu(); // standalone relu after a BN
    let skip_flat = b.next_layer_idx();
    b.flatten(); // [1,1,54], retained: pure Copy step
    b.dense(rng, 54, 16, true); // [1,1,16]
    let skip_cat = b.next_layer_idx();
    b.concat(skip_flat); // [1,1,70], itself retained
    b.bn(rng, 70); // affine reading a retained slot (copy branch)
    b.relu(); // standalone relu, in place
    b.dense(rng, 70, 16, true);
    b.concat(skip_cat); // [1,1,86]
    b.relu(); // standalone relu straight after a concat
    b.dense(rng, 86, 10, true);
    b.finish("oddball")
}

/// VGG7-shaped conv stack (width-scaled) for the interpret-vs-planned
/// benchmark: 2x conv3-w / pool / 2x conv3-2w / pool / dense head, BN+ReLU
/// after every conv.
pub fn vgg7ish(rng: &mut Rng, n_bits: u32, width: usize) -> (Manifest, Checkpoint) {
    let w = width;
    let mut b = ModelBuilder::new([16, 16, 3], 10, n_bits);
    b.conv(rng, 3, 3, w, 1, true, false)
        .bn(rng, w)
        .relu()
        .conv(rng, 3, w, w, 1, true, false)
        .bn(rng, w)
        .relu()
        .maxpool(2, 2)
        .conv(rng, 3, w, 2 * w, 1, true, false)
        .bn(rng, 2 * w)
        .relu()
        .conv(rng, 3, 2 * w, 2 * w, 1, true, false)
        .bn(rng, 2 * w)
        .relu()
        .maxpool(2, 2)
        .flatten()
        .dense(rng, 4 * 4 * 2 * w, 128, true)
        .relu()
        .dense(rng, 128, 10, true);
    b.finish("vgg7ish")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::IntModel;

    #[test]
    fn zoo_models_build_and_run() {
        let mut rng = Rng::new(7);
        for (man, ck) in [
            lenet5ish(&mut rng, 2),
            densenetish(&mut rng, 4),
            oddball(&mut rng, 2),
            vgg7ish(&mut rng, 2, 4),
        ] {
            let model = IntModel::build(&man, &ck).unwrap();
            let [h, w, c] = man.input_shape;
            let images: Vec<f32> = (0..2 * h * w * c).map(|_| rng.normal()).collect();
            let (logits, counts) = model.forward(&images, 2).unwrap();
            assert_eq!(logits.len(), 2 * man.num_classes);
            assert!(counts.acc_adds > 0);
        }
    }

    #[test]
    fn two_bit_codebook_is_ternary() {
        let mut rng = Rng::new(3);
        let (man, ck) = lenet5ish(&mut rng, 2);
        let model = IntModel::build(&man, &ck).unwrap();
        assert!(model.all_ternary);
    }
}
