//! Batched serving layer on the compile-then-execute seam.
//!
//! PR 3 split inference into an immutable, `Sync` [`ExecPlan`] and
//! per-thread `Scratch` state precisely so a serving layer could fan
//! request threads out over one compiled plan — this module is that
//! layer. It is synchronous at the API (`Server::infer` blocks until the
//! request's logits are ready) and batched internally:
//!
//! * [`Registry`] — multi-model catalog keyed by `(name, n_bits)`; each
//!   entry reuses the model's cache-backed shared plan;
//! * [`Server`] — per-model FIFO submission queues whose pending requests
//!   coalesce into dynamic micro-batches (up to the registered
//!   `max_batch`), flushed on a size or queue-empty watermark — never a
//!   timer, so batching behavior is deterministic and testable;
//! * bounded per-model scratch pools (checkout/return, zero steady-state
//!   growth) and per-model running [`ModelStats`] with analytic op
//!   accounting.
//!
//! The load-bearing numeric contract: every response is bit-identical to
//! a solo `Backend::Planned` forward of that request, regardless of
//! arrival order, micro-batch composition, or client thread count. The
//! engine's requantization statistics are batch-global, so this requires
//! executing coalesced rows with per-request isolation — see
//! [`ExecPlan::run_rows`] and DESIGN.md §"The serving layer".
//!
//! [`ExecPlan`]: crate::inference::ExecPlan
//! [`ExecPlan::run_rows`]: crate::inference::ExecPlan::run_rows

mod registry;
mod server;
mod stats;

pub use registry::{ModelKey, Registry};
pub use server::{ServeConfig, Server};
pub use stats::ModelStats;
