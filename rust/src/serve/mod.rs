//! Batched serving layer on the compile-then-execute seam.
//!
//! PR 3 split inference into an immutable, `Sync` [`ExecPlan`] and
//! per-thread `Scratch` state precisely so a serving layer could fan
//! request threads out over one compiled plan — this module is that
//! layer. It is synchronous at the API (`Server::infer` blocks until the
//! request's logits are ready) and batched internally:
//!
//! * [`Registry`] — multi-model catalog slotted by `(name, n_bits)`,
//!   populated from a [`ModelSource`] (an in-code `IntModel` whose
//!   cache-backed shared plan is reused, or a published `.fxpa` artifact)
//!   with [`RegisterOpts`] (micro-batch cap, version pinning);
//! * [`Server`] — per-slot FIFO submission queues whose pending requests
//!   coalesce into dynamic micro-batches (up to the registered
//!   `max_batch`), flushed on a size or queue-empty watermark — never a
//!   timer, so batching behavior is deterministic and testable;
//! * versioned serving: each slot holds an Arc-swapped version state;
//!   [`Server::swap`] installs a new model version atomically under
//!   traffic (in-flight drains finish on the version they pinned, nothing
//!   pauses, nothing drops) and [`Server::infer_versioned`] reports which
//!   version served each response;
//! * bounded per-version scratch pools (checkout/return, zero
//!   steady-state growth) and per-version running [`ModelStats`] with
//!   analytic op accounting ([`Server::stats_by_version`] partitions
//!   traffic exactly; [`Server::stats`] totals it);
//! * hardened failure domains: bounded admission ([`ServeConfig`]'s
//!   `queue_depth` sheds with a typed [`ServeError::Shed`]), per-request
//!   deadlines ([`Server::infer_with`] + [`InferOpts`]), per-version
//!   [`Health`] with a consecutive-failure circuit breaker and automatic
//!   last-good rollback ([`Server::rollback`], [`Server::health`]), all
//!   proven under seeded fault schedules (`util::fault`, `tests/chaos.rs`);
//! * a TCP front-end ([`net`]) — thread-per-connection listener speaking
//!   a length-prefixed binary protocol whose per-connection loop is a
//!   pure transport over [`Server::infer_with`], so networked responses
//!   inherit the bit-identity contract and typed failures cross the wire
//!   as pinned error codes; latency quantiles from each slot's
//!   [`LatencyHistogram`] ride the Stats frame.
//!
//! The load-bearing numeric contract: every response is bit-identical to
//! a solo `Backend::Planned` forward of that request on the version that
//! served it, regardless of arrival order, micro-batch composition,
//! client thread count, or concurrent swaps. The engine's requantization
//! statistics are batch-global, so this requires executing coalesced rows
//! with per-request isolation — see [`ExecPlan::run_rows`] and DESIGN.md
//! §"The serving layer" / §"Serving artifacts and hot-swap".
//!
//! [`ExecPlan`]: crate::inference::ExecPlan
//! [`ExecPlan::run_rows`]: crate::inference::ExecPlan::run_rows

mod health;
pub mod net;
mod registry;
mod server;
mod stats;

pub use health::{Health, ServeError};
pub use registry::{ModelKey, ModelSource, RegisterOpts, Registry};
pub use server::{InferOpts, ServeConfig, Server, DEFAULT_QUARANTINE_AFTER};
pub use stats::{LatencyHistogram, ModelStats, LATENCY_BUCKETS};
