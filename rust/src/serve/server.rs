//! The synchronous batched inference server, with atomic hot-swap and
//! overload-hardened failure domains.
//!
//! ## Queue / flush policy (wall-clock-free)
//!
//! Callers block in [`Server::infer`]. Each request is appended to its
//! model slot's FIFO submission queue; the first caller that finds the
//! queue non-empty with no drain in flight becomes the **drainer**: it
//! takes `min(pending, max_batch)` requests — the whole queue when
//! traffic is light, a full micro-batch under saturation — executes them,
//! scatters the logits back into each request's response slot, and wakes
//! everyone. Flushing is therefore triggered purely by queue state (size
//! watermark `max_batch`, or the executor going idle with work pending):
//! there is no timer anywhere, so a given arrival order produces a
//! reproducible batch partition — the property the conformance suite
//! leans on. Drains are serialized per slot (concurrency comes from row
//! fan-out inside a batch and from other models); while a drain runs, new
//! arrivals queue up and coalesce into the next micro-batch.
//!
//! ## Failure domains (admission → deadline → quarantine → rollback)
//!
//! Every submitted request resolves to **exactly one** typed terminal
//! outcome — logits, or one [`ServeError`] variant — and the per-version
//! counters in [`ModelStats`] account for it exactly
//! (`requests + sheds + timeouts + failures == submissions`). Each
//! terminal outcome that passed admission also deposits exactly one
//! enqueue→resolve sample into the version's latency histogram — the
//! request is stamped when it enters the queue and recorded (under the
//! same stats lock that bills its counter) at whichever site resolves it,
//! so `latency.count() == requests + timeouts + failures` is as exact as
//! the outcome identity:
//!
//! * **Admission control.** [`ServeConfig::queue_depth`] bounds each
//!   slot's queue; a request arriving at the bound is refused *at
//!   enqueue* with [`ServeError::Shed`] instead of growing the queue (and
//!   the tail latency of everything behind it) without bound.
//! * **Deadlines.** [`Server::infer_with`] carries an optional deadline.
//!   Expired requests are swept by the drainer *before* execution — they
//!   never consume engine time — and complete with
//!   [`ServeError::DeadlineExceeded`].
//! * **Panic quarantine.** A micro-batch that panics or fails inside the
//!   engine fails only its own batch: every batchmate resolves with
//!   [`ServeError::BatchPanicked`], the scratches return to the pool, the
//!   drain flag resets, and the slot keeps serving. A consecutive-failure
//!   circuit breaker ([`ServeConfig::quarantine_after`]) moves the
//!   version `Ready → Degraded → Quarantined` ([`Server::health`]).
//! * **Last-good rollback.** When a version quarantines, the slot
//!   atomically reroutes to the newest non-quarantined version it has
//!   served ([`Server::rollback`] does the same manually), so a bad
//!   deployment heals without a restart. [`Server::swap`] additionally
//!   runs a **probe row** through the incoming plan before install —
//!   a version that cannot execute one row never becomes current.
//!
//! ## Versioned slots and hot-swap
//!
//! A server slot is `(name, n_bits)`; what it *serves* is a
//! [`VersionState`] — plan, scratch pool, staging buffers, stats, and
//! breaker for one deployment generation — behind an
//! `RwLock<Arc<VersionState>>` ([`Server::swap`] is the writer). A
//! drainer pins the current `Arc` at the moment it takes its requests, so
//! a swap never pauses traffic and never drops a request: in-flight
//! drains finish on the version they pinned while new drains pick up the
//! new one, and each response (and its stats) is attributed to exactly
//! the version that executed it — still bit-identical to a solo forward
//! on that version. Retired versions stay resident for their stats and as
//! rollback targets ([`Server::stats_by_version`]); swaps are rare
//! control-plane events, serialized by the slot's install lock, and
//! validated for monotonically increasing versions (past *every* version
//! ever installed, so a rolled-back generation cannot be reinstalled
//! under the same number) and identical I/O geometry.
//!
//! ## Execution and the bit-exactness contract
//!
//! A drained micro-batch is gathered into a preallocated per-version
//! buffer and driven through [`ExecPlan::run_rows`], which executes every
//! row at batch 1 with per-request requantization isolation. Consequence:
//! each *accepted* response is **bit-identical to a solo
//! `Backend::Planned` forward** of that request on the version that
//! served it, independent of arrival order, batch composition, thread
//! count, concurrent swaps, or any amount of shedding/sweeping around it
//! (`tests/serve_conformance.rs`, `tests/serve_concurrency.rs`,
//! `tests/hot_swap.rs`, `tests/chaos.rs`).
//!
//! ## Scratch-pool lifecycle
//!
//! Row scratches (`ExecPlan::scratch_for(1)`) live in a bounded
//! per-version [`ScratchPool`], filled *eagerly* when the version is
//! installed (`Server::new` and `Server::swap` both create exactly
//! `workers` row scratches per version): a drain checks out up to
//! `workers.min(rows)` of them and returns every one afterwards — also on
//! the panic path, where the unwind is caught before it can leak a
//! checkout — and nothing ever creates more. The pool plus the
//! preallocated gather/scatter buffers are therefore a fixed set of
//! allocations from install onward — serving performs zero steady-state
//! growth, asserted via [`Server::pool_fingerprints`]. (Eager beats lazy
//! here for determinism: a lazily-warmed pool's final size would depend
//! on whether early traffic ever happened to coalesce a full-width
//! batch.)
//!
//! [`ExecPlan::run_rows`]: crate::inference::ExecPlan::run_rows
//! [`ModelStats`]: super::ModelStats

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::inference::ScratchPool;
use crate::util::{fault, pool};

use super::health::{Breaker, Health, ServeError};
use super::registry::{self, ModelEntry, ModelKey, ModelSource, RegisterOpts, Registry};
use super::stats::ModelStats;

/// Consecutive failed micro-batches before a version quarantines, when
/// [`ServeConfig::quarantine_after`] is left at 0.
pub const DEFAULT_QUARANTINE_AFTER: u32 = 3;

/// Server-wide tuning knobs (builder-style, like `RegisterOpts`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeConfig {
    /// Row-parallel workers per micro-batch, which is also each version's
    /// scratch-pool bound. 0 (the default) resolves to
    /// `util::pool::default_workers()` (`SYMOG_WORKERS` honored).
    pub workers: usize,
    /// Admission bound: a request arriving while a slot already has this
    /// many queued is refused with [`ServeError::Shed`]. 0 (the default)
    /// means unbounded — the pre-hardening behavior.
    pub queue_depth: usize,
    /// Consecutive failed micro-batches that trip a version's circuit
    /// breaker into quarantine (triggering rollback to last-good). 0 (the
    /// default) resolves to [`DEFAULT_QUARANTINE_AFTER`].
    pub quarantine_after: u32,
}

impl ServeConfig {
    pub fn new() -> ServeConfig {
        ServeConfig::default()
    }

    pub fn workers(mut self, n: usize) -> ServeConfig {
        self.workers = n;
        self
    }

    pub fn queue_depth(mut self, n: usize) -> ServeConfig {
        self.queue_depth = n;
        self
    }

    pub fn quarantine_after(mut self, n: u32) -> ServeConfig {
        self.quarantine_after = n;
        self
    }
}

/// Per-request options for [`Server::infer_with`].
#[derive(Clone, Copy, Debug, Default)]
pub struct InferOpts {
    /// Latest instant at which this request may still *start* executing.
    /// A drainer sweeps expired requests out of its micro-batch before
    /// running it; they resolve with [`ServeError::DeadlineExceeded`] and
    /// never touch the engine. `None` (the default) never expires.
    pub deadline: Option<Instant>,
}

impl InferOpts {
    pub fn new() -> InferOpts {
        InferOpts::default()
    }

    /// Absolute deadline.
    pub fn deadline_at(mut self, t: Instant) -> InferOpts {
        self.deadline = Some(t);
        self
    }

    /// Deadline `d` from now.
    pub fn deadline_in(self, d: Duration) -> InferOpts {
        self.deadline_at(Instant::now() + d)
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn rlock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Best-effort human rendering of a caught panic payload.
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Response rendezvous for one request. Filled exactly once by whichever
/// caller drains the batch containing the request (or sweeps/refuses it);
/// carries the serving version the drain was pinned to.
#[derive(Default)]
struct Slot {
    done: Mutex<Option<Result<(Vec<f32>, u32), ServeError>>>,
}

impl Slot {
    fn fill(&self, r: Result<(Vec<f32>, u32), ServeError>) {
        *lock(&self.done) = Some(r);
    }

    fn is_done(&self) -> bool {
        lock(&self.done).is_some()
    }

    fn take(&self) -> Option<Result<(Vec<f32>, u32), ServeError>> {
        lock(&self.done).take()
    }
}

/// Microseconds between two instants (saturating; the clock is monotonic
/// so `now < from` only via scheduler weirdness, which clamps to 0).
fn us_since(from: Instant, now: Instant) -> u64 {
    now.saturating_duration_since(from).as_micros() as u64
}

struct Request {
    image: Vec<f32>,
    slot: Arc<Slot>,
    deadline: Option<Instant>,
    /// admission timestamp: the latency histogram records
    /// enqueue→resolve time for every terminal outcome of an enqueued
    /// request (success, sweep, or failure)
    enqueued: Instant,
}

struct QueueState {
    pending: VecDeque<Request>,
    /// true while some caller is executing a drained micro-batch
    draining: bool,
}

/// Preallocated gather/scatter staging for one version (drains are
/// serialized per slot, so one pair suffices and is never contended).
struct ExecBufs {
    gather: Vec<f32>,
    logits: Vec<f32>,
}

/// Everything needed to serve one deployment generation of a model:
/// compiled plan, scratch pool, staging buffers, stats, and the circuit
/// breaker that tracks its health.
struct VersionState {
    version: u32,
    entry: ModelEntry,
    pool: ScratchPool,
    bufs: Mutex<ExecBufs>,
    stats: Mutex<ModelStats>,
    breaker: Breaker,
    workers: usize,
}

impl VersionState {
    /// Install-time construction: buffers sized for this version's cap,
    /// pool seeded eagerly *through* checkout so the scratches count
    /// toward the pool's lifetime-creation bound — the "nothing ever
    /// creates more" contract holds by construction.
    fn install(
        version: u32,
        entry: ModelEntry,
        workers: usize,
        quarantine_after: u32,
    ) -> Arc<VersionState> {
        let vs = VersionState {
            version,
            pool: ScratchPool::new(workers),
            bufs: Mutex::new(ExecBufs {
                gather: vec![0f32; entry.max_batch * entry.in_elems],
                logits: vec![0f32; entry.max_batch * entry.out_per_img],
            }),
            stats: Mutex::new(ModelStats::default()),
            breaker: Breaker::new(quarantine_after),
            workers,
            entry,
        };
        let mut mk = || vs.entry.plan.scratch_for(1);
        let seed = vs.pool.checkout(workers, &mut mk);
        vs.pool.put_all(seed);
        Arc::new(vs)
    }

    fn health(&self) -> Health {
        self.breaker.health()
    }

    /// Fail every request of a batch with one typed error, bill the
    /// failures (with their enqueue→resolve latency), and advance the
    /// breaker. Returns true iff this failure tripped the version into
    /// quarantine (the caller rolls back).
    fn fail_batch(&self, reqs: &[&Request], msg: String) -> bool {
        let err = ServeError::BatchPanicked(msg);
        let now = Instant::now();
        for r in reqs {
            r.slot.fill(Err(err.clone()));
        }
        let mut stats = lock(&self.stats);
        stats.failures += reqs.len() as u64;
        for r in reqs {
            stats.latency.record(us_since(r.enqueued, now));
        }
        drop(stats);
        self.breaker.record_failure()
    }

    /// Execute one drained micro-batch: gather rows, run with per-request
    /// isolation, scatter logits into the response slots, record stats.
    /// Never unwinds: an engine panic is caught *here* (scratches still
    /// return to the pool, staging stays consistent) and resolves the
    /// whole batch with [`ServeError::BatchPanicked`]. Returns true iff
    /// the failure tripped this version's breaker.
    fn run_batch(&self, reqs: &[&Request]) -> bool {
        let k = reqs.len();
        let (ie, oe) = (self.entry.in_elems, self.entry.out_per_img);
        let want = self.workers.min(k);
        let mut scratches = self.pool.checkout(want, &mut || self.entry.plan.scratch_for(1));
        if scratches.is_empty() {
            // unreachable while drains are serialized (the pool bound is
            // >= 1 and every drain returns its scratches), but stay safe
            scratches.push(self.entry.plan.scratch_for(1));
        }
        let mut bufs = lock(&self.bufs);
        for (i, r) in reqs.iter().enumerate() {
            bufs.gather[i * ie..(i + 1) * ie].copy_from_slice(&r.image);
        }
        let ExecBufs { gather, logits } = &mut *bufs;
        // the unwind boundary sits between scratch checkout and return, so
        // a poison batch (or an injected drain fault) can never leak pool
        // capacity or wedge the staging buffers
        let run = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
            if fault::fire(fault::SERVE_DRAIN_PANIC) {
                panic!("injected fault: {}", fault::SERVE_DRAIN_PANIC);
            }
            if fault::fire(fault::SERVE_DRAIN_FAIL) {
                bail!("injected fault: {}", fault::SERVE_DRAIN_FAIL);
            }
            self.entry.plan.run_rows(&gather[..k * ie], k, &mut scratches, &mut logits[..k * oe])
        }));
        let tripped = match run {
            Ok(Ok(())) => {
                // resolve-time stamp: one `now` for the whole batch (the
                // batchmates resolved together) before the fills, so a
                // caller that wakes instantly still reads a recorded sample
                let now = Instant::now();
                for (i, r) in reqs.iter().enumerate() {
                    r.slot.fill(Ok((logits[i * oe..(i + 1) * oe].to_vec(), self.version)));
                }
                let counts = self.entry.plan.op_counts(k);
                let mut stats = lock(&self.stats);
                stats.record_batch(k as u64, self.entry.max_batch as u64, &counts);
                for r in reqs {
                    stats.latency.record(us_since(r.enqueued, now));
                }
                drop(stats);
                self.breaker.record_success();
                false
            }
            Ok(Err(e)) => self.fail_batch(reqs, format!("{e:#}")),
            Err(p) => self.fail_batch(reqs, panic_message(p)),
        };
        drop(bufs);
        self.pool.put_all(scratches);
        tripped
    }
}

/// One `(name, n_bits)` serving slot: the request queue (shared across
/// versions — a swap never disturbs queued work) and the Arc-swapped
/// current version. `versions` doubles as the swap install lock, the
/// stats-retaining version history, and the rollback-target candidate
/// list.
struct SlotState {
    q: Mutex<QueueState>,
    cv: Condvar,
    cur: RwLock<Arc<VersionState>>,
    versions: Mutex<Vec<Arc<VersionState>>>,
    workers: usize,
    queue_depth: usize,
    quarantine_after: u32,
}

impl SlotState {
    fn cur(&self) -> Arc<VersionState> {
        Arc::clone(&rlock(&self.cur))
    }

    /// Reroute the slot away from `failed` (already quarantined) to the
    /// newest non-quarantined version in its history. No-op when `failed`
    /// is no longer serving (a concurrent swap beat us) or no healthy
    /// target exists — in the latter case the slot keeps answering with
    /// [`ServeError::VersionQuarantined`] until an operator swaps in a
    /// fixed version. Returns the version now serving, if rerouted.
    fn rollback_from(&self, failed: &Arc<VersionState>) -> Option<u32> {
        let versions = lock(&self.versions);
        let mut cur = self.cur.write().unwrap_or_else(|e| e.into_inner());
        if !Arc::ptr_eq(&cur, failed) {
            return None;
        }
        let target = versions
            .iter()
            .rev()
            .find(|v| !Arc::ptr_eq(v, failed) && v.health() != Health::Quarantined)?;
        *cur = Arc::clone(target);
        Some(target.version)
    }
}

/// Post-drain cleanup, run on both normal exit and unwind: answer any
/// request the drain left unanswered, release the drain flag, and wake
/// every waiter. `run_batch` catches engine panics itself, so this firing
/// on the unwind path means something outside the batch broke — the
/// leftovers are still billed as failures so the counter identity holds.
struct DrainGuard<'a> {
    m: &'a SlotState,
    reqs: &'a [Request],
    vs: &'a Arc<VersionState>,
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        let now = Instant::now();
        let mut leaked: Vec<&Request> = Vec::new();
        for r in self.reqs {
            if !r.slot.is_done() {
                r.slot.fill(Err(ServeError::BatchPanicked(
                    "drain panicked while executing this batch".to_string(),
                )));
                leaked.push(r);
            }
        }
        if !leaked.is_empty() {
            let mut stats = lock(&self.vs.stats);
            stats.failures += leaked.len() as u64;
            for r in &leaked {
                stats.latency.record(us_since(r.enqueued, now));
            }
        }
        lock(&self.m.q).draining = false;
        self.m.cv.notify_all();
    }
}

/// Multi-model batched inference server (see the module docs for the
/// queue, execution, pooling, failure-domain, and hot-swap contracts).
pub struct Server {
    models: BTreeMap<(String, u32), SlotState>,
}

impl Server {
    /// Build a server from a populated [`Registry`].
    pub fn new(registry: Registry, cfg: ServeConfig) -> Server {
        let workers = if cfg.workers == 0 {
            pool::default_workers()
        } else {
            // explicit overrides get the same generous ceiling as
            // SYMOG_WORKERS (see the cap rationale in util::pool)
            cfg.workers.min(pool::ENV_WORKERS_CAP)
        };
        let quarantine_after = if cfg.quarantine_after == 0 {
            DEFAULT_QUARANTINE_AFTER
        } else {
            cfg.quarantine_after
        };
        let models = registry
            .into_entries()
            .into_iter()
            .map(|(key, entry)| {
                let vs = VersionState::install(key.version, entry, workers, quarantine_after);
                let state = SlotState {
                    q: Mutex::new(QueueState { pending: VecDeque::new(), draining: false }),
                    cv: Condvar::new(),
                    versions: Mutex::new(vec![Arc::clone(&vs)]),
                    cur: RwLock::new(vs),
                    workers,
                    queue_depth: cfg.queue_depth,
                    quarantine_after,
                };
                (key.slot(), state)
            })
            .collect();
        Server { models }
    }

    fn slot(&self, key: &ModelKey) -> Result<&SlotState> {
        self.models
            .get(&key.slot())
            .with_context(|| format!("model {}@w{} is not registered", key.name, key.n_bits))
    }

    /// Install a new version into `key`'s slot atomically: queued and
    /// in-flight requests keep draining (on the old version if their drain
    /// already pinned it), new drains serve the new version. Validated:
    /// the slot must exist, the bit width and I/O geometry must match, the
    /// version must be strictly newer than *every* version the slot has
    /// ever installed (so rollback can never be undone by reinstalling the
    /// same number), and the incoming plan must survive a probe row —
    /// a version that cannot execute is refused before it can serve.
    /// Unpinned in-code sources get `max installed + 1`; artifacts bring
    /// their own version. Returns the installed key.
    pub fn swap(
        &self,
        key: &ModelKey,
        source: ModelSource<'_>,
        opts: &RegisterOpts,
    ) -> Result<ModelKey> {
        let slot = self.slot(key)?;
        // install lock: swaps are serialized per slot; serving never takes it
        let mut versions = lock(&slot.versions);
        let max_v = versions.iter().map(|v| v.version).max().unwrap_or(0);
        let (new_key, entry) = registry::build_entry(&key.name, &source, opts, max_v + 1)?;
        ensure!(
            new_key.n_bits == key.n_bits,
            "{}: swap cannot change the bit width (slot is w{}, source is w{})",
            key.name,
            key.n_bits,
            new_key.n_bits
        );
        ensure!(
            new_key.version > max_v,
            "{new_key}: swap version must exceed every installed version (max v{max_v})"
        );
        let cur = slot.cur();
        ensure!(
            entry.in_elems == cur.entry.in_elems && entry.out_per_img == cur.entry.out_per_img,
            "{new_key}: swap cannot change model geometry ({}->{} in, {}->{} out)",
            cur.entry.in_elems,
            entry.in_elems,
            cur.entry.out_per_img,
            entry.out_per_img
        );
        // probe row: one zero-image forward through the incoming plan,
        // with panics contained — a version that cannot execute a single
        // row must never become the serving version
        let probed = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
            if fault::fire(fault::SERVE_SWAP_PROBE) {
                bail!("injected fault: {}", fault::SERVE_SWAP_PROBE);
            }
            let mut scratches = vec![entry.plan.scratch_for(1)];
            let mut out = vec![0f32; entry.out_per_img];
            entry.plan.run_rows(&vec![0f32; entry.in_elems], 1, &mut scratches, &mut out)
        }));
        match probed {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                return Err(e.context(format!(
                    "{new_key}: probe row failed — refusing to install, v{} keeps serving",
                    cur.version
                )))
            }
            Err(p) => bail!(
                "{new_key}: probe row panicked ({}) — refusing to install, v{} keeps serving",
                panic_message(p),
                cur.version
            ),
        }
        let vs = VersionState::install(new_key.version, entry, slot.workers, slot.quarantine_after);
        *slot.cur.write().unwrap_or_else(|e| e.into_inner()) = Arc::clone(&vs);
        versions.push(vs);
        Ok(new_key)
    }

    /// Manually quarantine the serving version and reroute the slot to
    /// its newest non-quarantined predecessor (the same path a tripped
    /// circuit breaker takes automatically). Fails — leaving the slot
    /// serving untouched — when no rollback target exists. Returns the
    /// version serving after the rollback.
    pub fn rollback(&self, key: &ModelKey) -> Result<u32> {
        let slot = self.slot(key)?;
        let cur = slot.cur();
        {
            // refuse before quarantining: a rollback that would strand the
            // slot with zero healthy versions must leave it serving
            let versions = lock(&slot.versions);
            ensure!(
                versions
                    .iter()
                    .any(|v| !Arc::ptr_eq(v, &cur) && v.health() != Health::Quarantined),
                "{key}: no last-good version to roll back to from v{}",
                cur.version
            );
        }
        cur.breaker.quarantine();
        // None here means a concurrent swap replaced `cur` between the
        // check and the reroute — the slot is already on a newer version
        Ok(slot.rollback_from(&cur).unwrap_or_else(|| slot.cur().version))
    }

    /// Health of the currently serving version.
    pub fn health(&self, key: &ModelKey) -> Result<Health> {
        Ok(self.slot(key)?.cur().health())
    }

    /// Per-version health in install order (the companion of
    /// [`Server::stats_by_version`]).
    pub fn health_by_version(&self, key: &ModelKey) -> Result<Vec<(u32, Health)>> {
        Ok(lock(&self.slot(key)?.versions).iter().map(|vs| (vs.version, vs.health())).collect())
    }

    /// Registered keys at their *currently serving* versions, in
    /// deterministic (sorted) order.
    pub fn keys(&self) -> Vec<ModelKey> {
        self.models
            .iter()
            .map(|((name, bits), s)| ModelKey::versioned(name.clone(), *bits, s.cur().version))
            .collect()
    }

    /// The version currently serving `key`'s slot.
    pub fn current_version(&self, key: &ModelKey) -> Result<u32> {
        Ok(self.slot(key)?.cur().version)
    }

    /// The micro-batch cap of the currently serving version.
    pub fn max_batch(&self, key: &ModelKey) -> Result<usize> {
        Ok(self.slot(key)?.cur().entry.max_batch)
    }

    /// Totals across every version this slot has served (the pre-hot-swap
    /// semantics: one model, all its traffic).
    pub fn stats(&self, key: &ModelKey) -> Result<ModelStats> {
        let mut total = ModelStats::default();
        for vs in lock(&self.slot(key)?.versions).iter() {
            total.merge(&lock(&vs.stats));
        }
        Ok(total)
    }

    /// Per-version stats in install order. Counters partition exactly:
    /// every request (and every shed, sweep, and failure) is billed to
    /// precisely the version it was refused or executed under.
    pub fn stats_by_version(&self, key: &ModelKey) -> Result<Vec<(u32, ModelStats)>> {
        Ok(lock(&self.slot(key)?.versions)
            .iter()
            .map(|vs| (vs.version, lock(&vs.stats).clone()))
            .collect())
    }

    /// Canonical (sorted) fingerprint set of the currently serving
    /// version's allocations: every pooled row scratch plus the
    /// gather/scatter staging buffers. With no request in flight, two
    /// equal snapshots prove zero steady-state allocation in the serving
    /// engine.
    pub fn pool_fingerprints(&self, key: &ModelKey) -> Result<Vec<Vec<(usize, usize)>>> {
        let vs = self.slot(key)?.cur();
        let mut fps = vs.pool.fingerprints();
        let b = lock(&vs.bufs);
        fps.push(vec![
            (b.gather.as_ptr() as usize, b.gather.capacity()),
            (b.logits.as_ptr() as usize, b.logits.capacity()),
        ]);
        fps.sort();
        Ok(fps)
    }

    /// Classify one image, blocking until its logits are ready. See
    /// [`Server::infer_with`]; this drops the version tag.
    pub fn infer(&self, key: &ModelKey, image: &[f32]) -> Result<Vec<f32>> {
        self.infer_versioned(key, image).map(|(logits, _)| logits)
    }

    /// [`Server::infer_with`] with default options (no deadline).
    pub fn infer_versioned(&self, key: &ModelKey, image: &[f32]) -> Result<(Vec<f32>, u32)> {
        self.infer_with(key, image, &InferOpts::default())
    }

    /// Classify one image, blocking until its terminal outcome is ready.
    /// The call enqueues the request and then *participates*: whichever
    /// caller finds the queue ready first drains and executes the
    /// micro-batch containing it (leader/follower — no dedicated executor
    /// thread, no timer). Returns the logits plus the version that served
    /// them — bit-identical to a solo planned forward on that version —
    /// or an error whose source downcasts to [`ServeError`] (shed /
    /// deadline / batch failure / quarantine / bad request). The key's
    /// own `version` field is ignored for routing: a slot always serves
    /// its current version.
    pub fn infer_with(
        &self,
        key: &ModelKey,
        image: &[f32],
        opts: &InferOpts,
    ) -> Result<(Vec<f32>, u32)> {
        let m = self.slot(key)?;
        let vs0 = m.cur();
        let fail = |e: ServeError| anyhow::Error::new(e).context(key.to_string());
        if vs0.health() == Health::Quarantined {
            // quarantined with no rollback target: fail fast, and keep the
            // counter identity — the refusal is billed as a failure, with
            // a 0µs latency sample (resolved at the instant it would have
            // enqueued) so `latency.count == requests+timeouts+failures`
            // stays exact on this path too
            let mut stats = lock(&vs0.stats);
            stats.failures += 1;
            stats.latency.record(0);
            return Err(fail(ServeError::VersionQuarantined(vs0.version)));
        }
        let in_elems = vs0.entry.in_elems;
        if image.len() != in_elems {
            return Err(fail(ServeError::BadRequest(format!(
                "image has {} elements, model expects {in_elems}",
                image.len()
            ))));
        }
        let slot = Arc::new(Slot::default());
        {
            let mut q = lock(&m.q);
            // admission control: shed at enqueue, not at drain — a full
            // queue refuses new work instead of stretching everyone's tail
            if m.queue_depth > 0 && q.pending.len() >= m.queue_depth {
                drop(q);
                lock(&vs0.stats).sheds += 1;
                return Err(fail(ServeError::Shed { depth: m.queue_depth }));
            }
            q.pending.push_back(Request {
                image: image.to_vec(),
                slot: Arc::clone(&slot),
                deadline: opts.deadline,
                enqueued: Instant::now(),
            });
        }
        loop {
            // decide under the queue lock: return, drain, or wait. The
            // done-check happens with the lock held so a completion that
            // races this loop is never missed (the completing drainer must
            // take the queue lock before it notifies). Becoming drainer
            // also pins the serving version for the whole micro-batch.
            let drained: Option<(Vec<Request>, Arc<VersionState>)> = {
                let mut q = lock(&m.q);
                loop {
                    if slot.is_done() {
                        break None;
                    }
                    if !q.draining && !q.pending.is_empty() {
                        q.draining = true;
                        let vs = m.cur();
                        let k = q.pending.len().min(vs.entry.max_batch);
                        break Some((q.pending.drain(..k).collect(), vs));
                    }
                    q = m.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                }
            };
            match drained {
                None => {
                    let res = slot.take().expect("slot checked done under the lock");
                    return res.map_err(fail);
                }
                Some((reqs, vs)) => {
                    // the guard also covers unwinding: if anything below
                    // panics, unanswered slots get a typed error, the flag
                    // resets, followers wake — instead of wedging the
                    // model behind draining == true
                    let guard = DrainGuard { m, reqs: &reqs, vs: &vs };
                    // deadline sweep: requests already expired when the
                    // drain forms its batch are never executed
                    let now = Instant::now();
                    let mut live: Vec<&Request> = Vec::with_capacity(reqs.len());
                    let mut swept: Vec<&Request> = Vec::new();
                    for r in &reqs {
                        if r.deadline.is_some_and(|d| d <= now) {
                            r.slot.fill(Err(ServeError::DeadlineExceeded));
                            swept.push(r);
                        } else {
                            live.push(r);
                        }
                    }
                    if !swept.is_empty() {
                        let mut stats = lock(&vs.stats);
                        stats.timeouts += swept.len() as u64;
                        for r in &swept {
                            stats.latency.record(us_since(r.enqueued, now));
                        }
                    }
                    let tripped = if live.is_empty() {
                        false
                    } else if vs.health() == Health::Quarantined {
                        // the breaker tripped between pinning and running
                        // (or no rollback target exists): resolve, don't run
                        let now = Instant::now();
                        for r in &live {
                            r.slot.fill(Err(ServeError::VersionQuarantined(vs.version)));
                        }
                        let mut stats = lock(&vs.stats);
                        stats.failures += live.len() as u64;
                        for r in &live {
                            stats.latency.record(us_since(r.enqueued, now));
                        }
                        false
                    } else {
                        vs.run_batch(&live)
                    };
                    drop(guard);
                    if tripped {
                        // automatic rollback: the slot reroutes to its
                        // newest non-quarantined version; future drains
                        // (including ours, if our request is still queued)
                        // pin the rolled-back version
                        m.rollback_from(&vs);
                    }
                    // loop back: our own request was either in this batch
                    // or is now closer to the queue front
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::IntModel;
    use crate::testing::models;
    use crate::util::rng::Rng;

    fn lenet_server(n_bits: u32) -> (Server, ModelKey, IntModel, usize) {
        let mut rng = Rng::new(0x5E);
        let (man, ck) = models::lenet5ish(&mut rng, n_bits);
        let model = IntModel::build(&man, &ck).unwrap();
        let solo = IntModel::build(&man, &ck).unwrap();
        let elems: usize = man.input_shape.iter().product();
        let mut reg = Registry::new();
        let key = reg
            .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(4))
            .unwrap();
        (Server::new(reg, ServeConfig::new().workers(2)), key, solo, elems)
    }

    #[test]
    fn single_caller_matches_solo_forward_and_counts() {
        let (server, key, solo, elems) = lenet_server(2);
        let mut rng = Rng::new(7);
        for i in 0..5u64 {
            let img: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
            let (got, v) = server.infer_versioned(&key, &img).unwrap();
            let (want, _) = solo.forward(&img, 1).unwrap();
            assert_eq!(got, want, "request {i} diverged from solo forward");
            assert_eq!(v, 1, "fresh registration serves version 1");
        }
        let stats = server.stats(&key).unwrap();
        assert_eq!(stats.requests, 5);
        // a lone caller never queues behind itself: every batch is size 1
        assert_eq!(stats.batches, 5);
        assert_eq!(stats.max_occupancy, 1);
        assert_eq!((stats.sheds, stats.timeouts, stats.failures), (0, 0, 0));
        assert_eq!(server.health(&key).unwrap(), Health::Ready);
        let per_row = solo.cost_report(1).unwrap().counts;
        let mut want_counts = crate::inference::OpCounts::default();
        for _ in 0..5 {
            want_counts.merge(&per_row);
        }
        assert_eq!(stats.op_counts, want_counts);
    }

    #[test]
    fn rejects_unknown_model_and_bad_image() {
        let (server, key, _, elems) = lenet_server(2);
        let img = vec![0f32; elems];
        let missing = ModelKey::new("nope", 2);
        assert!(server.infer(&missing, &img).is_err());
        assert!(server.stats(&missing).is_err());
        let short = server.infer(&key, &img[..elems - 1]).unwrap_err();
        match short.downcast_ref::<ServeError>() {
            Some(ServeError::BadRequest(msg)) => {
                assert!(msg.contains("model expects"), "{msg}")
            }
            other => panic!("geometry rejection must be typed BadRequest, got {other:?}"),
        }
        // the key's version field does not affect routing
        let stale = ModelKey::versioned(key.name.clone(), key.n_bits, 99);
        assert!(server.infer(&stale, &img).is_ok());
    }

    #[test]
    fn swap_validates_version_geometry_and_probes() {
        let (server, key, _, _) = lenet_server(2);
        let mut rng = Rng::new(0x5F);
        let (man, ck) = models::lenet5ish(&mut rng, 2);
        let next = IntModel::build(&man, &ck).unwrap();
        // unpinned in-code swap: max installed + 1
        let opts = RegisterOpts::new().max_batch(4);
        let k2 = server.swap(&key, ModelSource::InCode(&next), &opts).unwrap();
        assert_eq!(k2.version, 2);
        assert_eq!(server.current_version(&key).unwrap(), 2);
        // stale or equal versions are rejected
        let pin1 = RegisterOpts::new().max_batch(4).version(2);
        assert!(server.swap(&key, ModelSource::InCode(&next), &pin1).is_err());
        // geometry changes are rejected
        let (man_b, ck_b) = models::densenetish(&mut rng, 2);
        let other = IntModel::build(&man_b, &ck_b).unwrap();
        assert!(server.swap(&key, ModelSource::InCode(&other), &RegisterOpts::new()).is_err());
        // unknown slots are rejected
        let missing = ModelKey::new("nope", 2);
        assert!(server.swap(&missing, ModelSource::InCode(&next), &RegisterOpts::new()).is_err());
    }

    #[test]
    fn expired_deadline_is_swept_not_executed() {
        let (server, key, _, elems) = lenet_server(2);
        let img = vec![0f32; elems];
        let past = InferOpts::new().deadline_at(Instant::now() - Duration::from_secs(1));
        let err = server.infer_with(&key, &img, &past).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ServeError>(),
            Some(&ServeError::DeadlineExceeded),
            "{err:#}"
        );
        let stats = server.stats(&key).unwrap();
        assert_eq!((stats.requests, stats.timeouts), (0, 1), "swept request must never execute");
        // a generous deadline serves normally
        let soon = InferOpts::new().deadline_in(Duration::from_secs(3600));
        server.infer_with(&key, &img, &soon).unwrap();
        assert_eq!(server.stats(&key).unwrap().requests, 1);
    }

    #[test]
    fn latency_histogram_counts_every_resolved_request() {
        let (server, key, _, elems) = lenet_server(2);
        let img = vec![0f32; elems];
        for _ in 0..4 {
            server.infer(&key, &img).unwrap();
        }
        // one swept deadline joins the sample set; a shed would not (it
        // never enqueues), but this config is unbounded so none occur
        let past = InferOpts::new().deadline_at(Instant::now() - Duration::from_secs(1));
        let _ = server.infer_with(&key, &img, &past).unwrap_err();
        let s = server.stats(&key).unwrap();
        assert_eq!((s.requests, s.timeouts, s.failures), (4, 1, 0));
        assert_eq!(
            s.latency.count(),
            s.requests + s.timeouts + s.failures,
            "every enqueued terminal outcome must deposit exactly one latency sample"
        );
        assert!(s.latency.p50_us() <= s.latency.p99_us());
        assert!(s.latency.p99_us() <= s.latency.max_us());
        assert!(s.render().contains("latency p50"), "{}", s.render());
    }

    #[test]
    fn manual_rollback_requires_a_last_good_version() {
        let (server, key, _, elems) = lenet_server(2);
        // v1 is the only version: rollback refuses and the slot still serves
        let err = server.rollback(&key).unwrap_err().to_string();
        assert!(err.contains("no last-good version"), "{err}");
        assert!(server.infer(&key, &vec![0f32; elems]).is_ok());
        assert_eq!(server.health(&key).unwrap(), Health::Ready);
    }
}
