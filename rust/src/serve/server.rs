//! The synchronous batched inference server.
//!
//! ## Queue / flush policy (wall-clock-free)
//!
//! Callers block in [`Server::infer`]. Each request is appended to its
//! model's FIFO submission queue; the first caller that finds the queue
//! non-empty with no drain in flight becomes the **drainer**: it takes
//! `min(pending, max_batch)` requests — the whole queue when traffic is
//! light, a full micro-batch under saturation — executes them, scatters
//! the logits back into each request's response slot, and wakes everyone.
//! Flushing is therefore triggered purely by queue state (size watermark
//! `max_batch`, or the executor going idle with work pending): there is no
//! timer anywhere, so a given arrival order produces a reproducible batch
//! partition — the property the conformance suite leans on. Drains are
//! serialized per model (concurrency comes from row fan-out inside a
//! batch and from other models); while a drain runs, new arrivals queue
//! up and coalesce into the next micro-batch.
//!
//! ## Execution and the bit-exactness contract
//!
//! A drained micro-batch is gathered into a preallocated per-model buffer
//! and driven through [`ExecPlan::run_rows`], which executes every row at
//! batch 1 with per-request requantization isolation. Consequence: each
//! response is **bit-identical to a solo `Backend::Planned` forward** of
//! that request, independent of arrival order, batch composition, or
//! thread count (`tests/serve_conformance.rs`, `tests/serve_concurrency.rs`).
//!
//! ## Scratch-pool lifecycle
//!
//! Row scratches (`ExecPlan::scratch_for(1)`) live in a bounded per-model
//! [`ScratchPool`], filled *eagerly* at construction: `Server::new`
//! creates exactly `workers` row scratches per model, a drain checks out
//! up to `workers.min(rows)` of them and returns every one afterwards,
//! and nothing ever creates more. The pool plus the preallocated
//! gather/scatter buffers are therefore a fixed set of allocations from
//! construction onward — serving performs zero steady-state growth,
//! asserted via [`Server::pool_fingerprints`]. (Eager beats lazy here for
//! determinism: a lazily-warmed pool's final size would depend on whether
//! early traffic ever happened to coalesce a full-width batch.)
//!
//! [`ExecPlan::run_rows`]: crate::inference::ExecPlan::run_rows

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use anyhow::{anyhow, ensure, Context, Result};

use crate::inference::ScratchPool;
use crate::util::pool;

use super::registry::{ModelEntry, ModelKey, Registry};
use super::stats::ModelStats;

/// Server-wide tuning knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeConfig {
    /// Row-parallel workers per micro-batch, which is also each model's
    /// scratch-pool bound. 0 (the default) resolves to
    /// `util::pool::default_workers()` (`SYMOG_WORKERS` honored).
    pub workers: usize,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Response rendezvous for one request. Filled exactly once by whichever
/// caller drains the batch containing the request.
#[derive(Default)]
struct Slot {
    done: Mutex<Option<Result<Vec<f32>, String>>>,
}

impl Slot {
    fn fill(&self, r: Result<Vec<f32>, String>) {
        *lock(&self.done) = Some(r);
    }

    fn is_done(&self) -> bool {
        lock(&self.done).is_some()
    }

    fn take(&self) -> Option<Result<Vec<f32>, String>> {
        lock(&self.done).take()
    }
}

struct Request {
    image: Vec<f32>,
    slot: Arc<Slot>,
}

struct QueueState {
    pending: VecDeque<Request>,
    /// true while some caller is executing a drained micro-batch
    draining: bool,
}

/// Preallocated gather/scatter staging for one model (drains are
/// serialized per model, so one pair suffices and is never contended).
struct ExecBufs {
    gather: Vec<f32>,
    logits: Vec<f32>,
}

struct ModelState {
    entry: ModelEntry,
    q: Mutex<QueueState>,
    cv: Condvar,
    pool: ScratchPool,
    bufs: Mutex<ExecBufs>,
    stats: Mutex<ModelStats>,
    workers: usize,
}

impl ModelState {
    /// Execute one drained micro-batch: gather rows, run with per-request
    /// isolation, scatter logits into the response slots, record stats.
    fn run_batch(&self, reqs: &[Request]) {
        let k = reqs.len();
        let (ie, oe) = (self.entry.in_elems, self.entry.out_per_img);
        let want = self.workers.min(k);
        let mut scratches = self.pool.checkout(want, &mut || self.entry.plan.scratch_for(1));
        if scratches.is_empty() {
            // unreachable while drains are serialized (the pool bound is
            // >= 1 and every drain returns its scratches), but stay safe
            scratches.push(self.entry.plan.scratch_for(1));
        }
        let mut bufs = lock(&self.bufs);
        for (i, r) in reqs.iter().enumerate() {
            bufs.gather[i * ie..(i + 1) * ie].copy_from_slice(&r.image);
        }
        let ExecBufs { gather, logits } = &mut *bufs;
        match self.entry.plan.run_rows(
            &gather[..k * ie],
            k,
            &mut scratches,
            &mut logits[..k * oe],
        ) {
            Ok(()) => {
                for (i, r) in reqs.iter().enumerate() {
                    r.slot.fill(Ok(logits[i * oe..(i + 1) * oe].to_vec()));
                }
                let counts = self.entry.plan.op_counts(k);
                lock(&self.stats).record_batch(k as u64, self.entry.max_batch as u64, &counts);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in reqs {
                    r.slot.fill(Err(msg.clone()));
                }
            }
        }
        drop(bufs);
        self.pool.put_all(scratches);
    }
}

/// Post-drain cleanup, run on both normal exit and unwind: answer any
/// request the drain left unanswered, release the drain flag, and wake
/// every waiter. Without this a panic inside a micro-batch would leave
/// `draining == true` forever, deadlocking all present and future callers
/// of the model.
struct DrainGuard<'a> {
    m: &'a ModelState,
    reqs: &'a [Request],
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        for r in self.reqs {
            if !r.slot.is_done() {
                r.slot.fill(Err("drain panicked while executing this batch".to_string()));
            }
        }
        lock(&self.m.q).draining = false;
        self.m.cv.notify_all();
    }
}

/// Multi-model batched inference server (see the module docs for the
/// queue, execution, and pooling contracts).
pub struct Server {
    models: BTreeMap<ModelKey, ModelState>,
}

impl Server {
    /// Build a server from a populated [`Registry`].
    pub fn new(registry: Registry, cfg: ServeConfig) -> Server {
        let workers = if cfg.workers == 0 {
            pool::default_workers()
        } else {
            cfg.workers.min(64)
        };
        let models = registry
            .into_entries()
            .into_iter()
            .map(|(key, entry)| {
                let state = ModelState {
                    q: Mutex::new(QueueState { pending: VecDeque::new(), draining: false }),
                    cv: Condvar::new(),
                    pool: ScratchPool::new(workers),
                    bufs: Mutex::new(ExecBufs {
                        gather: vec![0f32; entry.max_batch * entry.in_elems],
                        logits: vec![0f32; entry.max_batch * entry.out_per_img],
                    }),
                    stats: Mutex::new(ModelStats::default()),
                    workers,
                    entry,
                };
                // eager fill: the pool is a fixed allocation set from day 0.
                // Seeded *through* checkout so these scratches count toward
                // the pool's lifetime-creation bound — the "nothing ever
                // creates more" contract holds by construction, not just
                // because drains happen to be serialized
                let mut mk = || state.entry.plan.scratch_for(1);
                let seed = state.pool.checkout(workers, &mut mk);
                state.pool.put_all(seed);
                (key, state)
            })
            .collect();
        Server { models }
    }

    fn model(&self, key: &ModelKey) -> Result<&ModelState> {
        self.models
            .get(key)
            .with_context(|| format!("model {key} is not registered"))
    }

    /// Registered keys, in deterministic (sorted) order.
    pub fn keys(&self) -> Vec<ModelKey> {
        self.models.keys().cloned().collect()
    }

    /// The micro-batch cap `key` was registered with.
    pub fn max_batch(&self, key: &ModelKey) -> Result<usize> {
        Ok(self.model(key)?.entry.max_batch)
    }

    /// Snapshot of the model's running stats.
    pub fn stats(&self, key: &ModelKey) -> Result<ModelStats> {
        Ok(lock(&self.model(key)?.stats).clone())
    }

    /// Canonical (sorted) fingerprint set of the model's serving
    /// allocations: every pooled row scratch plus the gather/scatter
    /// staging buffers. With no request in flight, two equal snapshots
    /// prove zero steady-state allocation in the serving engine.
    pub fn pool_fingerprints(&self, key: &ModelKey) -> Result<Vec<Vec<(usize, usize)>>> {
        let m = self.model(key)?;
        let mut fps = m.pool.fingerprints();
        let b = lock(&m.bufs);
        fps.push(vec![
            (b.gather.as_ptr() as usize, b.gather.capacity()),
            (b.logits.as_ptr() as usize, b.logits.capacity()),
        ]);
        fps.sort();
        Ok(fps)
    }

    /// Classify one image, blocking until its logits are ready. The call
    /// enqueues the request and then *participates*: whichever caller
    /// finds the queue ready first drains and executes the micro-batch
    /// containing it (leader/follower — no dedicated executor thread, no
    /// timer). Returns the request's logits, bit-identical to a solo
    /// planned forward of `image`.
    pub fn infer(&self, key: &ModelKey, image: &[f32]) -> Result<Vec<f32>> {
        let m = self.model(key)?;
        ensure!(
            image.len() == m.entry.in_elems,
            "{key}: image has {} elements, model expects {}",
            image.len(),
            m.entry.in_elems
        );
        let slot = Arc::new(Slot::default());
        {
            let mut q = lock(&m.q);
            q.pending.push_back(Request { image: image.to_vec(), slot: Arc::clone(&slot) });
        }
        loop {
            // decide under the queue lock: return, drain, or wait. The
            // done-check happens with the lock held so a completion that
            // races this loop is never missed (the completing drainer must
            // take the queue lock before it notifies).
            let drained: Option<Vec<Request>> = {
                let mut q = lock(&m.q);
                loop {
                    if slot.is_done() {
                        break None;
                    }
                    if !q.draining && !q.pending.is_empty() {
                        q.draining = true;
                        let k = q.pending.len().min(m.entry.max_batch);
                        break Some(q.pending.drain(..k).collect());
                    }
                    q = m.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                }
            };
            match drained {
                None => {
                    let res = slot.take().expect("slot checked done under the lock");
                    return res.map_err(|msg| anyhow!("{key}: {msg}"));
                }
                Some(reqs) => {
                    // the guard also covers unwinding: if the drain panics
                    // (kernel bug mid-batch), fail this batch — unfilled
                    // slots get an error, the flag resets, followers wake —
                    // instead of wedging the model behind draining == true
                    let guard = DrainGuard { m, reqs: &reqs };
                    m.run_batch(&reqs);
                    drop(guard);
                    // loop back: our own request was either in this batch
                    // or is now closer to the queue front
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::IntModel;
    use crate::testing::models;
    use crate::util::rng::Rng;

    fn lenet_server(n_bits: u32) -> (Server, ModelKey, IntModel, usize) {
        let mut rng = Rng::new(0x5E);
        let (man, ck) = models::lenet5ish(&mut rng, n_bits);
        let model = IntModel::build(&man, &ck).unwrap();
        let solo = IntModel::build(&man, &ck).unwrap();
        let elems: usize = man.input_shape.iter().product();
        let mut reg = Registry::new();
        let key = reg.register("lenet5", &model, 4).unwrap();
        (Server::new(reg, ServeConfig { workers: 2 }), key, solo, elems)
    }

    #[test]
    fn single_caller_matches_solo_forward_and_counts() {
        let (server, key, solo, elems) = lenet_server(2);
        let mut rng = Rng::new(7);
        for i in 0..5u64 {
            let img: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
            let got = server.infer(&key, &img).unwrap();
            let (want, _) = solo.forward(&img, 1).unwrap();
            assert_eq!(got, want, "request {i} diverged from solo forward");
        }
        let stats = server.stats(&key).unwrap();
        assert_eq!(stats.requests, 5);
        // a lone caller never queues behind itself: every batch is size 1
        assert_eq!(stats.batches, 5);
        assert_eq!(stats.max_occupancy, 1);
        let per_row = solo.cost_report(1).unwrap().counts;
        let mut want_counts = crate::inference::OpCounts::default();
        for _ in 0..5 {
            want_counts.merge(&per_row);
        }
        assert_eq!(stats.op_counts, want_counts);
    }

    #[test]
    fn rejects_unknown_model_and_bad_image() {
        let (server, key, _, elems) = lenet_server(2);
        let img = vec![0f32; elems];
        let missing = ModelKey::new("nope", 2);
        assert!(server.infer(&missing, &img).is_err());
        assert!(server.stats(&missing).is_err());
        assert!(server.infer(&key, &img[..elems - 1]).is_err());
    }
}
