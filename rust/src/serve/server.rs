//! The synchronous batched inference server, with atomic hot-swap.
//!
//! ## Queue / flush policy (wall-clock-free)
//!
//! Callers block in [`Server::infer`]. Each request is appended to its
//! model slot's FIFO submission queue; the first caller that finds the
//! queue non-empty with no drain in flight becomes the **drainer**: it
//! takes `min(pending, max_batch)` requests — the whole queue when
//! traffic is light, a full micro-batch under saturation — executes them,
//! scatters the logits back into each request's response slot, and wakes
//! everyone. Flushing is therefore triggered purely by queue state (size
//! watermark `max_batch`, or the executor going idle with work pending):
//! there is no timer anywhere, so a given arrival order produces a
//! reproducible batch partition — the property the conformance suite
//! leans on. Drains are serialized per slot (concurrency comes from row
//! fan-out inside a batch and from other models); while a drain runs, new
//! arrivals queue up and coalesce into the next micro-batch.
//!
//! ## Versioned slots and hot-swap
//!
//! A server slot is `(name, n_bits)`; what it *serves* is a
//! [`VersionState`] — plan, scratch pool, staging buffers, and stats for
//! one deployment generation — behind an `RwLock<Arc<VersionState>>`
//! ([`Server::swap`] is the writer). A drainer pins the current `Arc` at
//! the moment it takes its requests, so a swap never pauses traffic and
//! never drops a request: in-flight drains finish on the version they
//! pinned while new drains pick up the new one, and each response (and
//! its stats) is attributed to exactly the version that executed it —
//! still bit-identical to a solo forward on that version. Retired
//! versions stay resident only for their stats
//! ([`Server::stats_by_version`]); swaps are rare control-plane events,
//! serialized by the slot's install lock, and validated for monotonically
//! increasing versions and identical I/O geometry.
//!
//! ## Execution and the bit-exactness contract
//!
//! A drained micro-batch is gathered into a preallocated per-version
//! buffer and driven through [`ExecPlan::run_rows`], which executes every
//! row at batch 1 with per-request requantization isolation. Consequence:
//! each response is **bit-identical to a solo `Backend::Planned` forward**
//! of that request on the version that served it, independent of arrival
//! order, batch composition, thread count, or concurrent swaps
//! (`tests/serve_conformance.rs`, `tests/serve_concurrency.rs`,
//! `tests/hot_swap.rs`).
//!
//! ## Scratch-pool lifecycle
//!
//! Row scratches (`ExecPlan::scratch_for(1)`) live in a bounded
//! per-version [`ScratchPool`], filled *eagerly* when the version is
//! installed (`Server::new` and `Server::swap` both create exactly
//! `workers` row scratches per version): a drain checks out up to
//! `workers.min(rows)` of them and returns every one afterwards, and
//! nothing ever creates more. The pool plus the preallocated
//! gather/scatter buffers are therefore a fixed set of allocations from
//! install onward — serving performs zero steady-state growth, asserted
//! via [`Server::pool_fingerprints`]. (Eager beats lazy here for
//! determinism: a lazily-warmed pool's final size would depend on whether
//! early traffic ever happened to coalesce a full-width batch.)
//!
//! [`ExecPlan::run_rows`]: crate::inference::ExecPlan::run_rows

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard};

use anyhow::{anyhow, ensure, Context, Result};

use crate::inference::ScratchPool;
use crate::util::pool;

use super::registry::{self, ModelEntry, ModelKey, ModelSource, RegisterOpts, Registry};
use super::stats::ModelStats;

/// Server-wide tuning knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeConfig {
    /// Row-parallel workers per micro-batch, which is also each version's
    /// scratch-pool bound. 0 (the default) resolves to
    /// `util::pool::default_workers()` (`SYMOG_WORKERS` honored).
    pub workers: usize,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn rlock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Response rendezvous for one request. Filled exactly once by whichever
/// caller drains the batch containing the request; carries the serving
/// version the drain was pinned to.
#[derive(Default)]
struct Slot {
    done: Mutex<Option<Result<(Vec<f32>, u32), String>>>,
}

impl Slot {
    fn fill(&self, r: Result<(Vec<f32>, u32), String>) {
        *lock(&self.done) = Some(r);
    }

    fn is_done(&self) -> bool {
        lock(&self.done).is_some()
    }

    fn take(&self) -> Option<Result<(Vec<f32>, u32), String>> {
        lock(&self.done).take()
    }
}

struct Request {
    image: Vec<f32>,
    slot: Arc<Slot>,
}

struct QueueState {
    pending: VecDeque<Request>,
    /// true while some caller is executing a drained micro-batch
    draining: bool,
}

/// Preallocated gather/scatter staging for one version (drains are
/// serialized per slot, so one pair suffices and is never contended).
struct ExecBufs {
    gather: Vec<f32>,
    logits: Vec<f32>,
}

/// Everything needed to serve one deployment generation of a model:
/// compiled plan, scratch pool, staging buffers, and its own stats.
struct VersionState {
    version: u32,
    entry: ModelEntry,
    pool: ScratchPool,
    bufs: Mutex<ExecBufs>,
    stats: Mutex<ModelStats>,
    workers: usize,
}

impl VersionState {
    /// Install-time construction: buffers sized for this version's cap,
    /// pool seeded eagerly *through* checkout so the scratches count
    /// toward the pool's lifetime-creation bound — the "nothing ever
    /// creates more" contract holds by construction.
    fn install(version: u32, entry: ModelEntry, workers: usize) -> Arc<VersionState> {
        let vs = VersionState {
            version,
            pool: ScratchPool::new(workers),
            bufs: Mutex::new(ExecBufs {
                gather: vec![0f32; entry.max_batch * entry.in_elems],
                logits: vec![0f32; entry.max_batch * entry.out_per_img],
            }),
            stats: Mutex::new(ModelStats::default()),
            workers,
            entry,
        };
        let mut mk = || vs.entry.plan.scratch_for(1);
        let seed = vs.pool.checkout(workers, &mut mk);
        vs.pool.put_all(seed);
        Arc::new(vs)
    }

    /// Execute one drained micro-batch: gather rows, run with per-request
    /// isolation, scatter logits into the response slots, record stats.
    fn run_batch(&self, reqs: &[Request]) {
        let k = reqs.len();
        let (ie, oe) = (self.entry.in_elems, self.entry.out_per_img);
        let want = self.workers.min(k);
        let mut scratches = self.pool.checkout(want, &mut || self.entry.plan.scratch_for(1));
        if scratches.is_empty() {
            // unreachable while drains are serialized (the pool bound is
            // >= 1 and every drain returns its scratches), but stay safe
            scratches.push(self.entry.plan.scratch_for(1));
        }
        let mut bufs = lock(&self.bufs);
        for (i, r) in reqs.iter().enumerate() {
            bufs.gather[i * ie..(i + 1) * ie].copy_from_slice(&r.image);
        }
        let ExecBufs { gather, logits } = &mut *bufs;
        match self.entry.plan.run_rows(
            &gather[..k * ie],
            k,
            &mut scratches,
            &mut logits[..k * oe],
        ) {
            Ok(()) => {
                for (i, r) in reqs.iter().enumerate() {
                    r.slot.fill(Ok((logits[i * oe..(i + 1) * oe].to_vec(), self.version)));
                }
                let counts = self.entry.plan.op_counts(k);
                lock(&self.stats).record_batch(k as u64, self.entry.max_batch as u64, &counts);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in reqs {
                    r.slot.fill(Err(msg.clone()));
                }
            }
        }
        drop(bufs);
        self.pool.put_all(scratches);
    }
}

/// One `(name, n_bits)` serving slot: the request queue (shared across
/// versions — a swap never disturbs queued work) and the Arc-swapped
/// current version. `versions` doubles as the swap install lock and the
/// stats-retaining version history.
struct SlotState {
    q: Mutex<QueueState>,
    cv: Condvar,
    cur: RwLock<Arc<VersionState>>,
    versions: Mutex<Vec<Arc<VersionState>>>,
    workers: usize,
}

impl SlotState {
    fn cur(&self) -> Arc<VersionState> {
        Arc::clone(&rlock(&self.cur))
    }
}

/// Post-drain cleanup, run on both normal exit and unwind: answer any
/// request the drain left unanswered, release the drain flag, and wake
/// every waiter. Without this a panic inside a micro-batch would leave
/// `draining == true` forever, deadlocking all present and future callers
/// of the model.
struct DrainGuard<'a> {
    m: &'a SlotState,
    reqs: &'a [Request],
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        for r in self.reqs {
            if !r.slot.is_done() {
                r.slot.fill(Err("drain panicked while executing this batch".to_string()));
            }
        }
        lock(&self.m.q).draining = false;
        self.m.cv.notify_all();
    }
}

/// Multi-model batched inference server (see the module docs for the
/// queue, execution, pooling, and hot-swap contracts).
pub struct Server {
    models: BTreeMap<(String, u32), SlotState>,
}

impl Server {
    /// Build a server from a populated [`Registry`].
    pub fn new(registry: Registry, cfg: ServeConfig) -> Server {
        let workers = if cfg.workers == 0 {
            pool::default_workers()
        } else {
            // explicit overrides get the same generous ceiling as
            // SYMOG_WORKERS (see the cap rationale in util::pool)
            cfg.workers.min(pool::ENV_WORKERS_CAP)
        };
        let models = registry
            .into_entries()
            .into_iter()
            .map(|(key, entry)| {
                let vs = VersionState::install(key.version, entry, workers);
                let state = SlotState {
                    q: Mutex::new(QueueState { pending: VecDeque::new(), draining: false }),
                    cv: Condvar::new(),
                    versions: Mutex::new(vec![Arc::clone(&vs)]),
                    cur: RwLock::new(vs),
                    workers,
                };
                (key.slot(), state)
            })
            .collect();
        Server { models }
    }

    fn slot(&self, key: &ModelKey) -> Result<&SlotState> {
        self.models
            .get(&key.slot())
            .with_context(|| format!("model {}@w{} is not registered", key.name, key.n_bits))
    }

    /// Install a new version into `key`'s slot atomically: queued and
    /// in-flight requests keep draining (on the old version if their drain
    /// already pinned it), new drains serve the new version. Validated:
    /// the slot must exist, the bit width and I/O geometry must match, and
    /// the version must be strictly newer than the one serving. Unpinned
    /// in-code sources get `current + 1`; artifacts bring their own
    /// version. Returns the installed key.
    pub fn swap(
        &self,
        key: &ModelKey,
        source: ModelSource<'_>,
        opts: &RegisterOpts,
    ) -> Result<ModelKey> {
        let slot = self.slot(key)?;
        // install lock: swaps are serialized per slot; serving never takes it
        let mut versions = lock(&slot.versions);
        let cur = slot.cur();
        let (new_key, entry) = registry::build_entry(&key.name, &source, opts, cur.version + 1)?;
        ensure!(
            new_key.n_bits == key.n_bits,
            "{}: swap cannot change the bit width (slot is w{}, source is w{})",
            key.name,
            key.n_bits,
            new_key.n_bits
        );
        ensure!(
            new_key.version > cur.version,
            "{new_key}: swap version must exceed the serving version v{}",
            cur.version
        );
        ensure!(
            entry.in_elems == cur.entry.in_elems && entry.out_per_img == cur.entry.out_per_img,
            "{new_key}: swap cannot change model geometry ({}->{} in, {}->{} out)",
            cur.entry.in_elems,
            entry.in_elems,
            cur.entry.out_per_img,
            entry.out_per_img
        );
        let vs = VersionState::install(new_key.version, entry, slot.workers);
        *slot.cur.write().unwrap_or_else(|e| e.into_inner()) = Arc::clone(&vs);
        versions.push(vs);
        Ok(new_key)
    }

    /// Registered keys at their *currently serving* versions, in
    /// deterministic (sorted) order.
    pub fn keys(&self) -> Vec<ModelKey> {
        self.models
            .iter()
            .map(|((name, bits), s)| ModelKey::versioned(name.clone(), *bits, s.cur().version))
            .collect()
    }

    /// The version currently serving `key`'s slot.
    pub fn current_version(&self, key: &ModelKey) -> Result<u32> {
        Ok(self.slot(key)?.cur().version)
    }

    /// The micro-batch cap of the currently serving version.
    pub fn max_batch(&self, key: &ModelKey) -> Result<usize> {
        Ok(self.slot(key)?.cur().entry.max_batch)
    }

    /// Totals across every version this slot has served (the pre-hot-swap
    /// semantics: one model, all its traffic).
    pub fn stats(&self, key: &ModelKey) -> Result<ModelStats> {
        let mut total = ModelStats::default();
        for vs in lock(&self.slot(key)?.versions).iter() {
            total.merge(&lock(&vs.stats));
        }
        Ok(total)
    }

    /// Per-version stats in install order. Counters partition exactly:
    /// every request is billed to precisely the version that executed it.
    pub fn stats_by_version(&self, key: &ModelKey) -> Result<Vec<(u32, ModelStats)>> {
        Ok(lock(&self.slot(key)?.versions)
            .iter()
            .map(|vs| (vs.version, lock(&vs.stats).clone()))
            .collect())
    }

    /// Canonical (sorted) fingerprint set of the currently serving
    /// version's allocations: every pooled row scratch plus the
    /// gather/scatter staging buffers. With no request in flight, two
    /// equal snapshots prove zero steady-state allocation in the serving
    /// engine.
    pub fn pool_fingerprints(&self, key: &ModelKey) -> Result<Vec<Vec<(usize, usize)>>> {
        let vs = self.slot(key)?.cur();
        let mut fps = vs.pool.fingerprints();
        let b = lock(&vs.bufs);
        fps.push(vec![
            (b.gather.as_ptr() as usize, b.gather.capacity()),
            (b.logits.as_ptr() as usize, b.logits.capacity()),
        ]);
        fps.sort();
        Ok(fps)
    }

    /// Classify one image, blocking until its logits are ready. See
    /// [`Server::infer_versioned`]; this drops the version tag.
    pub fn infer(&self, key: &ModelKey, image: &[f32]) -> Result<Vec<f32>> {
        self.infer_versioned(key, image).map(|(logits, _)| logits)
    }

    /// Classify one image, blocking until its logits are ready. The call
    /// enqueues the request and then *participates*: whichever caller
    /// finds the queue ready first drains and executes the micro-batch
    /// containing it (leader/follower — no dedicated executor thread, no
    /// timer). Returns the logits plus the version that served them —
    /// bit-identical to a solo planned forward on that version. The key's
    /// own `version` field is ignored for routing: a slot always serves
    /// its current version.
    pub fn infer_versioned(&self, key: &ModelKey, image: &[f32]) -> Result<(Vec<f32>, u32)> {
        let m = self.slot(key)?;
        let in_elems = m.cur().entry.in_elems;
        ensure!(
            image.len() == in_elems,
            "{key}: image has {} elements, model expects {in_elems}",
            image.len()
        );
        let slot = Arc::new(Slot::default());
        {
            let mut q = lock(&m.q);
            q.pending.push_back(Request { image: image.to_vec(), slot: Arc::clone(&slot) });
        }
        loop {
            // decide under the queue lock: return, drain, or wait. The
            // done-check happens with the lock held so a completion that
            // races this loop is never missed (the completing drainer must
            // take the queue lock before it notifies). Becoming drainer
            // also pins the serving version for the whole micro-batch.
            let drained: Option<(Vec<Request>, Arc<VersionState>)> = {
                let mut q = lock(&m.q);
                loop {
                    if slot.is_done() {
                        break None;
                    }
                    if !q.draining && !q.pending.is_empty() {
                        q.draining = true;
                        let vs = m.cur();
                        let k = q.pending.len().min(vs.entry.max_batch);
                        break Some((q.pending.drain(..k).collect(), vs));
                    }
                    q = m.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                }
            };
            match drained {
                None => {
                    let res = slot.take().expect("slot checked done under the lock");
                    return res.map_err(|msg| anyhow!("{key}: {msg}"));
                }
                Some((reqs, vs)) => {
                    // the guard also covers unwinding: if the drain panics
                    // (kernel bug mid-batch), fail this batch — unfilled
                    // slots get an error, the flag resets, followers wake —
                    // instead of wedging the model behind draining == true
                    let guard = DrainGuard { m, reqs: &reqs };
                    vs.run_batch(&reqs);
                    drop(guard);
                    // loop back: our own request was either in this batch
                    // or is now closer to the queue front
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::IntModel;
    use crate::testing::models;
    use crate::util::rng::Rng;

    fn lenet_server(n_bits: u32) -> (Server, ModelKey, IntModel, usize) {
        let mut rng = Rng::new(0x5E);
        let (man, ck) = models::lenet5ish(&mut rng, n_bits);
        let model = IntModel::build(&man, &ck).unwrap();
        let solo = IntModel::build(&man, &ck).unwrap();
        let elems: usize = man.input_shape.iter().product();
        let mut reg = Registry::new();
        let key = reg
            .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(4))
            .unwrap();
        (Server::new(reg, ServeConfig { workers: 2 }), key, solo, elems)
    }

    #[test]
    fn single_caller_matches_solo_forward_and_counts() {
        let (server, key, solo, elems) = lenet_server(2);
        let mut rng = Rng::new(7);
        for i in 0..5u64 {
            let img: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
            let (got, v) = server.infer_versioned(&key, &img).unwrap();
            let (want, _) = solo.forward(&img, 1).unwrap();
            assert_eq!(got, want, "request {i} diverged from solo forward");
            assert_eq!(v, 1, "fresh registration serves version 1");
        }
        let stats = server.stats(&key).unwrap();
        assert_eq!(stats.requests, 5);
        // a lone caller never queues behind itself: every batch is size 1
        assert_eq!(stats.batches, 5);
        assert_eq!(stats.max_occupancy, 1);
        let per_row = solo.cost_report(1).unwrap().counts;
        let mut want_counts = crate::inference::OpCounts::default();
        for _ in 0..5 {
            want_counts.merge(&per_row);
        }
        assert_eq!(stats.op_counts, want_counts);
    }

    #[test]
    fn rejects_unknown_model_and_bad_image() {
        let (server, key, _, elems) = lenet_server(2);
        let img = vec![0f32; elems];
        let missing = ModelKey::new("nope", 2);
        assert!(server.infer(&missing, &img).is_err());
        assert!(server.stats(&missing).is_err());
        assert!(server.infer(&key, &img[..elems - 1]).is_err());
        // the key's version field does not affect routing
        let stale = ModelKey::versioned(key.name.clone(), key.n_bits, 99);
        assert!(server.infer(&stale, &img).is_ok());
    }

    #[test]
    fn swap_validates_version_and_geometry() {
        let (server, key, _, _) = lenet_server(2);
        let mut rng = Rng::new(0x5F);
        let (man, ck) = models::lenet5ish(&mut rng, 2);
        let next = IntModel::build(&man, &ck).unwrap();
        // unpinned in-code swap: current + 1
        let opts = RegisterOpts::new().max_batch(4);
        let k2 = server.swap(&key, ModelSource::InCode(&next), &opts).unwrap();
        assert_eq!(k2.version, 2);
        assert_eq!(server.current_version(&key).unwrap(), 2);
        // stale or equal versions are rejected
        let pin1 = RegisterOpts::new().max_batch(4).version(2);
        assert!(server.swap(&key, ModelSource::InCode(&next), &pin1).is_err());
        // geometry changes are rejected
        let (man_b, ck_b) = models::densenetish(&mut rng, 2);
        let other = IntModel::build(&man_b, &ck_b).unwrap();
        assert!(server.swap(&key, ModelSource::InCode(&other), &RegisterOpts::new()).is_err());
        // unknown slots are rejected
        let missing = ModelKey::new("nope", 2);
        assert!(server.swap(&missing, ModelSource::InCode(&next), &RegisterOpts::new()).is_err());
    }
}
