//! Per-model running serving statistics.
//!
//! Counters are exact — the chaos and concurrency suites assert the
//! terminal-outcome identity `requests + sheds + timeouts + failures`
//! equals precisely the number of admitted `infer` calls, per version —
//! and op accounting is analytic: each micro-batch bills
//! `ExecPlan::op_counts` for its row count, so the totals are a pure
//! function of traffic — no instrumentation on the hot path beyond one
//! mutex-guarded add per batch.

use crate::inference::OpCounts;

/// Snapshot of one model's serving counters (see [`Server::stats`]).
///
/// [`Server::stats`]: super::Server::stats
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ModelStats {
    /// requests answered (== rows executed; every request is one image)
    pub requests: u64,
    /// micro-batches flushed
    pub batches: u64,
    /// batches that hit the size watermark (occupancy == the model's cap)
    pub full_batches: u64,
    /// largest micro-batch occupancy seen
    pub max_occupancy: u64,
    /// requests refused at enqueue by admission control (queue at depth)
    pub sheds: u64,
    /// requests swept at drain time with an expired deadline (never run)
    pub timeouts: u64,
    /// requests that reached a terminal failure: batch panic/engine
    /// error, or refusal because the version is quarantined
    pub failures: u64,
    /// analytic integer-op totals over all served requests
    pub op_counts: OpCounts,
}

impl ModelStats {
    /// Mean requests per flushed micro-batch (1.0 when traffic never
    /// queues; approaches the cap under saturation).
    pub fn mean_occupancy(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }

    /// Fold another stats snapshot into this one — used to total a
    /// slot's traffic across hot-swapped versions. Sums are exact;
    /// `max_occupancy` is the max over both.
    pub fn merge(&mut self, other: &ModelStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.full_batches += other.full_batches;
        self.max_occupancy = self.max_occupancy.max(other.max_occupancy);
        self.sheds += other.sheds;
        self.timeouts += other.timeouts;
        self.failures += other.failures;
        self.op_counts.merge(&other.op_counts);
    }

    pub(crate) fn record_batch(&mut self, rows: u64, cap: u64, counts: &OpCounts) {
        self.requests += rows;
        self.batches += 1;
        if rows == cap {
            self.full_batches += 1;
        }
        self.max_occupancy = self.max_occupancy.max(rows);
        self.op_counts.merge(counts);
    }

    /// One-line human summary for drivers/benches. The failure-domain
    /// tail appears only when something was refused, swept, or failed.
    pub fn render(&self) -> String {
        let mut s = format!(
            "{} requests in {} batches (mean occupancy {:.2}, max {}, {} full) — \
             {} adds, {} mults, {} shifts",
            self.requests,
            self.batches,
            self.mean_occupancy(),
            self.max_occupancy,
            self.full_batches,
            self.op_counts.acc_adds,
            self.op_counts.int_mults,
            self.op_counts.shifts,
        );
        if self.sheds + self.timeouts + self.failures > 0 {
            s.push_str(&format!(
                " — {} shed, {} timed out, {} failed",
                self.sheds, self.timeouts, self.failures
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_batch_accumulates_exactly() {
        let mut s = ModelStats::default();
        let c = OpCounts { acc_adds: 10, int_mults: 2, shifts: 3, compares: 1 };
        s.record_batch(3, 4, &c);
        s.record_batch(4, 4, &c);
        s.record_batch(1, 4, &c);
        assert_eq!(s.requests, 8);
        assert_eq!(s.batches, 3);
        assert_eq!(s.full_batches, 1);
        assert_eq!(s.max_occupancy, 4);
        assert_eq!(s.op_counts.acc_adds, 30);
        assert!((s.mean_occupancy() - 8.0 / 3.0).abs() < 1e-12);
        assert!(s.render().contains("8 requests in 3 batches"));
    }

    #[test]
    fn merge_totals_are_exact() {
        let c = OpCounts { acc_adds: 5, int_mults: 1, shifts: 2, compares: 0 };
        let mut a = ModelStats::default();
        a.record_batch(2, 4, &c);
        let mut b = ModelStats::default();
        b.record_batch(4, 4, &c);
        b.record_batch(1, 4, &c);
        a.merge(&b);
        assert_eq!(a.requests, 7);
        assert_eq!(a.batches, 3);
        assert_eq!(a.full_batches, 1);
        assert_eq!(a.max_occupancy, 4);
        assert_eq!(a.op_counts.acc_adds, 15);
        // merging an empty snapshot is the identity
        let before = a.clone();
        a.merge(&ModelStats::default());
        assert_eq!(a, before);
    }

    #[test]
    fn failure_counters_merge_and_render() {
        let mut a = ModelStats { sheds: 2, timeouts: 1, failures: 3, ..ModelStats::default() };
        let b = ModelStats { sheds: 5, timeouts: 0, failures: 1, ..ModelStats::default() };
        a.merge(&b);
        assert_eq!((a.sheds, a.timeouts, a.failures), (7, 1, 4));
        assert!(a.render().contains("7 shed, 1 timed out, 4 failed"));
        // a clean snapshot keeps the classic one-line shape
        let clean = ModelStats::default();
        assert!(!clean.render().contains("shed"));
    }
}
