//! Per-model running serving statistics.
//!
//! Counters are exact — the chaos and concurrency suites assert the
//! terminal-outcome identity `requests + sheds + timeouts + failures`
//! equals precisely the number of admitted `infer` calls, per version —
//! and op accounting is analytic: each micro-batch bills
//! `ExecPlan::op_counts` for its row count, so the totals are a pure
//! function of traffic — no instrumentation on the hot path beyond one
//! mutex-guarded add per batch.
//!
//! Latency observability rides the same discipline: a fixed
//! [`LatencyHistogram`] of log2-spaced buckets records each request's
//! enqueue→resolve time in microseconds. Recording is a couple of integer
//! ops into a fixed array (zero allocation, done under the stats lock the
//! resolve site already holds), merging is element-wise addition — exact,
//! like every other counter — and the sample-count identity is as sharp
//! as the terminal-outcome one: `latency.count() == requests + timeouts +
//! failures` (everything that entered the queue, or was refused *after*
//! the version was selected; sheds and bad requests are turned away
//! before they ever have an enqueue instant, so they are not latency
//! samples).

use crate::inference::OpCounts;

/// Number of log2-spaced histogram buckets. Bucket 0 holds 0µs
/// (sub-microsecond resolutions); bucket `k` holds `2^(k-1) ..= 2^k - 1`
/// µs; the last bucket absorbs everything from `2^38` µs (~76 h) up.
pub const LATENCY_BUCKETS: usize = 40;

/// Fixed-size log2-bucket latency histogram (microseconds).
///
/// The bucket index of a value `v` is its bit length `64 - v.leading_zeros()`
/// (0 for `v == 0`), clamped to the last bucket — i.e. buckets double in
/// width, giving ~2x worst-case quantile error across 12 orders of
/// magnitude for 40 * 8 bytes of state. Quantiles report the bucket's
/// *upper* bound (pessimistic), clamped to the exactly-tracked max.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; LATENCY_BUCKETS],
    count: u64,
    max_us: u64,
}

// [u64; 40] has no derived Default (std stops at 32), so spell it out
impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram { buckets: [0; LATENCY_BUCKETS], count: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    fn bucket_of(us: u64) -> usize {
        (64 - us.leading_zeros() as usize).min(LATENCY_BUCKETS - 1)
    }

    /// Upper bound (inclusive, in µs) of bucket `k`.
    fn bucket_bound(k: usize) -> u64 {
        if k == 0 {
            0
        } else {
            (1u64 << k) - 1
        }
    }

    /// Record one enqueue→resolve time. O(1), allocation-free.
    pub fn record(&mut self, us: u64) {
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded (== terminal outcomes of enqueued requests).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact largest sample, in µs (0 when empty).
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Upper bound on the `q`-quantile in µs (0 when empty): the bound of
    /// the first bucket whose cumulative count reaches rank `ceil(q *
    /// count)`, clamped to the exact max so `quantile(1.0) == max_us`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (k, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= rank {
                return Self::bucket_bound(k).min(self.max_us);
            }
        }
        self.max_us
    }

    pub fn p50_us(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Fold another histogram in: element-wise bucket addition — exact,
    /// like the counter merges (no resampling, no precision loss).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Raw bucket counts (tests assert they sum to `count`).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }
}

/// Snapshot of one model's serving counters (see [`Server::stats`]).
///
/// [`Server::stats`]: super::Server::stats
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ModelStats {
    /// requests answered (== rows executed; every request is one image)
    pub requests: u64,
    /// micro-batches flushed
    pub batches: u64,
    /// batches that hit the size watermark (occupancy == the model's cap)
    pub full_batches: u64,
    /// largest micro-batch occupancy seen
    pub max_occupancy: u64,
    /// requests refused at enqueue by admission control (queue at depth)
    pub sheds: u64,
    /// requests swept at drain time with an expired deadline (never run)
    pub timeouts: u64,
    /// requests that reached a terminal failure: batch panic/engine
    /// error, or refusal because the version is quarantined
    pub failures: u64,
    /// analytic integer-op totals over all served requests
    pub op_counts: OpCounts,
    /// enqueue→resolve latency histogram; its sample count equals
    /// `requests + timeouts + failures` exactly (sheds and bad requests
    /// never enqueue, so they are not samples)
    pub latency: LatencyHistogram,
}

impl ModelStats {
    /// Mean requests per flushed micro-batch (1.0 when traffic never
    /// queues; approaches the cap under saturation).
    pub fn mean_occupancy(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }

    /// Fold another stats snapshot into this one — used to total a
    /// slot's traffic across hot-swapped versions. Sums are exact;
    /// `max_occupancy` is the max over both.
    pub fn merge(&mut self, other: &ModelStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.full_batches += other.full_batches;
        self.max_occupancy = self.max_occupancy.max(other.max_occupancy);
        self.sheds += other.sheds;
        self.timeouts += other.timeouts;
        self.failures += other.failures;
        self.op_counts.merge(&other.op_counts);
        self.latency.merge(&other.latency);
    }

    pub(crate) fn record_batch(&mut self, rows: u64, cap: u64, counts: &OpCounts) {
        self.requests += rows;
        self.batches += 1;
        if rows == cap {
            self.full_batches += 1;
        }
        self.max_occupancy = self.max_occupancy.max(rows);
        self.op_counts.merge(counts);
    }

    /// One-line human summary for drivers/benches. The failure-domain
    /// tail appears only when something was refused, swept, or failed.
    pub fn render(&self) -> String {
        let mut s = format!(
            "{} requests in {} batches (mean occupancy {:.2}, max {}, {} full) — \
             {} adds, {} mults, {} shifts",
            self.requests,
            self.batches,
            self.mean_occupancy(),
            self.max_occupancy,
            self.full_batches,
            self.op_counts.acc_adds,
            self.op_counts.int_mults,
            self.op_counts.shifts,
        );
        if self.sheds + self.timeouts + self.failures > 0 {
            s.push_str(&format!(
                " — {} shed, {} timed out, {} failed",
                self.sheds, self.timeouts, self.failures
            ));
        }
        if self.latency.count() > 0 {
            s.push_str(&format!(
                " — latency p50 {}us p99 {}us max {}us ({} samples)",
                self.latency.p50_us(),
                self.latency.p99_us(),
                self.latency.max_us(),
                self.latency.count(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_batch_accumulates_exactly() {
        let mut s = ModelStats::default();
        let c = OpCounts { acc_adds: 10, int_mults: 2, shifts: 3, compares: 1 };
        s.record_batch(3, 4, &c);
        s.record_batch(4, 4, &c);
        s.record_batch(1, 4, &c);
        assert_eq!(s.requests, 8);
        assert_eq!(s.batches, 3);
        assert_eq!(s.full_batches, 1);
        assert_eq!(s.max_occupancy, 4);
        assert_eq!(s.op_counts.acc_adds, 30);
        assert!((s.mean_occupancy() - 8.0 / 3.0).abs() < 1e-12);
        assert!(s.render().contains("8 requests in 3 batches"));
    }

    #[test]
    fn merge_totals_are_exact() {
        let c = OpCounts { acc_adds: 5, int_mults: 1, shifts: 2, compares: 0 };
        let mut a = ModelStats::default();
        a.record_batch(2, 4, &c);
        let mut b = ModelStats::default();
        b.record_batch(4, 4, &c);
        b.record_batch(1, 4, &c);
        a.merge(&b);
        assert_eq!(a.requests, 7);
        assert_eq!(a.batches, 3);
        assert_eq!(a.full_batches, 1);
        assert_eq!(a.max_occupancy, 4);
        assert_eq!(a.op_counts.acc_adds, 15);
        // merging an empty snapshot is the identity
        let before = a.clone();
        a.merge(&ModelStats::default());
        assert_eq!(a, before);
    }

    #[test]
    fn histogram_buckets_quantiles_and_max_are_exact() {
        let mut h = LatencyHistogram::default();
        assert_eq!((h.count(), h.p50_us(), h.p99_us(), h.max_us()), (0, 0, 0, 0));
        for us in [0u64, 1, 2, 3, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), h.count());
        // rank ceil(0.5*5)=3 lands on sample `2` -> its bucket [2,3] bound
        assert_eq!(h.p50_us(), 3);
        // rank ceil(0.99*5)=5 lands on 1000 -> bucket bound 1023 clamped
        // to the exact max
        assert_eq!(h.p99_us(), 1000);
        assert_eq!(h.max_us(), 1000);
        assert_eq!(h.quantile(1.0), h.max_us());
        // a huge sample clamps into the last bucket instead of indexing
        // out of bounds
        h.record(u64::MAX);
        assert_eq!(h.bucket_counts()[LATENCY_BUCKETS - 1], 1);
        assert_eq!(h.max_us(), u64::MAX);
    }

    #[test]
    fn histogram_merge_equals_recording_into_one() {
        let samples = [0u64, 5, 17, 17, 300, 40_000, 7];
        let mut whole = LatencyHistogram::default();
        for &s in &samples {
            whole.record(s);
        }
        let (left, right) = samples.split_at(3);
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for &s in left {
            a.record(s);
        }
        for &s in right {
            b.record(s);
        }
        a.merge(&b);
        assert_eq!(a, whole, "merge must be exactly recording the union");
        // merging an empty histogram is the identity
        let before = a;
        a.merge(&LatencyHistogram::default());
        assert_eq!(a, before);
    }

    #[test]
    fn render_appends_latency_tail_only_with_samples() {
        let mut s = ModelStats::default();
        assert!(!s.render().contains("latency"));
        s.latency.record(120);
        s.latency.record(90);
        // both samples share the [64, 127] bucket; its 127µs bound is
        // clamped to the exactly-tracked 120µs max
        let line = s.render();
        assert!(line.contains("latency p50 120us p99 120us max 120us (2 samples)"), "{line}");
    }

    #[test]
    fn failure_counters_merge_and_render() {
        let mut a = ModelStats { sheds: 2, timeouts: 1, failures: 3, ..ModelStats::default() };
        let b = ModelStats { sheds: 5, timeouts: 0, failures: 1, ..ModelStats::default() };
        a.merge(&b);
        assert_eq!((a.sheds, a.timeouts, a.failures), (7, 1, 4));
        assert!(a.render().contains("7 shed, 1 timed out, 4 failed"));
        // a clean snapshot keeps the classic one-line shape
        let clean = ModelStats::default();
        assert!(!clean.render().contains("shed"));
    }
}
