//! Typed serving failures and per-version health tracking.
//!
//! Every request the server refuses or fails resolves to exactly one
//! [`ServeError`] variant — the stringly `Result<_, String>` channel is
//! gone, so callers can branch on the failure domain (shed vs deadline vs
//! batch failure vs quarantine) instead of grepping messages. `Display`
//! strings are stable and pinned by tests; the public `infer*` APIs wrap
//! the variant in `anyhow` context (the model key) without losing the
//! typed source, so `err.downcast_ref::<ServeError>()` always works.
//!
//! Each deployed version also carries a [`Health`] state driven by a
//! consecutive-failure circuit [`Breaker`]: one failed micro-batch marks
//! the version `Degraded`, a configurable run of consecutive failures
//! trips it to `Quarantined` (sticky until rollback or swap), and any
//! success while not quarantined resets to `Ready`. Quarantine is the
//! *version's* failure domain — the slot survives and rolls back to
//! last-good (see `server.rs`).

use std::fmt;
use std::sync::Mutex;

/// Serving health of one deployed model version.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Serving normally (no failure since the last success).
    Ready,
    /// At least one recent micro-batch failed; still serving.
    Degraded,
    /// The consecutive-failure breaker tripped: this version no longer
    /// serves (sticky — cleared only by rolling to another version).
    Quarantined,
}

impl fmt::Display for Health {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Health::Ready => "ready",
            Health::Degraded => "degraded",
            Health::Quarantined => "quarantined",
        })
    }
}

/// Typed terminal outcome for a failed serving request. Every submitted
/// request completes with logits or with exactly one of these; the
/// counter identity `requests + sheds + timeouts + failures ==
/// submissions` (per version, per slot) is pinned by the chaos suites.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control refused the request: the slot's queue was at its
    /// configured `queue_depth` when the request arrived.
    Shed {
        /// the configured bound the queue was at
        depth: usize,
    },
    /// The request's deadline had already passed when a drainer swept the
    /// queue; it was never executed.
    DeadlineExceeded,
    /// The micro-batch containing this request panicked or failed in the
    /// execution engine; the request was not served. Batchmates of a
    /// poison input land here and may retry — the slot itself survives.
    BatchPanicked(String),
    /// The version that would have served this request is quarantined
    /// (circuit breaker open) and no rollback target exists.
    VersionQuarantined(u32),
    /// The request was malformed (wrong input geometry) and was rejected
    /// before admission.
    BadRequest(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Shed { depth } => {
                write!(f, "request shed: queue is at its configured depth ({depth})")
            }
            ServeError::DeadlineExceeded => {
                f.write_str("deadline exceeded before execution (request swept, never run)")
            }
            ServeError::BatchPanicked(msg) => write!(f, "batch execution failed: {msg}"),
            ServeError::VersionQuarantined(v) => {
                write!(f, "version v{v} is quarantined (circuit breaker open)")
            }
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

struct BreakerState {
    consecutive: u32,
    health: Health,
}

/// Consecutive-failure circuit breaker for one version. Not a rate
/// limiter: only an unbroken run of `threshold` failed micro-batches
/// trips it, so a single poison input surrounded by healthy traffic
/// degrades but never quarantines.
pub(crate) struct Breaker {
    threshold: u32,
    state: Mutex<BreakerState>,
}

impl Breaker {
    pub(crate) fn new(threshold: u32) -> Breaker {
        debug_assert!(threshold >= 1, "a breaker needs a positive threshold");
        Breaker {
            threshold,
            state: Mutex::new(BreakerState { consecutive: 0, health: Health::Ready }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BreakerState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub(crate) fn health(&self) -> Health {
        self.lock().health
    }

    /// A micro-batch succeeded: reset the failure run. Quarantine is
    /// sticky — a success racing the trip does not resurrect the version.
    pub(crate) fn record_success(&self) {
        let mut s = self.lock();
        s.consecutive = 0;
        if s.health != Health::Quarantined {
            s.health = Health::Ready;
        }
    }

    /// A micro-batch failed. Returns `true` exactly once: on the failure
    /// that trips the breaker (the caller then performs the rollback).
    pub(crate) fn record_failure(&self) -> bool {
        let mut s = self.lock();
        if s.health == Health::Quarantined {
            return false;
        }
        s.consecutive += 1;
        if s.consecutive >= self.threshold {
            s.health = Health::Quarantined;
            true
        } else {
            s.health = Health::Degraded;
            false
        }
    }

    /// Force quarantine (manual rollback path). Returns `true` if this
    /// call transitioned the version into quarantine.
    pub(crate) fn quarantine(&self) -> bool {
        let mut s = self.lock();
        if s.health == Health::Quarantined {
            return false;
        }
        s.health = Health::Quarantined;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        // these strings are part of the public API surface: operators and
        // tests match on them, so changing one is a breaking change
        assert_eq!(
            ServeError::Shed { depth: 8 }.to_string(),
            "request shed: queue is at its configured depth (8)"
        );
        assert_eq!(
            ServeError::DeadlineExceeded.to_string(),
            "deadline exceeded before execution (request swept, never run)"
        );
        assert_eq!(
            ServeError::BatchPanicked("kernel bug".into()).to_string(),
            "batch execution failed: kernel bug"
        );
        assert_eq!(
            ServeError::VersionQuarantined(3).to_string(),
            "version v3 is quarantined (circuit breaker open)"
        );
        assert_eq!(
            ServeError::BadRequest("image has 7 elements".into()).to_string(),
            "bad request: image has 7 elements"
        );
        assert_eq!(Health::Ready.to_string(), "ready");
        assert_eq!(Health::Degraded.to_string(), "degraded");
        assert_eq!(Health::Quarantined.to_string(), "quarantined");
    }

    #[test]
    fn serve_error_downcasts_through_anyhow() {
        let err = anyhow::Error::new(ServeError::Shed { depth: 4 }).context("lenet5@w2#v1");
        let typed = err.downcast_ref::<ServeError>().expect("typed source survives context");
        assert_eq!(*typed, ServeError::Shed { depth: 4 });
        // the chain renders "context: source"
        assert!(format!("{err:#}").contains("lenet5@w2#v1"));
        assert!(format!("{err:#}").contains("configured depth (4)"));
    }

    #[test]
    fn breaker_trips_only_on_consecutive_failures() {
        let b = Breaker::new(3);
        assert_eq!(b.health(), Health::Ready);
        assert!(!b.record_failure());
        assert_eq!(b.health(), Health::Degraded);
        assert!(!b.record_failure());
        // a success resets the run: the next failure starts from scratch
        b.record_success();
        assert_eq!(b.health(), Health::Ready);
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        assert!(b.record_failure(), "third consecutive failure trips");
        assert_eq!(b.health(), Health::Quarantined);
        // tripping is reported exactly once; quarantine is sticky
        assert!(!b.record_failure());
        b.record_success();
        assert_eq!(b.health(), Health::Quarantined);
    }

    #[test]
    fn manual_quarantine_reports_the_transition_once() {
        let b = Breaker::new(100);
        assert!(b.quarantine());
        assert!(!b.quarantine());
        assert_eq!(b.health(), Health::Quarantined);
    }
}
