//! The wire protocol: length-prefixed binary frames over a byte stream.
//!
//! ## Framing
//!
//! Every frame is `u32 payload_len` (little-endian, excluding itself)
//! followed by `payload_len` bytes. The payload's first byte is the
//! opcode; the rest is a fixed field layout per opcode (all integers
//! little-endian, `f32` as IEEE-754 bits, strings length-prefixed).
//! A decoder error (unknown opcode, short payload, trailing bytes,
//! oversize length) is **malformed** — the peer answers with an
//! [`ErrCode::Malformed`] error frame and closes the connection, because
//! stream framing can no longer be trusted.
//!
//! ## Frames
//!
//! Requests (client → server):
//!
//! | op | frame   | payload after the opcode byte                        |
//! |----|---------|------------------------------------------------------|
//! | 1  | Infer   | name, `u32` n_bits, `u32` version_pin (0 = none),    |
//! |    |         | `u32` deadline_ms (0 = none), `u32` n + `f32`×n image|
//! | 2  | Stats   | name, `u32` n_bits                                   |
//! | 3  | Health  | name, `u32` n_bits                                   |
//! | 4  | Swap    | name, `u32` n_bits, `u32` max_batch,                 |
//! |    |         | `u32` version_pin (0 = none), path (server-local)    |
//!
//! Responses (server → client):
//!
//! | op   | frame       | payload after the opcode byte                  |
//! |------|-------------|------------------------------------------------|
//! | 0x81 | Logits      | `u32` version, `u64` latency_us, `u32` n + `f32`×n |
//! | 0x82 | StatsReply  | [`WireStats`] field layout (see struct docs)   |
//! | 0x83 | HealthReply | `u8` health (0/1/2), `u32` version             |
//! | 0x84 | SwapReply   | `u32` installed version                        |
//! | 0xFF | Error       | `u8` code, `u16`-prefixed message              |
//!
//! Strings are `u8`-length-prefixed UTF-8 (`u16` for the Swap path).
//!
//! ## Error codes
//!
//! Codes 1–5 are the five [`ServeError`] variants, pinned one-to-one
//! ([`code_for`]); 6–9 are wire-layer outcomes that have no in-process
//! equivalent. The numbers are part of the protocol and must never be
//! renumbered — `tests/serve_net.rs` pins them.

use std::io::{self, Read, Write};

use crate::serve::{Health, ServeError};

/// Upper bound on a frame payload; anything larger is malformed (the
/// largest legal frame is an Infer image, and no zoo model comes near
/// this). Guards the reader against allocating garbage lengths.
pub const MAX_FRAME_LEN: u32 = 64 << 20;

const OP_INFER: u8 = 1;
const OP_STATS: u8 = 2;
const OP_HEALTH: u8 = 3;
const OP_SWAP: u8 = 4;
const OP_LOGITS: u8 = 0x81;
const OP_STATS_REPLY: u8 = 0x82;
const OP_HEALTH_REPLY: u8 = 0x83;
const OP_SWAP_REPLY: u8 = 0x84;
const OP_ERROR: u8 = 0xFF;

/// Pinned wire error codes (see the module docs; renumbering is a
/// protocol break).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrCode {
    /// admission control refused the request ([`ServeError::Shed`])
    Shed = 1,
    /// deadline passed before execution ([`ServeError::DeadlineExceeded`])
    DeadlineExceeded = 2,
    /// the micro-batch failed in the engine ([`ServeError::BatchPanicked`])
    BatchFailed = 3,
    /// serving version quarantined ([`ServeError::VersionQuarantined`])
    Quarantined = 4,
    /// malformed request content, e.g. wrong image geometry
    /// ([`ServeError::BadRequest`])
    BadRequest = 5,
    /// no model registered under (name, n_bits)
    UnknownModel = 6,
    /// the response's serving version differs from the Infer frame's
    /// version_pin (a swap landed, or the pin was stale)
    PinMismatch = 7,
    /// undecodable frame; the server closes the connection after sending
    Malformed = 8,
    /// any other server-side failure (e.g. a refused swap)
    Internal = 9,
}

impl ErrCode {
    pub fn from_u8(v: u8) -> Option<ErrCode> {
        Some(match v {
            1 => ErrCode::Shed,
            2 => ErrCode::DeadlineExceeded,
            3 => ErrCode::BatchFailed,
            4 => ErrCode::Quarantined,
            5 => ErrCode::BadRequest,
            6 => ErrCode::UnknownModel,
            7 => ErrCode::PinMismatch,
            8 => ErrCode::Malformed,
            9 => ErrCode::Internal,
            _ => return None,
        })
    }
}

/// The pinned `ServeError` → wire-code mapping: every typed in-process
/// failure domain has exactly one code, so a remote client can branch on
/// the same domains the in-process API exposes.
pub fn code_for(e: &ServeError) -> ErrCode {
    match e {
        ServeError::Shed { .. } => ErrCode::Shed,
        ServeError::DeadlineExceeded => ErrCode::DeadlineExceeded,
        ServeError::BatchPanicked(_) => ErrCode::BatchFailed,
        ServeError::VersionQuarantined(_) => ErrCode::Quarantined,
        ServeError::BadRequest(_) => ErrCode::BadRequest,
    }
}

/// Wire byte for a [`Health`] state (HealthReply payload).
pub fn health_code(h: Health) -> u8 {
    match h {
        Health::Ready => 0,
        Health::Degraded => 1,
        Health::Quarantined => 2,
    }
}

pub fn health_from_code(v: u8) -> Option<Health> {
    Some(match v {
        0 => Health::Ready,
        1 => Health::Degraded,
        2 => Health::Quarantined,
        _ => return None,
    })
}

/// Per-model serving statistics as carried by the Stats wire frame:
/// the terminal-outcome counters plus the latency histogram's summary
/// quantiles. Field order is the payload layout (all `u64` except the
/// leading `u32` version).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// version currently serving the slot
    pub version: u32,
    pub requests: u64,
    pub batches: u64,
    pub max_occupancy: u64,
    pub sheds: u64,
    pub timeouts: u64,
    pub failures: u64,
    /// latency samples recorded (== requests + timeouts + failures)
    pub latency_count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// One decoded protocol frame (requests and responses share the enum;
/// each side only ever constructs its own half).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Infer { name: String, n_bits: u32, version_pin: u32, deadline_ms: u32, image: Vec<f32> },
    Stats { name: String, n_bits: u32 },
    Health { name: String, n_bits: u32 },
    Swap { name: String, n_bits: u32, max_batch: u32, version_pin: u32, path: String },
    Logits { version: u32, latency_us: u64, logits: Vec<f32> },
    StatsReply(WireStats),
    HealthReply { health: u8, version: u32 },
    SwapReply { version: u32 },
    Error { code: ErrCode, message: String },
}

/// Why a read failed: a clean close between frames, a transport error, or
/// a frame that decoded to garbage (the connection must be dropped).
#[derive(Debug)]
pub enum ProtoError {
    /// EOF at a frame boundary: the peer hung up cleanly.
    Eof,
    /// Transport failure (including EOF mid-frame).
    Io(io::Error),
    /// Undecodable frame; the message says what was wrong.
    Malformed(String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Eof => f.write_str("connection closed"),
            ProtoError::Io(e) => write!(f, "transport error: {e}"),
            ProtoError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for ProtoError {}

// ---------------------------------------------------------------- encode

fn put_str8(buf: &mut Vec<u8>, s: &str, what: &str) {
    debug_assert!(s.len() <= u8::MAX as usize, "{what} too long for the wire");
    buf.push(s.len().min(u8::MAX as usize) as u8);
    buf.extend_from_slice(&s.as_bytes()[..s.len().min(u8::MAX as usize)]);
}

fn put_str16(buf: &mut Vec<u8>, s: &str) {
    let n = s.len().min(u16::MAX as usize);
    buf.extend_from_slice(&(n as u16).to_le_bytes());
    buf.extend_from_slice(&s.as_bytes()[..n]);
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        buf.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Serialize `frame` into its payload bytes (no length prefix).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut b = Vec::new();
    match frame {
        Frame::Infer { name, n_bits, version_pin, deadline_ms, image } => {
            b.push(OP_INFER);
            put_str8(&mut b, name, "model name");
            b.extend_from_slice(&n_bits.to_le_bytes());
            b.extend_from_slice(&version_pin.to_le_bytes());
            b.extend_from_slice(&deadline_ms.to_le_bytes());
            put_f32s(&mut b, image);
        }
        Frame::Stats { name, n_bits } => {
            b.push(OP_STATS);
            put_str8(&mut b, name, "model name");
            b.extend_from_slice(&n_bits.to_le_bytes());
        }
        Frame::Health { name, n_bits } => {
            b.push(OP_HEALTH);
            put_str8(&mut b, name, "model name");
            b.extend_from_slice(&n_bits.to_le_bytes());
        }
        Frame::Swap { name, n_bits, max_batch, version_pin, path } => {
            b.push(OP_SWAP);
            put_str8(&mut b, name, "model name");
            b.extend_from_slice(&n_bits.to_le_bytes());
            b.extend_from_slice(&max_batch.to_le_bytes());
            b.extend_from_slice(&version_pin.to_le_bytes());
            put_str16(&mut b, path);
        }
        Frame::Logits { version, latency_us, logits } => {
            b.push(OP_LOGITS);
            b.extend_from_slice(&version.to_le_bytes());
            b.extend_from_slice(&latency_us.to_le_bytes());
            put_f32s(&mut b, logits);
        }
        Frame::StatsReply(s) => {
            b.push(OP_STATS_REPLY);
            b.extend_from_slice(&s.version.to_le_bytes());
            for v in [
                s.requests,
                s.batches,
                s.max_occupancy,
                s.sheds,
                s.timeouts,
                s.failures,
                s.latency_count,
                s.p50_us,
                s.p99_us,
                s.max_us,
            ] {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::HealthReply { health, version } => {
            b.push(OP_HEALTH_REPLY);
            b.push(*health);
            b.extend_from_slice(&version.to_le_bytes());
        }
        Frame::SwapReply { version } => {
            b.push(OP_SWAP_REPLY);
            b.extend_from_slice(&version.to_le_bytes());
        }
        Frame::Error { code, message } => {
            b.push(OP_ERROR);
            b.push(*code as u8);
            put_str16(&mut b, message);
        }
    }
    b
}

/// Write one length-prefixed frame. The caller flushes (a conn handler
/// batches a response per request; flushing per write would be wasteful
/// for pipelined clients).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    let payload = encode(frame);
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)
}

// ---------------------------------------------------------------- decode

/// Bounds-checked little-endian payload reader.
struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.b.len() - self.off < n {
            return Err(format!(
                "truncated payload: {what} needs {n} bytes, {} left",
                self.b.len() - self.off
            ));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn str8(&mut self, what: &str) -> Result<String, String> {
        let n = self.u8(what)? as usize;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| format!("{what} is not UTF-8"))
    }

    fn str16(&mut self, what: &str) -> Result<String, String> {
        let n = self.u16(what)? as usize;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| format!("{what} is not UTF-8"))
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>, String> {
        let n = self.u32(what)? as usize;
        let count = n.checked_mul(4).ok_or_else(|| format!("{what} element count overflow"))?;
        let bytes = self.take(count, what)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    /// Every opcode's field layout is fixed, so spare bytes mean the
    /// peers disagree about the protocol — reject instead of guessing.
    fn finish(self) -> Result<(), String> {
        if self.off != self.b.len() {
            return Err(format!("{} trailing bytes after the last field", self.b.len() - self.off));
        }
        Ok(())
    }
}

/// Decode one payload (the bytes after the length prefix).
pub fn decode(payload: &[u8]) -> Result<Frame, String> {
    let mut r = Rd { b: payload, off: 0 };
    let op = r.u8("opcode")?;
    let frame = match op {
        OP_INFER => Frame::Infer {
            name: r.str8("model name")?,
            n_bits: r.u32("n_bits")?,
            version_pin: r.u32("version_pin")?,
            deadline_ms: r.u32("deadline_ms")?,
            image: r.f32s("image")?,
        },
        OP_STATS => Frame::Stats { name: r.str8("model name")?, n_bits: r.u32("n_bits")? },
        OP_HEALTH => Frame::Health { name: r.str8("model name")?, n_bits: r.u32("n_bits")? },
        OP_SWAP => Frame::Swap {
            name: r.str8("model name")?,
            n_bits: r.u32("n_bits")?,
            max_batch: r.u32("max_batch")?,
            version_pin: r.u32("version_pin")?,
            path: r.str16("artifact path")?,
        },
        OP_LOGITS => Frame::Logits {
            version: r.u32("version")?,
            latency_us: r.u64("latency_us")?,
            logits: r.f32s("logits")?,
        },
        OP_STATS_REPLY => {
            let version = r.u32("version")?;
            let mut v = [0u64; 10];
            for (i, slot) in v.iter_mut().enumerate() {
                *slot = r.u64(&format!("stats field {i}"))?;
            }
            Frame::StatsReply(WireStats {
                version,
                requests: v[0],
                batches: v[1],
                max_occupancy: v[2],
                sheds: v[3],
                timeouts: v[4],
                failures: v[5],
                latency_count: v[6],
                p50_us: v[7],
                p99_us: v[8],
                max_us: v[9],
            })
        }
        OP_HEALTH_REPLY => {
            Frame::HealthReply { health: r.u8("health")?, version: r.u32("version")? }
        }
        OP_SWAP_REPLY => Frame::SwapReply { version: r.u32("version")? },
        OP_ERROR => {
            let raw = r.u8("error code")?;
            let code = ErrCode::from_u8(raw).ok_or_else(|| format!("unknown error code {raw}"))?;
            Frame::Error { code, message: r.str16("error message")? }
        }
        other => return Err(format!("unknown opcode 0x{other:02x}")),
    };
    r.finish()?;
    Ok(frame)
}

/// Read one length-prefixed frame. EOF *between* frames is
/// [`ProtoError::Eof`] (clean close); EOF inside a frame is a transport
/// error; an undecodable payload is [`ProtoError::Malformed`].
pub fn read_frame(r: &mut impl Read) -> Result<Frame, ProtoError> {
    let mut len = [0u8; 4];
    // distinguish clean close (0 bytes) from mid-prefix truncation
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Err(ProtoError::Eof),
            Ok(0) => {
                return Err(ProtoError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "EOF inside a frame length prefix",
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    let n = u32::from_le_bytes(len);
    if n == 0 || n > MAX_FRAME_LEN {
        return Err(ProtoError::Malformed(format!(
            "frame length {n} outside 1..={MAX_FRAME_LEN}"
        )));
    }
    let mut payload = vec![0u8; n as usize];
    r.read_exact(&mut payload).map_err(ProtoError::Io)?;
    decode(&payload).map_err(ProtoError::Malformed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: Frame) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let got = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(got, f, "frame did not survive the wire");
        // and the stream is positioned at a clean boundary
        let mut rest = &buf[buf.len()..];
        assert!(matches!(read_frame(&mut rest), Err(ProtoError::Eof)));
    }

    #[test]
    fn every_frame_round_trips_bit_exactly() {
        round_trip(Frame::Infer {
            name: "lenet5".into(),
            n_bits: 2,
            version_pin: 3,
            deadline_ms: 250,
            image: vec![0.0, -1.5, f32::MIN_POSITIVE, 3.25e7],
        });
        round_trip(Frame::Stats { name: "densenet".into(), n_bits: 4 });
        round_trip(Frame::Health { name: "vgg7".into(), n_bits: 8 });
        round_trip(Frame::Swap {
            name: "lenet5".into(),
            n_bits: 2,
            max_batch: 8,
            version_pin: 0,
            path: "/tmp/lenet5-v2.fxpa".into(),
        });
        round_trip(Frame::Logits {
            version: 7,
            latency_us: 12_345,
            logits: vec![-0.0, 1.0, f32::NEG_INFINITY],
        });
        round_trip(Frame::StatsReply(WireStats {
            version: 2,
            requests: 100,
            batches: 30,
            max_occupancy: 8,
            sheds: 5,
            timeouts: 2,
            failures: 1,
            latency_count: 103,
            p50_us: 511,
            p99_us: 4095,
            max_us: 5000,
        }));
        round_trip(Frame::HealthReply { health: health_code(Health::Degraded), version: 4 });
        round_trip(Frame::SwapReply { version: 9 });
        round_trip(Frame::Error { code: ErrCode::Shed, message: "queue at depth 4".into() });
    }

    #[test]
    fn error_codes_are_pinned() {
        // renumbering any of these is a protocol break: deployed clients
        // branch on the numeric value
        assert_eq!(code_for(&ServeError::Shed { depth: 1 }) as u8, 1);
        assert_eq!(code_for(&ServeError::DeadlineExceeded) as u8, 2);
        assert_eq!(code_for(&ServeError::BatchPanicked("x".into())) as u8, 3);
        assert_eq!(code_for(&ServeError::VersionQuarantined(1)) as u8, 4);
        assert_eq!(code_for(&ServeError::BadRequest("x".into())) as u8, 5);
        assert_eq!(ErrCode::UnknownModel as u8, 6);
        assert_eq!(ErrCode::PinMismatch as u8, 7);
        assert_eq!(ErrCode::Malformed as u8, 8);
        assert_eq!(ErrCode::Internal as u8, 9);
        for raw in 1..=9u8 {
            assert_eq!(ErrCode::from_u8(raw).unwrap() as u8, raw);
        }
        assert_eq!(ErrCode::from_u8(0), None);
        assert_eq!(ErrCode::from_u8(10), None);
        assert_eq!(health_from_code(health_code(Health::Quarantined)), Some(Health::Quarantined));
        assert_eq!(health_from_code(3), None);
    }

    #[test]
    fn malformed_frames_are_rejected_not_guessed() {
        // unknown opcode
        assert!(decode(&[0x42]).unwrap_err().contains("unknown opcode"));
        // truncated: Stats promises a name longer than the payload
        assert!(decode(&[OP_STATS, 200]).unwrap_err().contains("truncated"));
        // trailing garbage after a complete frame
        let mut ok = encode(&Frame::SwapReply { version: 1 });
        ok.push(0);
        assert!(decode(&ok).unwrap_err().contains("trailing"));
        // zero-length and oversize frames die at the length prefix
        let zero = 0u32.to_le_bytes();
        assert!(matches!(read_frame(&mut zero.as_slice()), Err(ProtoError::Malformed(_))));
        let huge = (MAX_FRAME_LEN + 1).to_le_bytes();
        assert!(matches!(read_frame(&mut huge.as_slice()), Err(ProtoError::Malformed(_))));
        // EOF mid-frame is a transport error, not a clean close
        let mut partial = Vec::new();
        write_frame(&mut partial, &Frame::SwapReply { version: 1 }).unwrap();
        partial.truncate(6);
        assert!(matches!(read_frame(&mut partial.as_slice()), Err(ProtoError::Io(_))));
    }
}
