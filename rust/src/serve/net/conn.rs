//! Per-connection request loop: decode → submit → encode, nothing else.
//!
//! The handler is a **pure transport** over the in-process serving API:
//! an `Infer` frame becomes exactly one [`Server::infer_with`] call (the
//! same entry point the conformance/chaos suites pin), so a networked
//! response is bit-identical to a solo planned forward by construction —
//! the wire layer never touches images, logits, batching, or stats
//! beyond copying bytes. Control frames map one-to-one onto
//! [`Server::stats`]/[`Server::health`]/[`Server::swap`].
//!
//! One connection is one blocking request at a time (thread-per-
//! connection; concurrency comes from more connections, exactly like the
//! in-process API's one-thread-one-request shape). Typed serving
//! failures travel as pinned error codes ([`proto::code_for`]); a
//! malformed frame gets an [`ErrCode::Malformed`] reply and the
//! connection is closed, since framing can no longer be trusted.
//!
//! [`Server::infer_with`]: crate::serve::Server::infer_with
//! [`Server::stats`]: crate::serve::Server::stats
//! [`Server::health`]: crate::serve::Server::health
//! [`Server::swap`]: crate::serve::Server::swap

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::serve::{InferOpts, ModelKey, ModelSource, RegisterOpts, ServeError, Server};

use super::proto::{self, ErrCode, Frame, ProtoError, WireStats};

/// Serve one accepted connection until the peer hangs up (or a frame is
/// malformed). Transport errors just end the loop — the peer is gone.
pub(super) fn handle(server: &Server, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    loop {
        let reply = match proto::read_frame(&mut reader) {
            Ok(frame) => dispatch(server, frame),
            Err(ProtoError::Eof) | Err(ProtoError::Io(_)) => return,
            Err(ProtoError::Malformed(msg)) => {
                // answer, then drop the connection: after a framing error
                // there is no way to find the next frame boundary
                let _ = proto::write_frame(
                    &mut writer,
                    &Frame::Error { code: ErrCode::Malformed, message: msg },
                );
                let _ = writer.flush();
                return;
            }
        };
        if proto::write_frame(&mut writer, &reply).and_then(|()| writer.flush()).is_err() {
            return;
        }
    }
}

fn err_frame(code: ErrCode, message: impl Into<String>) -> Frame {
    Frame::Error { code, message: message.into() }
}

/// Map one request frame to one serving-API call. Response frames only —
/// never panics, never unwinds into the connection loop (the serving API
/// already contains engine panics to typed errors).
fn dispatch(server: &Server, frame: Frame) -> Frame {
    match frame {
        Frame::Infer { name, n_bits, version_pin, deadline_ms, image } => {
            let key = ModelKey::new(name, n_bits);
            // resolve slot existence up front so "no such model" is typed
            // apart from in-band serving failures
            let cur = match server.current_version(&key) {
                Ok(v) => v,
                Err(e) => return err_frame(ErrCode::UnknownModel, format!("{e:#}")),
            };
            // best-effort pre-check; the authoritative check is on the
            // response version below (a swap can land mid-request)
            if version_pin != 0 && cur != version_pin {
                return err_frame(
                    ErrCode::PinMismatch,
                    format!("{key}: pinned v{version_pin}, slot is serving v{cur}"),
                );
            }
            let opts = if deadline_ms == 0 {
                InferOpts::new()
            } else {
                InferOpts::new().deadline_in(Duration::from_millis(deadline_ms as u64))
            };
            let t0 = Instant::now();
            match server.infer_with(&key, &image, &opts) {
                Ok((logits, version)) => {
                    if version_pin != 0 && version != version_pin {
                        return err_frame(
                            ErrCode::PinMismatch,
                            format!("{key}: pinned v{version_pin}, served by v{version}"),
                        );
                    }
                    Frame::Logits { version, latency_us: t0.elapsed().as_micros() as u64, logits }
                }
                Err(e) => match e.downcast_ref::<ServeError>() {
                    Some(se) => err_frame(proto::code_for(se), se.to_string()),
                    None => err_frame(ErrCode::Internal, format!("{e:#}")),
                },
            }
        }
        Frame::Stats { name, n_bits } => {
            let key = ModelKey::new(name, n_bits);
            let (stats, version) = match (server.stats(&key), server.current_version(&key)) {
                (Ok(s), Ok(v)) => (s, v),
                (Err(e), _) | (_, Err(e)) => {
                    return err_frame(ErrCode::UnknownModel, format!("{e:#}"))
                }
            };
            Frame::StatsReply(WireStats {
                version,
                requests: stats.requests,
                batches: stats.batches,
                max_occupancy: stats.max_occupancy,
                sheds: stats.sheds,
                timeouts: stats.timeouts,
                failures: stats.failures,
                latency_count: stats.latency.count(),
                p50_us: stats.latency.p50_us(),
                p99_us: stats.latency.p99_us(),
                max_us: stats.latency.max_us(),
            })
        }
        Frame::Health { name, n_bits } => {
            let key = ModelKey::new(name, n_bits);
            match (server.health(&key), server.current_version(&key)) {
                (Ok(h), Ok(v)) => Frame::HealthReply { health: proto::health_code(h), version: v },
                (Err(e), _) | (_, Err(e)) => err_frame(ErrCode::UnknownModel, format!("{e:#}")),
            }
        }
        Frame::Swap { name, n_bits, max_batch, version_pin, path } => {
            let key = ModelKey::new(name, n_bits);
            if server.current_version(&key).is_err() {
                return err_frame(
                    ErrCode::UnknownModel,
                    format!("{}@w{} is not registered", key.name, key.n_bits),
                );
            }
            let mut opts = RegisterOpts::new().max_batch(max_batch.max(1) as usize);
            if version_pin != 0 {
                opts = opts.version(version_pin);
            }
            match server.swap(&key, ModelSource::Artifact(Path::new(&path)), &opts) {
                Ok(installed) => Frame::SwapReply { version: installed.version },
                Err(e) => err_frame(ErrCode::Internal, format!("{e:#}")),
            }
        }
        // a response frame arriving at the server is a confused peer
        other => err_frame(
            ErrCode::Malformed,
            format!("server received a response frame: {other:?}"),
        ),
    }
}
