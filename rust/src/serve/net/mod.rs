//! TCP front-end for the serving layer: a network skin over [`Server`].
//!
//! [`TcpFront`] binds a `std::net::TcpListener` and serves each accepted
//! connection on its own thread (thread-per-connection — the in-process
//! API is blocking and one-request-per-thread, so the natural network
//! shape is one *connection* per thread; concurrency and micro-batch
//! coalescing come from many connections, exactly as they come from many
//! threads in-process). The protocol ([`proto`]) is length-prefixed
//! binary frames; the per-connection loop ([`conn`]) is a pure transport
//! over `Server::infer_with`/`stats`/`health`/`swap`, so networked
//! responses are **bit-identical** to solo planned forwards and every
//! typed failure domain crosses the wire as a pinned error code.
//!
//! [`Client`] is the matching blocking client, used by the test suite,
//! `examples/serve_bench --tcp`, and the `serve` subcommand's
//! documentation examples. A typed server-side refusal surfaces as a
//! [`WireFail`] in the returned `anyhow::Error`, so callers branch on
//! failure domains exactly as in-process callers downcast `ServeError`.
//!
//! Shutdown: [`TcpFront::shutdown`] stops the accept loop (flag + self-
//! connect to unblock `accept`) and joins connection threads; connection
//! threads exit when their client hangs up, so an orderly shutdown is
//! "clients disconnect, then `shutdown()`".

pub mod proto;

mod conn;

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::Server;

use proto::{ErrCode, Frame, ProtoError, WireStats};

/// A typed wire-level refusal: the server answered with an Error frame.
/// Carried inside the `anyhow::Error` returned by [`Client`] calls so
/// callers can `downcast_ref::<WireFail>()` and branch on the pinned
/// [`ErrCode`] — the remote analogue of downcasting `ServeError`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireFail {
    pub code: ErrCode,
    pub message: String,
}

impl std::fmt::Display for WireFail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server refused ({:?}): {}", self.code, self.message)
    }
}

impl std::error::Error for WireFail {}

/// A successful Infer round trip.
#[derive(Clone, Debug, PartialEq)]
pub struct InferReply {
    /// logits, bit-identical to a solo planned forward on `version`
    pub logits: Vec<f32>,
    /// model version that served the request
    pub version: u32,
    /// server-measured submit→resolve wall time
    pub latency_us: u64,
}

/// Listening TCP front-end. Owns the accept thread and every live
/// connection thread; dropping it stops accepting (best effort) but only
/// [`shutdown`](TcpFront::shutdown) joins the threads.
pub struct TcpFront {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl TcpFront {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting connections against `server`.
    pub fn bind(server: Arc<Server>, addr: &str) -> Result<TcpFront> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding TCP front-end to {addr}"))?;
        let local = listener.local_addr().context("resolving bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("serve-net-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                        let Ok(stream) = stream else { continue };
                        let server = Arc::clone(&server);
                        let handle = std::thread::Builder::new()
                            .name("serve-net-conn".into())
                            .spawn(move || conn::handle(&server, stream));
                        if let Ok(h) = handle {
                            conns.lock().unwrap_or_else(|e| e.into_inner()).push(h);
                        }
                    }
                })
                .context("spawning the accept thread")?
        };
        Ok(TcpFront { addr: local, stop, accept: Some(accept), conns })
    }

    /// The bound address (the real port when bound to `:0`).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread plus every connection
    /// thread. Connection threads exit when their peer hangs up, so
    /// call this after clients have disconnected (or dropped their
    /// sockets) — it blocks until the last one does.
    pub fn shutdown(mut self) {
        self.stop_accept();
        for h in self.conns.lock().unwrap_or_else(|e| e.into_inner()).drain(..) {
            let _ = h.join();
        }
    }

    fn stop_accept(&mut self) {
        self.stop.store(true, Ordering::Release);
        // unblock accept(): the flag is checked per accepted connection,
        // so a throwaway self-connect guarantees one more wakeup
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpFront {
    fn drop(&mut self) {
        // best effort: stop accepting; connection threads are detached
        // here (shutdown() is the orderly path that joins them)
        if self.accept.is_some() {
            self.stop_accept();
        }
    }
}

/// Blocking protocol client over one TCP connection. One request in
/// flight at a time (matching the per-connection server loop); open more
/// clients for concurrency.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to the TCP front-end")?;
        let read_half = stream.try_clone().context("cloning the client socket")?;
        Ok(Client { reader: BufReader::new(read_half), writer: BufWriter::new(stream) })
    }

    fn round_trip(&mut self, frame: &Frame) -> Result<Frame> {
        proto::write_frame(&mut self.writer, frame).context("sending request frame")?;
        self.writer.flush().context("flushing request frame")?;
        let reply = match proto::read_frame(&mut self.reader) {
            Ok(f) => f,
            Err(ProtoError::Eof) => bail!("server closed the connection"),
            Err(e) => return Err(anyhow!("{e}")),
        };
        if let Frame::Error { code, message } = reply {
            return Err(anyhow!(WireFail { code, message }));
        }
        Ok(reply)
    }

    /// Infer with no deadline and no version pin.
    pub fn infer(&mut self, name: &str, n_bits: u32, image: &[f32]) -> Result<InferReply> {
        self.infer_with(name, n_bits, image, 0, 0)
    }

    /// Infer with optional relative deadline (`deadline_ms`, 0 = none)
    /// and optional version pin (`version_pin`, 0 = none). A pinned
    /// request answered by any other version fails with
    /// [`ErrCode::PinMismatch`].
    pub fn infer_with(
        &mut self,
        name: &str,
        n_bits: u32,
        image: &[f32],
        deadline_ms: u32,
        version_pin: u32,
    ) -> Result<InferReply> {
        let req = Frame::Infer {
            name: name.to_string(),
            n_bits,
            version_pin,
            deadline_ms,
            image: image.to_vec(),
        };
        match self.round_trip(&req)? {
            Frame::Logits { version, latency_us, logits } => {
                Ok(InferReply { logits, version, latency_us })
            }
            other => bail!("expected Logits, got {other:?}"),
        }
    }

    /// Fetch the slot's terminal-outcome counters and latency quantiles.
    pub fn stats(&mut self, name: &str, n_bits: u32) -> Result<WireStats> {
        let req = Frame::Stats { name: name.to_string(), n_bits };
        match self.round_trip(&req)? {
            Frame::StatsReply(s) => Ok(s),
            other => bail!("expected StatsReply, got {other:?}"),
        }
    }

    /// Fetch the slot's health byte (0 Ready / 1 Degraded / 2
    /// Quarantined) and current serving version.
    pub fn health(&mut self, name: &str, n_bits: u32) -> Result<(u8, u32)> {
        let req = Frame::Health { name: name.to_string(), n_bits };
        match self.round_trip(&req)? {
            Frame::HealthReply { health, version } => Ok((health, version)),
            other => bail!("expected HealthReply, got {other:?}"),
        }
    }

    /// Hot-swap the slot to a server-local `.fxpa` artifact at `path`.
    /// Returns the installed version.
    pub fn swap(
        &mut self,
        name: &str,
        n_bits: u32,
        max_batch: u32,
        version_pin: u32,
        path: &str,
    ) -> Result<u32> {
        let req = Frame::Swap {
            name: name.to_string(),
            n_bits,
            max_batch,
            version_pin,
            path: path.to_string(),
        };
        match self.round_trip(&req)? {
            Frame::SwapReply { version } => Ok(version),
            other => bail!("expected SwapReply, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::IntModel;
    use crate::serve::{ModelKey, ModelSource, RegisterOpts, Registry, ServeConfig};
    use crate::testing::models;
    use crate::util::rng::Rng;

    fn tiny_server() -> (Arc<Server>, ModelKey) {
        let mut rng = Rng::new(11);
        let (man, ck) = models::lenet5ish(&mut rng, 2);
        let model = IntModel::build(&man, &ck).unwrap();
        let mut reg = Registry::new();
        let key = reg
            .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(2))
            .unwrap();
        (Arc::new(Server::new(reg, ServeConfig::new().workers(1))), key)
    }

    #[test]
    fn front_binds_ephemeral_port_and_shuts_down() {
        let (server, key) = tiny_server();
        let front = TcpFront::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
        let addr = front.local_addr();
        assert_ne!(addr.port(), 0, "ephemeral bind must resolve to a real port");
        {
            let mut c = Client::connect(addr).unwrap();
            let (health, version) = c.health(&key.name, key.n_bits).unwrap();
            assert_eq!((health, version), (0, 1));
        } // client drops → conn thread exits
        front.shutdown();
    }

    #[test]
    fn wire_fail_downcasts_with_its_pinned_code() {
        let (server, _key) = tiny_server();
        let front = TcpFront::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
        let mut c = Client::connect(front.local_addr()).unwrap();
        let err = c.infer("nope", 2, &[0.0; 4]).unwrap_err();
        let wf = err.downcast_ref::<WireFail>().expect("typed wire failure");
        assert_eq!(wf.code, ErrCode::UnknownModel);
        drop(c);
        front.shutdown();
    }
}
