//! Multi-model registry: named, bit-width-qualified handles to compiled
//! execution plans.
//!
//! A deployment typically serves several hard-quantized variants of the
//! same architecture side by side (the paper's Table 1 sweeps n_bits ∈
//! {2, 4, 8} over one net), so the registry key is `(name, n_bits)` — the
//! same network quantized at two widths is two distinct served models
//! with distinct plans, stats, and scratch pools.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::inference::{ExecPlan, IntModel};

/// Registry key: model name + quantization bit width.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ModelKey {
    pub name: String,
    pub n_bits: u32,
}

impl ModelKey {
    pub fn new(name: impl Into<String>, n_bits: u32) -> ModelKey {
        ModelKey { name: name.into(), n_bits }
    }
}

impl fmt::Display for ModelKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@w{}", self.name, self.n_bits)
    }
}

/// One registered model: the shared compiled plan plus the static facts
/// the server needs per request (resolved once at registration).
pub(crate) struct ModelEntry {
    pub(crate) plan: Arc<ExecPlan>,
    pub(crate) in_elems: usize,
    pub(crate) out_per_img: usize,
    /// micro-batch cap: the `max_batch` this model was registered with
    /// (the cached shared plan may have been compiled for a larger batch
    /// by an earlier `forward`; the server still honors the registered cap)
    pub(crate) max_batch: usize,
}

/// Name → plan registry a [`Server`](super::Server) is built from.
///
/// `register` pulls the model's *cache-backed* shared plan
/// ([`IntModel::shared_plan`]), so serving a model and calling its
/// `forward()` directly execute one and the same compiled artifact — no
/// second plan compilation, no drift between the two paths.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<ModelKey, ModelEntry>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register `model` under `name` (keyed together with its bit width).
    /// `max_batch` becomes the model's micro-batch cap: the server never
    /// coalesces more requests than the plan was compiled for.
    pub fn register(&mut self, name: &str, model: &IntModel, max_batch: usize) -> Result<ModelKey> {
        ensure!(max_batch >= 1, "register needs max_batch >= 1");
        let key = ModelKey::new(name, model.n_bits);
        ensure!(
            !self.models.contains_key(&key),
            "model {key} is already registered"
        );
        let plan = model
            .shared_plan(max_batch)
            .with_context(|| format!("compiling plan for {key}"))?;
        let entry = ModelEntry {
            in_elems: plan.in_elems(),
            out_per_img: plan.out_per_img(),
            max_batch: max_batch.min(plan.max_batch()),
            plan,
        };
        self.models.insert(key.clone(), entry);
        Ok(key)
    }

    /// Registered keys, in deterministic (sorted) order.
    pub fn keys(&self) -> Vec<ModelKey> {
        self.models.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub(crate) fn into_entries(self) -> BTreeMap<ModelKey, ModelEntry> {
        self.models
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::models;
    use crate::util::rng::Rng;

    #[test]
    fn same_name_different_bits_coexist() {
        let mut rng = Rng::new(1);
        let (m2, c2) = models::lenet5ish(&mut rng, 2);
        let (m8, c8) = models::lenet5ish(&mut rng, 8);
        let model2 = IntModel::build(&m2, &c2).unwrap();
        let model8 = IntModel::build(&m8, &c8).unwrap();
        let mut reg = Registry::new();
        let k2 = reg.register("lenet5", &model2, 4).unwrap();
        let k8 = reg.register("lenet5", &model8, 4).unwrap();
        assert_ne!(k2, k8);
        assert_eq!(reg.len(), 2);
        // duplicate key rejected
        assert!(reg.register("lenet5", &model2, 4).is_err());
        assert_eq!(format!("{k2}"), "lenet5@w2");
    }

    #[test]
    fn registry_reuses_the_models_shared_plan() {
        let mut rng = Rng::new(2);
        let (man, ck) = models::lenet5ish(&mut rng, 2);
        let model = IntModel::build(&man, &ck).unwrap();
        let plan = model.shared_plan(6).unwrap();
        let mut reg = Registry::new();
        reg.register("lenet5", &model, 6).unwrap();
        let entries = reg.into_entries();
        let entry = entries.values().next().unwrap();
        assert!(Arc::ptr_eq(&entry.plan, &plan), "registry compiled a second plan");
    }
}
