//! Multi-model registry: named, bit-width-qualified, *versioned* handles
//! to compiled execution plans.
//!
//! A deployment typically serves several hard-quantized variants of the
//! same architecture side by side (the paper's Table 1 sweeps n_bits ∈
//! {2, 4, 8} over one net), so models are slotted by `(name, n_bits)` —
//! the same network quantized at two widths is two distinct served models
//! with distinct plans, stats, and scratch pools. Within a slot, entries
//! carry a **version**: the deployment generation of the weights, which
//! [`Server::swap`](super::Server::swap) advances atomically at runtime.
//! Version numbers are monotonic over a slot's whole history and are
//! *burned* on rollback — a generation quarantined by the circuit
//! breaker can never be re-pinned; a replacement must be strictly newer.
//!
//! Models come from a [`ModelSource`]: either an in-process [`IntModel`]
//! (`InCode`) or a published `.fxpa` file on disk (`Artifact`), with
//! per-registration knobs in the [`RegisterOpts`] builder.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::inference::{ExecPlan, IntModel};

/// Registry key: model name + quantization bit width + deployment version.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ModelKey {
    pub name: String,
    pub n_bits: u32,
    /// deployment generation of the weights (1 = first install). Routing
    /// ignores it — a server slot is `(name, n_bits)` and always serves
    /// its *current* version — but responses and stats are pinned to it.
    pub version: u32,
}

impl ModelKey {
    /// Key at version 1 (the default for a first in-code registration).
    pub fn new(name: impl Into<String>, n_bits: u32) -> ModelKey {
        ModelKey::versioned(name, n_bits, 1)
    }

    pub fn versioned(name: impl Into<String>, n_bits: u32, version: u32) -> ModelKey {
        ModelKey { name: name.into(), n_bits, version }
    }

    /// The server routing slot: version-agnostic (name, bits) identity.
    pub(crate) fn slot(&self) -> (String, u32) {
        (self.name.clone(), self.n_bits)
    }
}

impl fmt::Display for ModelKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@w{}#v{}", self.name, self.n_bits, self.version)
    }
}

/// Where a served model's weights come from.
pub enum ModelSource<'a> {
    /// An in-process integer model (the plan is shared, not copied).
    InCode(&'a IntModel),
    /// A published `.fxpa` serving artifact on disk (`artifact::publish`).
    Artifact(&'a Path),
}

/// Registration knobs (builder: `RegisterOpts::new().max_batch(8)`).
#[derive(Clone, Copy, Debug)]
pub struct RegisterOpts {
    /// Micro-batch cap: the server never coalesces more requests than
    /// this. Default 1 (no batching).
    pub max_batch: usize,
    /// Version pin. For `InCode` sources this *sets* the version
    /// (default: 1 on register, current + 1 on swap); for `Artifact`
    /// sources the file's own model version is authoritative and a pin
    /// that disagrees is a registration error.
    pub version: Option<u32>,
}

impl Default for RegisterOpts {
    fn default() -> RegisterOpts {
        RegisterOpts { max_batch: 1, version: None }
    }
}

impl RegisterOpts {
    pub fn new() -> RegisterOpts {
        RegisterOpts::default()
    }

    pub fn max_batch(mut self, n: usize) -> RegisterOpts {
        self.max_batch = n;
        self
    }

    pub fn version(mut self, v: u32) -> RegisterOpts {
        self.version = Some(v);
        self
    }
}

/// One registered model version: the shared compiled plan plus the static
/// facts the server needs per request (resolved once at registration).
pub(crate) struct ModelEntry {
    pub(crate) plan: Arc<ExecPlan>,
    pub(crate) in_elems: usize,
    pub(crate) out_per_img: usize,
    /// micro-batch cap: the `max_batch` this model was registered with
    /// (the cached shared plan may have been compiled for a larger batch
    /// by an earlier `forward`; the server still honors the registered cap)
    pub(crate) max_batch: usize,
}

/// Resolve a source + opts into a keyed entry. `default_version` is used
/// for in-code sources with no pin (1 at registration; `cur + 1` on swap).
pub(crate) fn build_entry(
    name: &str,
    source: &ModelSource<'_>,
    opts: &RegisterOpts,
    default_version: u32,
) -> Result<(ModelKey, ModelEntry)> {
    ensure!(opts.max_batch >= 1, "registering {name} needs max_batch >= 1");
    let (key, plan) = match source {
        ModelSource::InCode(model) => {
            let version = opts.version.unwrap_or(default_version);
            ensure!(version >= 1, "{name}: model versions start at 1");
            let key = ModelKey::versioned(name, model.n_bits, version);
            let plan = model
                .shared_plan(opts.max_batch)
                .with_context(|| format!("compiling plan for {key}"))?;
            (key, plan)
        }
        ModelSource::Artifact(path) => {
            let art = crate::artifact::load(path)
                .with_context(|| format!("loading artifact for {name}"))?;
            if let Some(pin) = opts.version {
                ensure!(
                    art.version == pin,
                    "{}: artifact is model version {}, registration pinned v{pin}",
                    path.display(),
                    art.version
                );
            }
            let key = ModelKey::versioned(name, art.model.n_bits, art.version);
            let plan = art
                .model
                .shared_plan(opts.max_batch)
                .with_context(|| format!("compiling plan for {key}"))?;
            (key, plan)
        }
    };
    let entry = ModelEntry {
        in_elems: plan.in_elems(),
        out_per_img: plan.out_per_img(),
        max_batch: opts.max_batch.min(plan.max_batch()),
        plan,
    };
    Ok((key, entry))
}

/// Name → plan registry a [`Server`](super::Server) is built from.
///
/// In-code registration pulls the model's *cache-backed* shared plan
/// ([`IntModel::shared_plan`]), so serving a model and calling its
/// `forward()` directly execute one and the same compiled artifact — no
/// second plan compilation, no drift between the two paths. Artifact
/// registration loads + verifies the `.fxpa` and compiles its plan once.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<ModelKey, ModelEntry>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a model under `name` from an in-code plan or a published
    /// artifact. Each `(name, n_bits)` slot holds one entry; the key's
    /// version is 1 for unpinned in-code sources, the artifact's own model
    /// version for `Artifact` sources (later generations are installed at
    /// runtime via [`Server::swap`](super::Server::swap)).
    pub fn add(
        &mut self,
        name: &str,
        source: ModelSource<'_>,
        opts: &RegisterOpts,
    ) -> Result<ModelKey> {
        let (key, entry) = build_entry(name, &source, opts, 1)?;
        ensure!(
            !self.models.keys().any(|k| k.slot() == key.slot()),
            "model slot {}@w{} is already registered",
            key.name,
            key.n_bits
        );
        self.models.insert(key.clone(), entry);
        Ok(key)
    }

    /// Pre-`ModelSource` call shape, kept so existing suites compile with
    /// a one-line diff. Equivalent to
    /// `add(name, ModelSource::InCode(model), &RegisterOpts::new().max_batch(max_batch))`.
    #[deprecated(note = "use Registry::add with a ModelSource and RegisterOpts")]
    pub fn register(&mut self, name: &str, model: &IntModel, max_batch: usize) -> Result<ModelKey> {
        self.add(name, ModelSource::InCode(model), &RegisterOpts::new().max_batch(max_batch))
    }

    /// Registered keys, in deterministic (sorted) order.
    pub fn keys(&self) -> Vec<ModelKey> {
        self.models.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub(crate) fn into_entries(self) -> BTreeMap<ModelKey, ModelEntry> {
        self.models
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::models;
    use crate::util::rng::Rng;

    #[test]
    fn same_name_different_bits_coexist() {
        let mut rng = Rng::new(1);
        let (m2, c2) = models::lenet5ish(&mut rng, 2);
        let (m8, c8) = models::lenet5ish(&mut rng, 8);
        let model2 = IntModel::build(&m2, &c2).unwrap();
        let model8 = IntModel::build(&m8, &c8).unwrap();
        let mut reg = Registry::new();
        let opts = RegisterOpts::new().max_batch(4);
        let k2 = reg.add("lenet5", ModelSource::InCode(&model2), &opts).unwrap();
        let k8 = reg.add("lenet5", ModelSource::InCode(&model8), &opts).unwrap();
        assert_ne!(k2, k8);
        assert_eq!(reg.len(), 2);
        // duplicate (name, n_bits) slot rejected, even at another version
        assert!(reg.add("lenet5", ModelSource::InCode(&model2), &opts).is_err());
        let pinned = RegisterOpts::new().max_batch(4).version(9);
        assert!(reg.add("lenet5", ModelSource::InCode(&model2), &pinned).is_err());
        assert_eq!(format!("{k2}"), "lenet5@w2#v1");
    }

    #[test]
    fn registry_reuses_the_models_shared_plan() {
        let mut rng = Rng::new(2);
        let (man, ck) = models::lenet5ish(&mut rng, 2);
        let model = IntModel::build(&man, &ck).unwrap();
        let plan = model.shared_plan(6).unwrap();
        let mut reg = Registry::new();
        reg.add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().max_batch(6)).unwrap();
        let entries = reg.into_entries();
        let entry = entries.values().next().unwrap();
        assert!(Arc::ptr_eq(&entry.plan, &plan), "registry compiled a second plan");
    }

    #[test]
    fn version_pinning_sets_the_key() {
        let mut rng = Rng::new(3);
        let (man, ck) = models::lenet5ish(&mut rng, 4);
        let model = IntModel::build(&man, &ck).unwrap();
        let mut reg = Registry::new();
        let k = reg
            .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().version(7))
            .unwrap();
        assert_eq!(k.version, 7);
        assert_eq!(format!("{k}"), "lenet5@w4#v7");
        // version 0 is reserved (versions are 1-based)
        let mut reg2 = Registry::new();
        assert!(reg2
            .add("lenet5", ModelSource::InCode(&model), &RegisterOpts::new().version(0))
            .is_err());
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_register_wrapper_still_works() {
        let mut rng = Rng::new(4);
        let (man, ck) = models::lenet5ish(&mut rng, 2);
        let model = IntModel::build(&man, &ck).unwrap();
        let mut reg = Registry::new();
        let k = reg.register("lenet5", &model, 4).unwrap();
        assert_eq!((k.name.as_str(), k.n_bits, k.version), ("lenet5", 2, 1));
    }
}
