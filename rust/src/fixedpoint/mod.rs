//! Fixed-point substrate: the paper's quantizer, the step-size solver, and
//! a fixed-point scalar type used by the integer inference engine.
//!
//! The quantizer (Eq. 1) must match `python/compile/kernels/ref.py`
//! bit-for-bit — rounding is half-away-from-zero so Q is odd, and the
//! integer range is symmetric: `[-(2^{N-1}-1), 2^{N-1}-1]` (section 3.1).

mod fxp;
mod quantizer;
mod solver;

pub use fxp::{Fxp, round_shift as fxp_round_shift};
pub use quantizer::{
    clip_bound, mode_index, mode_indices, quant_error, quantize, quantize_slice, Quantizer,
};
pub use solver::{optimal_delta, optimal_delta_refined};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    #[test]
    fn fig2_transfer_curve() {
        // Figure 2: the 2-bit quantizer with delta = 1 has ternary plateaus.
        let q = Quantizer::new(2, 1.0);
        for i in 0..=400 {
            let x = -2.0 + i as f32 * 0.01;
            let y = q.apply(x);
            // round-half-away-from-zero: +-0.5 land on the outer modes
            if x <= -0.5 {
                assert_eq!(y, -1.0, "x={x}");
            } else if x < 0.5 {
                assert_eq!(y, 0.0, "x={x}");
            } else {
                assert_eq!(y, 1.0, "x={x}");
            }
        }
    }

    #[test]
    fn prop_solver_beats_neighbours() {
        // optimality of the brute-force argmin over f: no neighbouring
        // exponent does better (property over random weight samples)
        forall(32, |rng: &mut Rng| {
            let n = 16 + rng.below(500);
            let sigma = rng.range_f32(0.01, 2.0);
            let w: Vec<f32> = (0..n).map(|_| rng.normal() * sigma).collect();
            let (delta, f) = optimal_delta(&w, 2);
            let err = quant_error(&w, delta, 2);
            for nf in [f - 1, f + 1] {
                let nd = (2.0f32).powi(-nf);
                assert!(
                    quant_error(&w, nd, 2) + 1e-9 >= err,
                    "f={f} beaten by {nf}"
                );
            }
        });
    }
}
