//! The symmetric uniform N-bit quantizer Q_N(x; delta) of Eq. 1.

/// Round half away from zero (keeps the quantizer odd: Q(-x) = -Q(x)).
#[inline]
pub(crate) fn round_away(x: f32) -> f32 {
    (x.abs() + 0.5).floor().copysign(x)
}

/// Largest mantissa magnitude for an N-bit symmetric code: 2^{N-1} - 1.
#[inline]
pub fn qmax(n_bits: u32) -> i32 {
    (1i32 << (n_bits - 1)) - 1
}

/// The clipping bound of section 3.4: delta * (2^{N-1} - 1).
#[inline]
pub fn clip_bound(n_bits: u32, delta: f32) -> f32 {
    delta * qmax(n_bits) as f32
}

/// Q_N(x; delta): scale, round, clip, rescale (Eq. 1).
#[inline]
pub fn quantize(x: f32, delta: f32, n_bits: u32) -> f32 {
    let q = qmax(n_bits) as f32;
    round_away(x / delta).clamp(-q, q) * delta
}

/// Quantize a slice into `out`.
pub fn quantize_slice(xs: &[f32], delta: f32, n_bits: u32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    let q = qmax(n_bits) as f32;
    let inv = 1.0 / delta;
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = round_away(x * inv).clamp(-q, q) * delta;
    }
}

/// The signed mode index clip(round(x/delta)) in [-qmax, qmax] — the
/// "fixed-point annotation" whose epoch-to-epoch changes Figure 4 plots.
#[inline]
pub fn mode_index(x: f32, delta: f32, n_bits: u32) -> i8 {
    let q = qmax(n_bits) as f32;
    round_away(x / delta).clamp(-q, q) as i8
}

/// Mode indices for a whole tensor.
pub fn mode_indices(xs: &[f32], delta: f32, n_bits: u32) -> Vec<i8> {
    xs.iter().map(|&x| mode_index(x, delta, n_bits)).collect()
}

/// Sum of squared quantization error ||x - Q(x)||^2 (the R term, Eq. 3,
/// before the 1/M normalization).
pub fn quant_error(xs: &[f32], delta: f32, n_bits: u32) -> f64 {
    xs.iter()
        .map(|&x| {
            let e = (x - quantize(x, delta, n_bits)) as f64;
            e * e
        })
        .sum()
}

/// A bound quantizer: N bits + step size, convenient for per-layer use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quantizer {
    pub n_bits: u32,
    pub delta: f32,
}

impl Quantizer {
    pub fn new(n_bits: u32, delta: f32) -> Self {
        assert!(n_bits >= 2, "need at least 2 bits for a symmetric code");
        assert!(delta > 0.0);
        Quantizer { n_bits, delta }
    }

    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        quantize(x, self.delta, self.n_bits)
    }

    #[inline]
    pub fn mode(&self, x: f32) -> i8 {
        mode_index(x, self.delta, self.n_bits)
    }

    pub fn clip_bound(&self) -> f32 {
        clip_bound(self.n_bits, self.delta)
    }

    /// Number of codebook entries: 2^N - 1 (symmetric, zero included).
    pub fn levels(&self) -> usize {
        (1usize << self.n_bits) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    #[test]
    fn known_values_2bit() {
        // delta = 1: codebook {-1, 0, 1}; 0.5 rounds away from zero
        for (x, want) in [
            (0.0, 0.0),
            (0.4, 0.0),
            (0.5, 1.0),
            (-0.5, -1.0),
            (1.7, 1.0),
            (99.0, 1.0),
            (-99.0, -1.0),
        ] {
            assert_eq!(quantize(x, 1.0, 2), want, "x={x}");
        }
    }

    #[test]
    fn known_values_3bit() {
        // delta = 0.5, qmax = 3: codebook {-1.5 ... 1.5} step 0.5
        assert_eq!(quantize(0.6, 0.5, 3), 0.5);
        assert_eq!(quantize(0.76, 0.5, 3), 1.0);
        assert_eq!(quantize(5.0, 0.5, 3), 1.5);
        assert_eq!(quantize(-0.24, 0.5, 3), 0.0);
    }

    #[test]
    fn prop_idempotent_odd_bounded() {
        forall(64, |rng: &mut Rng| {
            let n_bits = 2 + rng.below(6) as u32;
            let f = rng.below(9) as i32 - 4;
            let delta = (2.0f32).powi(-f);
            let x = rng.normal() * rng.range_f32(0.01, 4.0);
            let q = quantize(x, delta, n_bits);
            // idempotent
            assert_eq!(quantize(q, delta, n_bits), q);
            // odd
            assert_eq!(quantize(-x, delta, n_bits), -q);
            // bounded
            assert!(q.abs() <= clip_bound(n_bits, delta) + 1e-6);
            // codebook membership: q / delta is an integer
            let m = q / delta;
            assert!((m - m.round()).abs() < 1e-5);
        });
    }

    #[test]
    fn prop_error_bounded_inside_domain() {
        forall(64, |rng: &mut Rng| {
            let delta = 0.25;
            let x = rng.range_f32(-0.25, 0.25); // inside clip range for 2 bits
            assert!((x - quantize(x, delta, 2)).abs() <= delta / 2.0 + 1e-6);
        });
    }

    #[test]
    fn mode_index_matches_quantizer() {
        forall(64, |rng: &mut Rng| {
            let x = rng.normal();
            let m = mode_index(x, 0.5, 2);
            assert_eq!(m as f32 * 0.5, quantize(x, 0.5, 2));
        });
    }

    #[test]
    fn quantizer_levels() {
        assert_eq!(Quantizer::new(2, 1.0).levels(), 3);
        assert_eq!(Quantizer::new(3, 1.0).levels(), 7);
        assert_eq!(Quantizer::new(8, 1.0).levels(), 255);
    }

    #[test]
    #[should_panic]
    fn one_bit_rejected() {
        Quantizer::new(1, 1.0);
    }
}
