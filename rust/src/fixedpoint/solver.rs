//! Step-size solver: Algorithm 1, lines 2–5.
//!
//! ```text
//! min_{Delta_l} ||W_l - Q_N(W_l; Delta_l)||^2   s.t. Delta_l = 2^{-f}, f in Z
//! ```
//!
//! The feasible set is a one-dimensional integer lattice, so brute force
//! over a generous exponent window is exact and fast (O(|window| * M)).

use super::quantizer::quant_error;

/// Default exponent search window (covers deltas from 2^-12 to 2^12).
pub const F_RANGE: (i32, i32) = (-12, 12);

/// Exact argmin over f in [F_RANGE]: returns (delta, f) with delta = 2^-f.
pub fn optimal_delta(w: &[f32], n_bits: u32) -> (f32, i32) {
    optimal_delta_in(w, n_bits, F_RANGE)
}

/// Exact argmin over a caller-supplied window.
pub fn optimal_delta_in(w: &[f32], n_bits: u32, range: (i32, i32)) -> (f32, i32) {
    assert!(!w.is_empty(), "cannot solve step size of an empty tensor");
    let mut best = (f32::INFINITY as f64, range.0);
    for f in range.0..=range.1 {
        let delta = (2.0f32).powi(-f);
        let err = quant_error(w, delta, n_bits);
        if err < best.0 {
            best = (err, f);
        }
    }
    ((2.0f32).powi(-best.1), best.1)
}

/// Seeded variant: start the window around the magnitude of the weights
/// (max|w| should land near the top of the code range) and widen by +-3.
/// Equivalent result to `optimal_delta` on every distribution we generate,
/// ~8x fewer error evaluations on large tensors.
pub fn optimal_delta_refined(w: &[f32], n_bits: u32) -> (f32, i32) {
    let amax = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if amax == 0.0 {
        return (1.0, 0);
    }
    let qm = super::quantizer::qmax(n_bits) as f32;
    // want delta * qmax ~ amax  =>  f ~ log2(qmax / amax)
    let f0 = (qm / amax).log2().round() as i32;
    let lo = (f0 - 3).max(F_RANGE.0);
    let hi = (f0 + 3).min(F_RANGE.1);
    optimal_delta_in(w, n_bits, (lo.min(hi), hi.max(lo)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    #[test]
    fn exact_on_synthetic_trimodal() {
        // weights exactly on {-0.25, 0, 0.25}: delta = 0.25 gives zero error
        let w: Vec<f32> = (0..300)
            .map(|i| [(-0.25f32), 0.0, 0.25][i % 3])
            .collect();
        let (delta, f) = optimal_delta(&w, 2);
        assert_eq!(f, 2);
        assert_eq!(delta, 0.25);
        assert_eq!(quant_error_of(&w, delta), 0.0);
    }

    fn quant_error_of(w: &[f32], delta: f32) -> f64 {
        w.iter()
            .map(|&x| {
                let e = (x - quantize(x, delta, 2)) as f64;
                e * e
            })
            .sum()
    }

    #[test]
    fn prop_global_optimality() {
        forall(24, |rng: &mut Rng| {
            let n = 8 + rng.below(256);
            let sigma = rng.range_f32(1e-3, 8.0);
            let w: Vec<f32> = (0..n).map(|_| rng.normal() * sigma).collect();
            let (delta, _) = optimal_delta(&w, 2);
            let best = quant_error_of(&w, delta);
            for f in F_RANGE.0..=F_RANGE.1 {
                let d = (2.0f32).powi(-f);
                assert!(quant_error_of(&w, d) >= best - 1e-9);
            }
        });
    }

    #[test]
    fn prop_refined_matches_exact() {
        forall(24, |rng: &mut Rng| {
            let n = 32 + rng.below(512);
            let sigma = rng.range_f32(1e-2, 4.0);
            let w: Vec<f32> = (0..n).map(|_| rng.normal() * sigma).collect();
            let n_bits = 2 + rng.below(3) as u32;
            assert_eq!(
                optimal_delta(&w, n_bits).1,
                optimal_delta_refined(&w, n_bits).1
            );
        });
    }

    #[test]
    fn scales_with_sigma() {
        // larger weights need larger delta (smaller f)
        let mut rng = Rng::new(0);
        let small: Vec<f32> = (0..1000).map(|_| rng.normal() * 0.05).collect();
        let big: Vec<f32> = (0..1000).map(|_| rng.normal() * 2.0).collect();
        assert!(optimal_delta(&small, 2).1 > optimal_delta(&big, 2).1);
    }

    #[test]
    fn zero_tensor_refined() {
        assert_eq!(optimal_delta_refined(&[0.0; 8], 2), (1.0, 0));
    }

    #[test]
    #[should_panic]
    fn empty_rejected() {
        optimal_delta(&[], 2);
    }
}
