//! `Fxp`: a signed fixed-point scalar (mantissa * 2^-frac_bits).
//!
//! This is the number type of the integer inference engine. All arithmetic
//! is integer adds / multiplies / shifts — the paper's section 3.1 claim
//! that the constrained quantizer enables pure fixed-point hardware is
//! demonstrated by running a whole forward pass on these.

use anyhow::{bail, Result};

/// Signed fixed-point value: `mantissa * 2^-frac`. The mantissa is i32; the
/// engine's accumulators widen to i64 before rescaling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fxp {
    pub mantissa: i32,
    pub frac: i32, // binary point position f: value = m * 2^-f
}

impl Fxp {
    pub const ZERO: Fxp = Fxp { mantissa: 0, frac: 0 };

    /// Encode `x` with `frac` fractional bits (round half away from zero).
    pub fn from_f32(x: f32, frac: i32) -> Result<Fxp> {
        let scaled = (x as f64) * (2f64.powi(frac));
        let m = (scaled.abs() + 0.5).floor().copysign(scaled);
        if m.abs() > i32::MAX as f64 {
            bail!("fixed-point overflow encoding {x} with frac={frac}");
        }
        Ok(Fxp { mantissa: m as i32, frac })
    }

    pub fn to_f32(self) -> f32 {
        self.mantissa as f32 * (2f32).powi(-self.frac)
    }

    /// Rescale to `frac` fractional bits with round-half-away-from-zero —
    /// a pure shift (+ rounding addend) in hardware.
    pub fn rescale(self, frac: i32) -> Fxp {
        if frac >= self.frac {
            return Fxp {
                mantissa: (self.mantissa as i64)
                    .checked_shl((frac - self.frac) as u32)
                    .map(|v| v.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
                    .unwrap_or(if self.mantissa >= 0 { i32::MAX } else { i32::MIN }),
                frac,
            };
        }
        let shift = self.frac - frac;
        Fxp { mantissa: round_shift(self.mantissa as i64, shift) as i32, frac }
    }
}

/// Exact product: mantissas multiply, binary points add. Integer-only.
impl std::ops::Mul for Fxp {
    type Output = Fxp;

    fn mul(self, other: Fxp) -> Fxp {
        Fxp {
            mantissa: (self.mantissa as i64 * other.mantissa as i64)
                .clamp(i32::MIN as i64, i32::MAX as i64) as i32,
            frac: self.frac + other.frac,
        }
    }
}

/// Sum after aligning binary points (shift the coarser operand up).
impl std::ops::Add for Fxp {
    type Output = Fxp;

    fn add(self, other: Fxp) -> Fxp {
        let frac = self.frac.max(other.frac);
        let a = (self.mantissa as i64) << (frac - self.frac);
        let b = (other.mantissa as i64) << (frac - other.frac);
        Fxp {
            mantissa: (a + b).clamp(i32::MIN as i64, i32::MAX as i64) as i32,
            frac,
        }
    }
}

/// `v / 2^shift` with round-half-away-from-zero — the requantization
/// primitive of the integer engine (works on i64 accumulators).
#[inline]
pub fn round_shift(v: i64, shift: i32) -> i64 {
    if shift <= 0 {
        return v << (-shift);
    }
    let half = 1i64 << (shift - 1);
    if v >= 0 {
        (v + half) >> shift
    } else {
        -((-v + half) >> shift)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_on_grid() {
        for f in -3..10 {
            let delta = (2.0f32).powi(-f);
            for m in -5..=5 {
                let x = m as f32 * delta;
                let e = Fxp::from_f32(x, f).unwrap();
                assert_eq!(e.mantissa, m);
                assert_eq!(e.to_f32(), x);
            }
        }
    }

    #[test]
    fn prop_encode_error_half_ulp() {
        forall(64, |rng: &mut Rng| {
            let frac = rng.below(16) as i32;
            let x = rng.normal() * 4.0;
            let e = Fxp::from_f32(x, frac).unwrap();
            let ulp = (2.0f32).powi(-frac);
            assert!((e.to_f32() - x).abs() <= ulp / 2.0 + 1e-6, "x={x} frac={frac}");
        });
    }

    #[test]
    fn mul_is_exact() {
        let a = Fxp::from_f32(1.25, 2).unwrap(); // m=5, f=2
        let b = Fxp::from_f32(-0.5, 1).unwrap(); // m=-1, f=1
        let c = a * b;
        assert_eq!(c.to_f32(), -0.625);
        assert_eq!(c.frac, 3);
    }

    #[test]
    fn add_aligns_points() {
        let a = Fxp::from_f32(1.5, 1).unwrap();
        let b = Fxp::from_f32(0.25, 2).unwrap();
        assert_eq!((a + b).to_f32(), 1.75);
        assert_eq!((b + a).to_f32(), 1.75);
    }

    #[test]
    fn rescale_rounds_away() {
        let x = Fxp { mantissa: 3, frac: 1 }; // 1.5
        assert_eq!(x.rescale(0).mantissa, 2); // 1.5 -> 2
        let y = Fxp { mantissa: -3, frac: 1 }; // -1.5
        assert_eq!(y.rescale(0).mantissa, -2);
        let z = Fxp { mantissa: 5, frac: 2 }; // 1.25
        assert_eq!(z.rescale(1).to_f32(), 1.5); // 1.25 -> 1.5 (half away)
    }

    #[test]
    fn round_shift_matches_float() {
        forall(128, |rng: &mut Rng| {
            let v = (rng.next_u64() as i64) >> 34; // ~30-bit values
            let s = 1 + rng.below(8) as i32;
            let want = {
                let f = v as f64 / (1i64 << s) as f64;
                (f.abs() + 0.5).floor().copysign(f) as i64
            };
            assert_eq!(round_shift(v, s), want, "v={v} s={s}");
        });
    }

    #[test]
    fn overflow_rejected() {
        assert!(Fxp::from_f32(1e9, 20).is_err());
    }
}
