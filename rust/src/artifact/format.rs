//! `.fxpa` binary layout: header, payload codec, and CRC-32 integrity.
//!
//! Layout (little-endian throughout):
//!
//! ```text
//!   offset  size  field
//!        0     8  magic b"SYMOGFXA"
//!        8     4  u32 format_version   (this build writes/reads 1)
//!       12     4  u32 model_version    (serving version of the payload)
//!       16     8  u64 payload_len
//!       24     4  u32 payload_crc32    (IEEE CRC-32 of the payload bytes)
//!       28     …  payload
//! ```
//!
//! Payload:
//!
//! ```text
//!   u32 manifest_len, manifest JSON (the full model manifest, embedded)
//!   u32 n_quant; per quantized tensor (qidx order):
//!       u32 numel, i32 frac, packed codes ceil(numel * n_bits / 8)
//!   u32 n_aux; per aux tensor (bias / BN gamma-beta / running stats):
//!       u32 name_len + name, u8 ndim, u32 dims[], f32 data
//! ```
//!
//! Every decode failure names the offending file and section; magic,
//! format-version, length, and checksum mismatches are four *distinct*
//! errors so corruption is distinguishable from version skew.

use std::path::Path;

use anyhow::{bail, ensure, Result};

pub(crate) const MAGIC: &[u8; 8] = b"SYMOGFXA";
pub(crate) const FORMAT_VERSION: u32 = 1;
pub(crate) const HEADER_LEN: usize = 28;

/// IEEE CRC-32 lookup table (polynomial 0xEDB88320, reflected).
const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// IEEE CRC-32 (the zlib/PNG/gzip checksum).
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Parsed `.fxpa` header.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Header {
    pub(crate) model_version: u32,
    pub(crate) payload_len: u64,
    pub(crate) payload_crc: u32,
}

/// Serialize a header for `payload` (format version pinned to this build's).
pub(crate) fn write_header(out: &mut Vec<u8>, model_version: u32, payload: &[u8]) {
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&model_version.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
}

/// Validate magic + format version and read the header fields. `path` is
/// used only for error messages.
pub(crate) fn parse_header(bytes: &[u8], path: &Path) -> Result<Header> {
    ensure!(
        bytes.len() >= HEADER_LEN,
        "{}: truncated .fxpa — {} bytes is smaller than the {HEADER_LEN}-byte header",
        path.display(),
        bytes.len()
    );
    if &bytes[..8] != MAGIC {
        if &bytes[..8] == b"SYMGFXP1" {
            bail!(
                "{}: this is a .fxpm packed model, not a .fxpa serving artifact — \
                 load it with quant::packed::read_packed or republish via artifact::publish",
                path.display()
            );
        }
        bail!("{}: not a .fxpa serving artifact (bad magic {:02x?})", path.display(), &bytes[..8]);
    }
    let u32_at = |off: usize| u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
    let fmt = u32_at(8);
    ensure!(fmt != 0, "{}: corrupt header — format version 0 is never written", path.display());
    ensure!(
        fmt <= FORMAT_VERSION,
        "{}: format version {fmt} is newer than this build supports ({FORMAT_VERSION}) — \
         .fxpa artifacts are not forward-compatible, upgrade the serving binary",
        path.display()
    );
    Ok(Header {
        model_version: u32_at(12),
        payload_len: u64::from_le_bytes(bytes[16..24].try_into().unwrap()),
        payload_crc: u32_at(24),
    })
}

/// Bounds-checked little-endian reader over an in-memory payload. Each
/// read names the section it was decoding, so a truncated or corrupt
/// payload produces "truncated payload reading <what>" rather than a
/// generic I/O error.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let left = self.buf.len() - self.pos;
        ensure!(
            n <= left,
            "truncated payload reading {what}: need {n} bytes at offset {}, only {left} left",
            self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub(crate) fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub(crate) fn i32(&mut self, what: &str) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub(crate) fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let raw = self.take(n * 4, what)?;
        Ok(raw.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
    }

    pub(crate) fn str(&mut self, n: usize, what: &str) -> Result<&'a str> {
        std::str::from_utf8(self.take(n, what)?)
            .map_err(|e| anyhow::anyhow!("{what} is not valid UTF-8: {e}"))
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // canonical CRC-32 test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // sensitivity: one flipped bit changes the sum
        assert_ne!(crc32(b"123456789"), crc32(b"123456788"));
    }

    #[test]
    fn header_roundtrips() {
        let payload = b"hello payload";
        let mut buf = Vec::new();
        write_header(&mut buf, 7, payload);
        assert_eq!(buf.len(), HEADER_LEN);
        let h = parse_header(&buf, Path::new("x.fxpa")).unwrap();
        assert_eq!(h.model_version, 7);
        assert_eq!(h.payload_len, payload.len() as u64);
        assert_eq!(h.payload_crc, crc32(payload));
    }

    #[test]
    fn header_rejections_are_distinct() {
        let mut buf = Vec::new();
        write_header(&mut buf, 1, b"p");
        let p = Path::new("bad.fxpa");

        let short = parse_header(&buf[..10], p).unwrap_err().to_string();
        assert!(short.contains("smaller than the 28-byte header"), "{short}");

        let mut wrong = buf.clone();
        wrong[..8].copy_from_slice(b"SYMGFXP1");
        let fxpm = parse_header(&wrong, p).unwrap_err().to_string();
        assert!(fxpm.contains(".fxpm packed model"), "{fxpm}");

        wrong[..8].copy_from_slice(b"GARBAGE!");
        let magic = parse_header(&wrong, p).unwrap_err().to_string();
        assert!(magic.contains("bad magic"), "{magic}");

        let mut newer = buf.clone();
        newer[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        let fwd = parse_header(&newer, p).unwrap_err().to_string();
        assert!(fwd.contains("not forward-compatible"), "{fwd}");
    }

    #[test]
    fn cursor_reports_section_names() {
        let mut c = Cursor::new(&[1, 0, 0, 0, 9]);
        assert_eq!(c.u32("count").unwrap(), 1);
        assert_eq!(c.remaining(), 1);
        let e = c.u32("tensor body").unwrap_err().to_string();
        assert!(e.contains("tensor body") && e.contains("offset 4"), "{e}");
        // the failed read consumed nothing
        assert_eq!(c.u8("tail").unwrap(), 9);
    }
}
