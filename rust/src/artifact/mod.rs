//! `.fxpa` — versioned serving artifacts: publish, verify, load, plan.
//!
//! This is the deployment hand-off the paper's fixed-point story ends in:
//! training produces hard-quantized weights (i8 mantissas + power-of-two
//! deltas), and a serving fleet wants them as a single integrity-checked
//! file it can load straight into a compiled [`ExecPlan`] — no float
//! weights, no re-derived quantization state, no training code.
//!
//! * [`publish`] exports any `(Manifest, Checkpoint)` pair — the exact
//!   inputs [`IntModel::build`] consumes, so anything servable in-code is
//!   publishable — quantizing weights to packed codes with the checkpoint's
//!   `__deltas__` during packing. [`publish_native`] does the same for a
//!   pure-Rust [`NativeModel`] straight out of the trainer.
//! * [`load`] reads the file back into a ready [`IntModel`]. Deltas travel
//!   as per-tensor `frac` exponents, so the loader reconstructs
//!   `delta = 2^-frac` exactly; because every stored weight is on the
//!   codebook (`m · delta` with delta a power of two), the loaded model's
//!   logits are **bit-identical** to the source model's
//!   (`tests/artifact_roundtrip.rs`).
//! * The header carries a **format version** (layout compatibility; this
//!   build speaks version 1 and refuses newer files explicitly) and a
//!   **model version** (which deployment of this model the payload is —
//!   the hot-swap handle `serve::Server::swap` keys on).
//! * A CRC-32 over the payload plus per-section bounds checks turn disk
//!   corruption into named errors instead of garbage weights; the CRC is
//!   re-verified over the exact bytes handed to the planner, so a payload
//!   mutated between validation and planning (TOCTOU) is refused too.
//!
//! Publishing is atomic: the file is written to a `.tmp` sibling and
//! renamed into place, so a watcher never observes a half-written artifact.
//!
//! See `format.rs` for the byte layout and DESIGN.md §"Serving artifacts
//! and hot-swap".
//!
//! [`IntModel::build`]: crate::inference::IntModel::build
//! [`NativeModel`]: crate::train::NativeModel
//! [`ExecPlan`]: crate::inference::ExecPlan

pub(crate) mod format;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::coordinator::{Checkpoint, Kind, Tensor};
use crate::inference::{ExecPlan, IntModel};
use crate::quant::packed::{pack_codes, unpack_codes};
use crate::runtime::{Manifest, ParamMeta};
use crate::train::NativeModel;

use format::Cursor;

/// Publishing knobs (builder-style, like `serve::RegisterOpts`).
#[derive(Clone, Copy, Debug)]
pub struct PublishOpts {
    /// Model version stamped into the header; serving uses it for
    /// monotonic hot-swap ordering. Must be >= 1.
    pub version: u32,
}

impl Default for PublishOpts {
    fn default() -> PublishOpts {
        PublishOpts { version: 1 }
    }
}

impl PublishOpts {
    pub fn new() -> PublishOpts {
        PublishOpts::default()
    }

    pub fn version(mut self, v: u32) -> PublishOpts {
        self.version = v;
        self
    }
}

/// What [`publish`] wrote.
#[derive(Clone, Copy, Debug)]
pub struct ArtifactInfo {
    pub version: u32,
    /// total file size on disk
    pub bytes: u64,
    pub quant_tensors: usize,
    pub aux_tensors: usize,
}

/// A loaded-and-verified `.fxpa`: the embedded manifest, the header's
/// model version, and a ready-to-plan [`IntModel`].
pub struct LoadedArtifact {
    pub path: PathBuf,
    pub manifest: Manifest,
    pub version: u32,
    pub model: IntModel,
}

impl LoadedArtifact {
    /// Compile (or fetch the cached) execution plan — same cache-backed
    /// shared plan `forward` and the serving registry use.
    pub fn plan(&self, max_batch: usize) -> Result<Arc<ExecPlan>> {
        self.model.shared_plan(max_batch)
    }
}

/// Quantized params in qidx order — the canonical on-disk tensor order.
fn quant_params(man: &Manifest) -> Vec<(&ParamMeta, usize)> {
    let mut quant: Vec<(&ParamMeta, usize)> =
        man.params.iter().filter_map(|p| p.qidx.map(|q| (p, q))).collect();
    quant.sort_by_key(|(_, q)| *q);
    quant
}

fn encode_payload(man: &Manifest, ck: &Checkpoint) -> Result<(Vec<u8>, usize, usize)> {
    let deltas = &ck.find("__deltas__").context("checkpoint has no __deltas__ tensor")?.data;
    let man_json = man.to_json();
    let mut out = Vec::new();
    out.extend_from_slice(&(man_json.len() as u32).to_le_bytes());
    out.extend_from_slice(man_json.as_bytes());

    let quant = quant_params(man);
    out.extend_from_slice(&(quant.len() as u32).to_le_bytes());
    let qmax = ((1i32 << (man.n_bits - 1)) - 1) as f32;
    for (p, qidx) in &quant {
        let t = ck
            .find(&p.name)
            .with_context(|| format!("checkpoint is missing quantized tensor {}", p.name))?;
        ensure!(
            t.data.len() == p.numel(),
            "{}: checkpoint has {} elements, manifest says {}",
            p.name,
            t.data.len(),
            p.numel()
        );
        ensure!(*qidx < deltas.len(), "{}: qidx {qidx} out of range", p.name);
        let delta = deltas[*qidx];
        ensure!(delta > 0.0, "{}: non-positive delta {delta}", p.name);
        // same rounding as QWeight::encode / fixedpoint::quantize
        // (round-half-away-from-zero, clamp to the symmetric codebook), so
        // loading reproduces the in-code IntModel's mantissas exactly
        let frac = (-delta.log2()).round() as i32;
        let mantissas: Vec<i8> = t
            .data
            .iter()
            .map(|&w| {
                let s = w / delta;
                (s.abs() + 0.5).floor().copysign(s).clamp(-qmax, qmax) as i8
            })
            .collect();
        out.extend_from_slice(&(t.data.len() as u32).to_le_bytes());
        out.extend_from_slice(&frac.to_le_bytes());
        out.extend_from_slice(&pack_codes(&mantissas, man.n_bits));
    }

    // aux tensors: everything the engine needs that is not a packed weight
    // (bias, folded-BN gamma/beta, running stats); momenta and the deltas
    // vector itself are training state and stay out of the artifact
    let aux: Vec<&Tensor> = ck
        .tensors
        .iter()
        .filter(|t| {
            t.name != "__deltas__"
                && !t.name.ends_with("#m")
                && !man.params.iter().any(|p| p.qidx.is_some() && p.name == t.name)
        })
        .collect();
    out.extend_from_slice(&(aux.len() as u32).to_le_bytes());
    for t in &aux {
        out.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
        out.extend_from_slice(t.name.as_bytes());
        out.push(t.dims.len() as u8);
        for &d in &t.dims {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in &t.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok((out, quant.len(), aux.len()))
}

fn decode_payload(buf: &[u8]) -> Result<(Manifest, Checkpoint)> {
    let mut c = Cursor::new(buf);
    let mlen = c.u32("manifest length")? as usize;
    let man = Manifest::parse(c.str(mlen, "embedded manifest")?)
        .context("parsing the embedded manifest")?;

    let mut ck = Checkpoint::default();
    let n_quant = c.u32("quantized tensor count")? as usize;
    let quant = quant_params(&man);
    ensure!(
        n_quant == quant.len(),
        "payload declares {n_quant} quantized tensors, the embedded manifest has {}",
        quant.len()
    );
    let mut deltas = vec![1.0f32; man.deltas_len()];
    for (p, qidx) in &quant {
        let numel = c.u32(&format!("numel of {}", p.name))? as usize;
        ensure!(
            numel == p.numel(),
            "{}: payload has {numel} elements, the embedded manifest says {}",
            p.name,
            p.numel()
        );
        let frac = c.i32(&format!("frac exponent of {}", p.name))?;
        let delta = (2.0f32).powi(-frac);
        deltas[*qidx] = delta;
        let packed = c.take(
            (numel * man.n_bits as usize).div_ceil(8),
            &format!("packed codes of {}", p.name),
        )?;
        ck.tensors.push(Tensor {
            name: p.name.clone(),
            kind: Kind::Weight,
            dims: p.shape.clone(),
            data: unpack_codes(packed, numel, man.n_bits)
                .into_iter()
                .map(|m| m as f32 * delta)
                .collect(),
        });
    }

    let n_aux = c.u32("aux tensor count")? as usize;
    for i in 0..n_aux {
        let nlen = c.u32(&format!("name length of aux tensor {i}"))? as usize;
        let name = c.str(nlen, &format!("name of aux tensor {i}"))?.to_string();
        let ndim = c.u8(&format!("rank of {name}"))? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(c.u32(&format!("dims of {name}"))? as usize);
        }
        let numel = dims.iter().product::<usize>().max(1);
        let data = c.f32s(numel, &format!("data of {name}"))?;
        ck.tensors.push(Tensor { name, kind: Kind::State, dims, data });
    }
    ensure!(
        c.remaining() == 0,
        "{} unread bytes of trailing garbage after the last aux tensor",
        c.remaining()
    );
    ck.tensors.push(Tensor {
        name: "__deltas__".into(),
        kind: Kind::Deltas,
        dims: vec![deltas.len()],
        data: deltas,
    });
    Ok((man, ck))
}

/// Publish a `(Manifest, Checkpoint)` pair — the inputs `IntModel::build`
/// consumes — as a `.fxpa` at `path`, quantizing weights with the
/// checkpoint's `__deltas__` during packing. Atomic: written to a `.tmp`
/// sibling, then renamed into place.
pub fn publish(
    man: &Manifest,
    ck: &Checkpoint,
    opts: &PublishOpts,
    path: &Path,
) -> Result<ArtifactInfo> {
    ensure!(opts.version >= 1, "artifact model version must be >= 1 (got {})", opts.version);
    let (payload, nq, na) = encode_payload(man, ck)
        .with_context(|| format!("publishing {}", path.display()))?;
    let mut file = Vec::with_capacity(format::HEADER_LEN + payload.len());
    format::write_header(&mut file, opts.version, &payload);
    file.extend_from_slice(&payload);
    let bytes = file.len() as u64;
    let tmp = path.with_extension("fxpa.tmp");
    std::fs::write(&tmp, &file).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(ArtifactInfo { version: opts.version, bytes, quant_tensors: nq, aux_tensors: na })
}

/// Publish a native-trainer model: derives the manifest from the graph
/// ([`NativeModel::to_manifest`]) and snapshots weights + deltas.
pub fn publish_native(
    model: &NativeModel,
    deltas: &[f32],
    n_bits: u32,
    opts: &PublishOpts,
    path: &Path,
) -> Result<ArtifactInfo> {
    ensure!(
        deltas.len() == model.n_quant.max(1),
        "model has {} quantized tensors, got {} deltas",
        model.n_quant,
        deltas.len()
    );
    let man = model.to_manifest(n_bits);
    let ck = model.to_checkpoint(deltas, 0, "symog");
    publish(&man, &ck, opts, path)
}

/// Read the model version from a `.fxpa` header without loading the
/// payload — cheap existence/compatibility probe for swap loops.
pub fn peek_version(path: &Path) -> Result<u32> {
    use std::io::Read as _;
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut head = [0u8; format::HEADER_LEN];
    let mut got = 0;
    while got < head.len() {
        match f.read(&mut head[got..]).with_context(|| format!("reading {}", path.display()))? {
            0 => break,
            n => got += n,
        }
    }
    Ok(format::parse_header(&head[..got], path)?.model_version)
}

/// Load and verify a `.fxpa`, reconstructing the quantization state
/// (codebook weights + deltas) exactly as published — straight to an
/// [`IntModel`] whose plans are bit-identical to the source model's.
pub fn load(path: &Path) -> Result<LoadedArtifact> {
    let mut bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let h = format::parse_header(&bytes, path)?;
    let have = (bytes.len() - format::HEADER_LEN) as u64;
    ensure!(
        have >= h.payload_len,
        "{}: truncated payload — header declares {} bytes, file holds {have}",
        path.display(),
        h.payload_len
    );
    ensure!(
        have == h.payload_len,
        "{}: {} bytes of trailing garbage after the declared payload",
        path.display(),
        have - h.payload_len
    );
    let crc = format::crc32(&bytes[format::HEADER_LEN..]);
    ensure!(
        crc == h.payload_crc,
        "{}: payload checksum mismatch (stored {:#010x}, computed {crc:#010x}) — \
         the artifact is corrupt",
        path.display(),
        h.payload_crc
    );
    if crate::util::fault::fire(crate::util::fault::ARTIFACT_PAYLOAD_CORRUPT) {
        // chaos hook: mutate the buffer *after* validation to model a
        // TOCTOU bit-flip (bad RAM, a racing writer on a non-atomic copy)
        let mid = format::HEADER_LEN + bytes[format::HEADER_LEN..].len() / 2;
        bytes[mid] ^= 0x01;
    }
    // TOCTOU hardening: everything below consumes this one buffer, and the
    // CRC is re-verified over the exact bytes handed to the planner — a
    // payload mutated between validation and planning is refused, never
    // silently decoded into garbage weights
    let payload = &bytes[format::HEADER_LEN..];
    let (man, ck) = decode_payload(payload)
        .with_context(|| format!("{}: decoding .fxpa payload", path.display()))?;
    let recrc = format::crc32(payload);
    ensure!(
        recrc == h.payload_crc,
        "{}: payload mutated between validation and planning \
         (checksum {:#010x} became {recrc:#010x}) — refusing the artifact",
        path.display(),
        h.payload_crc
    );
    let model = IntModel::build(&man, &ck)
        .with_context(|| format!("{}: building the integer model", path.display()))?;
    Ok(LoadedArtifact { path: path.to_path_buf(), manifest: man, version: h.model_version, model })
}
