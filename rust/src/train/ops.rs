//! f32 forward/backward primitives for the native training backend.
//!
//! Layouts match the AOT side: activations are NHWC, conv weights are HWIO,
//! dense weights are [in, out] row-major. Since the shared-GEMM refactor
//! the hot paths run through `crate::kernels` — the same `MR x NR`
//! register-blocked, packed-panel GEMM core the integer inference engine
//! uses — batch-parallel over `util::pool`'s persistent worker pool
//! (worker count from `SYMOG_WORKERS` / `pool::default_workers`; no
//! thread spawn per op — see the threading-model notes in `util::pool`):
//!
//! * `dense_forward` / `conv2d_forward`: (im2col +) GEMM against packed
//!   weight panels, images/row-blocks fanned out across workers;
//! * `dense_backward` / `conv2d_backward`: `dx` as a GEMM against packed
//!   transposed weights (conv adds a `col2im` scatter), `dw` as
//!   patchesᵀ x dy GEMMs, `db` as row sums;
//! * **determinism**: `dw`/`db` are reduced through a *fixed* number of
//!   partial-sum cells ([`REDUCE_CELLS`], a function of the batch only)
//!   that are combined serially in cell order — results are bit-identical
//!   for every worker count, which the worker-invariance tests (and the
//!   PR-2 seed-calibrated smoke margins) rely on.
//!
//! The original sequential triple loops are retained as `*_naive` oracles;
//! property tests race the two families (tight epsilon — f32 summation
//! order differs under blocking) and the finite-difference gradient checks
//! run against the GEMM path.

use crate::kernels;
use crate::util::pool;

/// Fixed partial-sum cell count for the `dw`/`db` reductions. Parallelism
/// for the weight gradient is capped here, but the cell population and the
/// serial cell-order reduce depend only on the batch — never on the worker
/// count — so gradients are bit-reproducible on any machine configuration.
const REDUCE_CELLS: usize = 8;

/// Contiguous image ranges of the fixed reduction cells (empty cells
/// dropped). A pure function of `batch`.
fn cell_ranges(batch: usize) -> Vec<(usize, usize)> {
    let cells = batch.min(REDUCE_CELLS).max(1);
    let per = batch.div_ceil(cells);
    (0..cells)
        .map(|c| (c * per, ((c + 1) * per).min(batch)))
        .filter(|(b0, b1)| b0 < b1)
        .collect()
}

/// Serially combine per-cell `(dw, db)` partials in cell order — the
/// determinism-critical half of the reduction, defined once for the dense
/// and conv backward paths.
fn sum_cells(partials: &[(Vec<f32>, Vec<f32>)], dw: &mut [f32], db: &mut [f32]) {
    for (dw_c, db_c) in partials {
        for (d, &v) in dw.iter_mut().zip(dw_c) {
            *d += v;
        }
        for (d, &v) in db.iter_mut().zip(db_c) {
            *d += v;
        }
    }
}

/// `db += ` column sums of a row-major `rows x cols` block, row order.
fn add_row_sums(dy: &[f32], cols: usize, db: &mut [f32]) {
    for row in dy.chunks(cols) {
        for (d, &v) in db.iter_mut().zip(row) {
            *d += v;
        }
    }
}

/// `C[batch, bp.cols] += A[batch, bp.depth] * B`, fanned out over
/// contiguous row blocks (one per worker). Per-row results are independent
/// of the blocking, so any worker count yields identical bits.
fn par_gemm_rows(
    a: &[f32],
    bp: &kernels::PackedB<f32>,
    c: &mut [f32],
    batch: usize,
    workers: usize,
) {
    if batch == 0 || bp.cols == 0 {
        return;
    }
    let width = bp.cols;
    let workers = workers.clamp(1, batch);
    let rows_per = batch.div_ceil(workers);
    let mut views: Vec<&mut [f32]> = c.chunks_mut(rows_per * width).collect();
    pool::par_chunks_mut(&mut views, workers, |offset, chunk| {
        for (bi, block) in chunk.iter_mut().enumerate() {
            let r0 = (offset + bi) * rows_per;
            let rows = block.len() / width;
            kernels::gemm_packed(&a[r0 * bp.depth..(r0 + rows) * bp.depth], bp, block, rows);
        }
    });
}

/// Static geometry of one conv layer (batch is supplied per call).
#[derive(Clone, Copy, Debug)]
pub struct Conv2dShape {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    /// square kernel edge (odd)
    pub k: usize,
    pub stride: usize,
    pub cout: usize,
}

impl Conv2dShape {
    /// (out_h, out_w, pad_top, pad_left), delegating to the single SAME
    /// geometry implementation shared with the integer inference engine —
    /// a trained checkpoint and the engine can never disagree on shapes.
    fn geometry(&self) -> (usize, usize, usize, usize) {
        kernels::conv_geometry(self.h, self.w, self.k, self.k, self.stride, true)
    }

    /// SAME-padding output height: ceil(h / stride).
    pub fn out_h(&self) -> usize {
        self.geometry().0
    }

    pub fn out_w(&self) -> usize {
        self.geometry().1
    }

    pub fn in_elems(&self, batch: usize) -> usize {
        batch * self.h * self.w * self.cin
    }

    pub fn out_elems(&self, batch: usize) -> usize {
        batch * self.out_h() * self.out_w() * self.cout
    }

    pub fn weight_elems(&self) -> usize {
        self.k * self.k * self.cin * self.cout
    }
}

// ---------------------------------------------------------------------------
// dense

/// y[b, out] = x[b, in] · w[in, out] + bias[out] — packed-panel GEMM,
/// batch-row-parallel with `pool::default_workers()` workers.
pub fn dense_forward(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
) -> Vec<f32> {
    dense_forward_with(x, w, bias, batch, fin, fout, pool::default_workers())
}

/// [`dense_forward`] with an explicit worker count (results are
/// bit-identical for any value; this tunes wall-clock only).
pub fn dense_forward_with(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
    workers: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), batch * fin);
    debug_assert_eq!(w.len(), fin * fout);
    debug_assert_eq!(bias.len(), fout);
    let mut y = vec![0f32; batch * fout];
    if batch == 0 || fout == 0 {
        return y;
    }
    for row in y.chunks_mut(fout) {
        row.copy_from_slice(bias);
    }
    let bp = kernels::pack_b(w, fin, fout);
    par_gemm_rows(x, &bp, &mut y, batch, workers);
    y
}

/// Reference loops for [`dense_forward`] (the oracle the GEMM path races).
pub fn dense_forward_naive(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), batch * fin);
    debug_assert_eq!(w.len(), fin * fout);
    debug_assert_eq!(bias.len(), fout);
    let mut y = vec![0f32; batch * fout];
    for i in 0..batch {
        let yrow = &mut y[i * fout..(i + 1) * fout];
        yrow.copy_from_slice(bias);
        let xrow = &x[i * fin..(i + 1) * fin];
        for (p, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue; // ReLU sparsity
            }
            let wrow = &w[p * fout..(p + 1) * fout];
            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
    }
    y
}

/// Gradients of `dense_forward`: returns (dx, dw, dbias). `dx = dy · wᵀ`
/// batch-parallel; `dw = xᵀ · dy` and `db` through the fixed reduction
/// cells (bit-identical for any worker count).
pub fn dense_backward(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    dense_backward_with(x, w, dy, batch, fin, fout, pool::default_workers())
}

/// [`dense_backward`] with an explicit worker count.
pub fn dense_backward_with(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
    workers: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), batch * fin);
    debug_assert_eq!(w.len(), fin * fout);
    debug_assert_eq!(dy.len(), batch * fout);
    let workers = workers.max(1);
    let mut dx = vec![0f32; batch * fin];
    let mut dw = vec![0f32; fin * fout];
    let mut db = vec![0f32; fout];
    if batch == 0 || fout == 0 {
        return (dx, dw, db);
    }
    // dx = dy · wᵀ, independent per batch row
    let wt = kernels::pack_b_transposed(w, fin, fout); // depth fout, cols fin
    par_gemm_rows(dy, &wt, &mut dx, batch, workers);
    // dw/db: per-cell partials in image order, then a serial cell-order sum
    let ranges = cell_ranges(batch);
    let partials = pool::par_map(ranges.len(), workers, |ci| {
        let (b0, b1) = ranges[ci];
        let bc = b1 - b0;
        let mut dw_c = vec![0f32; fin * fout];
        let mut db_c = vec![0f32; fout];
        let mut xt = vec![0f32; fin * bc];
        kernels::transpose(&x[b0 * fin..b1 * fin], bc, fin, &mut xt);
        let dyp = kernels::pack_b(&dy[b0 * fout..b1 * fout], bc, fout);
        kernels::gemm_packed(&xt, &dyp, &mut dw_c, fin);
        add_row_sums(&dy[b0 * fout..b1 * fout], fout, &mut db_c);
        (dw_c, db_c)
    });
    sum_cells(&partials, &mut dw, &mut db);
    (dx, dw, db)
}

/// Reference loops for [`dense_backward`].
pub fn dense_backward_naive(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), batch * fout);
    let mut dx = vec![0f32; batch * fin];
    let mut dw = vec![0f32; fin * fout];
    let mut db = vec![0f32; fout];
    for i in 0..batch {
        let dyrow = &dy[i * fout..(i + 1) * fout];
        for (dbv, &dyv) in db.iter_mut().zip(dyrow) {
            *dbv += dyv;
        }
        let xrow = &x[i * fin..(i + 1) * fin];
        let dxrow = &mut dx[i * fin..(i + 1) * fin];
        for p in 0..fin {
            let wrow = &w[p * fout..(p + 1) * fout];
            let mut acc = 0f32;
            for (&dyv, &wv) in dyrow.iter().zip(wrow) {
                acc += dyv * wv;
            }
            dxrow[p] = acc;
            let xv = xrow[p];
            if xv != 0.0 {
                let dwrow = &mut dw[p * fout..(p + 1) * fout];
                for (dwv, &dyv) in dwrow.iter_mut().zip(dyrow) {
                    *dwv += xv * dyv;
                }
            }
        }
    }
    (dx, dw, db)
}

// ---------------------------------------------------------------------------
// conv2d

/// NHWC conv with HWIO weights, SAME padding, square stride — im2col +
/// packed-panel GEMM, parallel over the batch.
pub fn conv2d_forward(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    batch: usize,
    s: &Conv2dShape,
) -> Vec<f32> {
    conv2d_forward_with(x, wt, bias, batch, s, pool::default_workers())
}

/// [`conv2d_forward`] with an explicit worker count.
pub fn conv2d_forward_with(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    batch: usize,
    s: &Conv2dShape,
    workers: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), s.in_elems(batch));
    debug_assert_eq!(wt.len(), s.weight_elems());
    debug_assert_eq!(bias.len(), s.cout);
    let (oh, ow, ph, pw) = s.geometry();
    let k_dim = s.k * s.k * s.cin;
    let m_dim = oh * ow;
    let mut y = vec![0f32; s.out_elems(batch)];
    if batch == 0 || m_dim == 0 || s.cout == 0 {
        return y;
    }
    let bp = kernels::pack_b(wt, k_dim, s.cout);
    let mut views: Vec<&mut [f32]> = y.chunks_mut(m_dim * s.cout).collect();
    let workers = workers.clamp(1, views.len());
    pool::par_chunks_mut(&mut views, workers, |offset, chunk| {
        let mut patches = vec![0f32; m_dim * k_dim];
        for (bi, y_img) in chunk.iter_mut().enumerate() {
            let img = offset + bi;
            kernels::im2col(
                x,
                (s.h, s.w, s.cin),
                img,
                s.k,
                s.k,
                s.stride,
                ph,
                pw,
                oh,
                ow,
                &mut patches,
            );
            for row in y_img.chunks_mut(s.cout) {
                row.copy_from_slice(bias);
            }
            kernels::gemm_packed(&patches, &bp, y_img, m_dim);
        }
    });
    y
}

/// Reference loops for [`conv2d_forward`] (the oracle the GEMM path races).
pub fn conv2d_forward_naive(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    batch: usize,
    s: &Conv2dShape,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), s.in_elems(batch));
    debug_assert_eq!(wt.len(), s.weight_elems());
    debug_assert_eq!(bias.len(), s.cout);
    let (oh, ow, pt, pl) = s.geometry();
    let (pt, pl) = (pt as i64, pl as i64);
    let mut y = vec![0f32; s.out_elems(batch)];
    for im in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let ybase = ((im * oh + oy) * ow + ox) * s.cout;
                y[ybase..ybase + s.cout].copy_from_slice(bias);
                for ky in 0..s.k {
                    let iy = (oy * s.stride + ky) as i64 - pt;
                    if iy < 0 || iy >= s.h as i64 {
                        continue;
                    }
                    for kx in 0..s.k {
                        let ix = (ox * s.stride + kx) as i64 - pl;
                        if ix < 0 || ix >= s.w as i64 {
                            continue;
                        }
                        let xbase = ((im * s.h + iy as usize) * s.w + ix as usize) * s.cin;
                        let wbase = (ky * s.k + kx) * s.cin * s.cout;
                        for ci in 0..s.cin {
                            let xv = x[xbase + ci];
                            if xv == 0.0 {
                                continue;
                            }
                            let wrow = &wt[wbase + ci * s.cout..wbase + (ci + 1) * s.cout];
                            let yrow = &mut y[ybase..ybase + s.cout];
                            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                                *yv += xv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
    y
}

/// Gradients of `conv2d_forward`: returns (dx, dw, dbias). `dx` is a
/// per-image `dy · Wᵀ` GEMM followed by a `col2im` scatter (batch-parallel,
/// no cross-image writes); `dw` is a patchesᵀ x dy GEMM and `db` a row sum,
/// both reduced through the fixed cells — bit-identical for any worker
/// count.
pub fn conv2d_backward(
    x: &[f32],
    wt: &[f32],
    dy: &[f32],
    batch: usize,
    s: &Conv2dShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    conv2d_backward_with(x, wt, dy, batch, s, pool::default_workers())
}

/// [`conv2d_backward`] with an explicit worker count.
pub fn conv2d_backward_with(
    x: &[f32],
    wt: &[f32],
    dy: &[f32],
    batch: usize,
    s: &Conv2dShape,
    workers: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), s.in_elems(batch));
    debug_assert_eq!(wt.len(), s.weight_elems());
    debug_assert_eq!(dy.len(), s.out_elems(batch));
    let (oh, ow, ph, pw) = s.geometry();
    let k_dim = s.k * s.k * s.cin;
    let m_dim = oh * ow;
    let workers = workers.max(1);
    let mut dx = vec![0f32; s.in_elems(batch)];
    let mut dw = vec![0f32; s.weight_elems()];
    let mut db = vec![0f32; s.cout];
    let img_in = s.h * s.w * s.cin;
    if batch == 0 || m_dim == 0 || s.cout == 0 || img_in == 0 {
        return (dx, dw, db);
    }
    // dx: dpatches = dy_img · Wᵀ, then scatter — each image owns its slice
    let wtp = kernels::pack_b_transposed(wt, k_dim, s.cout); // depth cout, cols k_dim
    let img_out = m_dim * s.cout;
    let mut views: Vec<&mut [f32]> = dx.chunks_mut(img_in).collect();
    pool::par_chunks_mut(&mut views, workers.min(batch), |offset, chunk| {
        let mut dpatches = vec![0f32; m_dim * k_dim];
        for (bi, dx_img) in chunk.iter_mut().enumerate() {
            let img = offset + bi;
            dpatches.fill(0.0);
            kernels::gemm_packed(
                &dy[img * img_out..(img + 1) * img_out],
                &wtp,
                &mut dpatches,
                m_dim,
            );
            kernels::col2im(
                &dpatches,
                (s.h, s.w, s.cin),
                s.k,
                s.k,
                s.stride,
                ph,
                pw,
                oh,
                ow,
                dx_img,
            );
        }
    });
    // dw/db: per-cell partials in image order, then a serial cell-order sum
    let ranges = cell_ranges(batch);
    let partials = pool::par_map(ranges.len(), workers, |ci| {
        let (b0, b1) = ranges[ci];
        let mut dw_c = vec![0f32; k_dim * s.cout];
        let mut db_c = vec![0f32; s.cout];
        let mut patches = vec![0f32; m_dim * k_dim];
        let mut patches_t = vec![0f32; m_dim * k_dim];
        let mut dyp = kernels::pack_b(&[], 0, 0);
        for img in b0..b1 {
            kernels::im2col(
                x,
                (s.h, s.w, s.cin),
                img,
                s.k,
                s.k,
                s.stride,
                ph,
                pw,
                oh,
                ow,
                &mut patches,
            );
            kernels::transpose(&patches, m_dim, k_dim, &mut patches_t);
            let dy_img = &dy[img * img_out..(img + 1) * img_out];
            dyp.repack(dy_img, m_dim, s.cout);
            kernels::gemm_packed(&patches_t, &dyp, &mut dw_c, k_dim);
            add_row_sums(dy_img, s.cout, &mut db_c);
        }
        (dw_c, db_c)
    });
    sum_cells(&partials, &mut dw, &mut db);
    (dx, dw, db)
}

/// Reference loops for [`conv2d_backward`].
pub fn conv2d_backward_naive(
    x: &[f32],
    wt: &[f32],
    dy: &[f32],
    batch: usize,
    s: &Conv2dShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), s.out_elems(batch));
    let (oh, ow, pt, pl) = s.geometry();
    let (pt, pl) = (pt as i64, pl as i64);
    let mut dx = vec![0f32; s.in_elems(batch)];
    let mut dw = vec![0f32; s.weight_elems()];
    let mut db = vec![0f32; s.cout];
    for im in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let dybase = ((im * oh + oy) * ow + ox) * s.cout;
                let dyrow = &dy[dybase..dybase + s.cout];
                for (dbv, &dyv) in db.iter_mut().zip(dyrow) {
                    *dbv += dyv;
                }
                for ky in 0..s.k {
                    let iy = (oy * s.stride + ky) as i64 - pt;
                    if iy < 0 || iy >= s.h as i64 {
                        continue;
                    }
                    for kx in 0..s.k {
                        let ix = (ox * s.stride + kx) as i64 - pl;
                        if ix < 0 || ix >= s.w as i64 {
                            continue;
                        }
                        let xbase = ((im * s.h + iy as usize) * s.w + ix as usize) * s.cin;
                        let wbase = (ky * s.k + kx) * s.cin * s.cout;
                        for ci in 0..s.cin {
                            let xv = x[xbase + ci];
                            let wrow = &wt[wbase + ci * s.cout..wbase + (ci + 1) * s.cout];
                            let dwrow = &mut dw[wbase + ci * s.cout..wbase + (ci + 1) * s.cout];
                            let mut acc = 0f32;
                            for co in 0..s.cout {
                                let dyv = dyrow[co];
                                acc += wrow[co] * dyv;
                                dwrow[co] += xv * dyv;
                            }
                            dx[xbase + ci] += acc;
                        }
                    }
                }
            }
        }
    }
    (dx, dw, db)
}

// ---------------------------------------------------------------------------
// elementwise + loss

pub fn relu_forward(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

/// dx = dy where the pre-activation was positive, else 0.
pub fn relu_backward(pre: &[f32], dy: &[f32]) -> Vec<f32> {
    debug_assert_eq!(pre.len(), dy.len());
    pre.iter().zip(dy).map(|(&p, &d)| if p > 0.0 { d } else { 0.0 }).collect()
}

/// Mean softmax cross-entropy over the batch.
/// Returns (mean loss, argmax-hit count as f32, dlogits already / batch).
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    batch: usize,
    classes: usize,
) -> (f32, f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), batch * classes);
    debug_assert_eq!(labels.len(), batch);
    let mut d = vec![0f32; batch * classes];
    let mut loss = 0f64;
    let mut correct = 0usize;
    let inv_b = 1.0 / batch as f32;
    for i in 0..batch {
        let row = &logits[i * classes..(i + 1) * classes];
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > max {
                max = v;
                argmax = j;
            }
        }
        let mut sum = 0f64;
        for &v in row {
            sum += ((v - max) as f64).exp();
        }
        let y = labels[i] as usize;
        assert!(y < classes, "label {y} out of range for {classes} classes");
        loss += sum.ln() - (row[y] - max) as f64;
        if argmax == y {
            correct += 1;
        }
        let drow = &mut d[i * classes..(i + 1) * classes];
        for j in 0..classes {
            let p = (((row[j] - max) as f64).exp() / sum) as f32;
            let target = if j == y { 1.0 } else { 0.0 };
            drow[j] = (p - target) * inv_b;
        }
    }
    ((loss / batch as f64) as f32, correct as f32, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose_rel, forall};
    use crate::util::rng::Rng;

    /// Random activations with exact zeros mixed in (post-ReLU shape).
    fn acts(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| if rng.bool(0.4) { 0.0 } else { rng.normal() }).collect()
    }

    #[test]
    fn dense_forward_known_values() {
        // x = [[1, 2]], w = [[1, 0, -1], [2, 1, 0]], b = [0.5, 0, 0]
        let y =
            dense_forward(&[1.0, 2.0], &[1.0, 0.0, -1.0, 2.0, 1.0, 0.0], &[0.5, 0.0, 0.0], 1, 2, 3);
        assert_eq!(y, vec![5.5, 2.0, -1.0]);
    }

    #[test]
    fn conv1x1_equals_per_pixel_dense() {
        // a 1x1 stride-1 conv is a dense layer applied at every pixel
        let mut rng = Rng::new(3);
        let s = Conv2dShape { h: 4, w: 3, cin: 2, k: 1, stride: 1, cout: 5 };
        let x: Vec<f32> = (0..s.in_elems(2)).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..s.cout).map(|_| rng.normal()).collect();
        let yc = conv2d_forward(&x, &w, &b, 2, &s);
        let yd = dense_forward(&x, &w, &b, 2 * 4 * 3, 2, 5);
        crate::testing::assert_allclose(&yc, &yd, 1e-6);
    }

    #[test]
    fn conv_same_padding_shapes() {
        let s = Conv2dShape { h: 7, w: 7, cin: 1, k: 3, stride: 2, cout: 1 };
        assert_eq!((s.out_h(), s.out_w()), (4, 4));
        let x = vec![1.0f32; s.in_elems(1)];
        let w = vec![1.0f32; s.weight_elems()];
        let y = conv2d_forward(&x, &w, &[0.0], 1, &s);
        assert_eq!(y.len(), 16);
        // interior output pixels see the full 3x3 window of ones
        assert_eq!(y[5], 9.0); // (oy=1, ox=1) -> centered at (2, 2)
    }

    #[test]
    fn relu_roundtrip() {
        let pre = [-1.0f32, 0.0, 2.0];
        assert_eq!(relu_forward(&pre), vec![0.0, 0.0, 2.0]);
        assert_eq!(relu_backward(&pre, &[5.0, 5.0, 5.0]), vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn softmax_uniform_logits() {
        let (loss, correct, d) = softmax_xent(&[0.0; 8], &[1, 3], 2, 4);
        assert!((loss - (4f32).ln()).abs() < 1e-6);
        assert!(correct <= 2.0); // argmax of uniform row is index 0
        // gradient rows sum to zero
        let s0: f32 = d[..4].iter().sum();
        assert!(s0.abs() < 1e-6);
    }

    #[test]
    fn cell_ranges_cover_batch_exactly() {
        for batch in [1usize, 2, 7, 8, 9, 31, 64] {
            let r = cell_ranges(batch);
            assert!(r.len() <= REDUCE_CELLS);
            assert_eq!(r[0].0, 0);
            assert_eq!(r.last().unwrap().1, batch);
            for win in r.windows(2) {
                assert_eq!(win[0].1, win[1].0, "cells must tile the batch");
            }
        }
    }

    // --- GEMM vs naive races (tight epsilon: blocking reorders f32 sums) --

    #[test]
    fn prop_dense_forward_matches_naive() {
        forall(16, |rng: &mut Rng| {
            let batch = 1 + rng.below(9);
            let fin = 1 + rng.below(150);
            let fout = 1 + rng.below(40);
            let x = acts(rng, batch * fin);
            let w: Vec<f32> = (0..fin * fout).map(|_| rng.normal() * 0.5).collect();
            let b: Vec<f32> = (0..fout).map(|_| rng.normal() * 0.1).collect();
            let got = dense_forward(&x, &w, &b, batch, fin, fout);
            let want = dense_forward_naive(&x, &w, &b, batch, fin, fout);
            assert_allclose_rel(&got, &want, 1e-4, 1e-5);
        });
    }

    #[test]
    fn prop_dense_backward_matches_naive() {
        forall(16, |rng: &mut Rng| {
            let batch = 1 + rng.below(12);
            let fin = 1 + rng.below(90);
            let fout = 1 + rng.below(30);
            let x = acts(rng, batch * fin);
            let w: Vec<f32> = (0..fin * fout).map(|_| rng.normal() * 0.5).collect();
            let dy: Vec<f32> = (0..batch * fout).map(|_| rng.normal() * 0.2).collect();
            let (dx, dw, db) = dense_backward(&x, &w, &dy, batch, fin, fout);
            let (dxn, dwn, dbn) = dense_backward_naive(&x, &w, &dy, batch, fin, fout);
            assert_allclose_rel(&dx, &dxn, 1e-4, 5e-5);
            assert_allclose_rel(&dw, &dwn, 1e-4, 5e-5);
            assert_allclose_rel(&db, &dbn, 1e-4, 5e-5);
        });
    }

    #[test]
    fn prop_conv_forward_matches_naive() {
        forall(12, |rng: &mut Rng| {
            let s = Conv2dShape {
                h: 3 + rng.below(8),
                w: 3 + rng.below(8),
                cin: 1 + rng.below(5),
                k: 1 + 2 * rng.below(2), // 1 or 3
                stride: 1 + rng.below(2),
                cout: 1 + rng.below(8),
            };
            let batch = 1 + rng.below(5);
            let x = acts(rng, s.in_elems(batch));
            let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal() * 0.3).collect();
            let b: Vec<f32> = (0..s.cout).map(|_| rng.normal() * 0.1).collect();
            let got = conv2d_forward(&x, &w, &b, batch, &s);
            let want = conv2d_forward_naive(&x, &w, &b, batch, &s);
            assert_allclose_rel(&got, &want, 1e-4, 1e-5);
        });
    }

    #[test]
    fn prop_conv_backward_matches_naive() {
        forall(12, |rng: &mut Rng| {
            let s = Conv2dShape {
                h: 3 + rng.below(7),
                w: 3 + rng.below(7),
                cin: 1 + rng.below(4),
                k: 1 + 2 * rng.below(2),
                stride: 1 + rng.below(2),
                cout: 1 + rng.below(6),
            };
            let batch = 1 + rng.below(10);
            let x = acts(rng, s.in_elems(batch));
            let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal() * 0.3).collect();
            let dy: Vec<f32> = (0..s.out_elems(batch)).map(|_| rng.normal() * 0.2).collect();
            let (dx, dw, db) = conv2d_backward(&x, &w, &dy, batch, &s);
            let (dxn, dwn, dbn) = conv2d_backward_naive(&x, &w, &dy, batch, &s);
            assert_allclose_rel(&dx, &dxn, 1e-4, 5e-5);
            assert_allclose_rel(&dw, &dwn, 1e-4, 5e-5);
            assert_allclose_rel(&db, &dbn, 1e-4, 5e-5);
        });
    }

    // --- worker-count invariance: gradients must be bit-identical --------

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn dense_grads_invariant_across_worker_counts() {
        let mut rng = Rng::new(21);
        let (batch, fin, fout) = (9usize, 37usize, 11usize);
        let x = acts(&mut rng, batch * fin);
        let w: Vec<f32> = (0..fin * fout).map(|_| rng.normal() * 0.5).collect();
        let dy: Vec<f32> = (0..batch * fout).map(|_| rng.normal()).collect();
        let (dx1, dw1, db1) = dense_backward_with(&x, &w, &dy, batch, fin, fout, 1);
        for workers in [2usize, 4, 7] {
            let (dx, dw, db) = dense_backward_with(&x, &w, &dy, batch, fin, fout, workers);
            assert_bits_eq(&dx1, &dx, "dx");
            assert_bits_eq(&dw1, &dw, "dw");
            assert_bits_eq(&db1, &db, "db");
        }
        let bias = vec![0.1f32; fout];
        let y1 = dense_forward_with(&x, &w, &bias, batch, fin, fout, 1);
        let y4 = dense_forward_with(&x, &w, &bias, batch, fin, fout, 4);
        assert_bits_eq(&y1, &y4, "y");
    }

    #[test]
    fn conv_grads_invariant_across_worker_counts() {
        let mut rng = Rng::new(23);
        let s = Conv2dShape { h: 6, w: 5, cin: 3, k: 3, stride: 2, cout: 4 };
        let batch = 9usize;
        let x = acts(&mut rng, s.in_elems(batch));
        let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal() * 0.3).collect();
        let b: Vec<f32> = (0..s.cout).map(|_| rng.normal() * 0.1).collect();
        let dy: Vec<f32> = (0..s.out_elems(batch)).map(|_| rng.normal()).collect();
        let (dx1, dw1, db1) = conv2d_backward_with(&x, &w, &dy, batch, &s, 1);
        for workers in [2usize, 4, 7] {
            let (dx, dw, db) = conv2d_backward_with(&x, &w, &dy, batch, &s, workers);
            assert_bits_eq(&dx1, &dx, "dx");
            assert_bits_eq(&dw1, &dw, "dw");
            assert_bits_eq(&db1, &db, "db");
        }
        let y1 = conv2d_forward_with(&x, &w, &b, batch, &s, 1);
        let y4 = conv2d_forward_with(&x, &w, &b, batch, &s, 4);
        assert_bits_eq(&y1, &y4, "y");
    }

    // --- finite differences (run against the GEMM path) ------------------

    /// Central finite difference of a scalar-valued closure at params[i].
    fn num_grad<F: FnMut(&[f32]) -> f32>(params: &[f32], i: usize, mut f: F) -> f32 {
        let h = 1e-2f32;
        let mut p = params.to_vec();
        p[i] = params[i] + h;
        let up = f(&p);
        p[i] = params[i] - h;
        let dn = f(&p);
        (up - dn) / (2.0 * h)
    }

    fn check_grads(ana: &[f32], params: &[f32], f: impl FnMut(&[f32]) -> f32 + Copy) {
        for i in 0..params.len() {
            let num = num_grad(params, i, f);
            let tol = 2e-3 + 2e-2 * num.abs();
            assert!(
                (ana[i] - num).abs() <= tol,
                "grad[{i}]: analytic {} vs numeric {num}",
                ana[i]
            );
        }
    }

    #[test]
    fn dense_gradients_match_finite_differences() {
        let mut rng = Rng::new(7);
        let (batch, fin, fout) = (3usize, 4usize, 5usize);
        let x: Vec<f32> = (0..batch * fin).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..fin * fout).map(|_| rng.normal() * 0.5).collect();
        let b: Vec<f32> = (0..fout).map(|_| rng.normal() * 0.1).collect();
        let labels: Vec<i32> = (0..batch).map(|_| rng.below(fout) as i32).collect();

        let y = dense_forward(&x, &w, &b, batch, fin, fout);
        let (_, _, dy) = softmax_xent(&y, &labels, batch, fout);
        let (dx, dw, db) = dense_backward(&x, &w, &dy, batch, fin, fout);

        let loss_of_w = |wp: &[f32]| {
            let y = dense_forward(&x, wp, &b, batch, fin, fout);
            softmax_xent(&y, &labels, batch, fout).0
        };
        check_grads(&dw, &w, &loss_of_w);

        let loss_of_b = |bp: &[f32]| {
            let y = dense_forward(&x, &w, bp, batch, fin, fout);
            softmax_xent(&y, &labels, batch, fout).0
        };
        check_grads(&db, &b, &loss_of_b);

        let loss_of_x = |xp: &[f32]| {
            let y = dense_forward(xp, &w, &b, batch, fin, fout);
            softmax_xent(&y, &labels, batch, fout).0
        };
        check_grads(&dx, &x, &loss_of_x);
    }

    #[test]
    fn conv_gradients_match_finite_differences() {
        let mut rng = Rng::new(11);
        let batch = 2usize;
        let s = Conv2dShape { h: 5, w: 4, cin: 2, k: 3, stride: 2, cout: 3 };
        let classes = s.out_elems(1); // flatten conv output straight into xent
        let x: Vec<f32> = (0..s.in_elems(batch)).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal() * 0.3).collect();
        let b: Vec<f32> = (0..s.cout).map(|_| rng.normal() * 0.1).collect();
        let labels: Vec<i32> = (0..batch).map(|_| rng.below(classes) as i32).collect();

        let y = conv2d_forward(&x, &w, &b, batch, &s);
        let (_, _, dy) = softmax_xent(&y, &labels, batch, classes);
        let (dx, dw, db) = conv2d_backward(&x, &w, &dy, batch, &s);

        let loss_of_w = |wp: &[f32]| {
            let y = conv2d_forward(&x, wp, &b, batch, &s);
            softmax_xent(&y, &labels, batch, classes).0
        };
        check_grads(&dw, &w, &loss_of_w);

        let loss_of_b = |bp: &[f32]| {
            let y = conv2d_forward(&x, &w, bp, batch, &s);
            softmax_xent(&y, &labels, batch, classes).0
        };
        check_grads(&db, &b, &loss_of_b);

        let loss_of_x = |xp: &[f32]| {
            let y = conv2d_forward(xp, &w, &b, batch, &s);
            softmax_xent(&y, &labels, batch, classes).0
        };
        check_grads(&dx, &x, &loss_of_x);
    }
}
