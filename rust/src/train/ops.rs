//! f32 forward/backward primitives for the native training backend.
//!
//! Layouts match the AOT side: activations are NHWC, conv weights are HWIO,
//! dense weights are [in, out] row-major. All loops are plain sequential
//! Rust — deterministic regardless of thread count, and fast enough for the
//! tiny-to-small models the native backend targets (the integer GEMM hot
//! path stays the inference engine's job).

/// Static geometry of one conv layer (batch is supplied per call).
#[derive(Clone, Copy, Debug)]
pub struct Conv2dShape {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    /// square kernel edge (odd)
    pub k: usize,
    pub stride: usize,
    pub cout: usize,
}

impl Conv2dShape {
    /// (out_h, out_w, pad_top, pad_left), delegating to the single SAME
    /// geometry implementation shared with the integer inference engine —
    /// a trained checkpoint and the engine can never disagree on shapes.
    fn geometry(&self) -> (usize, usize, i64, i64) {
        let (oh, ow, pt, pl) = crate::inference::gemm::conv_geometry(
            self.h, self.w, self.k, self.k, self.stride, true,
        );
        (oh, ow, pt as i64, pl as i64)
    }

    /// SAME-padding output height: ceil(h / stride).
    pub fn out_h(&self) -> usize {
        self.geometry().0
    }

    pub fn out_w(&self) -> usize {
        self.geometry().1
    }

    /// SAME padding before the top row (TF convention: excess goes after).
    fn pad_top(&self) -> i64 {
        self.geometry().2
    }

    fn pad_left(&self) -> i64 {
        self.geometry().3
    }

    pub fn in_elems(&self, batch: usize) -> usize {
        batch * self.h * self.w * self.cin
    }

    pub fn out_elems(&self, batch: usize) -> usize {
        batch * self.out_h() * self.out_w() * self.cout
    }

    pub fn weight_elems(&self) -> usize {
        self.k * self.k * self.cin * self.cout
    }
}

/// y[b, out] = x[b, in] · w[in, out] + bias[out].
pub fn dense_forward(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), batch * fin);
    debug_assert_eq!(w.len(), fin * fout);
    debug_assert_eq!(bias.len(), fout);
    let mut y = vec![0f32; batch * fout];
    for i in 0..batch {
        let yrow = &mut y[i * fout..(i + 1) * fout];
        yrow.copy_from_slice(bias);
        let xrow = &x[i * fin..(i + 1) * fin];
        for (p, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue; // ReLU sparsity
            }
            let wrow = &w[p * fout..(p + 1) * fout];
            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
    }
    y
}

/// Gradients of `dense_forward`: returns (dx, dw, dbias).
pub fn dense_backward(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    batch: usize,
    fin: usize,
    fout: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), batch * fout);
    let mut dx = vec![0f32; batch * fin];
    let mut dw = vec![0f32; fin * fout];
    let mut db = vec![0f32; fout];
    for i in 0..batch {
        let dyrow = &dy[i * fout..(i + 1) * fout];
        for (dbv, &dyv) in db.iter_mut().zip(dyrow) {
            *dbv += dyv;
        }
        let xrow = &x[i * fin..(i + 1) * fin];
        let dxrow = &mut dx[i * fin..(i + 1) * fin];
        for p in 0..fin {
            let wrow = &w[p * fout..(p + 1) * fout];
            let mut acc = 0f32;
            for (&dyv, &wv) in dyrow.iter().zip(wrow) {
                acc += dyv * wv;
            }
            dxrow[p] = acc;
            let xv = xrow[p];
            if xv != 0.0 {
                let dwrow = &mut dw[p * fout..(p + 1) * fout];
                for (dwv, &dyv) in dwrow.iter_mut().zip(dyrow) {
                    *dwv += xv * dyv;
                }
            }
        }
    }
    (dx, dw, db)
}

/// NHWC conv with HWIO weights, SAME padding, square stride.
pub fn conv2d_forward(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    batch: usize,
    s: &Conv2dShape,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), s.in_elems(batch));
    debug_assert_eq!(wt.len(), s.weight_elems());
    debug_assert_eq!(bias.len(), s.cout);
    let (oh, ow) = (s.out_h(), s.out_w());
    let (pt, pl) = (s.pad_top(), s.pad_left());
    let mut y = vec![0f32; s.out_elems(batch)];
    for im in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let ybase = ((im * oh + oy) * ow + ox) * s.cout;
                y[ybase..ybase + s.cout].copy_from_slice(bias);
                for ky in 0..s.k {
                    let iy = (oy * s.stride + ky) as i64 - pt;
                    if iy < 0 || iy >= s.h as i64 {
                        continue;
                    }
                    for kx in 0..s.k {
                        let ix = (ox * s.stride + kx) as i64 - pl;
                        if ix < 0 || ix >= s.w as i64 {
                            continue;
                        }
                        let xbase = ((im * s.h + iy as usize) * s.w + ix as usize) * s.cin;
                        let wbase = (ky * s.k + kx) * s.cin * s.cout;
                        for ci in 0..s.cin {
                            let xv = x[xbase + ci];
                            if xv == 0.0 {
                                continue;
                            }
                            let wrow = &wt[wbase + ci * s.cout..wbase + (ci + 1) * s.cout];
                            let yrow = &mut y[ybase..ybase + s.cout];
                            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                                *yv += xv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
    y
}

/// Gradients of `conv2d_forward`: returns (dx, dw, dbias).
pub fn conv2d_backward(
    x: &[f32],
    wt: &[f32],
    dy: &[f32],
    batch: usize,
    s: &Conv2dShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), s.out_elems(batch));
    let (oh, ow) = (s.out_h(), s.out_w());
    let (pt, pl) = (s.pad_top(), s.pad_left());
    let mut dx = vec![0f32; s.in_elems(batch)];
    let mut dw = vec![0f32; s.weight_elems()];
    let mut db = vec![0f32; s.cout];
    for im in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let dybase = ((im * oh + oy) * ow + ox) * s.cout;
                let dyrow = &dy[dybase..dybase + s.cout];
                for (dbv, &dyv) in db.iter_mut().zip(dyrow) {
                    *dbv += dyv;
                }
                for ky in 0..s.k {
                    let iy = (oy * s.stride + ky) as i64 - pt;
                    if iy < 0 || iy >= s.h as i64 {
                        continue;
                    }
                    for kx in 0..s.k {
                        let ix = (ox * s.stride + kx) as i64 - pl;
                        if ix < 0 || ix >= s.w as i64 {
                            continue;
                        }
                        let xbase = ((im * s.h + iy as usize) * s.w + ix as usize) * s.cin;
                        let wbase = (ky * s.k + kx) * s.cin * s.cout;
                        for ci in 0..s.cin {
                            let xv = x[xbase + ci];
                            let wrow = &wt[wbase + ci * s.cout..wbase + (ci + 1) * s.cout];
                            let dwrow = &mut dw[wbase + ci * s.cout..wbase + (ci + 1) * s.cout];
                            let mut acc = 0f32;
                            for co in 0..s.cout {
                                let dyv = dyrow[co];
                                acc += wrow[co] * dyv;
                                dwrow[co] += xv * dyv;
                            }
                            dx[xbase + ci] += acc;
                        }
                    }
                }
            }
        }
    }
    (dx, dw, db)
}

pub fn relu_forward(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

/// dx = dy where the pre-activation was positive, else 0.
pub fn relu_backward(pre: &[f32], dy: &[f32]) -> Vec<f32> {
    debug_assert_eq!(pre.len(), dy.len());
    pre.iter().zip(dy).map(|(&p, &d)| if p > 0.0 { d } else { 0.0 }).collect()
}

/// Mean softmax cross-entropy over the batch.
/// Returns (mean loss, argmax-hit count as f32, dlogits already / batch).
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    batch: usize,
    classes: usize,
) -> (f32, f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), batch * classes);
    debug_assert_eq!(labels.len(), batch);
    let mut d = vec![0f32; batch * classes];
    let mut loss = 0f64;
    let mut correct = 0usize;
    let inv_b = 1.0 / batch as f32;
    for i in 0..batch {
        let row = &logits[i * classes..(i + 1) * classes];
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > max {
                max = v;
                argmax = j;
            }
        }
        let mut sum = 0f64;
        for &v in row {
            sum += ((v - max) as f64).exp();
        }
        let y = labels[i] as usize;
        assert!(y < classes, "label {y} out of range for {classes} classes");
        loss += sum.ln() - (row[y] - max) as f64;
        if argmax == y {
            correct += 1;
        }
        let drow = &mut d[i * classes..(i + 1) * classes];
        for j in 0..classes {
            let p = (((row[j] - max) as f64).exp() / sum) as f32;
            let target = if j == y { 1.0 } else { 0.0 };
            drow[j] = (p - target) * inv_b;
        }
    }
    ((loss / batch as f64) as f32, correct as f32, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_forward_known_values() {
        // x = [[1, 2]], w = [[1, 0, -1], [2, 1, 0]], b = [0.5, 0, 0]
        let y =
            dense_forward(&[1.0, 2.0], &[1.0, 0.0, -1.0, 2.0, 1.0, 0.0], &[0.5, 0.0, 0.0], 1, 2, 3);
        assert_eq!(y, vec![5.5, 2.0, -1.0]);
    }

    #[test]
    fn conv1x1_equals_per_pixel_dense() {
        // a 1x1 stride-1 conv is a dense layer applied at every pixel
        let mut rng = Rng::new(3);
        let s = Conv2dShape { h: 4, w: 3, cin: 2, k: 1, stride: 1, cout: 5 };
        let x: Vec<f32> = (0..s.in_elems(2)).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..s.cout).map(|_| rng.normal()).collect();
        let yc = conv2d_forward(&x, &w, &b, 2, &s);
        let yd = dense_forward(&x, &w, &b, 2 * 4 * 3, 2, 5);
        crate::testing::assert_allclose(&yc, &yd, 1e-6);
    }

    #[test]
    fn conv_same_padding_shapes() {
        let s = Conv2dShape { h: 7, w: 7, cin: 1, k: 3, stride: 2, cout: 1 };
        assert_eq!((s.out_h(), s.out_w()), (4, 4));
        let x = vec![1.0f32; s.in_elems(1)];
        let w = vec![1.0f32; s.weight_elems()];
        let y = conv2d_forward(&x, &w, &[0.0], 1, &s);
        assert_eq!(y.len(), 16);
        // interior output pixels see the full 3x3 window of ones
        assert_eq!(y[5], 9.0); // (oy=1, ox=1) -> centered at (2, 2)
    }

    #[test]
    fn relu_roundtrip() {
        let pre = [-1.0f32, 0.0, 2.0];
        assert_eq!(relu_forward(&pre), vec![0.0, 0.0, 2.0]);
        assert_eq!(relu_backward(&pre, &[5.0, 5.0, 5.0]), vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn softmax_uniform_logits() {
        let (loss, correct, d) = softmax_xent(&[0.0; 8], &[1, 3], 2, 4);
        assert!((loss - (4f32).ln()).abs() < 1e-6);
        assert!(correct <= 2.0); // argmax of uniform row is index 0
        // gradient rows sum to zero
        let s0: f32 = d[..4].iter().sum();
        assert!(s0.abs() < 1e-6);
    }

    /// Central finite difference of a scalar-valued closure at params[i].
    fn num_grad<F: FnMut(&[f32]) -> f32>(params: &[f32], i: usize, mut f: F) -> f32 {
        let h = 1e-2f32;
        let mut p = params.to_vec();
        p[i] = params[i] + h;
        let up = f(&p);
        p[i] = params[i] - h;
        let dn = f(&p);
        (up - dn) / (2.0 * h)
    }

    fn check_grads(ana: &[f32], params: &[f32], f: impl FnMut(&[f32]) -> f32 + Copy) {
        for i in 0..params.len() {
            let num = num_grad(params, i, f);
            let tol = 2e-3 + 2e-2 * num.abs();
            assert!(
                (ana[i] - num).abs() <= tol,
                "grad[{i}]: analytic {} vs numeric {num}",
                ana[i]
            );
        }
    }

    #[test]
    fn dense_gradients_match_finite_differences() {
        let mut rng = Rng::new(7);
        let (batch, fin, fout) = (3usize, 4usize, 5usize);
        let x: Vec<f32> = (0..batch * fin).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..fin * fout).map(|_| rng.normal() * 0.5).collect();
        let b: Vec<f32> = (0..fout).map(|_| rng.normal() * 0.1).collect();
        let labels: Vec<i32> = (0..batch).map(|_| rng.below(fout) as i32).collect();

        let y = dense_forward(&x, &w, &b, batch, fin, fout);
        let (_, _, dy) = softmax_xent(&y, &labels, batch, fout);
        let (dx, dw, db) = dense_backward(&x, &w, &dy, batch, fin, fout);

        let loss_of_w = |wp: &[f32]| {
            let y = dense_forward(&x, wp, &b, batch, fin, fout);
            softmax_xent(&y, &labels, batch, fout).0
        };
        check_grads(&dw, &w, &loss_of_w);

        let loss_of_b = |bp: &[f32]| {
            let y = dense_forward(&x, &w, bp, batch, fin, fout);
            softmax_xent(&y, &labels, batch, fout).0
        };
        check_grads(&db, &b, &loss_of_b);

        let loss_of_x = |xp: &[f32]| {
            let y = dense_forward(xp, &w, &b, batch, fin, fout);
            softmax_xent(&y, &labels, batch, fout).0
        };
        check_grads(&dx, &x, &loss_of_x);
    }

    #[test]
    fn conv_gradients_match_finite_differences() {
        let mut rng = Rng::new(11);
        let batch = 2usize;
        let s = Conv2dShape { h: 5, w: 4, cin: 2, k: 3, stride: 2, cout: 3 };
        let classes = s.out_elems(1); // flatten conv output straight into xent
        let x: Vec<f32> = (0..s.in_elems(batch)).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal() * 0.3).collect();
        let b: Vec<f32> = (0..s.cout).map(|_| rng.normal() * 0.1).collect();
        let labels: Vec<i32> = (0..batch).map(|_| rng.below(classes) as i32).collect();

        let y = conv2d_forward(&x, &w, &b, batch, &s);
        let (_, _, dy) = softmax_xent(&y, &labels, batch, classes);
        let (dx, dw, db) = conv2d_backward(&x, &w, &dy, batch, &s);

        let loss_of_w = |wp: &[f32]| {
            let y = conv2d_forward(&x, wp, &b, batch, &s);
            softmax_xent(&y, &labels, batch, classes).0
        };
        check_grads(&dw, &w, &loss_of_w);

        let loss_of_b = |bp: &[f32]| {
            let y = conv2d_forward(&x, &w, bp, batch, &s);
            softmax_xent(&y, &labels, batch, classes).0
        };
        check_grads(&db, &b, &loss_of_b);

        let loss_of_x = |xp: &[f32]| {
            let y = conv2d_forward(xp, &w, &b, batch, &s);
            softmax_xent(&y, &labels, batch, classes).0
        };
        check_grads(&dx, &x, &loss_of_x);
    }
}
