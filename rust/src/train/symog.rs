//! The SYMOG soft-quantization loss pieces (Eqs. 2–4) on host tensors.
//!
//! The regularizer for one layer is `R_l = (1/M) ||w - Q_N(w; delta_l)||^2`
//! (Eq. 3's per-layer term) and its gradient — with the quantizer treated as
//! piecewise-constant (straight-through zero derivative, Eq. 4) — is
//! `dR/dw = (2/M) (w - Q_N(w; delta_l))`. Both match
//! `python/compile/kernels/ref.py` bit-for-bit in structure.

use crate::fixedpoint::quantize;

/// Per-layer regularizer value R_l (Eq. 3 term, mean squared mode distance).
pub fn regularizer(w: &[f32], delta: f32, n_bits: u32) -> f64 {
    crate::fixedpoint::quant_error(w, delta, n_bits) / w.len().max(1) as f64
}

/// dR/dw = (2/M)(w - Q_N(w; delta)) into a fresh vector (Eq. 4).
pub fn reg_grad(w: &[f32], delta: f32, n_bits: u32) -> Vec<f32> {
    let inv_m2 = 2.0 / w.len().max(1) as f32;
    w.iter().map(|&x| inv_m2 * (x - quantize(x, delta, n_bits))).collect()
}

/// Fraction of weights within `frac * delta` of their nearest quantization
/// mode — the mode-concentration measure behind Figure 3's narrative (mass
/// collapsing onto the mixture modes as lambda grows).
pub fn mode_mass(w: &[f32], delta: f32, n_bits: u32, frac: f32) -> f32 {
    if w.is_empty() {
        return 0.0;
    }
    let tol = frac * delta;
    let near = w.iter().filter(|&&x| (x - quantize(x, delta, n_bits)).abs() <= tol).count();
    near as f32 / w.len() as f32
}

/// Element-count-weighted mean `mode_mass` over (weights, delta) layers.
pub fn mean_mode_mass(layers: &[(Vec<f32>, f32)], n_bits: u32, frac: f32) -> f32 {
    let total: usize = layers.iter().map(|(w, _)| w.len()).sum();
    if total == 0 {
        return 0.0;
    }
    let mut acc = 0f64;
    for (w, delta) in layers {
        acc += mode_mass(w, *delta, n_bits, frac) as f64 * w.len() as f64;
    }
    (acc / total as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn regularizer_zero_on_codebook() {
        let w = [-0.5f32, 0.0, 0.5, 0.5, -0.5];
        assert_eq!(regularizer(&w, 0.5, 2), 0.0);
        assert_eq!(mode_mass(&w, 0.5, 2, 0.0), 1.0);
    }

    #[test]
    fn reg_grad_points_at_nearest_mode() {
        // w = 0.6 with delta 0.5 -> nearest mode 0.5, gradient positive
        let g = reg_grad(&[0.6, 0.4, -0.6], 0.5, 2);
        let m2 = 2.0 / 3.0;
        crate::testing::assert_allclose(
            &g,
            &[m2 * 0.1, m2 * -0.1, m2 * -0.1],
            1e-6,
        );
    }

    #[test]
    fn reg_grad_is_odd() {
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let neg: Vec<f32> = w.iter().map(|x| -x).collect();
        let g = reg_grad(&w, 0.25, 2);
        let gn = reg_grad(&neg, 0.25, 2);
        for (a, b) in g.iter().zip(&gn) {
            assert!((a + b).abs() < 1e-6);
        }
    }

    #[test]
    fn mode_mass_bounds_and_growth() {
        let mut rng = Rng::new(1);
        let spread: Vec<f32> = (0..2000).map(|_| rng.normal() * 0.3).collect();
        let tight: Vec<f32> = spread
            .iter()
            .map(|&x| quantize(x, 0.25, 2) + 0.01 * rng.normal())
            .collect();
        let m_spread = mode_mass(&spread, 0.25, 2, 0.25);
        let m_tight = mode_mass(&tight, 0.25, 2, 0.25);
        assert!((0.0..=1.0).contains(&m_spread));
        assert!(m_tight > 0.95, "tight mass {m_tight}");
        assert!(m_tight > m_spread);
    }

    #[test]
    fn mean_mode_mass_weights_by_numel() {
        // layer A: all on modes (mass 1), 3 elems; layer B: all off (mass 0), 1 elem
        let layers = vec![
            (vec![0.5f32, -0.5, 0.0], 0.5f32),
            (vec![0.26f32], 0.5f32),
        ];
        let m = mean_mode_mass(&layers, 2, 0.1);
        assert!((m - 0.75).abs() < 1e-6, "mass {m}");
    }
}
