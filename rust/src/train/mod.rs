//! Native training substrate: Algorithm 1's compute, in pure Rust.
//!
//! This module is the `TrainBackend` the repo falls back to (and ships as
//! the default end-to-end path) when no AOT artifact exists: f32
//! forward/backward for dense / conv / ReLU / softmax-cross-entropy,
//! minibatch Nesterov SGD, and the SYMOG regularizer gradient
//! `lambda * (2/M)(w - Q_N(w; delta))` of Eqs. 3-4 — making the paper's
//! "the learning task and the quantization are solved simultaneously"
//! loop executable with nothing but this crate.
//!
//! * `ops`     — forward + backward primitives (NHWC / HWIO layouts) on
//!   the shared `crate::kernels` packed-panel GEMM core, batch-parallel
//!   with a deterministic fixed-cell `dw`/`db` reduction
//! * `model`   — sequential model, He init, checkpoint interop
//! * `sgd`     — Nesterov + fused SYMOG update (Alg. 1 lines 14-17)
//! * `symog`   — regularizer value/gradient + mode-concentration probes
//! * `backend` — `NativeBackend`, the `TrainBackend` impl

pub mod backend;
pub mod model;
pub mod ops;
pub mod sgd;
pub mod symog;

pub use backend::{NativeBackend, NativeHyper};
pub use model::{ModelBuilder, NativeModel, Param};
pub use ops::Conv2dShape;
pub use symog::{mean_mode_mass, mode_mass};
