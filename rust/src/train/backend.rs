//! `NativeBackend`: the pure-Rust [`TrainBackend`] — Algorithm 1's inner
//! loop with no artifact, no Python, and no PJRT anywhere near it.
//!
//! Forward/backward run through `train::ops`, the update is the fused
//! SYMOG SGD of `train::sgd`, and the per-layer step sizes are solved at
//! construction with `fixedpoint::optimal_delta_refined` (Alg. 1 lines
//! 2-5, seeded window — ~8x fewer error evaluations than the exhaustive
//! solver, property-tested equivalent).

use anyhow::{Context, Result};

use crate::coordinator::backend::{StepOut, TrainBackend};
use crate::coordinator::checkpoint::{Checkpoint, Kind};
use crate::fixedpoint;

use super::model::NativeModel;
use super::{ops, sgd};

/// Static hyper-parameters of the native substrate (the manifest-baked
/// subset the XLA path gets from aot.py's `Hyper`).
#[derive(Clone, Copy, Debug)]
pub struct NativeHyper {
    pub n_bits: u32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// SYMOG weight clipping to the quantization domain (section 3.4)
    pub clip: bool,
}

impl Default for NativeHyper {
    fn default() -> Self {
        NativeHyper { n_bits: 2, momentum: 0.9, weight_decay: 0.0, clip: true }
    }
}

/// Pure-Rust training backend over a [`NativeModel`].
pub struct NativeBackend {
    pub model: NativeModel,
    pub hyper: NativeHyper,
    batch: usize,
    deltas: Vec<f32>,
}

impl NativeBackend {
    /// Wrap a freshly-initialized model, solving the step sizes from its
    /// current weights (Alg. 1 lines 2-5).
    pub fn new(model: NativeModel, hyper: NativeHyper, batch: usize) -> NativeBackend {
        assert!(batch > 0);
        let mut b = NativeBackend { model, hyper, batch, deltas: Vec::new() };
        b.resolve_deltas();
        b
    }

    /// Re-solve every per-layer step size from the current weights.
    pub fn resolve_deltas(&mut self) {
        let n_bits = self.hyper.n_bits;
        self.deltas = self
            .model
            .quant_weights()
            .iter()
            .map(|p| fixedpoint::optimal_delta_refined(&p.data, n_bits).0)
            .collect();
    }

    /// Restore weights/momenta from a checkpoint written by this backend
    /// (same architecture). With `resolve_deltas` the step sizes are
    /// re-solved from the loaded weights; otherwise `__deltas__` is used.
    pub fn load_checkpoint(&mut self, ck: &Checkpoint, resolve_deltas: bool) -> Result<()> {
        self.model.load_checkpoint(ck)?;
        if resolve_deltas {
            self.resolve_deltas();
        } else {
            let d = ck
                .find("__deltas__")
                .context("checkpoint missing __deltas__ (pass resolve_deltas=true?)")?;
            anyhow::ensure!(
                d.data.len() == self.model.n_quant,
                "__deltas__ has {} entries, model has {} quantized layers",
                d.data.len(),
                self.model.n_quant
            );
            self.deltas = d.data.clone();
        }
        Ok(())
    }
}

impl TrainBackend for NativeBackend {
    fn tag(&self) -> String {
        self.model.tag.clone()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn n_bits(&self) -> u32 {
        self.hyper.n_bits
    }

    fn n_quant(&self) -> usize {
        self.model.n_quant
    }

    fn deltas(&self) -> &[f32] {
        &self.deltas
    }

    fn train_step(
        &mut self,
        images: &[f32],
        labels: &[i32],
        lr: f32,
        lambda: f32,
    ) -> Result<StepOut> {
        anyhow::ensure!(labels.len() == self.batch, "batch size mismatch");
        let acts = self.model.forward_cached(images, self.batch);
        let logits = acts.last().unwrap();
        let (loss, correct, dlogits) =
            ops::softmax_xent(logits, labels, self.batch, self.model.classes);
        self.model.backward(&acts, dlogits, self.batch);
        let h = self.hyper;
        for p in &mut self.model.params {
            debug_assert_eq!(p.grad.len(), p.data.len(), "{}: stale gradient", p.name);
            // split borrows: data/momentum mutably, grad immutably
            let (data, momentum, grad) = (&mut p.data, &mut p.momentum, &p.grad);
            match (p.kind, p.qidx) {
                (Kind::Weight, Some(q)) => sgd::symog_step(
                    data,
                    momentum,
                    grad,
                    self.deltas[q],
                    h.n_bits,
                    lr,
                    lambda,
                    h.momentum,
                    h.weight_decay,
                    h.clip,
                ),
                _ => sgd::nesterov_step(data, momentum, grad, lr, h.momentum, h.weight_decay),
            }
        }
        Ok(StepOut { loss, correct })
    }

    fn eval_batch(&self, images: &[f32], labels: &[i32], quantized: bool) -> Result<StepOut> {
        anyhow::ensure!(labels.len() == self.batch, "batch size mismatch");
        let quant = quantized.then_some((self.deltas.as_slice(), self.hyper.n_bits));
        let logits = self.model.logits(images, self.batch, quant);
        let (loss, correct, _) = ops::softmax_xent(&logits, labels, self.batch, self.model.classes);
        Ok(StepOut { loss, correct })
    }

    fn quant_layers_host(&self) -> Result<Vec<(Vec<f32>, f32)>> {
        Ok(self
            .model
            .quant_weights()
            .iter()
            .zip(&self.deltas)
            .map(|(p, &d)| (p.data.clone(), d))
            .collect())
    }

    fn to_checkpoint(&self, epoch: u32) -> Result<Checkpoint> {
        Ok(self.model.to_checkpoint(&self.deltas, epoch, "symog"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_backend(seed: u64) -> NativeBackend {
        let model = NativeModel::mlp([4, 4, 1], &[8], 4, seed);
        NativeBackend::new(model, NativeHyper::default(), 8)
    }

    fn tiny_batch(backend: &NativeBackend, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let n = backend.batch() * 16;
        let images: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let labels: Vec<i32> = (0..backend.batch()).map(|_| rng.below(4) as i32).collect();
        (images, labels)
    }

    #[test]
    fn deltas_are_powers_of_two() {
        let b = tiny_backend(0);
        assert_eq!(b.deltas().len(), b.n_quant());
        for &d in b.deltas() {
            assert!(d > 0.0);
            let f = d.log2();
            assert!((f - f.round()).abs() < 1e-6, "delta {d} not a power of two");
        }
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let mut b = tiny_backend(1);
        let (images, labels) = tiny_batch(&b, 2);
        let first = b.train_step(&images, &labels, 0.05, 0.0).unwrap();
        let mut last = first;
        for _ in 0..20 {
            last = b.train_step(&images, &labels, 0.05, 0.0).unwrap();
        }
        assert!(
            last.loss < first.loss * 0.8,
            "loss {} -> {}",
            first.loss,
            last.loss
        );
    }

    #[test]
    fn clip_confines_weights() {
        let mut b = tiny_backend(3);
        let (images, labels) = tiny_batch(&b, 4);
        for _ in 0..10 {
            b.train_step(&images, &labels, 0.05, 50.0).unwrap();
        }
        for (w, d) in b.quant_layers_host().unwrap() {
            let bound = fixedpoint::clip_bound(b.n_bits(), d);
            assert!(w.iter().all(|x| x.abs() <= bound + 1e-5));
        }
    }

    #[test]
    fn eval_is_deterministic_and_state_free() {
        let b = tiny_backend(5);
        let (images, labels) = tiny_batch(&b, 6);
        let a = b.eval_batch(&images, &labels, true).unwrap();
        let c = b.eval_batch(&images, &labels, true).unwrap();
        assert_eq!(a.loss, c.loss);
        assert_eq!(a.correct, c.correct);
        // evaluating must not have mutated the model
        let before = b.quant_layers_host().unwrap();
        b.eval_batch(&images, &labels, false).unwrap();
        assert_eq!(before[0].0, b.quant_layers_host().unwrap()[0].0);
    }

    #[test]
    fn checkpoint_roundtrip_matches_eval() {
        let mut b = tiny_backend(7);
        let (images, labels) = tiny_batch(&b, 8);
        for _ in 0..5 {
            b.train_step(&images, &labels, 0.02, 10.0).unwrap();
        }
        let ck = b.to_checkpoint(5).unwrap();
        assert_eq!(ck.meta_i64("epoch"), Some(5));

        let model2 = NativeModel::mlp([4, 4, 1], &[8], 4, 999);
        let mut b2 = NativeBackend::new(model2, NativeHyper::default(), 8);
        b2.load_checkpoint(&ck, false).unwrap();
        assert_eq!(b.deltas(), b2.deltas());
        let e1 = b.eval_batch(&images, &labels, true).unwrap();
        let e2 = b2.eval_batch(&images, &labels, true).unwrap();
        assert_eq!(e1.loss, e2.loss);
        assert_eq!(e1.correct, e2.correct);
    }

    #[test]
    fn missing_deltas_rejected_without_resolve() {
        let b = tiny_backend(9);
        let mut ck = b.to_checkpoint(0).unwrap();
        ck.tensors.retain(|t| t.name != "__deltas__");
        let mut b2 = tiny_backend(9);
        assert!(b2.load_checkpoint(&ck, false).is_err());
        // but resolving from weights still works
        b2.load_checkpoint(&ck, true).unwrap();
        assert_eq!(b2.deltas().len(), b2.n_quant());
    }
}
