//! Minibatch SGD with Nesterov momentum, plus the fused SYMOG weight
//! update (Algorithm 1, lines 14–17) — the native twin of the Pallas
//! `sgd_update` kernel and its `ref.py` oracle:
//!
//! ```text
//! g_total = dC/dw + lam * (2/M)(w - Q_N(w; delta)) + weight_decay * w
//! v'      = momentum * v - lr * g_total
//! w'      = w + momentum * v' - lr * g_total      (Nesterov lookahead)
//! w'      = clip(w', +-delta (2^{N-1} - 1))       (section 3.4)
//! ```

use crate::fixedpoint::{clip_bound, quantize};

/// Plain Nesterov step for non-quantized parameters (bias / BN affine).
pub fn nesterov_step(
    w: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    momentum: f32,
    weight_decay: f32,
) {
    debug_assert!(w.len() == v.len() && w.len() == g.len());
    for i in 0..w.len() {
        let gt = g[i] + weight_decay * w[i];
        let vn = momentum * v[i] - lr * gt;
        w[i] += momentum * vn - lr * gt;
        v[i] = vn;
    }
}

/// Fused SYMOG update for one quantized weight tensor.
#[allow(clippy::too_many_arguments)]
pub fn symog_step(
    w: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    delta: f32,
    n_bits: u32,
    lr: f32,
    lam: f32,
    momentum: f32,
    weight_decay: f32,
    clip: bool,
) {
    debug_assert!(w.len() == v.len() && w.len() == g.len());
    let inv_m2 = 2.0 / w.len().max(1) as f32;
    let bound = clip_bound(n_bits, delta);
    for i in 0..w.len() {
        let q = quantize(w[i], delta, n_bits);
        let gt = g[i] + lam * inv_m2 * (w[i] - q) + weight_decay * w[i];
        let vn = momentum * v[i] - lr * gt;
        let mut wn = w[i] + momentum * vn - lr * gt;
        if clip {
            wn = wn.clamp(-bound, bound);
        }
        w[i] = wn;
        v[i] = vn;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nesterov_matches_hand_computation() {
        // one step: v' = 0.9*0 - 0.1*1 = -0.1; w' = 1 + 0.9*(-0.1) - 0.1 = 0.81
        let mut w = vec![1.0f32];
        let mut v = vec![0.0f32];
        nesterov_step(&mut w, &mut v, &[1.0], 0.1, 0.9, 0.0);
        assert!((w[0] - 0.81).abs() < 1e-6);
        assert!((v[0] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut w = vec![2.0f32];
        let mut v = vec![0.0f32];
        nesterov_step(&mut w, &mut v, &[0.0], 0.1, 0.0, 0.5);
        // g_total = 0.5*2 = 1; w' = 2 - 0.1*1 = 1.9
        assert!((w[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn symog_zero_lambda_reduces_to_nesterov_plus_clip() {
        let g = [0.3f32, -0.2];
        let mut w1 = vec![0.1f32, -0.05];
        let mut v1 = vec![0.0f32; 2];
        let mut w2 = w1.clone();
        let mut v2 = v1.clone();
        symog_step(&mut w1, &mut v1, &g, 0.5, 2, 0.01, 0.0, 0.9, 0.0, false);
        nesterov_step(&mut w2, &mut v2, &g, 0.01, 0.9, 0.0);
        crate::testing::assert_allclose(&w1, &w2, 1e-7);
        crate::testing::assert_allclose(&v1, &v2, 1e-7);
    }

    #[test]
    fn clip_keeps_weights_in_domain() {
        let mut w = vec![0.49f32, -0.49];
        let mut v = vec![0.0f32; 2];
        // huge task gradient pushing both weights out of [-0.5, 0.5]
        symog_step(&mut w, &mut v, &[-50.0, 50.0], 0.5, 2, 0.1, 0.0, 0.9, 0.0, true);
        assert!(w.iter().all(|x| x.abs() <= 0.5 + 1e-6), "{w:?}");
    }

    #[test]
    fn pure_regularizer_converges_to_nearest_mode() {
        // no task gradient: repeated steps must pull w onto the codebook
        let delta = 0.25f32;
        let mut w = vec![0.31f32, -0.12, 0.04, -0.29];
        let targets: Vec<f32> =
            w.iter().map(|&x| crate::fixedpoint::quantize(x, delta, 2)).collect();
        let mut v = vec![0.0f32; w.len()];
        let g = vec![0.0f32; w.len()];
        let lam = 100.0; // lam * 2/M = 50 -> lr*that = 0.05 per unit distance
        for _ in 0..400 {
            symog_step(&mut w, &mut v, &g, delta, 2, 0.001, lam, 0.9, 0.0, true);
        }
        for (x, t) in w.iter().zip(&targets) {
            assert!((x - t).abs() < 0.01, "w {x} did not reach mode {t}");
        }
    }
}
