//! Sequential f32 model for the native training backend.
//!
//! A `NativeModel` is a stack of conv / dense / ReLU nodes over NHWC
//! activations, with its parameters held host-side (data + gradient +
//! momentum per tensor). Naming and kinds mirror the AOT manifest
//! convention (`l{i}.dense.w`, kind "weight"/"bias", qidx per quantized
//! weight) so checkpoints interoperate with the rest of the toolbox.

use anyhow::{Context, Result};

use crate::coordinator::checkpoint::{Checkpoint, Kind, Tensor};
use crate::fixedpoint::quantize_slice;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::ops::{self, Conv2dShape};

/// One trainable tensor with its optimizer state.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub kind: Kind,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
    pub grad: Vec<f32>,
    pub momentum: Vec<f32>,
    /// index into the deltas vector; Some only for quantized weights
    pub qidx: Option<usize>,
}

impl Param {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One node of the sequential graph (shapes resolved at build time).
#[derive(Clone, Copy, Debug)]
enum Node {
    Conv { w: usize, b: usize, shape: Conv2dShape },
    Dense { w: usize, b: usize, fin: usize, fout: usize },
    Relu,
}

/// A sequential model: input -> nodes -> logits.
pub struct NativeModel {
    pub tag: String,
    pub input_shape: [usize; 3],
    pub classes: usize,
    pub params: Vec<Param>,
    pub n_quant: usize,
    nodes: Vec<Node>,
}

/// Incremental builder so architectures stay declarative at call sites.
pub struct ModelBuilder {
    tag: String,
    input_shape: [usize; 3],
    cur: [usize; 3],
    params: Vec<Param>,
    nodes: Vec<Node>,
    n_quant: usize,
    rng: Rng,
}

impl ModelBuilder {
    pub fn new(tag: &str, input_shape: [usize; 3], seed: u64) -> Self {
        ModelBuilder {
            tag: tag.to_string(),
            input_shape,
            cur: input_shape,
            params: Vec::new(),
            nodes: Vec::new(),
            n_quant: 0,
            rng: Rng::new(seed ^ 0x4E415456), // "NATV"
        }
    }

    fn he_init(&mut self, numel: usize, fan_in: usize) -> Vec<f32> {
        let sigma = (2.0 / fan_in.max(1) as f32).sqrt();
        let mut w = vec![0f32; numel];
        self.rng.fill_normal(&mut w, sigma);
        w
    }

    fn push_param(&mut self, name: String, kind: Kind, shape: Vec<usize>, data: Vec<f32>) -> usize {
        let n = data.len();
        self.params.push(Param {
            name,
            kind,
            shape,
            data,
            grad: Vec::new(),
            momentum: vec![0f32; n],
            qidx: None,
        });
        self.params.len() - 1
    }

    /// 3x3-style SAME conv (odd k), stride `stride`, `cout` filters.
    pub fn conv(mut self, k: usize, stride: usize, cout: usize) -> Self {
        assert!(k % 2 == 1, "conv kernel must be odd for SAME padding");
        let [h, w, cin] = self.cur;
        let shape = Conv2dShape { h, w, cin, k, stride, cout };
        let li = self.nodes.len();
        let fan_in = k * k * cin;
        let wdata = self.he_init(shape.weight_elems(), fan_in);
        let wi = self.push_param(
            format!("l{li}.conv.w"),
            Kind::Weight,
            vec![k, k, cin, cout],
            wdata,
        );
        self.params[wi].qidx = Some(self.n_quant);
        self.n_quant += 1;
        let bi = self.push_param(format!("l{li}.conv.b"), Kind::Bias, vec![cout], vec![0f32; cout]);
        self.nodes.push(Node::Conv { w: wi, b: bi, shape });
        self.cur = [shape.out_h(), shape.out_w(), cout];
        self
    }

    /// Fully-connected layer over the flattened current activation.
    pub fn dense(mut self, fout: usize) -> Self {
        let fin = self.cur[0] * self.cur[1] * self.cur[2];
        let li = self.nodes.len();
        let wdata = self.he_init(fin * fout, fin);
        let wi = self.push_param(format!("l{li}.dense.w"), Kind::Weight, vec![fin, fout], wdata);
        self.params[wi].qidx = Some(self.n_quant);
        self.n_quant += 1;
        let bi =
            self.push_param(format!("l{li}.dense.b"), Kind::Bias, vec![fout], vec![0f32; fout]);
        self.nodes.push(Node::Dense { w: wi, b: bi, fin, fout });
        self.cur = [1, 1, fout];
        self
    }

    pub fn relu(mut self) -> Self {
        self.nodes.push(Node::Relu);
        self
    }

    /// Finish with the classifier head already in place.
    pub fn build(self) -> NativeModel {
        let classes = self.cur[0] * self.cur[1] * self.cur[2];
        NativeModel {
            tag: self.tag,
            input_shape: self.input_shape,
            classes,
            params: self.params,
            n_quant: self.n_quant,
            nodes: self.nodes,
        }
    }
}

impl NativeModel {
    /// MLP: flatten -> (dense -> relu)* -> dense(classes).
    pub fn mlp(input_shape: [usize; 3], hidden: &[usize], classes: usize, seed: u64) -> Self {
        let mut b = ModelBuilder::new("native-mlp", input_shape, seed);
        for &h in hidden {
            b = b.dense(h).relu();
        }
        b.dense(classes).build()
    }

    /// Small convnet: (conv3x3 s2 -> relu)* -> dense(classes).
    pub fn convnet(
        input_shape: [usize; 3],
        channels: &[usize],
        classes: usize,
        seed: u64,
    ) -> NativeModel {
        let mut b = ModelBuilder::new("native-convnet", input_shape, seed);
        for &c in channels {
            b = b.conv(3, 2, c).relu();
        }
        b.dense(classes).build()
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Host weight tensors of the quantized layers in qidx order.
    pub fn quant_weights(&self) -> Vec<&Param> {
        let mut v: Vec<&Param> = self.params.iter().filter(|p| p.qidx.is_some()).collect();
        v.sort_by_key(|p| p.qidx);
        v
    }

    /// Weight slice for node param `idx`, hard-quantized when `quant` is set.
    fn weight_of<'a>(
        &'a self,
        idx: usize,
        quant: Option<(&[f32], u32)>,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        let p = &self.params[idx];
        match (quant, p.qidx) {
            (Some((deltas, n_bits)), Some(q)) => {
                scratch.resize(p.data.len(), 0.0);
                quantize_slice(&p.data, deltas[q], n_bits, scratch);
                scratch
            }
            _ => &p.data,
        }
    }

    /// Forward pass keeping every intermediate activation (for backward).
    /// `acts[0]` is the input; `acts[i + 1]` is node i's output.
    pub fn forward_cached(&self, images: &[f32], batch: usize) -> Vec<Vec<f32>> {
        self.forward_impl(images, batch, None)
    }

    /// Logits only, optionally with hard-quantized weights (evalq semantics).
    pub fn logits(&self, images: &[f32], batch: usize, quant: Option<(&[f32], u32)>) -> Vec<f32> {
        self.forward_impl(images, batch, quant).pop().unwrap()
    }

    fn forward_impl(
        &self,
        images: &[f32],
        batch: usize,
        quant: Option<(&[f32], u32)>,
    ) -> Vec<Vec<f32>> {
        let e = self.input_shape.iter().product::<usize>();
        assert_eq!(images.len(), batch * e, "input size mismatch");
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.nodes.len() + 1);
        acts.push(images.to_vec());
        let mut scratch = Vec::new();
        for node in &self.nodes {
            let x = acts.last().unwrap();
            let y = match *node {
                Node::Conv { w, b, shape } => {
                    let wt = self.weight_of(w, quant, &mut scratch);
                    ops::conv2d_forward(x, wt, &self.params[b].data, batch, &shape)
                }
                Node::Dense { w, b, fin, fout } => {
                    let wt = self.weight_of(w, quant, &mut scratch);
                    ops::dense_forward(x, wt, &self.params[b].data, batch, fin, fout)
                }
                Node::Relu => ops::relu_forward(x),
            };
            acts.push(y);
        }
        acts
    }

    /// Backward pass from `dlogits`; fills `params[i].grad` (overwriting).
    pub fn backward(&mut self, acts: &[Vec<f32>], dlogits: Vec<f32>, batch: usize) {
        assert_eq!(acts.len(), self.nodes.len() + 1);
        let mut dy = dlogits;
        for i in (0..self.nodes.len()).rev() {
            let node = self.nodes[i];
            let x = &acts[i];
            match node {
                Node::Conv { w, b, shape } => {
                    let (dx, dw, db) =
                        ops::conv2d_backward(x, &self.params[w].data, &dy, batch, &shape);
                    self.params[w].grad = dw;
                    self.params[b].grad = db;
                    dy = dx;
                }
                Node::Dense { w, b, fin, fout } => {
                    let (dx, dw, db) =
                        ops::dense_backward(x, &self.params[w].data, &dy, batch, fin, fout);
                    self.params[w].grad = dw;
                    self.params[b].grad = db;
                    dy = dx;
                }
                Node::Relu => {
                    dy = ops::relu_backward(x, &dy);
                }
            }
        }
    }

    /// Derive the inference manifest for this graph: the same layer-dict
    /// convention aot.py emits, so `IntModel::build` (and
    /// `artifact::publish`) consume native models with no special casing.
    /// A `flatten` layer is inserted before the first dense whenever the
    /// running activation is still spatial.
    pub fn to_manifest(&self, n_bits: u32) -> crate::runtime::Manifest {
        use crate::runtime::{LayerDesc, Manifest, ParamMeta};
        fn obj(fields: Vec<(&str, Json)>) -> Json {
            Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
        }
        let idx = |i: usize| Json::Num(i as f64);
        let mut layers = Vec::new();
        let mut cur = self.input_shape;
        for node in &self.nodes {
            match *node {
                Node::Conv { w, b, shape } => {
                    layers.push(obj(vec![
                        ("type", Json::Str("conv".into())),
                        ("w", idx(w)),
                        ("b", idx(b)),
                        ("stride", idx(shape.stride)),
                        ("padding", Json::Str("SAME".into())),
                    ]));
                    cur = [shape.out_h(), shape.out_w(), shape.cout];
                }
                Node::Dense { w, b, fout, .. } => {
                    if cur[0] * cur[1] != 1 {
                        layers.push(obj(vec![("type", Json::Str("flatten".into()))]));
                    }
                    layers.push(obj(vec![
                        ("type", Json::Str("dense".into())),
                        ("w", idx(w)),
                        ("b", idx(b)),
                    ]));
                    cur = [1, 1, fout];
                }
                Node::Relu => layers.push(obj(vec![("type", Json::Str("relu".into()))])),
            }
        }
        let params = self
            .params
            .iter()
            .map(|p| ParamMeta {
                name: p.name.clone(),
                shape: p.shape.clone(),
                kind: match p.kind {
                    Kind::Bias => "bias".to_string(),
                    _ => "weight".to_string(),
                },
                qidx: p.qidx,
                fan_in: match p.kind {
                    // conv [k,k,cin,cout] -> k*k*cin; dense [fin,fout] -> fin
                    Kind::Weight => p.shape[..p.shape.len() - 1].iter().product::<usize>().max(1),
                    _ => 0,
                },
            })
            .collect();
        Manifest {
            tag: self.tag.clone(),
            model: self.tag.clone(),
            method: "symog".to_string(),
            dataset: "native".to_string(),
            width_mult: 1.0,
            batch: 8,
            n_bits,
            momentum: 0.9,
            weight_decay: 0.0,
            clip: true,
            input_shape: self.input_shape,
            num_classes: self.classes,
            n_quant: self.n_quant,
            params,
            state: Vec::new(),
            layers: layers.into_iter().map(LayerDesc).collect(),
        }
    }

    /// Snapshot params + momenta (+ `__deltas__`) into a checkpoint.
    pub fn to_checkpoint(&self, deltas: &[f32], epoch: u32, method: &str) -> Checkpoint {
        let mut ck = Checkpoint::default();
        ck.set_meta("model", Json::Str(self.tag.clone()));
        ck.set_meta("method", Json::Str(method.to_string()));
        ck.set_meta("epoch", Json::Num(epoch as f64));
        for p in &self.params {
            ck.tensors.push(Tensor {
                name: p.name.clone(),
                kind: p.kind,
                dims: p.shape.clone(),
                data: p.data.clone(),
            });
            ck.tensors.push(Tensor {
                name: format!("{}#m", p.name),
                kind: Kind::Momentum,
                dims: p.shape.clone(),
                data: p.momentum.clone(),
            });
        }
        ck.tensors.push(Tensor {
            name: "__deltas__".into(),
            kind: Kind::Deltas,
            dims: vec![deltas.len()],
            data: deltas.to_vec(),
        });
        ck
    }

    /// Load parameter data (+ momenta when present) from a checkpoint
    /// written by `to_checkpoint` for the same architecture.
    pub fn load_checkpoint(&mut self, ck: &Checkpoint) -> Result<()> {
        for p in &mut self.params {
            let t = ck
                .find(&p.name)
                .with_context(|| format!("checkpoint missing tensor {}", p.name))?;
            anyhow::ensure!(
                t.dims == p.shape,
                "{}: ckpt shape {:?} != model {:?}",
                p.name, t.dims, p.shape
            );
            p.data = t.data.clone();
            match ck.find(&format!("{}#m", p.name)) {
                Some(m) => {
                    anyhow::ensure!(m.data.len() == p.numel(), "{}#m: bad momentum size", p.name);
                    p.momentum = m.data.clone();
                }
                None => p.momentum = vec![0f32; p.numel()],
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_shapes_and_naming() {
        let m = NativeModel::mlp([4, 4, 1], &[8], 3, 0);
        assert_eq!(m.classes, 3);
        assert_eq!(m.n_quant, 2);
        assert_eq!(m.params.len(), 4);
        assert_eq!(m.params[0].name, "l0.dense.w");
        assert_eq!(m.params[0].shape, vec![16, 8]);
        assert_eq!(m.params[0].qidx, Some(0));
        assert_eq!(m.params[1].kind, Kind::Bias);
        assert_eq!(m.num_params(), 16 * 8 + 8 + 8 * 3 + 3);
        let x = vec![0.5f32; 2 * 16];
        let logits = m.logits(&x, 2, None);
        assert_eq!(logits.len(), 2 * 3);
    }

    #[test]
    fn convnet_shapes() {
        let m = NativeModel::convnet([8, 8, 1], &[4, 8], 10, 1);
        // 8x8 -> 4x4x4 -> 2x2x8 -> dense 10
        assert_eq!(m.n_quant, 3);
        let dense_w = m.params.iter().find(|p| p.name.contains("dense.w")).unwrap();
        assert_eq!(dense_w.shape, vec![2 * 2 * 8, 10]);
        let x = vec![0.1f32; 8 * 8];
        let logits = m.logits(&x, 1, None);
        assert_eq!(logits.len(), 10);
    }

    #[test]
    fn he_init_scale_is_sane() {
        let m = NativeModel::mlp([8, 8, 1], &[32], 10, 3);
        let w = &m.params[0];
        let sigma = crate::util::std_dev(&w.data);
        let want = (2.0f32 / 64.0).sqrt();
        assert!((sigma - want).abs() < 0.25 * want, "sigma {sigma} vs {want}");
        // biases start at zero
        assert!(m.params[1].data.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn quantized_forward_uses_codebook_weights() {
        let m = NativeModel::mlp([2, 2, 1], &[], 4, 5);
        let deltas = vec![0.125f32; m.n_quant];
        let x = vec![1.0f32, 0.0, 0.0, 0.0];
        // quantized logits == forward through a hand-quantized copy
        let lq = m.logits(&x, 1, Some((&deltas, 2)));
        let wq: Vec<f32> = m.params[0]
            .data
            .iter()
            .map(|&v| crate::fixedpoint::quantize(v, 0.125, 2))
            .collect();
        let want = ops::dense_forward(&x, &wq, &m.params[1].data, 1, 4, 4);
        crate::testing::assert_allclose(&lq, &want, 1e-6);
        // and differs from the float forward (He weights are off-codebook)
        let lf = m.logits(&x, 1, None);
        assert!(lq.iter().zip(&lf).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn checkpoint_roundtrip_preserves_state() {
        let mut m = NativeModel::mlp([4, 4, 1], &[6], 5, 9);
        m.params[0].momentum[3] = 0.25;
        let ck = m.to_checkpoint(&[0.5, 0.25], 7, "symog");
        assert_eq!(ck.meta_i64("epoch"), Some(7));
        let mut m2 = NativeModel::mlp([4, 4, 1], &[6], 5, 1234);
        assert_ne!(m2.params[0].data, m.params[0].data);
        m2.load_checkpoint(&ck).unwrap();
        assert_eq!(m2.params[0].data, m.params[0].data);
        assert_eq!(m2.params[0].momentum[3], 0.25);
        assert_eq!(ck.find("__deltas__").unwrap().data, vec![0.5, 0.25]);
    }

    #[test]
    fn to_manifest_builds_an_int_model() {
        // the manifest + checkpoint pair must be directly consumable by
        // IntModel::build, flatten inserted where the activation is spatial
        let m = NativeModel::convnet([8, 8, 1], &[4], 10, 2);
        let man = m.to_manifest(2);
        assert_eq!(man.n_quant, 2);
        assert_eq!(man.input_shape, [8, 8, 1]);
        assert_eq!(man.num_classes, 10);
        let types: Vec<&str> = man.layers.iter().map(|l| l.ty()).collect();
        assert_eq!(types, vec!["conv", "relu", "flatten", "dense"]);
        assert_eq!(man.params[0].fan_in, 9);
        let deltas = vec![0.25f32; m.n_quant];
        let ck = m.to_checkpoint(&deltas, 0, "symog");
        let int = crate::inference::IntModel::build(&man, &ck).unwrap();
        let x = vec![0.5f32; 64];
        let (logits, _) = int.forward(&x, 1).unwrap();
        assert_eq!(logits.len(), 10);
        // an all-dense model needs no flatten after the first dense
        let mlp = NativeModel::mlp([4, 4, 1], &[6], 3, 7);
        let types: Vec<String> =
            mlp.to_manifest(2).layers.iter().map(|l| l.ty().to_string()).collect();
        assert_eq!(types, vec!["flatten", "dense", "relu", "dense"]);
    }

    #[test]
    fn wrong_arch_checkpoint_rejected() {
        let m = NativeModel::mlp([4, 4, 1], &[6], 5, 0);
        let ck = m.to_checkpoint(&[1.0, 1.0], 0, "symog");
        let mut other = NativeModel::mlp([4, 4, 1], &[7], 5, 0);
        assert!(other.load_checkpoint(&ck).is_err());
    }
}
