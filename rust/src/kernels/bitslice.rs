//! Bit-sliced AND/popcount GEMM for symmetric low-bit codes.
//!
//! SYMOG's symmetric codebook keeps 2-/3-bit weight mantissas in
//! {-qmax..qmax}, and SYQ-style bit-plane execution turns the resulting
//! dot products into bitwise AND + population count — no multiplier, not
//! even the add/sub walk of the ternary plan. This module holds the whole
//! path:
//!
//! * **dual sign-magnitude planes**: a weight column decomposes as
//!   `m = sum_jb 2^jb * (Wp_jb - Wn_jb)` where plane `Wp_jb` holds bit
//!   `jb` of `|m|` for positive mantissas and `Wn_jb` for negative ones
//!   (one magnitude plane for ternary, two for |m| <= 3). Activations
//!   slice the same way per A-row: `a = sum_i 2^i * (Ap_i - An_i)`. Zero
//!   values set no bits in any plane, so SYMOG's dominant zero mode and
//!   post-ReLU activation sparsity survive as empty (skippable) planes.
//! * **the exact identity**: with all planes over the same `depth` lanes,
//!   `dot = sum_{i,jb} 2^(i+jb) * [pc(Ap_i & Wp_jb) - pc(Ap_i & Wn_jb)
//!   - pc(An_i & Wp_jb) + pc(An_i & Wn_jb)]` — no correction terms, and
//!   padded lanes beyond `depth` are zero in every plane so they
//!   contribute nothing. Popcounts accumulate in i64 and the final value
//!   narrows to i32 exactly (the engine's accumulator bound applies to
//!   every kernel equally). Because `Ap_i & Wp_jb` and `An_i & Wn_jb`
//!   can never share a set bit (a lane is positive on one side or the
//!   other), the two positive-signed terms fuse into one popcount of an
//!   OR — halving the popcount work when both sign planes are live.
//! * **runtime dispatch ladder** ([`simd_level`]): AVX2 on x86_64 (nibble
//!   LUT via `vpshufb` + `vpsadbw` accumulation), NEON on aarch64
//!   (`vcntq_u8` + `vaddlvq_u8`), with the portable scalar
//!   `count_ones` loop as the always-available oracle. Detection runs
//!   once per process; `SYMOG_SIMD=scalar` forces the fallback (CI's
//!   simd-matrix job runs every suite under each rung). All `unsafe` is
//!   confined to the `#[target_feature]` call boundary — every memory
//!   access goes through safe slices.
//!
//! [`crate::inference::gemm`] races this kernel against the ternary
//! add/sub plan and the packed-panel multiply GEMM per weight (see
//! [`estimated_row_cost`]); `BitslicePlan::from_packed` builds planes
//! straight from `.fxpm` packed codes without unpacking a mantissa
//! tensor first.

use std::sync::OnceLock;

/// Largest |mantissa| the plane decomposition covers (two magnitude
/// planes): every n_bits <= 3 code, and any wider code that happens to
/// stay within +/-3.
pub const MAX_MAGNITUDE: u32 = 3;

/// Estimated live activation planes for the analytic cost race. Interior
/// activations are requantized to 16 bits but are one-sided after ReLU
/// (~15 single-sign planes), and network inputs are 8-bit two-sided
/// (~7 planes per sign with at most one side live per lane): both land
/// near 8 plane-pair equivalents.
const ACT_PLANES_EST: u64 = 8;

/// Scalar-op weight of one u64 AND+popcount+accumulate word step,
/// relative to the one integer add a ternary index-list entry costs.
const WORD_OP_WEIGHT: u64 = 2;

// ---------------------------------------------------------------------------
// runtime SIMD dispatch

/// One rung of the dispatch ladder. Arch-foreign rungs don't exist at
/// compile time, so a match on the level can never name an unavailable
/// intrinsic set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable `count_ones` loop — the bit-exact oracle and the forced
    /// fallback under `SYMOG_SIMD=scalar`.
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => "neon",
        }
    }
}

fn parse_level(s: &str) -> Option<SimdLevel> {
    match s.trim().to_ascii_lowercase().as_str() {
        "scalar" => Some(SimdLevel::Scalar),
        #[cfg(target_arch = "x86_64")]
        "avx2" => Some(SimdLevel::Avx2),
        #[cfg(target_arch = "aarch64")]
        "neon" => Some(SimdLevel::Neon),
        _ => None,
    }
}

fn supported(level: SimdLevel) -> bool {
    match level {
        SimdLevel::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => true,
    }
}

// the tail fallback is unreachable on aarch64, where NEON is baseline
#[allow(unreachable_code)]
fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

/// The SIMD rung this process dispatches to, decided once: an explicit
/// `SYMOG_SIMD` override (`scalar` always honored; `avx2`/`neon` honored
/// when the host supports them) or runtime feature detection. Read once
/// per process — this sits on the GEMM hot path, like `SYMOG_WORKERS` in
/// `util::pool`.
pub fn simd_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        match std::env::var("SYMOG_SIMD").ok().and_then(|s| parse_level(&s)) {
            Some(SimdLevel::Scalar) => SimdLevel::Scalar,
            Some(l) if supported(l) => l,
            _ => detect(),
        }
    })
}

/// Every rung the current host can execute (scalar first). Tests race
/// all of them against each other.
pub fn available_levels() -> Vec<SimdLevel> {
    let mut v = vec![SimdLevel::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(SimdLevel::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        v.push(SimdLevel::Neon);
    }
    v
}

// ---------------------------------------------------------------------------
// popcount primitives

/// `popcount(a & b)` over equal-length u64 slices.
#[inline]
fn popcount_and(a: &[u64], b: &[u64], level: SimdLevel) -> u64 {
    match level {
        SimdLevel::Scalar => popcount_and_scalar(a, b),
        // SAFETY: the Avx2/Neon rungs are only ever constructed after a
        // runtime feature check (`supported`/`detect`), so the required
        // target features are present.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { avx2::popcount_and(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::popcount_and(a, b) },
    }
}

/// `popcount((a1 & b1) | (a2 & b2))` — exact fused sum of two popcounts
/// when the two AND results are bitwise disjoint (sign planes of the
/// same value are; see the module docs).
#[inline]
fn popcount_and2(a1: &[u64], b1: &[u64], a2: &[u64], b2: &[u64], level: SimdLevel) -> u64 {
    match level {
        SimdLevel::Scalar => popcount_and2_scalar(a1, b1, a2, b2),
        // SAFETY: see `popcount_and` — the rung implies the feature.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { avx2::popcount_and2(a1, b1, a2, b2) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::popcount_and2(a1, b1, a2, b2) },
    }
}

fn popcount_and_scalar(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones() as u64).sum()
}

fn popcount_and2_scalar(a1: &[u64], b1: &[u64], a2: &[u64], b2: &[u64]) -> u64 {
    debug_assert_eq!(a1.len(), b1.len());
    debug_assert_eq!(a1.len(), a2.len());
    debug_assert_eq!(a1.len(), b2.len());
    a1.iter()
        .zip(b1)
        .zip(a2.iter().zip(b2))
        .map(|((&x1, &y1), (&x2, &y2))| ((x1 & y1) | (x2 & y2)).count_ones() as u64)
        .sum()
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 popcount: nibble lookup (`vpshufb` against a 0..=4 table for
    //! the low and high nibbles) summed horizontally into four u64 lanes
    //! with `vpsadbw`. Unaligned loads throughout — plane buffers carry
    //! no alignment contract. The scalar tail handles `len % 4` words.

    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn accumulate(acc: __m256i, v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3,
            2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        // per-qword byte sums: each SAD lane grows by <= 64 per step, so
        // the u64 lanes cannot overflow for any realizable plane length
        _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()))
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn reduce(v: __m256i) -> u64 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr().cast(), v);
        lanes[0] + lanes[1] + lanes[2] + lanes[3]
    }

    /// # Safety
    /// Caller must ensure the host supports AVX2 (the dispatch ladder
    /// only selects this rung after `is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn popcount_and(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 4 <= n {
            let va = _mm256_loadu_si256(a.as_ptr().add(i).cast());
            let vb = _mm256_loadu_si256(b.as_ptr().add(i).cast());
            acc = accumulate(acc, _mm256_and_si256(va, vb));
            i += 4;
        }
        let mut total = reduce(acc);
        while i < n {
            total += (a[i] & b[i]).count_ones() as u64;
            i += 1;
        }
        total
    }

    /// # Safety
    /// Same contract as [`popcount_and`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn popcount_and2(a1: &[u64], b1: &[u64], a2: &[u64], b2: &[u64]) -> u64 {
        debug_assert_eq!(a1.len(), b1.len());
        debug_assert_eq!(a1.len(), a2.len());
        debug_assert_eq!(a1.len(), b2.len());
        let n = a1.len();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 4 <= n {
            let x1 = _mm256_and_si256(
                _mm256_loadu_si256(a1.as_ptr().add(i).cast()),
                _mm256_loadu_si256(b1.as_ptr().add(i).cast()),
            );
            let x2 = _mm256_and_si256(
                _mm256_loadu_si256(a2.as_ptr().add(i).cast()),
                _mm256_loadu_si256(b2.as_ptr().add(i).cast()),
            );
            acc = accumulate(acc, _mm256_or_si256(x1, x2));
            i += 4;
        }
        let mut total = reduce(acc);
        while i < n {
            total += ((a1[i] & b1[i]) | (a2[i] & b2[i])).count_ones() as u64;
            i += 1;
        }
        total
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON popcount: `vcntq_u8` per 16-byte chunk, horizontally summed
    //! with `vaddlvq_u8`. NEON is baseline on aarch64, so this rung is
    //! always available there.

    use std::arch::aarch64::*;

    /// # Safety
    /// NEON is mandatory on aarch64; the dispatch ladder only selects
    /// this rung on aarch64 hosts.
    #[target_feature(enable = "neon")]
    pub unsafe fn popcount_and(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut total = 0u64;
        let mut i = 0usize;
        while i + 2 <= n {
            let v = vandq_u64(vld1q_u64(a.as_ptr().add(i)), vld1q_u64(b.as_ptr().add(i)));
            total += vaddlvq_u8(vcntq_u8(vreinterpretq_u8_u64(v))) as u64;
            i += 2;
        }
        if i < n {
            total += (a[i] & b[i]).count_ones() as u64;
        }
        total
    }

    /// # Safety
    /// Same contract as [`popcount_and`].
    #[target_feature(enable = "neon")]
    pub unsafe fn popcount_and2(a1: &[u64], b1: &[u64], a2: &[u64], b2: &[u64]) -> u64 {
        debug_assert_eq!(a1.len(), b1.len());
        debug_assert_eq!(a1.len(), a2.len());
        debug_assert_eq!(a1.len(), b2.len());
        let n = a1.len();
        let mut total = 0u64;
        let mut i = 0usize;
        while i + 2 <= n {
            let x1 = vandq_u64(vld1q_u64(a1.as_ptr().add(i)), vld1q_u64(b1.as_ptr().add(i)));
            let x2 = vandq_u64(vld1q_u64(a2.as_ptr().add(i)), vld1q_u64(b2.as_ptr().add(i)));
            let v = vorrq_u64(x1, x2);
            total += vaddlvq_u8(vcntq_u8(vreinterpretq_u8_u64(v))) as u64;
            i += 2;
        }
        if i < n {
            total += ((a1[i] & b1[i]) | (a2[i] & b2[i])).count_ones() as u64;
        }
        total
    }
}

// ---------------------------------------------------------------------------
// eligibility + analytic cost

/// Largest |mantissa| of a weight — the bit-slice eligibility test
/// (`<=` [`MAX_MAGNITUDE`]) works off actual magnitudes, not the nominal
/// code width, so a wide code that trained into a narrow range still
/// qualifies.
pub fn max_magnitude(mantissa: &[i8]) -> u32 {
    mantissa.iter().map(|&m| (m as i32).unsigned_abs()).max().unwrap_or(0)
}

/// Can this weight run on the bit-sliced kernel?
pub fn eligible(mantissa: &[i8]) -> bool {
    max_magnitude(mantissa) <= MAX_MAGNITUDE
}

/// Estimated cost of one bit-sliced A-row, in scalar-op equivalents: per
/// output column, `2 * mag_bits` weight planes race [`ACT_PLANES_EST`]
/// activation plane-pairs over `ceil(depth/64)` words, each word step
/// weighing [`WORD_OP_WEIGHT`]. The ternary add/sub plan costs one add
/// per nonzero weight per row, so for a ternary matrix this race
/// degenerates to the old >= 50%-zeros rule at large depth; the packed
/// multiply GEMM costs `depth * cols` MACs per row and always loses to
/// an eligible bit-sliced plan. `inference::gemm::select_kernel` runs
/// the race once per weight.
pub fn estimated_row_cost(depth: usize, cols: usize, mag_bits: usize) -> u64 {
    let words = depth.div_ceil(64) as u64;
    cols as u64 * 2 * mag_bits as u64 * ACT_PLANES_EST * words * WORD_OP_WEIGHT
}

// ---------------------------------------------------------------------------
// weight planes

/// Per-column dual sign-magnitude bit planes of a `[depth, cols]` weight
/// matrix with |mantissa| <= [`MAX_MAGNITUDE`]. Column `j`'s planes are
/// contiguous — `[Wp_0 .. Wp_{mb-1}, Wn_0 .. Wn_{mb-1}]`, each
/// `ceil(depth/64)` words — so one output element streams one compact
/// run (a 2-bit column costs 2 bit-planes ~ depth/4 bytes, 16x less
/// weight traffic than i32 panels).
#[derive(Clone, Debug, PartialEq)]
pub struct BitslicePlan {
    planes: Vec<u64>,
    /// per plane, in column-major plane order: does it have any set bit?
    /// (SYMOG's zero mode and single-sign columns make empty planes
    /// common; empty ones are skipped without touching their words)
    nonempty: Vec<bool>,
    /// magnitude planes per sign: 1 covers |m| <= 1, 2 covers |m| <= 3
    mag_bits: usize,
    words: usize,
    pub depth: usize,
    pub cols: usize,
}

impl BitslicePlan {
    /// Build from a row-major `[depth, cols]` mantissa matrix.
    pub fn build(b: &[i32], depth: usize, cols: usize) -> BitslicePlan {
        debug_assert_eq!(b.len(), depth * cols);
        Self::build_with(depth, cols, |k, j| b[k * cols + j])
    }

    /// Build straight from `quant::packed` codes (row-major `[depth,
    /// cols]` mantissas, `n_bits`-wide biased codes) — the `.fxpm`
    /// deployment path never materializes an unpacked weight tensor.
    pub fn from_packed(packed: &[u8], n_bits: u32, depth: usize, cols: usize) -> BitslicePlan {
        Self::build_with(depth, cols, |k, j| {
            crate::quant::packed::mantissa_at(packed, k * cols + j, n_bits) as i32
        })
    }

    fn build_with(depth: usize, cols: usize, get: impl Fn(usize, usize) -> i32) -> BitslicePlan {
        let mut max_mag = 0u32;
        for k in 0..depth {
            for j in 0..cols {
                max_mag = max_mag.max(get(k, j).unsigned_abs());
            }
        }
        assert!(
            max_mag <= MAX_MAGNITUDE,
            "bit-slice plan needs |mantissa| <= {MAX_MAGNITUDE}, got {max_mag}"
        );
        let mag_bits = if max_mag <= 1 { 1 } else { 2 };
        let words = depth.div_ceil(64);
        let stride = 2 * mag_bits * words;
        let mut planes = vec![0u64; cols * stride];
        for k in 0..depth {
            let (word, bit) = (k / 64, 1u64 << (k % 64));
            for j in 0..cols {
                let m = get(k, j);
                if m == 0 {
                    continue;
                }
                let base = j * stride + if m > 0 { 0 } else { mag_bits * words };
                let mag = m.unsigned_abs();
                for jb in 0..mag_bits {
                    if mag >> jb & 1 == 1 {
                        planes[base + jb * words + word] |= bit;
                    }
                }
            }
        }
        let nonempty = planes
            .chunks(words.max(1))
            .map(|p| p.iter().any(|&w| w != 0))
            .collect();
        BitslicePlan { planes, nonempty, mag_bits, words, depth, cols }
    }

    pub fn mag_bits(&self) -> usize {
        self.mag_bits
    }

    pub fn words(&self) -> usize {
        self.words
    }

    /// Exact dot product of one sliced A-row against column `j` (see the
    /// module docs for the identity). i64 accumulation; callers narrow.
    fn dot_col(&self, row: &RowPlanes, j: usize, level: SimdLevel) -> i64 {
        let (mb, words) = (self.mag_bits, self.words);
        let col = &self.planes[j * 2 * mb * words..(j + 1) * 2 * mb * words];
        let flags = &self.nonempty[j * 2 * mb..(j + 1) * 2 * mb];
        let mut acc = 0i64;
        for jb in 0..mb {
            let (wp_live, wn_live) = (flags[jb], flags[mb + jb]);
            if !wp_live && !wn_live {
                continue;
            }
            let wp = &col[jb * words..(jb + 1) * words];
            let wn = &col[(mb + jb) * words..(mb + jb + 1) * words];
            for i in 0..row.abits {
                let ap_live = row.pos_mask >> i & 1 == 1;
                let an_live = row.neg_mask >> i & 1 == 1;
                if !ap_live && !an_live {
                    continue;
                }
                let ap = &row.pos[i * words..(i + 1) * words];
                let an = &row.neg[i * words..(i + 1) * words];
                // (Ap & Wp) and (An & Wn) are disjoint, as are the two
                // cross terms — each pair fuses into one popcount
                let pos = pc_pair(ap, ap_live && wp_live, wp, an, an_live && wn_live, wn, level);
                let neg = pc_pair(ap, ap_live && wn_live, wn, an, an_live && wp_live, wp, level);
                acc += (pos as i64 - neg as i64) << (i + jb);
            }
        }
        acc
    }
}

#[inline]
fn pc_pair(
    a1: &[u64],
    live1: bool,
    b1: &[u64],
    a2: &[u64],
    live2: bool,
    b2: &[u64],
    level: SimdLevel,
) -> u64 {
    match (live1, live2) {
        (true, true) => popcount_and2(a1, b1, a2, b2, level),
        (true, false) => popcount_and(a1, b1, level),
        (false, true) => popcount_and(a2, b2, level),
        (false, false) => 0,
    }
}

// ---------------------------------------------------------------------------
// activation slicing + the GEMM

/// Sign-magnitude bit planes of one A-row, rebuilt per row and reused
/// across every output column. Plane count follows the row's actual
/// |max| (post-ReLU rows have no negative planes at all), and the
/// per-plane live masks let `dot_col` skip empty planes.
struct RowPlanes {
    pos: Vec<u64>,
    neg: Vec<u64>,
    pos_mask: u32,
    neg_mask: u32,
    abits: usize,
    words: usize,
}

impl RowPlanes {
    fn new(words: usize) -> RowPlanes {
        RowPlanes { pos: Vec::new(), neg: Vec::new(), pos_mask: 0, neg_mask: 0, abits: 0, words }
    }

    fn slice(&mut self, a_row: &[i32]) {
        let mut max_mag = 0u32;
        for &v in a_row {
            max_mag = max_mag.max(v.unsigned_abs());
        }
        self.abits = (32 - max_mag.leading_zeros()) as usize;
        self.pos_mask = 0;
        self.neg_mask = 0;
        let need = self.abits * self.words;
        if self.pos.len() < need {
            self.pos.resize(need, 0);
            self.neg.resize(need, 0);
        }
        // only planes 0..abits are consulted this row, so only they are
        // cleared — stale higher planes from a wider previous row are dead
        self.pos[..need].fill(0);
        self.neg[..need].fill(0);
        for (k, &v) in a_row.iter().enumerate() {
            if v == 0 {
                continue;
            }
            let (planes, mask) = if v > 0 {
                (&mut self.pos, &mut self.pos_mask)
            } else {
                (&mut self.neg, &mut self.neg_mask)
            };
            let (word, bit) = (k / 64, 1u64 << (k % 64));
            let mut mag = v.unsigned_abs();
            while mag != 0 {
                let i = mag.trailing_zeros() as usize;
                planes[i * self.words + word] |= bit;
                *mask |= 1 << i;
                mag &= mag - 1;
            }
        }
    }
}

/// `C += A * B` where `B` is a [`BitslicePlan`] — AND/popcount per plane
/// pair, bit-identical to the multiply kernels on every dispatch rung.
pub fn gemm_bitsliced(
    a: &[i32],
    plan: &BitslicePlan,
    c: &mut [i32],
    rows: usize,
    depth: usize,
    cols: usize,
) {
    gemm_bitsliced_at(a, plan, c, rows, depth, cols, simd_level());
}

/// [`gemm_bitsliced`] pinned to an explicit dispatch rung (tests race
/// every available rung against the scalar oracle).
pub fn gemm_bitsliced_at(
    a: &[i32],
    plan: &BitslicePlan,
    c: &mut [i32],
    rows: usize,
    depth: usize,
    cols: usize,
    level: SimdLevel,
) {
    debug_assert_eq!(a.len(), rows * depth);
    debug_assert_eq!(c.len(), rows * cols);
    debug_assert_eq!(depth, plan.depth);
    debug_assert_eq!(cols, plan.cols);
    let mut row_planes = RowPlanes::new(plan.words);
    for (a_row, c_row) in a.chunks(depth.max(1)).zip(c.chunks_mut(cols.max(1))) {
        row_planes.slice(a_row);
        if row_planes.pos_mask == 0 && row_planes.neg_mask == 0 {
            continue; // all-zero row adds nothing
        }
        for (j, out) in c_row.iter_mut().enumerate() {
            *out += plan.dot_col(&row_planes, j, level) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    /// Schoolbook reference — the same oracle the blocked GEMM races.
    fn gemm_ref(a: &[i32], b: &[i32], rows: usize, depth: usize, cols: usize) -> Vec<i32> {
        let mut c = vec![0i32; rows * cols];
        for i in 0..rows {
            for kk in 0..depth {
                for j in 0..cols {
                    c[i * cols + j] += a[i * depth + kk] * b[kk * cols + j];
                }
            }
        }
        c
    }

    fn check_all_levels(a: &[i32], b: &[i32], rows: usize, depth: usize, cols: usize) {
        let plan = BitslicePlan::build(b, depth, cols);
        let want = gemm_ref(a, b, rows, depth, cols);
        for level in available_levels() {
            let mut c = vec![0i32; rows * cols];
            gemm_bitsliced_at(a, &plan, &mut c, rows, depth, cols, level);
            assert_eq!(c, want, "{rows}x{depth}x{cols} level={}", level.name());
        }
    }

    #[test]
    fn prop_bitslice_matches_schoolbook_on_every_level() {
        forall(20, |rng: &mut Rng| {
            let rows = 1 + rng.below(6);
            let depth = 1 + rng.below(150);
            let cols = 1 + rng.below(20);
            let max_mag = 1 + rng.below(3) as i32; // 1..=3: both mag_bits arms
            let a: Vec<i32> =
                (0..rows * depth).map(|_| rng.below(511) as i32 - 255).collect();
            let b: Vec<i32> = (0..depth * cols)
                .map(|_| rng.below(2 * max_mag as usize + 1) as i32 - max_mag)
                .collect();
            check_all_levels(&a, &b, rows, depth, cols);
        });
    }

    #[test]
    fn word_edge_and_ragged_simd_tail_depths() {
        // depths straddling the u64 word edge and leaving every possible
        // ragged tail for the 4-word AVX2 / 2-word NEON chunking
        for depth in [1usize, 3, 63, 64, 65, 127, 128, 129, 191, 192, 200, 256, 300] {
            let mut rng = Rng::new(depth as u64 ^ 0xB175);
            let (rows, cols) = (3usize, 5usize);
            let a: Vec<i32> = (0..rows * depth).map(|_| rng.below(65) as i32 - 32).collect();
            let b: Vec<i32> = (0..depth * cols).map(|_| rng.below(7) as i32 - 3).collect();
            check_all_levels(&a, &b, rows, depth, cols);
        }
    }

    #[test]
    fn qmax_extreme_codes_and_wide_activations() {
        // every code at +/-qmax for both widths, activations near the
        // 16-bit requantization ceiling (depth kept small so the exact
        // dot stays far inside i32)
        for qmax in [1i32, 3] {
            let (rows, depth, cols) = (2usize, 70usize, 4usize);
            let b: Vec<i32> = (0..depth * cols)
                .map(|i| if i % 2 == 0 { qmax } else { -qmax })
                .collect();
            let a: Vec<i32> = (0..rows * depth)
                .map(|i| match i % 4 {
                    0 => 32767,
                    1 => -32768,
                    2 => 0,
                    _ => 1,
                })
                .collect();
            check_all_levels(&a, &b, rows, depth, cols);
        }
    }

    #[test]
    fn all_zero_planes_are_skipped_exactly() {
        let (rows, depth, cols) = (2usize, 100usize, 6usize);
        // all-zero weights: C stays exactly as preloaded
        let plan = BitslicePlan::build(&vec![0i32; depth * cols], depth, cols);
        assert_eq!(plan.mag_bits(), 1);
        let a: Vec<i32> = (0..rows * depth).map(|i| i as i32 % 17 - 8).collect();
        for level in available_levels() {
            let mut c: Vec<i32> = (0..rows * cols).map(|i| i as i32).collect();
            gemm_bitsliced_at(&a, &plan, &mut c, rows, depth, cols, level);
            assert_eq!(c, (0..(rows * cols) as i32).collect::<Vec<_>>());
        }
        // all-zero activations: likewise
        let b: Vec<i32> = (0..depth * cols).map(|i| i as i32 % 3 - 1).collect();
        let plan = BitslicePlan::build(&b, depth, cols);
        for level in available_levels() {
            let mut c = vec![7i32; rows * cols];
            gemm_bitsliced_at(&vec![0i32; rows * depth], &plan, &mut c, rows, depth, cols, level);
            assert_eq!(c, vec![7i32; rows * cols]);
        }
        // single-sign rows (post-ReLU shape): negative planes never built
        let a_pos: Vec<i32> = (0..rows * depth).map(|i| i as i32 % 9).collect();
        check_all_levels(&a_pos, &b, rows, depth, cols);
    }

    #[test]
    fn accumulates_into_preloaded_c() {
        let (rows, depth, cols) = (2usize, 40usize, 3usize);
        let a: Vec<i32> = (0..rows * depth).map(|i| i as i32 % 11 - 5).collect();
        let b: Vec<i32> = (0..depth * cols).map(|i| i as i32 % 5 - 2).collect();
        let plan = BitslicePlan::build(&b, depth, cols);
        let want = gemm_ref(&a, &b, rows, depth, cols);
        let mut c: Vec<i32> = (0..rows * cols).map(|i| 100 + i as i32).collect();
        gemm_bitsliced_at(&a, &plan, &mut c, rows, depth, cols, SimdLevel::Scalar);
        for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
            assert_eq!(got, 100 + i as i32 + w);
        }
    }

    #[test]
    fn prop_from_packed_matches_dense_build() {
        forall(16, |rng: &mut Rng| {
            let n_bits = 2 + rng.below(2) as u32; // 2 or 3
            let qmax = (1i16 << (n_bits - 1)) - 1;
            let depth = 1 + rng.below(90);
            let cols = 1 + rng.below(12);
            let m: Vec<i8> = (0..depth * cols)
                .map(|_| (rng.below(2 * qmax as usize + 1) as i16 - qmax) as i8)
                .collect();
            let packed = crate::quant::packed::pack_codes(&m, n_bits);
            let wide: Vec<i32> = m.iter().map(|&v| v as i32).collect();
            let dense = BitslicePlan::build(&wide, depth, cols);
            let from_packed = BitslicePlan::from_packed(&packed, n_bits, depth, cols);
            assert_eq!(from_packed, dense, "n_bits={n_bits} {depth}x{cols}");
        });
    }

    #[test]
    fn prop_popcount_primitives_agree_across_levels() {
        forall(24, |rng: &mut Rng| {
            let n = rng.below(41);
            let mk = |rng: &mut Rng| -> Vec<u64> {
                (0..n)
                    .map(|_| {
                        let hi = rng.below(1 << 16) as u64;
                        let lo = rng.below(1 << 16) as u64;
                        hi << 48 | lo << 17 | rng.below(1 << 16) as u64
                    })
                    .collect()
            };
            let (a1, b1, a2, b2) = (mk(rng), mk(rng), mk(rng), mk(rng));
            let want1 = popcount_and_scalar(&a1, &b1);
            let want2 = popcount_and2_scalar(&a1, &b1, &a2, &b2);
            for level in available_levels() {
                assert_eq!(popcount_and(&a1, &b1, level), want1, "{}", level.name());
                assert_eq!(
                    popcount_and2(&a1, &b1, &a2, &b2, level),
                    want2,
                    "{}",
                    level.name()
                );
            }
        });
    }

    #[test]
    fn mag_bits_follows_actual_magnitudes() {
        let t = BitslicePlan::build(&[1, 0, -1, 1], 2, 2);
        assert_eq!(t.mag_bits(), 1);
        let w = BitslicePlan::build(&[1, 0, -3, 2], 2, 2);
        assert_eq!(w.mag_bits(), 2);
        assert_eq!(BitslicePlan::build(&[2, -2], 2, 1).mag_bits(), 2);
    }

    #[test]
    fn env_override_parsing_and_detection() {
        assert_eq!(parse_level("scalar"), Some(SimdLevel::Scalar));
        assert_eq!(parse_level(" SCALAR "), Some(SimdLevel::Scalar));
        assert_eq!(parse_level("sse9"), None);
        assert_eq!(parse_level(""), None);
        #[cfg(target_arch = "x86_64")]
        assert_eq!(parse_level("avx2"), Some(SimdLevel::Avx2));
        #[cfg(target_arch = "aarch64")]
        assert_eq!(parse_level("neon"), Some(SimdLevel::Neon));
        // whatever the process-level decision was, it must be runnable
        // here (honors SYMOG_SIMD=scalar under the CI matrix' forced leg)
        let l = simd_level();
        assert!(available_levels().contains(&l), "dispatched to unavailable {:?}", l);
        assert!(supported(l));
        assert!(available_levels().starts_with(&[SimdLevel::Scalar]));
    }

    #[test]
    fn eligibility_and_cost_model() {
        assert!(eligible(&[0, 1, -1]));
        assert!(eligible(&[3, -3, 2]));
        assert!(!eligible(&[4, 0]));
        assert!(eligible(&[]));
        assert_eq!(max_magnitude(&[-3, 1]), 3);
        // the analytic race reproduces the old ternary threshold at
        // large depth: cost(mb=1) ~ depth*cols/2 scalar adds
        assert_eq!(estimated_row_cost(6400, 100, 1), 100 * 2 * 8 * 100 * 2);
        // and the two-plane cost is exactly double
        assert_eq!(
            estimated_row_cost(640, 64, 2),
            2 * estimated_row_cost(640, 64, 1)
        );
    }
}
