//! Shared blocked-GEMM core — one register-blocked kernel, two scalar types.
//!
//! The integer inference engine and the f32 native trainer run the same
//! matrix shapes (im2col patches x HWIO weight panels), so the blocked
//! kernel lives here once, generic over [`GemmScalar`]:
//!
//! * **packed B panels** ([`PackedB`]): the `[depth, cols]` operand is
//!   repacked once into `NR`-column panels (`[panel][k][NR]`, zero-padded
//!   at the ragged edge) so the micro-kernel streams one contiguous,
//!   cache-resident panel instead of striding whole `B` rows. Inference
//!   packs at `ExecPlan` build time (weights are immutable); training
//!   packs per layer call (O(|B|) against the O(rows x |B|) GEMM it
//!   feeds, and weights change every step).
//! * **register blocking**: `MR = 4` A-rows x `NR = 16` panel columns of
//!   accumulators per micro-kernel step — each loaded panel row is reused
//!   `MR`-fold from registers, each A value `NR`-fold.
//! * **depth blocking**: `KC`-deep slabs keep the active panel slice
//!   small; per output element the depth summation order is ascending
//!   within a slab and slabs ascend, so results are reproducible run to
//!   run for f32 and bit-exact (order-free) for i32.
//! * zero A values are skipped (ReLU sparsity on both the integer
//!   activations and the f32 training activations).
//!
//! `im2col`/`col2im`/`conv_geometry` sit next to the kernel because both
//! hot paths lower convolution through them: forward as patches x weights,
//! the training backward as dy x Wᵀ followed by a `col2im` scatter (dx)
//! and patchesᵀ x dy (dw).

/// Bit-sliced AND/popcount GEMM for |mantissa| <= 3 codes, with the
/// runtime-dispatched AVX2/NEON/scalar ladder (`SYMOG_SIMD`).
pub mod bitslice;

/// A-rows processed together by the micro-kernel.
pub const MR: usize = 4;

/// Panel width: columns of `C` accumulated together in registers.
pub const NR: usize = 16;

/// Depth-block size: the active panel slab is `KC * NR` scalars.
pub const KC: usize = 256;

/// Scalar a GEMM can run on. Implementations must keep `madd`/`add` the
/// plain `acc + a * b` / `a + b` of the type — the kernels rely on
/// nothing else, so i32 stays exact and f32 matches the naive loops up
/// to summation order.
pub trait GemmScalar: Copy + Send + Sync + PartialEq + 'static {
    const ZERO: Self;
    /// `acc + a * b`.
    fn madd(a: Self, b: Self, acc: Self) -> Self;
    fn add(a: Self, b: Self) -> Self;
    #[inline]
    fn is_zero(self) -> bool {
        self == Self::ZERO
    }
}

impl GemmScalar for i32 {
    const ZERO: i32 = 0;
    #[inline]
    fn madd(a: i32, b: i32, acc: i32) -> i32 {
        acc + a * b
    }
    #[inline]
    fn add(a: i32, b: i32) -> i32 {
        a + b
    }
}

impl GemmScalar for f32 {
    const ZERO: f32 = 0.0;
    #[inline]
    fn madd(a: f32, b: f32, acc: f32) -> f32 {
        acc + a * b
    }
    #[inline]
    fn add(a: f32, b: f32) -> f32 {
        a + b
    }
}

/// A `[depth, cols]` GEMM operand repacked into `NR`-column panels:
/// `data[panel][k][0..NR]`, the ragged last panel zero-padded. The
/// micro-kernel reads `NR` consecutive scalars per depth step regardless
/// of the original `cols` stride.
#[derive(Clone, Debug)]
pub struct PackedB<T> {
    data: Vec<T>,
    pub depth: usize,
    pub cols: usize,
}

impl<T: GemmScalar> PackedB<T> {
    fn panels(&self) -> std::slice::Chunks<'_, T> {
        self.data.chunks(self.depth * NR)
    }

    /// (Re)fill from a row-major `[depth, cols]` matrix, reusing the
    /// allocation — hot loops that repack a *changing* operand (the
    /// training dw GEMM's per-image dy panels) pay no per-call Vec.
    pub fn repack(&mut self, b: &[T], depth: usize, cols: usize) {
        debug_assert_eq!(b.len(), depth * cols);
        self.depth = depth;
        self.cols = cols;
        self.data.clear();
        if depth == 0 || cols == 0 {
            return;
        }
        let n_panels = cols.div_ceil(NR);
        // clear-then-resize zeroes everything, so ragged-edge panel
        // padding is ZERO no matter what the buffer held before
        self.data.resize(n_panels * depth * NR, T::ZERO);
        for (pi, panel) in self.data.chunks_mut(depth * NR).enumerate() {
            let j0 = pi * NR;
            let jn = NR.min(cols - j0);
            for k in 0..depth {
                panel[k * NR..k * NR + jn].copy_from_slice(&b[k * cols + j0..k * cols + j0 + jn]);
            }
        }
    }
}

/// Pack a row-major `[depth, cols]` matrix into panels.
pub fn pack_b<T: GemmScalar>(b: &[T], depth: usize, cols: usize) -> PackedB<T> {
    let mut p = PackedB { data: Vec::new(), depth, cols };
    p.repack(b, depth, cols);
    p
}

/// Pack the *transpose* of a row-major `[rows, cols]` matrix: the result
/// is `bᵀ` as a `[cols, rows]` operand (`depth = cols`, `cols = rows`).
/// The strided reads happen once here so the GEMM inner loop never does.
pub fn pack_b_transposed<T: GemmScalar>(b: &[T], rows: usize, cols: usize) -> PackedB<T> {
    debug_assert_eq!(b.len(), rows * cols);
    let (depth, pcols) = (cols, rows);
    if depth == 0 || pcols == 0 {
        return PackedB { data: Vec::new(), depth, cols: pcols };
    }
    let n_panels = pcols.div_ceil(NR);
    let mut data = vec![T::ZERO; n_panels * depth * NR];
    for (pi, panel) in data.chunks_mut(depth * NR).enumerate() {
        let j0 = pi * NR;
        let jn = NR.min(pcols - j0);
        for k in 0..depth {
            let prow = &mut panel[k * NR..k * NR + jn];
            for (j, pv) in prow.iter_mut().enumerate() {
                *pv = b[(j0 + j) * cols + k];
            }
        }
    }
    PackedB { data, depth, cols: pcols }
}

/// `C[rows, b.cols] += A[rows, b.depth] * B` with `B` pre-packed. Row-major
/// `A`/`C`; accumulates into `C` so callers can pre-fill bias rows or chain
/// partial products.
pub fn gemm_packed<T: GemmScalar>(a: &[T], b: &PackedB<T>, c: &mut [T], rows: usize) {
    debug_assert_eq!(a.len(), rows * b.depth);
    debug_assert_eq!(c.len(), rows * b.cols);
    let depth = b.depth;
    if depth == 0 || b.cols == 0 || rows == 0 {
        return;
    }
    for k0 in (0..depth).step_by(KC) {
        let k1 = (k0 + KC).min(depth);
        let mut i0 = 0;
        while i0 < rows {
            let rm = MR.min(rows - i0);
            for (pi, panel) in b.panels().enumerate() {
                let j0 = pi * NR;
                let jn = NR.min(b.cols - j0);
                micro_kernel(a, i0, rm, depth, panel, k0, k1, c, j0, jn, b.cols);
            }
            i0 += rm;
        }
    }
}

/// `rm x NR` accumulator tile over one depth slab of one panel. `acc` is
/// always full `MR x NR` (the panel's zero padding makes the extra lanes
/// no-ops); the write-back trims to the live `rm` rows and `jn` columns.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel<T: GemmScalar>(
    a: &[T],
    i0: usize,
    rm: usize,
    depth: usize,
    panel: &[T],
    k0: usize,
    k1: usize,
    c: &mut [T],
    j0: usize,
    jn: usize,
    cols: usize,
) {
    let mut acc = [[T::ZERO; NR]; MR];
    let mut arows: [&[T]; MR] = [&[]; MR];
    for (i, ar) in arows.iter_mut().enumerate().take(rm) {
        *ar = &a[(i0 + i) * depth..(i0 + i + 1) * depth];
    }
    for k in k0..k1 {
        let brow = &panel[k * NR..(k + 1) * NR];
        for (ar, row) in arows.iter().zip(acc.iter_mut()).take(rm) {
            let av = ar[k];
            if av.is_zero() {
                continue;
            }
            for (r, &bv) in row.iter_mut().zip(brow) {
                *r = T::madd(av, bv, *r);
            }
        }
    }
    for (i, row) in acc.iter().enumerate().take(rm) {
        let crow = &mut c[(i0 + i) * cols + j0..(i0 + i) * cols + j0 + jn];
        for (cv, &av) in crow.iter_mut().zip(row) {
            *cv = T::add(*cv, av);
        }
    }
}

/// `dst[cols, rows] = src[rows, cols]ᵀ` — scratch transpose for the
/// training dw GEMM (patchesᵀ x dy).
pub fn transpose<T: GemmScalar>(src: &[T], rows: usize, cols: usize, dst: &mut [T]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    for i in 0..rows {
        for (j, &v) in src[i * cols..(i + 1) * cols].iter().enumerate() {
            dst[j * rows + i] = v;
        }
    }
}

/// SAME/VALID output geometry shared by every conv path (integer naive,
/// integer GEMM, planned executor, f32 training): `(oh, ow, pad_top,
/// pad_left)`. TF convention — excess SAME padding goes after.
pub fn conv_geometry(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad_same: bool,
) -> (usize, usize, usize, usize) {
    if pad_same {
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let ph = ((oh - 1) * stride + kh).saturating_sub(h);
        let pw = ((ow - 1) * stride + kw).saturating_sub(w);
        (oh, ow, ph / 2, pw / 2)
    } else {
        ((h - kh) / stride + 1, (w - kw) / stride + 1, 0, 0)
    }
}

/// Gather image `img`'s receptive fields from NHWC `x` into the patch
/// matrix `patches[oh*ow, kh*kw*cin]`. Out-of-range taps are zeroed up
/// front — but only when some tap actually falls outside the image: when
/// every receptive field lies fully inside (VALID convs and
/// stride-aligned SAME convs), every patch element is overwritten and the
/// full-buffer memset is skipped. The coverage test must also check the
/// bottom/right edge: SAME padding is asymmetric (TF convention), so
/// `pad == 0` alone does not prove taps cannot run past `h`/`w`.
#[allow(clippy::too_many_arguments)]
pub fn im2col<T: GemmScalar>(
    x: &[T],
    (h, w, cin): (usize, usize, usize),
    img: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    oh: usize,
    ow: usize,
    patches: &mut [T],
) {
    let k_dim = kh * kw * cin;
    debug_assert!(patches.len() >= oh * ow * k_dim);
    let fully_covered = pad_h == 0
        && pad_w == 0
        && oh.saturating_sub(1) * stride + kh <= h
        && ow.saturating_sub(1) * stride + kw <= w;
    if !fully_covered {
        patches.fill(T::ZERO);
    }
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * k_dim;
            for ky in 0..kh {
                let iy = (oy * stride + ky) as isize - pad_h as isize;
                if !(0..h as isize).contains(&iy) {
                    continue;
                }
                for kx in 0..kw {
                    let ix = (ox * stride + kx) as isize - pad_w as isize;
                    if !(0..w as isize).contains(&ix) {
                        continue;
                    }
                    let src = ((img * h + iy as usize) * w + ix as usize) * cin;
                    let dst = row + (ky * kw + kx) * cin;
                    patches[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
                }
            }
        }
    }
}

/// Adjoint of [`im2col`] for a single image: scatter-add the patch-matrix
/// gradient `dpatches[oh*ow, kh*kw*cin]` back into the image gradient
/// `dx[h*w*cin]` (one image's slice). Taps that fell in the padding are
/// simply not scattered. Scatter order is the fixed (oy, ox, ky, kx)
/// walk, so results never depend on thread count.
#[allow(clippy::too_many_arguments)]
pub fn col2im<T: GemmScalar>(
    dpatches: &[T],
    (h, w, cin): (usize, usize, usize),
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    oh: usize,
    ow: usize,
    dx: &mut [T],
) {
    let k_dim = kh * kw * cin;
    debug_assert!(dpatches.len() >= oh * ow * k_dim);
    debug_assert_eq!(dx.len(), h * w * cin);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * k_dim;
            for ky in 0..kh {
                let iy = (oy * stride + ky) as isize - pad_h as isize;
                if !(0..h as isize).contains(&iy) {
                    continue;
                }
                for kx in 0..kw {
                    let ix = (ox * stride + kx) as isize - pad_w as isize;
                    if !(0..w as isize).contains(&ix) {
                        continue;
                    }
                    let dst = (iy as usize * w + ix as usize) * cin;
                    let src = row + (ky * kw + kx) * cin;
                    for (d, &g) in dx[dst..dst + cin].iter_mut().zip(&dpatches[src..src + cin]) {
                        *d = T::add(*d, g);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Schoolbook `C += A * B` reference, generic like the kernel.
    fn gemm_ref<T: GemmScalar>(a: &[T], b: &[T], rows: usize, depth: usize, cols: usize) -> Vec<T> {
        let mut c = vec![T::ZERO; rows * cols];
        for i in 0..rows {
            for kk in 0..depth {
                for j in 0..cols {
                    c[i * cols + j] = T::madd(a[i * depth + kk], b[kk * cols + j], c[i * cols + j]);
                }
            }
        }
        c
    }

    #[test]
    fn prop_packed_gemm_i32_matches_schoolbook_exactly() {
        crate::testing::forall(24, |rng: &mut Rng| {
            let rows = 1 + rng.below(13);
            let depth = 1 + rng.below(300);
            let cols = 1 + rng.below(40);
            let a: Vec<i32> = (0..rows * depth).map(|_| rng.below(21) as i32 - 10).collect();
            let b: Vec<i32> = (0..depth * cols).map(|_| rng.below(7) as i32 - 3).collect();
            let bp = pack_b(&b, depth, cols);
            let mut c = vec![0i32; rows * cols];
            gemm_packed(&a, &bp, &mut c, rows);
            assert_eq!(c, gemm_ref(&a, &b, rows, depth, cols), "{rows}x{depth}x{cols}");
        });
    }

    #[test]
    fn prop_packed_gemm_f32_matches_schoolbook() {
        crate::testing::forall(24, |rng: &mut Rng| {
            let rows = 1 + rng.below(10);
            let depth = 1 + rng.below(280);
            let cols = 1 + rng.below(37);
            // mix in exact zeros so the sparsity skip is exercised
            let a: Vec<f32> = (0..rows * depth)
                .map(|_| if rng.bool(0.3) { 0.0 } else { rng.normal() })
                .collect();
            let b: Vec<f32> = (0..depth * cols).map(|_| rng.normal() * 0.5).collect();
            let bp = pack_b(&b, depth, cols);
            let mut c = vec![0f32; rows * cols];
            gemm_packed(&a, &bp, &mut c, rows);
            let want = gemm_ref(&a, &b, rows, depth, cols);
            crate::testing::assert_allclose_rel(&c, &want, 1e-5, 1e-5);
        });
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let a = [1i32, 2, 3, 4];
        let b = [1i32, 0, 0, 1];
        let bp = pack_b(&b, 2, 2);
        let mut c = vec![10i32; 4];
        gemm_packed(&a, &bp, &mut c, 2);
        assert_eq!(c, vec![11, 12, 13, 14]);
    }

    #[test]
    fn prop_transposed_pack_equals_packing_the_transpose() {
        crate::testing::forall(12, |rng: &mut Rng| {
            let rows = 1 + rng.below(30);
            let cols = 1 + rng.below(30);
            let b: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
            let mut bt = vec![0f32; rows * cols];
            transpose(&b, rows, cols, &mut bt);
            let via_transpose = pack_b(&bt, cols, rows);
            let direct = pack_b_transposed(&b, rows, cols);
            assert_eq!(direct.depth, via_transpose.depth);
            assert_eq!(direct.cols, via_transpose.cols);
            assert_eq!(direct.data, via_transpose.data);
        });
    }

    #[test]
    fn transpose_roundtrip() {
        let src: Vec<i32> = (0..12).collect();
        let mut t = vec![0i32; 12];
        transpose(&src, 3, 4, &mut t);
        assert_eq!(t[0], 0); // [0,0]
        assert_eq!(t[1], 4); // [0,1] = src[1,0]
        let mut back = vec![0i32; 12];
        transpose(&t, 4, 3, &mut back);
        assert_eq!(back, src);
    }

    #[test]
    fn im2col_memset_skip_never_leaks_stale_data() {
        // the memset skip is only sound if every element is written: run
        // im2col into a poisoned buffer and compare against a fresh one.
        // Cases cover VALID, zero-pad SAME, and the treacherous
        // asymmetric-SAME shapes (pad_top == 0 but bottom/right taps run
        // past the image — e.g. k=3 s=2 on even h, the native convnet's
        // downsampling conv) where the fill MUST still happen.
        let mut rng = Rng::new(41);
        for (h, w, k, stride, pad_same) in [
            (7usize, 5usize, 3usize, 1usize, false), // VALID
            (8, 6, 2, 2, true),                      // SAME, zero pad, full coverage
            (4, 4, 1, 1, true),                      // SAME 1x1
            (8, 8, 3, 2, true),                      // SAME, pad_top 0, bottom tap out of range
            (6, 6, 3, 1, true),                      // SAME, symmetric pad 1
        ] {
            let cin = 3;
            let x: Vec<i32> = (0..2 * h * w * cin).map(|_| rng.below(100) as i32 - 50).collect();
            let (oh, ow, ph, pw) = conv_geometry(h, w, k, k, stride, pad_same);
            let len = oh * ow * k * k * cin;
            let mut fresh = vec![0i32; len];
            im2col(&x, (h, w, cin), 1, k, k, stride, ph, pw, oh, ow, &mut fresh);
            let mut dirty = vec![i32::MIN; len];
            im2col(&x, (h, w, cin), 1, k, k, stride, ph, pw, oh, ow, &mut dirty);
            assert_eq!(fresh, dirty, "stale data leaked at {h}x{w} k{k} s{stride}");
        }
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), p> == <x, col2im(p)> for random x, p — the defining
        // property of the scatter, covering padded and unpadded geometry
        let mut rng = Rng::new(7);
        for pad_same in [false, true] {
            let (h, w, cin, k, stride) = (6usize, 5usize, 2usize, 3usize, 2usize);
            let (oh, ow, ph, pw) = conv_geometry(h, w, k, k, stride, pad_same);
            let k_dim = k * k * cin;
            let x: Vec<f32> = (0..h * w * cin).map(|_| rng.normal()).collect();
            let p: Vec<f32> = (0..oh * ow * k_dim).map(|_| rng.normal()).collect();
            let mut gathered = vec![0f32; oh * ow * k_dim];
            im2col(&x, (h, w, cin), 0, k, k, stride, ph, pw, oh, ow, &mut gathered);
            let lhs: f64 =
                gathered.iter().zip(&p).map(|(&g, &pv)| g as f64 * pv as f64).sum();
            let mut scattered = vec![0f32; h * w * cin];
            col2im(&p, (h, w, cin), k, k, stride, ph, pw, oh, ow, &mut scattered);
            let rhs: f64 = x.iter().zip(&scattered).map(|(&xv, &s)| xv as f64 * s as f64).sum();
            assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
        }
    }

    #[test]
    fn repack_reuses_buffer_and_matches_fresh_pack() {
        let mut rng = Rng::new(5);
        let big: Vec<f32> = (0..6 * 40).map(|_| rng.normal()).collect();
        let mut p = pack_b(&big, 6, 40);
        // shrink onto a smaller ragged shape: stale data must not leak
        // into the new panels' zero padding
        let small: Vec<f32> = (0..3 * 5).map(|_| rng.normal()).collect();
        p.repack(&small, 3, 5);
        let fresh = pack_b(&small, 3, 5);
        assert_eq!(p.data, fresh.data);
        assert_eq!((p.depth, p.cols), (3, 5));
    }

    #[test]
    fn ragged_panel_edges_are_zero_padded() {
        let b: Vec<i32> = (1..=2 * 5).collect(); // depth 2, cols 5 (< NR)
        let bp = pack_b(&b, 2, 5);
        assert_eq!(bp.data.len(), 2 * NR);
        assert_eq!(&bp.data[..5], &[1, 2, 3, 4, 5]);
        assert!(bp.data[5..NR].iter().all(|&v| v == 0));
        assert_eq!(&bp.data[NR..NR + 5], &[6, 7, 8, 9, 10]);
    }
}
