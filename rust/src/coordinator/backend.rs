//! The training-backend seam: one trait, two substrates.
//!
//! `Trainer` drives Algorithm 1 (epoch loop, schedules, probes,
//! checkpointing) against a [`TrainBackend`], which owns the mutable model
//! state and knows how to execute one fused train / eval step on a host
//! batch:
//!
//! * [`XlaBackend`] — the AOT-artifact path: params live as `xla::Literal`s
//!   and steps run the compiled train/eval/evalq executables on PJRT.
//! * `train::NativeBackend` — the pure-Rust path: params live as host
//!   vectors and steps run the `train::ops` forward/backward + the fused
//!   SYMOG SGD update. No artifact, no Python, no PJRT.
//!
//! Both expose host copies of the quantized weights so the Fig-3/4 probes
//! (`histogram`, `tracker`) are backend-agnostic.

use anyhow::{Context, Result};

use crate::fixedpoint;
use crate::runtime::{literal_f32, literal_i32, literal_scalar_f32, run, XlaArtifact};

use super::checkpoint::{Checkpoint, Kind, Tensor};

/// Loss/accuracy numbers of one executed batch.
#[derive(Clone, Copy, Debug)]
pub struct StepOut {
    /// mean loss over the batch
    pub loss: f32,
    /// argmax-hit count (f32 so both substrates share one interface)
    pub correct: f32,
}

/// What the coordinator needs from a training substrate.
pub trait TrainBackend {
    /// Display tag for logs (artifact tag / native model tag).
    fn tag(&self) -> String;

    /// Static batch size of one step.
    fn batch(&self) -> usize;

    fn n_bits(&self) -> u32;

    /// Number of quantized weight tensors.
    fn n_quant(&self) -> usize;

    /// Per-layer step sizes, qidx order.
    fn deltas(&self) -> &[f32];

    /// One fused SGD step (Alg. 1 lines 10-18) on a host batch.
    fn train_step(
        &mut self,
        images: &[f32],
        labels: &[i32],
        lr: f32,
        lambda: f32,
    ) -> Result<StepOut>;

    /// Loss/correct on one batch, float or hard-quantized weights.
    fn eval_batch(&self, images: &[f32], labels: &[i32], quantized: bool) -> Result<StepOut>;

    /// Host copies of all quantized weight tensors with their deltas, in
    /// qidx order (probe input for tracker / histograms).
    fn quant_layers_host(&self) -> Result<Vec<(Vec<f32>, f32)>>;

    /// Snapshot everything into a checkpoint (float weights + momenta +
    /// state + deltas; quantization is applied by the consumer).
    fn to_checkpoint(&self, epoch: u32) -> Result<Checkpoint>;
}

/// The AOT-artifact backend: host mirrors of device literals + the three
/// compiled executables.
pub struct XlaBackend<'a> {
    pub artifact: &'a XlaArtifact,
    params: Vec<xla::Literal>,
    momenta: Vec<xla::Literal>,
    state: Vec<xla::Literal>,
    deltas: Vec<f32>,
}

impl<'a> XlaBackend<'a> {
    /// Initialize from a checkpoint (aot.py's init.ckpt or a previously
    /// saved training checkpoint). `resolve_deltas` recomputes the optimal
    /// step sizes from the loaded weights (Alg. 1 lines 2-5, via the seeded
    /// `optimal_delta_refined` solver) — pass true when starting SYMOG from
    /// a pretrained float model.
    pub fn from_checkpoint(
        artifact: &'a XlaArtifact,
        ckpt: &Checkpoint,
        resolve_deltas: bool,
    ) -> Result<XlaBackend<'a>> {
        let man = &artifact.manifest;
        let mut params = Vec::with_capacity(man.params.len());
        let mut momenta = Vec::with_capacity(man.params.len());
        let mut weights_for_delta: Vec<&Tensor> = Vec::new();
        for p in &man.params {
            let t = ckpt
                .find(&p.name)
                .with_context(|| format!("checkpoint missing tensor {}", p.name))?;
            anyhow::ensure!(
                t.dims == p.shape,
                "{}: ckpt shape {:?} != manifest {:?}",
                p.name, t.dims, p.shape
            );
            params.push(literal_f32(&t.data, &p.shape)?);
            // momenta: stored under "<name>#m" if present, else zeros
            let mname = format!("{}#m", p.name);
            match ckpt.find(&mname) {
                Some(m) => momenta.push(literal_f32(&m.data, &p.shape)?),
                None => momenta.push(literal_f32(&vec![0.0; p.numel()], &p.shape)?),
            }
            if p.is_quantized() {
                weights_for_delta.push(t);
            }
        }
        let mut state = Vec::with_capacity(man.state.len());
        for s in &man.state {
            let t = ckpt
                .find(&s.name)
                .with_context(|| format!("checkpoint missing state {}", s.name))?;
            state.push(literal_f32(&t.data, &s.shape)?);
        }
        let deltas = if resolve_deltas {
            weights_for_delta
                .iter()
                .map(|t| fixedpoint::optimal_delta_refined(&t.data, man.n_bits).0)
                .collect()
        } else {
            let d = ckpt
                .find("__deltas__")
                .context("checkpoint missing __deltas__ (pass resolve_deltas=true?)")?;
            d.data.clone()
        };
        let mut deltas = deltas;
        deltas.resize(man.deltas_len(), 1.0);
        Ok(XlaBackend { artifact, params, momenta, state, deltas })
    }

    /// Pull a parameter tensor back to the host.
    pub fn param_host(&self, i: usize) -> Result<Vec<f32>> {
        crate::runtime::to_f32_vec(&self.params[i])
    }

    fn img_dims(&self) -> [usize; 4] {
        let man = &self.artifact.manifest;
        [man.batch, man.input_shape[0], man.input_shape[1], man.input_shape[2]]
    }
}

impl TrainBackend for XlaBackend<'_> {
    fn tag(&self) -> String {
        self.artifact.manifest.tag.clone()
    }

    fn batch(&self) -> usize {
        self.artifact.manifest.batch
    }

    fn n_bits(&self) -> u32 {
        self.artifact.manifest.n_bits
    }

    fn n_quant(&self) -> usize {
        self.artifact.manifest.n_quant
    }

    fn deltas(&self) -> &[f32] {
        &self.deltas
    }

    fn train_step(
        &mut self,
        images: &[f32],
        labels: &[i32],
        lr: f32,
        lambda: f32,
    ) -> Result<StepOut> {
        let man = &self.artifact.manifest;
        let img_lit = literal_f32(images, &self.img_dims())?;
        let lab_lit = literal_i32(labels, &[man.batch])?;
        let deltas_lit = literal_f32(&self.deltas, &[man.deltas_len()])?;
        let lr_lit = literal_scalar_f32(lr);
        let lam_lit = literal_scalar_f32(lambda);
        // flat calling convention: images, labels, params, momenta, state,
        // deltas, lr, lam
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(man.train_arity());
        args.push(&img_lit);
        args.push(&lab_lit);
        args.extend(self.params.iter());
        args.extend(self.momenta.iter());
        args.extend(self.state.iter());
        args.push(&deltas_lit);
        args.push(&lr_lit);
        args.push(&lam_lit);
        let mut out = run(&self.artifact.train, &args)?;
        anyhow::ensure!(
            out.len() == man.train_outputs(),
            "train step returned {} outputs, expected {}",
            out.len(),
            man.train_outputs()
        );
        // outputs: loss, correct, params', momenta', state'
        let p_n = man.params.len();
        let s_n = man.state.len();
        let state_new: Vec<xla::Literal> = out.split_off(2 + 2 * p_n);
        let momenta_new: Vec<xla::Literal> = out.split_off(2 + p_n);
        let params_new: Vec<xla::Literal> = out.split_off(2);
        let correct = out.pop().unwrap().to_vec::<f32>()?[0];
        let loss = out.pop().unwrap().to_vec::<f32>()?[0];
        self.params = params_new;
        self.momenta = momenta_new;
        self.state = state_new;
        debug_assert_eq!(self.state.len(), s_n);
        Ok(StepOut { loss, correct })
    }

    fn eval_batch(&self, images: &[f32], labels: &[i32], quantized: bool) -> Result<StepOut> {
        let man = &self.artifact.manifest;
        let exe = if quantized { &self.artifact.evalq } else { &self.artifact.eval };
        let img_lit = literal_f32(images, &self.img_dims())?;
        let lab_lit = literal_i32(labels, &[man.batch])?;
        let deltas_lit = if quantized {
            Some(literal_f32(&self.deltas, &[man.deltas_len()])?)
        } else {
            None
        };
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.push(&img_lit);
        args.push(&lab_lit);
        args.extend(self.params.iter());
        args.extend(self.state.iter());
        args.extend(deltas_lit.iter());
        let out = run(exe, &args)?;
        Ok(StepOut {
            loss: out[0].to_vec::<f32>()?[0],
            correct: out[1].to_vec::<f32>()?[0],
        })
    }

    fn quant_layers_host(&self) -> Result<Vec<(Vec<f32>, f32)>> {
        let man = &self.artifact.manifest;
        let mut out = Vec::with_capacity(man.n_quant);
        for (i, p) in man.params.iter().enumerate() {
            if let Some(q) = p.qidx {
                out.push((self.param_host(i)?, self.deltas[q]));
            }
        }
        Ok(out)
    }

    fn to_checkpoint(&self, epoch: u32) -> Result<Checkpoint> {
        let man = &self.artifact.manifest;
        let mut ck = Checkpoint::default();
        ck.set_meta("model", crate::util::json::Json::Str(man.model.clone()));
        ck.set_meta("method", crate::util::json::Json::Str(man.method.clone()));
        ck.set_meta("epoch", crate::util::json::Json::Num(epoch as f64));
        for (i, p) in man.params.iter().enumerate() {
            ck.tensors.push(Tensor {
                name: p.name.clone(),
                kind: Kind::from_name(&p.kind)?,
                dims: p.shape.clone(),
                data: self.param_host(i)?,
            });
            ck.tensors.push(Tensor {
                name: format!("{}#m", p.name),
                kind: Kind::Momentum,
                dims: p.shape.clone(),
                data: crate::runtime::to_f32_vec(&self.momenta[i])?,
            });
        }
        for (i, s) in man.state.iter().enumerate() {
            ck.tensors.push(Tensor {
                name: s.name.clone(),
                kind: Kind::State,
                dims: s.shape.clone(),
                data: crate::runtime::to_f32_vec(&self.state[i])?,
            });
        }
        ck.tensors.push(Tensor {
            name: "__deltas__".into(),
            kind: Kind::Deltas,
            dims: vec![self.deltas.len()],
            data: self.deltas.clone(),
        });
        Ok(ck)
    }
}
