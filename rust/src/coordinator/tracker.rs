//! Mode-switch tracker — the measurement behind Figure 4.
//!
//! After each epoch the tracker reassigns every quantized weight to its
//! nearest fixed-point mode (`clip(round(w/delta))`) and reports, per layer,
//! the fraction of weights whose assignment changed since the previous
//! epoch ("the percentage of weights that change their fixed-point prior").

use crate::fixedpoint::mode_indices;

/// Per-layer mode assignments + switch statistics.
pub struct ModeTracker {
    n_bits: u32,
    prev: Vec<Vec<i8>>, // one assignment vector per quantized layer
    /// switch_rates[epoch][layer] = fraction changed at that epoch
    pub switch_rates: Vec<Vec<f32>>,
}

impl ModeTracker {
    pub fn new(n_layers: usize, n_bits: u32) -> Self {
        ModeTracker { n_bits, prev: vec![Vec::new(); n_layers], switch_rates: Vec::new() }
    }

    pub fn n_layers(&self) -> usize {
        self.prev.len()
    }

    /// Record one epoch: `layers` yields (weights, delta) per quantized
    /// layer, in stable order. Returns the per-layer switch fractions
    /// (first call establishes the baseline and returns zeros).
    pub fn record<'a>(
        &mut self,
        layers: impl Iterator<Item = (&'a [f32], f32)>,
    ) -> Vec<f32> {
        let mut rates = Vec::with_capacity(self.prev.len());
        for (li, (w, delta)) in layers.enumerate() {
            let modes = mode_indices(w, delta, self.n_bits);
            let rate = if self.prev[li].is_empty() {
                0.0
            } else {
                debug_assert_eq!(self.prev[li].len(), modes.len());
                let changed = self.prev[li]
                    .iter()
                    .zip(&modes)
                    .filter(|(a, b)| a != b)
                    .count();
                changed as f32 / modes.len() as f32
            };
            self.prev[li] = modes;
            rates.push(rate);
        }
        self.switch_rates.push(rates.clone());
        rates
    }

    /// Mean switch rate across layers for the most recent epoch.
    pub fn last_mean(&self) -> f32 {
        self.switch_rates
            .last()
            .map(|r| crate::util::mean(r))
            .unwrap_or(0.0)
    }

    /// CSV dump: epoch, layer0, layer1, ...
    pub fn to_csv(&self) -> String {
        let mut out = String::from("epoch");
        for i in 0..self.prev.len() {
            out.push_str(&format!(",layer{i}"));
        }
        out.push('\n');
        for (e, rates) in self.switch_rates.iter().enumerate() {
            out.push_str(&format!("{e}"));
            for r in rates {
                out.push_str(&format!(",{r:.6}"));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_epoch_is_baseline() {
        let mut t = ModeTracker::new(1, 2);
        let w = vec![0.6f32, -0.6, 0.1];
        let rates = t.record([(w.as_slice(), 1.0f32)].into_iter());
        assert_eq!(rates, vec![0.0]);
    }

    #[test]
    fn detects_switches() {
        let mut t = ModeTracker::new(1, 2);
        let w0 = vec![0.6f32, -0.6, 0.1, 0.1]; // modes [1, -1, 0, 0]
        t.record([(w0.as_slice(), 1.0f32)].into_iter());
        let w1 = vec![0.6f32, 0.6, 0.1, 0.6]; // modes [1, 1, 0, 1]
        let rates = t.record([(w1.as_slice(), 1.0f32)].into_iter());
        assert_eq!(rates, vec![0.5]); // 2 of 4 changed
    }

    #[test]
    fn stable_weights_zero_rate() {
        let mut t = ModeTracker::new(2, 2);
        let a = vec![0.9f32; 10];
        let b = vec![-0.9f32; 4];
        for _ in 0..3 {
            t.record([(a.as_slice(), 1.0f32), (b.as_slice(), 1.0f32)].into_iter());
        }
        assert_eq!(t.switch_rates[2], vec![0.0, 0.0]);
        assert_eq!(t.last_mean(), 0.0);
    }

    #[test]
    fn csv_shape() {
        let mut t = ModeTracker::new(2, 2);
        let a = vec![0.1f32];
        t.record([(a.as_slice(), 1.0f32), (a.as_slice(), 1.0f32)].into_iter());
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "epoch,layer0,layer1");
        assert_eq!(lines.len(), 2);
    }

    #[test]
    fn delta_changes_are_switches() {
        // same weights, different delta => different modes => switches
        let mut t = ModeTracker::new(1, 2);
        let w = vec![0.3f32; 8];
        t.record([(w.as_slice(), 1.0f32)].into_iter()); // mode 0
        let rates = t.record([(w.as_slice(), 0.25f32)].into_iter()); // mode 1
        assert_eq!(rates, vec![1.0]);
    }
}
