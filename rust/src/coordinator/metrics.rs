//! Training metrics: per-epoch records, CSV/JSONL serialization, summaries.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// One epoch's measurements.
#[derive(Clone, Debug, Default)]
pub struct EpochLog {
    pub epoch: u32,
    pub lr: f32,
    pub lambda: f32,
    pub train_loss: f32,
    pub train_acc: f32,
    pub test_loss: f32,
    pub test_acc: f32,
    /// accuracy with hard-quantized weights (the paper's reported metric)
    pub testq_loss: f32,
    pub testq_acc: f32,
    /// mean mode-switch rate across layers (Fig 4 aggregate)
    pub switch_rate: f32,
    pub seconds: f64,
}

impl EpochLog {
    pub fn quantized_error(&self) -> f32 {
        1.0 - self.testq_acc
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("epoch".into(), Json::Num(self.epoch as f64));
        m.insert("lr".into(), Json::Num(self.lr as f64));
        m.insert("lambda".into(), Json::Num(self.lambda as f64));
        m.insert("train_loss".into(), Json::Num(self.train_loss as f64));
        m.insert("train_acc".into(), Json::Num(self.train_acc as f64));
        m.insert("test_loss".into(), Json::Num(self.test_loss as f64));
        m.insert("test_acc".into(), Json::Num(self.test_acc as f64));
        m.insert("testq_loss".into(), Json::Num(self.testq_loss as f64));
        m.insert("testq_acc".into(), Json::Num(self.testq_acc as f64));
        m.insert("switch_rate".into(), Json::Num(self.switch_rate as f64));
        m.insert("seconds".into(), Json::Num(self.seconds));
        Json::Obj(m)
    }
}

/// A whole run's log.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub tag: String,
    pub epochs: Vec<EpochLog>,
}

impl RunLog {
    pub fn new(tag: &str) -> Self {
        RunLog { tag: tag.to_string(), epochs: Vec::new() }
    }

    pub fn push(&mut self, log: EpochLog) {
        self.epochs.push(log);
    }

    pub fn last(&self) -> Option<&EpochLog> {
        self.epochs.last()
    }

    /// Best (lowest) quantized test error over the run — Table 1's metric.
    pub fn best_quantized_error(&self) -> f32 {
        self.epochs
            .iter()
            .map(|e| e.quantized_error())
            .fold(f32::INFINITY, f32::min)
    }

    /// Best float test error (the FP32-baseline metric).
    pub fn best_float_error(&self) -> f32 {
        self.epochs
            .iter()
            .map(|e| 1.0 - e.test_acc)
            .fold(f32::INFINITY, f32::min)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "epoch,lr,lambda,train_loss,train_acc,test_loss,test_acc,testq_loss,testq_acc,switch_rate,seconds\n",
        );
        for e in &self.epochs {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{}\n",
                e.epoch, e.lr, e.lambda, e.train_loss, e.train_acc, e.test_loss,
                e.test_acc, e.testq_loss, e.testq_acc, e.switch_rate, e.seconds
            ));
        }
        s
    }

    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for e in &self.epochs {
            s.push_str(&e.to_json().to_string());
            s.push('\n');
        }
        s
    }

    pub fn save_csv(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log(epoch: u32, testq_acc: f32) -> EpochLog {
        EpochLog { epoch, testq_acc, test_acc: testq_acc + 0.01, ..Default::default() }
    }

    #[test]
    fn best_error_tracks_minimum() {
        let mut run = RunLog::new("t");
        run.push(log(0, 0.50));
        run.push(log(1, 0.80));
        run.push(log(2, 0.75));
        assert!((run.best_quantized_error() - 0.2).abs() < 1e-6);
        assert!((run.best_float_error() - 0.19).abs() < 1e-6);
    }

    #[test]
    fn csv_has_header_plus_rows() {
        let mut run = RunLog::new("t");
        run.push(log(0, 0.5));
        let csv = run.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("epoch,lr,lambda"));
    }

    #[test]
    fn jsonl_parses_back() {
        let mut run = RunLog::new("t");
        run.push(log(3, 0.9));
        let line = run.to_jsonl();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("epoch").unwrap().int().unwrap(), 3);
    }

    #[test]
    fn empty_run() {
        let run = RunLog::new("e");
        assert!(run.best_quantized_error().is_infinite());
        assert!(run.last().is_none());
    }
}
