//! The training coordinator: Algorithm 1 driven from Rust.
//!
//! The coordinator owns all mutable training state (weights, momenta, BN
//! statistics, per-layer step sizes) as device-ready literals and drives the
//! single fused train-step executable batch by batch. Python is never on
//! this path — the executable was lowered once at `make artifacts` time.
//!
//! Responsibilities mapped to the paper:
//! * step-size solve at init (Alg. 1 l.2-5) — `fixedpoint::optimal_delta`
//! * lr ramp + exponential lambda (l.7-8)   — `schedule::*`
//! * batched SGD epoch loop (l.9-19)        — `run_epoch`
//! * final hard quantization (l.21-24)      — `quantize_weights` / evalq
//! * Fig-3/4 probes                          — `histogram::*`, `tracker::*`

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::{AugmentConfig, BatchIter, Dataset};
use crate::fixedpoint;
use crate::runtime::{Artifact, literal_f32, literal_i32, literal_scalar_f32, run};

use super::checkpoint::{Checkpoint, Kind, Tensor};
use super::histogram::{Histogram, HistogramSeries};
use super::metrics::{EpochLog, RunLog};
use super::schedule::{LambdaSchedule, LrSchedule};
use super::tracker::ModeTracker;

/// Training options beyond what the artifact manifest pins down.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub epochs: u32,
    pub lr: LrSchedule,
    pub lambda: LambdaSchedule,
    pub seed: u64,
    pub augment: AugmentConfig,
    /// cap batches per epoch (None = full epoch) — used by fast tests/benches
    pub steps_per_epoch: Option<usize>,
    /// track per-layer mode switches (Fig 4)
    pub track_modes: bool,
    /// epochs at which to snapshot weight histograms (Fig 1/3)
    pub hist_epochs: Vec<u32>,
    /// quantized-layer indices to snapshot (Fig 3 uses layers 1/4/7)
    pub hist_layers: Vec<usize>,
    pub hist_bins: usize,
    /// print an epoch summary line
    pub verbose: bool,
}

impl TrainOptions {
    /// Paper-recommended schedules for an E-epoch run.
    pub fn paper(epochs: u32) -> Self {
        TrainOptions {
            epochs,
            lr: LrSchedule::paper(epochs),
            lambda: LambdaSchedule::paper(epochs),
            seed: 0,
            augment: AugmentConfig::none(),
            steps_per_epoch: None,
            track_modes: false,
            hist_epochs: Vec::new(),
            hist_layers: Vec::new(),
            hist_bins: 61,
            verbose: false,
        }
    }
}

/// Everything a finished run hands back.
pub struct TrainOutcome {
    pub log: RunLog,
    pub tracker: Option<ModeTracker>,
    /// (quant-layer index -> histogram series) for requested layers
    pub histograms: Vec<(usize, HistogramSeries)>,
    pub deltas: Vec<f32>,
}

/// The coordinator. Holds host-side state mirrors + the artifact.
pub struct Trainer<'a> {
    pub artifact: &'a Artifact,
    params: Vec<xla::Literal>,
    momenta: Vec<xla::Literal>,
    state: Vec<xla::Literal>,
    pub deltas: Vec<f32>,
    pub epoch: u32,
}

impl<'a> Trainer<'a> {
    /// Initialize from a checkpoint (aot.py's init.ckpt or a previously
    /// saved training checkpoint). `resolve_deltas` recomputes the optimal
    /// step sizes from the loaded weights (Alg. 1 lines 2-5) — pass true
    /// when starting SYMOG from a pretrained float model.
    pub fn from_checkpoint(
        artifact: &'a Artifact,
        ckpt: &Checkpoint,
        resolve_deltas: bool,
    ) -> Result<Trainer<'a>> {
        let man = &artifact.manifest;
        let mut params = Vec::with_capacity(man.params.len());
        let mut momenta = Vec::with_capacity(man.params.len());
        let mut weights_for_delta: Vec<&Tensor> = Vec::new();
        for p in &man.params {
            let t = ckpt
                .find(&p.name)
                .with_context(|| format!("checkpoint missing tensor {}", p.name))?;
            anyhow::ensure!(
                t.dims == p.shape,
                "{}: ckpt shape {:?} != manifest {:?}",
                p.name, t.dims, p.shape
            );
            params.push(literal_f32(&t.data, &p.shape)?);
            // momenta: stored under "<name>#m" if present, else zeros
            let mname = format!("{}#m", p.name);
            match ckpt.find(&mname) {
                Some(m) => momenta.push(literal_f32(&m.data, &p.shape)?),
                None => momenta.push(literal_f32(&vec![0.0; p.numel()], &p.shape)?),
            }
            if p.is_quantized() {
                weights_for_delta.push(t);
            }
        }
        let mut state = Vec::with_capacity(man.state.len());
        for s in &man.state {
            let t = ckpt
                .find(&s.name)
                .with_context(|| format!("checkpoint missing state {}", s.name))?;
            state.push(literal_f32(&t.data, &s.shape)?);
        }
        let deltas = if resolve_deltas {
            weights_for_delta
                .iter()
                .map(|t| fixedpoint::optimal_delta_refined(&t.data, man.n_bits).0)
                .collect()
        } else {
            let d = ckpt
                .find("__deltas__")
                .context("checkpoint missing __deltas__ (pass resolve_deltas=true?)")?;
            d.data.clone()
        };
        let mut deltas = deltas;
        deltas.resize(man.deltas_len(), 1.0);
        let epoch = ckpt.meta_i64("epoch").unwrap_or(0) as u32;
        Ok(Trainer { artifact, params, momenta, state, deltas, epoch })
    }

    /// Convenience: load the artifact's own init checkpoint.
    pub fn from_init(artifact: &'a Artifact) -> Result<Trainer<'a>> {
        let ckpt = Checkpoint::read(&artifact.init_ckpt())?;
        Trainer::from_checkpoint(artifact, &ckpt, true)
    }

    /// Pull a parameter tensor back to the host.
    pub fn param_host(&self, i: usize) -> Result<Vec<f32>> {
        crate::runtime::to_f32_vec(&self.params[i])
    }

    /// Host copies of all quantized weight tensors with their deltas, in
    /// qidx order (probe input for tracker / histograms).
    pub fn quant_layers_host(&self) -> Result<Vec<(Vec<f32>, f32)>> {
        let man = &self.artifact.manifest;
        let mut out = Vec::with_capacity(man.n_quant);
        for (i, p) in man.params.iter().enumerate() {
            if let Some(q) = p.qidx {
                out.push((self.param_host(i)?, self.deltas[q]));
            }
        }
        Ok(out)
    }

    /// One epoch of Algorithm 1's inner loop. Returns (mean loss, accuracy).
    pub fn run_epoch(
        &mut self,
        data: &Dataset,
        opts: &TrainOptions,
        lr: f32,
        lambda: f32,
    ) -> Result<(f32, f32)> {
        let man = &self.artifact.manifest;
        let batch = man.batch;
        let mut iter = BatchIter::new(data, batch, opts.seed, self.epoch as u64, opts.augment);
        let max_steps = opts.steps_per_epoch.unwrap_or(usize::MAX);
        let deltas_lit = literal_f32(&self.deltas, &[man.deltas_len()])?;
        let lr_lit = literal_scalar_f32(lr);
        let lam_lit = literal_scalar_f32(lambda);
        let img_dims = [batch, man.input_shape[0], man.input_shape[1], man.input_shape[2]];

        let (mut images, mut labels) = (Vec::new(), Vec::new());
        let (mut loss_sum, mut correct_sum, mut seen) = (0f64, 0f64, 0usize);
        let (p_n, s_n) = (man.params.len(), man.state.len());
        let mut steps = 0usize;
        while steps < max_steps && iter.next_into(&mut images, &mut labels) {
            let img_lit = literal_f32(&images, &img_dims)?;
            let lab_lit = literal_i32(&labels, &[batch])?;
            // flat calling convention: images, labels, params, momenta,
            // state, deltas, lr, lam
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(man.train_arity());
            args.push(&img_lit);
            args.push(&lab_lit);
            args.extend(self.params.iter());
            args.extend(self.momenta.iter());
            args.extend(self.state.iter());
            args.push(&deltas_lit);
            args.push(&lr_lit);
            args.push(&lam_lit);
            let mut out = run(&self.artifact.train, &args)?;
            anyhow::ensure!(
                out.len() == man.train_outputs(),
                "train step returned {} outputs, expected {}",
                out.len(),
                man.train_outputs()
            );
            // outputs: loss, correct, params', momenta', state'
            let state_new: Vec<xla::Literal> = out.split_off(2 + 2 * p_n);
            let momenta_new: Vec<xla::Literal> = out.split_off(2 + p_n);
            let params_new: Vec<xla::Literal> = out.split_off(2);
            let correct = out.pop().unwrap().to_vec::<f32>()?[0];
            let loss = out.pop().unwrap().to_vec::<f32>()?[0];
            self.params = params_new;
            self.momenta = momenta_new;
            self.state = state_new;
            debug_assert_eq!(self.state.len(), s_n);
            loss_sum += loss as f64;
            correct_sum += correct as f64;
            seen += batch;
            steps += 1;
        }
        self.epoch += 1;
        Ok((
            (loss_sum / steps.max(1) as f64) as f32,
            (correct_sum / seen.max(1) as f64) as f32,
        ))
    }

    /// Evaluate on `data` with float (quantized=false) or hard-quantized
    /// (quantized=true) weights. Uses the largest batch-multiple prefix of
    /// the test set (static-shape executable).
    pub fn evaluate(&self, data: &Dataset, quantized: bool) -> Result<(f32, f32)> {
        let man = &self.artifact.manifest;
        let batch = man.batch;
        let usable = (data.len() / batch) * batch;
        anyhow::ensure!(usable > 0, "test set smaller than one batch");
        let exe = if quantized { &self.artifact.evalq } else { &self.artifact.eval };
        let deltas_lit = literal_f32(&self.deltas, &[man.deltas_len()])?;
        let img_dims = [batch, man.input_shape[0], man.input_shape[1], man.input_shape[2]];
        let e = data.image_elems();
        let (mut loss_sum, mut correct_sum) = (0f64, 0f64);
        for start in (0..usable).step_by(batch) {
            let img_lit = literal_f32(&data.images[start * e..(start + batch) * e], &img_dims)?;
            let lab_lit = literal_i32(&data.labels[start..start + batch], &[batch])?;
            let mut args: Vec<&xla::Literal> = Vec::new();
            args.push(&img_lit);
            args.push(&lab_lit);
            args.extend(self.params.iter());
            args.extend(self.state.iter());
            if quantized {
                args.push(&deltas_lit);
            }
            let out = run(exe, &args)?;
            loss_sum += out[0].to_vec::<f32>()?[0] as f64;
            correct_sum += out[1].to_vec::<f32>()?[0] as f64;
        }
        let n_batches = usable / batch;
        Ok(((loss_sum / n_batches as f64) as f32, (correct_sum / usable as f64) as f32))
    }

    /// Full training run: epochs, schedules, eval, probes (Alg. 1 + Fig 3/4).
    pub fn train(
        &mut self,
        train_data: &Dataset,
        test_data: &Dataset,
        opts: &TrainOptions,
    ) -> Result<TrainOutcome> {
        let man = &self.artifact.manifest;
        let mut log = RunLog::new(&man.tag);
        let mut tracker = opts
            .track_modes
            .then(|| ModeTracker::new(man.n_quant, man.n_bits));
        let mut histograms: Vec<(usize, HistogramSeries)> = opts
            .hist_layers
            .iter()
            .map(|&l| (l, HistogramSeries::default()))
            .collect();

        // epoch-0 probes (pre-training distribution — Fig 3's first panel)
        self.probe(&mut tracker, &mut histograms, opts, 0)?;

        let start_epoch = self.epoch;
        for e in start_epoch..start_epoch + opts.epochs {
            let lr = opts.lr.at(e - start_epoch);
            let lambda = opts.lambda.at(e - start_epoch);
            let t0 = Instant::now();
            let (train_loss, train_acc) = self.run_epoch(train_data, opts, lr, lambda)?;
            let (test_loss, test_acc) = self.evaluate(test_data, false)?;
            let (testq_loss, testq_acc) = self.evaluate(test_data, true)?;
            let switch_rate = match &mut tracker {
                Some(t) => {
                    let layers = self.quant_layers_host()?;
                    crate::util::mean(
                        &t.record(layers.iter().map(|(w, d)| (w.as_slice(), *d))),
                    )
                }
                None => 0.0,
            };
            self.snapshot_hists(&mut histograms, opts, e + 1 - start_epoch)?;
            let entry = EpochLog {
                epoch: e + 1,
                lr,
                lambda,
                train_loss,
                train_acc,
                test_loss,
                test_acc,
                testq_loss,
                testq_acc,
                switch_rate,
                seconds: t0.elapsed().as_secs_f64(),
            };
            if opts.verbose {
                println!(
                    "epoch {:3}  lr {:.4}  λ {:8.1}  train {:.4}/{:.3}  test {:.4}/{:.3}  testq {:.4}/{:.3}  switch {:.3}  {:.1}s",
                    entry.epoch, lr, lambda, train_loss, train_acc, test_loss,
                    test_acc, testq_loss, testq_acc, switch_rate, entry.seconds
                );
            }
            log.push(entry);
        }
        Ok(TrainOutcome {
            log,
            tracker,
            histograms,
            deltas: self.deltas.clone(),
        })
    }

    fn probe(
        &self,
        tracker: &mut Option<ModeTracker>,
        histograms: &mut [(usize, HistogramSeries)],
        opts: &TrainOptions,
        epoch: u32,
    ) -> Result<()> {
        if let Some(t) = tracker {
            let layers = self.quant_layers_host()?;
            t.record(layers.iter().map(|(w, d)| (w.as_slice(), *d)));
        }
        self.snapshot_hists(histograms, opts, epoch)
    }

    fn snapshot_hists(
        &self,
        histograms: &mut [(usize, HistogramSeries)],
        opts: &TrainOptions,
        epoch: u32,
    ) -> Result<()> {
        if histograms.is_empty() || !opts.hist_epochs.contains(&epoch) {
            return Ok(());
        }
        let man = &self.artifact.manifest;
        let layers = self.quant_layers_host()?;
        for (qidx, series) in histograms.iter_mut() {
            if let Some((w, d)) = layers.get(*qidx) {
                series.push(epoch, Histogram::for_layer(w, *d, man.n_bits, opts.hist_bins));
            }
        }
        Ok(())
    }

    /// Snapshot everything into a checkpoint (Alg. 1 line 21-23's float
    /// weights + momenta + BN state + deltas; quantization is applied by
    /// the consumer: evalq, the integer engine, or `quant::quantize_ckpt`).
    pub fn to_checkpoint(&self) -> Result<Checkpoint> {
        let man = &self.artifact.manifest;
        let mut ck = Checkpoint::default();
        ck.set_meta("model", crate::util::json::Json::Str(man.model.clone()));
        ck.set_meta("method", crate::util::json::Json::Str(man.method.clone()));
        ck.set_meta("epoch", crate::util::json::Json::Num(self.epoch as f64));
        for (i, p) in man.params.iter().enumerate() {
            ck.tensors.push(Tensor {
                name: p.name.clone(),
                kind: Kind::from_name(&p.kind)?,
                dims: p.shape.clone(),
                data: self.param_host(i)?,
            });
            ck.tensors.push(Tensor {
                name: format!("{}#m", p.name),
                kind: Kind::Momentum,
                dims: p.shape.clone(),
                data: crate::runtime::to_f32_vec(&self.momenta[i])?,
            });
        }
        for (i, s) in man.state.iter().enumerate() {
            ck.tensors.push(Tensor {
                name: s.name.clone(),
                kind: Kind::State,
                dims: s.shape.clone(),
                data: crate::runtime::to_f32_vec(&self.state[i])?,
            });
        }
        ck.tensors.push(Tensor {
            name: "__deltas__".into(),
            kind: Kind::Deltas,
            dims: vec![self.deltas.len()],
            data: self.deltas.clone(),
        });
        Ok(ck)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_checkpoint()?.write(path)
    }
}
