//! The training coordinator: Algorithm 1 driven from Rust.
//!
//! The coordinator owns the epoch loop, schedules, probes and
//! checkpointing; the per-batch compute lives behind the
//! [`TrainBackend`] seam (`backend.rs`), so the same `Trainer` drives
//! both the AOT-artifact path ([`XlaBackend`]) and the pure-Rust
//! [`crate::train::NativeBackend`].
//!
//! Responsibilities mapped to the paper:
//! * step-size solve at init (Alg. 1 l.2-5) — `fixedpoint::optimal_delta_refined`
//! * lr ramp + exponential lambda (l.7-8)   — `schedule::*`
//! * batched SGD epoch loop (l.9-19)        — `run_epoch`
//! * final hard quantization (l.21-24)      — backend `eval_batch(quantized)`
//! * Fig-3/4 probes                          — `histogram::*`, `tracker::*`

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::data::{AugmentConfig, BatchIter, Dataset};
use crate::runtime::XlaArtifact;

use super::backend::{TrainBackend, XlaBackend};
use super::checkpoint::Checkpoint;
use super::histogram::{Histogram, HistogramSeries};
use super::metrics::{EpochLog, RunLog};
use super::schedule::{LambdaSchedule, LrSchedule};
use super::tracker::ModeTracker;

/// Training options beyond what the backend pins down.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub epochs: u32,
    pub lr: LrSchedule,
    pub lambda: LambdaSchedule,
    pub seed: u64,
    pub augment: AugmentConfig,
    /// cap batches per epoch (None = full epoch) — used by fast tests/benches
    pub steps_per_epoch: Option<usize>,
    /// track per-layer mode switches (Fig 4)
    pub track_modes: bool,
    /// epochs at which to snapshot weight histograms (Fig 1/3)
    pub hist_epochs: Vec<u32>,
    /// quantized-layer indices to snapshot (Fig 3 uses layers 1/4/7)
    pub hist_layers: Vec<usize>,
    pub hist_bins: usize,
    /// print an epoch summary line
    pub verbose: bool,
}

impl TrainOptions {
    /// Paper-recommended schedules for an E-epoch run.
    pub fn paper(epochs: u32) -> Self {
        TrainOptions {
            epochs,
            lr: LrSchedule::paper(epochs),
            lambda: LambdaSchedule::paper(epochs),
            seed: 0,
            augment: AugmentConfig::none(),
            steps_per_epoch: None,
            track_modes: false,
            hist_epochs: Vec::new(),
            hist_layers: Vec::new(),
            hist_bins: 61,
            verbose: false,
        }
    }
}

/// Everything a finished run hands back.
pub struct TrainOutcome {
    pub log: RunLog,
    pub tracker: Option<ModeTracker>,
    /// (quant-layer index -> histogram series) for requested layers
    pub histograms: Vec<(usize, HistogramSeries)>,
    pub deltas: Vec<f32>,
}

/// The coordinator: epoch loop + probes over any [`TrainBackend`].
pub struct Trainer<B: TrainBackend> {
    pub backend: B,
    pub epoch: u32,
}

impl<'a> Trainer<XlaBackend<'a>> {
    /// Initialize the artifact path from a checkpoint. `resolve_deltas`
    /// re-solves the step sizes from the loaded weights (Alg. 1 lines 2-5)
    /// — pass true when starting SYMOG from a pretrained float model.
    pub fn from_checkpoint(
        artifact: &'a XlaArtifact,
        ckpt: &Checkpoint,
        resolve_deltas: bool,
    ) -> Result<Trainer<XlaBackend<'a>>> {
        let backend = XlaBackend::from_checkpoint(artifact, ckpt, resolve_deltas)?;
        let epoch = ckpt.meta_i64("epoch").unwrap_or(0) as u32;
        Ok(Trainer { backend, epoch })
    }

    /// Convenience: load the artifact's own init checkpoint.
    pub fn from_init(artifact: &'a XlaArtifact) -> Result<Trainer<XlaBackend<'a>>> {
        let ckpt = Checkpoint::read(&artifact.init_ckpt())?;
        Trainer::from_checkpoint(artifact, &ckpt, true)
    }
}

impl<B: TrainBackend> Trainer<B> {
    /// Wrap any backend at epoch 0 (the native path's entry point).
    pub fn new(backend: B) -> Trainer<B> {
        Trainer { backend, epoch: 0 }
    }

    /// Per-layer step sizes, qidx order.
    pub fn deltas(&self) -> &[f32] {
        self.backend.deltas()
    }

    /// Host copies of all quantized weight tensors with their deltas, in
    /// qidx order (probe input for tracker / histograms).
    pub fn quant_layers_host(&self) -> Result<Vec<(Vec<f32>, f32)>> {
        self.backend.quant_layers_host()
    }

    /// One epoch of Algorithm 1's inner loop. Returns (mean loss, accuracy).
    pub fn run_epoch(
        &mut self,
        data: &Dataset,
        opts: &TrainOptions,
        lr: f32,
        lambda: f32,
    ) -> Result<(f32, f32)> {
        let batch = self.backend.batch();
        let mut iter = BatchIter::new(data, batch, opts.seed, self.epoch as u64, opts.augment);
        let max_steps = opts.steps_per_epoch.unwrap_or(usize::MAX);
        let (mut images, mut labels) = (Vec::new(), Vec::new());
        let (mut loss_sum, mut correct_sum, mut seen) = (0f64, 0f64, 0usize);
        let mut steps = 0usize;
        while steps < max_steps && iter.next_into(&mut images, &mut labels) {
            let out = self.backend.train_step(&images, &labels, lr, lambda)?;
            loss_sum += out.loss as f64;
            correct_sum += out.correct as f64;
            seen += batch;
            steps += 1;
        }
        self.epoch += 1;
        Ok((
            (loss_sum / steps.max(1) as f64) as f32,
            (correct_sum / seen.max(1) as f64) as f32,
        ))
    }

    /// Evaluate on `data` with float (quantized=false) or hard-quantized
    /// (quantized=true) weights. Uses the largest batch-multiple prefix of
    /// the test set (the step shape is static on both backends).
    pub fn evaluate(&self, data: &Dataset, quantized: bool) -> Result<(f32, f32)> {
        let batch = self.backend.batch();
        let usable = (data.len() / batch) * batch;
        anyhow::ensure!(usable > 0, "test set smaller than one batch");
        let e = data.image_elems();
        let (mut loss_sum, mut correct_sum) = (0f64, 0f64);
        for start in (0..usable).step_by(batch) {
            let out = self.backend.eval_batch(
                &data.images[start * e..(start + batch) * e],
                &data.labels[start..start + batch],
                quantized,
            )?;
            loss_sum += out.loss as f64;
            correct_sum += out.correct as f64;
        }
        let n_batches = usable / batch;
        Ok(((loss_sum / n_batches as f64) as f32, (correct_sum / usable as f64) as f32))
    }

    /// Full training run: epochs, schedules, eval, probes (Alg. 1 + Fig 3/4).
    pub fn train(
        &mut self,
        train_data: &Dataset,
        test_data: &Dataset,
        opts: &TrainOptions,
    ) -> Result<TrainOutcome> {
        let mut log = RunLog::new(&self.backend.tag());
        let mut tracker = opts
            .track_modes
            .then(|| ModeTracker::new(self.backend.n_quant(), self.backend.n_bits()));
        let mut histograms: Vec<(usize, HistogramSeries)> = opts
            .hist_layers
            .iter()
            .map(|&l| (l, HistogramSeries::default()))
            .collect();

        // epoch-0 probes (pre-training distribution — Fig 3's first panel)
        self.probe(&mut tracker, &mut histograms, opts, 0)?;

        let start_epoch = self.epoch;
        for e in start_epoch..start_epoch + opts.epochs {
            let lr = opts.lr.at(e - start_epoch);
            let lambda = opts.lambda.at(e - start_epoch);
            let t0 = Instant::now();
            let (train_loss, train_acc) = self.run_epoch(train_data, opts, lr, lambda)?;
            let (test_loss, test_acc) = self.evaluate(test_data, false)?;
            let (testq_loss, testq_acc) = self.evaluate(test_data, true)?;
            let switch_rate = match &mut tracker {
                Some(t) => {
                    let layers = self.backend.quant_layers_host()?;
                    crate::util::mean(
                        &t.record(layers.iter().map(|(w, d)| (w.as_slice(), *d))),
                    )
                }
                None => 0.0,
            };
            self.snapshot_hists(&mut histograms, opts, e + 1 - start_epoch)?;
            let entry = EpochLog {
                epoch: e + 1,
                lr,
                lambda,
                train_loss,
                train_acc,
                test_loss,
                test_acc,
                testq_loss,
                testq_acc,
                switch_rate,
                seconds: t0.elapsed().as_secs_f64(),
            };
            if opts.verbose {
                println!(
                    "epoch {:3}  lr {:.4}  λ {:8.1}  train {:.4}/{:.3}  test {:.4}/{:.3}  testq {:.4}/{:.3}  switch {:.3}  {:.1}s",
                    entry.epoch, lr, lambda, train_loss, train_acc, test_loss,
                    test_acc, testq_loss, testq_acc, switch_rate, entry.seconds
                );
            }
            log.push(entry);
        }
        Ok(TrainOutcome {
            log,
            tracker,
            histograms,
            deltas: self.backend.deltas().to_vec(),
        })
    }

    fn probe(
        &self,
        tracker: &mut Option<ModeTracker>,
        histograms: &mut [(usize, HistogramSeries)],
        opts: &TrainOptions,
        epoch: u32,
    ) -> Result<()> {
        if let Some(t) = tracker {
            let layers = self.backend.quant_layers_host()?;
            t.record(layers.iter().map(|(w, d)| (w.as_slice(), *d)));
        }
        self.snapshot_hists(histograms, opts, epoch)
    }

    fn snapshot_hists(
        &self,
        histograms: &mut [(usize, HistogramSeries)],
        opts: &TrainOptions,
        epoch: u32,
    ) -> Result<()> {
        if histograms.is_empty() || !opts.hist_epochs.contains(&epoch) {
            return Ok(());
        }
        let layers = self.backend.quant_layers_host()?;
        for (qidx, series) in histograms.iter_mut() {
            if let Some((w, d)) = layers.get(*qidx) {
                series.push(
                    epoch,
                    Histogram::for_layer(w, *d, self.backend.n_bits(), opts.hist_bins),
                );
            }
        }
        Ok(())
    }

    /// Snapshot everything into a checkpoint (Alg. 1 line 21-23's float
    /// weights + momenta + state + deltas; quantization is applied by the
    /// consumer: evalq, the integer engine, or `quant::quantize_ckpt`).
    pub fn to_checkpoint(&self) -> Result<Checkpoint> {
        self.backend.to_checkpoint(self.epoch)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_checkpoint()?.write(path)
    }
}
