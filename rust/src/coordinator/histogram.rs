//! Weight-distribution probe — the measurement behind Figures 1 and 3.
//!
//! Fixed-bin histograms of layer weights, recorded at selected epochs, plus
//! per-mode occupancy (the discrete version used by Figure 3's "three
//! separated Gaussian modes" narrative) and an ASCII sparkline renderer so
//! runs are inspectable straight from the terminal.

use crate::fixedpoint::{clip_bound, mode_indices};

/// A single histogram snapshot.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u32>,
}

impl Histogram {
    /// Histogram `bins` equal-width bins over [lo, hi]; out-of-range values
    /// clamp into the edge bins (they are clipped weights anyway).
    pub fn compute(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Histogram {
        assert!(bins >= 1 && hi > lo);
        let mut counts = vec![0u32; bins];
        let scale = bins as f32 / (hi - lo);
        for &x in xs {
            let b = (((x - lo) * scale) as isize).clamp(0, bins as isize - 1) as usize;
            counts[b] += 1;
        }
        Histogram { lo, hi, counts }
    }

    /// Default domain for a SYMOG layer: +-1.5 * clip bound.
    pub fn for_layer(w: &[f32], delta: f32, n_bits: u32, bins: usize) -> Histogram {
        let b = 1.5 * clip_bound(n_bits, delta).max(1e-6);
        Histogram::compute(w, -b, b, bins)
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().map(|&c| c as u64).sum()
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f32> {
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        (0..self.counts.len())
            .map(|i| self.lo + (i as f32 + 0.5) * w)
            .collect()
    }

    /// Terminal sparkline (unicode block elements).
    pub fn sparkline(&self) -> String {
        const BLOCKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1) as f32;
        self.counts
            .iter()
            .map(|&c| BLOCKS[((c as f32 / max) * 7.0).round() as usize])
            .collect()
    }

    /// CSV row: lo,hi,count0,count1,...
    pub fn csv_row(&self) -> String {
        let mut s = format!("{},{}", self.lo, self.hi);
        for c in &self.counts {
            s.push_str(&format!(",{c}"));
        }
        s
    }
}

/// Per-mode occupancy (2^N - 1 symmetric modes).
pub fn mode_occupancy(w: &[f32], delta: f32, n_bits: u32) -> Vec<u32> {
    let qmax = (1i32 << (n_bits - 1)) - 1;
    let mut counts = vec![0u32; (2 * qmax + 1) as usize];
    for m in mode_indices(w, delta, n_bits) {
        counts[(m as i32 + qmax) as usize] += 1;
    }
    counts
}

/// Multi-epoch histogram series for one layer (Figure 3's panel).
#[derive(Default)]
pub struct HistogramSeries {
    pub epochs: Vec<u32>,
    pub hists: Vec<Histogram>,
}

impl HistogramSeries {
    pub fn push(&mut self, epoch: u32, hist: Histogram) {
        self.epochs.push(epoch);
        self.hists.push(hist);
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("epoch,lo,hi,counts...\n");
        for (e, h) in self.epochs.iter().zip(&self.hists) {
            out.push_str(&format!("{e},{}\n", h.csv_row()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn counts_and_total() {
        let xs = [-1.0f32, -0.5, 0.0, 0.5, 1.0];
        let h = Histogram::compute(&xs, -1.0, 1.0, 4);
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts.iter().sum::<u32>(), 5);
    }

    #[test]
    fn out_of_range_clamps() {
        let xs = [-99.0f32, 99.0];
        let h = Histogram::compute(&xs, -1.0, 1.0, 10);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[9], 1);
    }

    #[test]
    fn trimodal_weights_have_three_peaks() {
        // SYMOG-trained-like distribution: tight Gaussians at {-D, 0, D}
        let mut rng = Rng::new(0);
        let delta = 0.5f32;
        let xs: Vec<f32> = (0..6000)
            .map(|i| [-delta, 0.0, delta][i % 3] + 0.02 * rng.normal())
            .collect();
        let h = Histogram::for_layer(&xs, delta, 2, 33);
        // find local maxima
        let peaks = (1..32)
            .filter(|&i| {
                h.counts[i] > h.counts[i - 1] && h.counts[i] > h.counts[i + 1]
                    && h.counts[i] > 100
            })
            .count();
        assert_eq!(peaks, 3, "{:?}", h.counts);
    }

    #[test]
    fn mode_occupancy_sums() {
        let mut rng = Rng::new(1);
        let xs: Vec<f32> = (0..999).map(|_| rng.normal()).collect();
        let occ = mode_occupancy(&xs, 0.5, 2);
        assert_eq!(occ.len(), 3);
        assert_eq!(occ.iter().sum::<u32>() as usize, xs.len());
    }

    #[test]
    fn sparkline_has_bin_count_chars() {
        let h = Histogram::compute(&[0.0, 0.1, 0.2], 0.0, 1.0, 8);
        assert_eq!(h.sparkline().chars().count(), 8);
    }

    #[test]
    fn series_csv() {
        let mut s = HistogramSeries::default();
        s.push(0, Histogram::compute(&[0.0], -1.0, 1.0, 2));
        s.push(5, Histogram::compute(&[0.5], -1.0, 1.0, 2));
        let csv = s.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(2).unwrap().starts_with("5,"));
    }
}
