//! Checkpoint I/O — binary format shared with python/compile/ckpt.py.
//!
//! Layout (little-endian): magic "SYMGCKP1", u32 meta_len + JSON meta,
//! u32 n_tensors, then per tensor: u32 name_len + name, u8 kind, u8 ndim,
//! u32 dims[ndim], f32 data. Kind codes must match ckpt.KINDS.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"SYMGCKP1";

/// Tensor kind codes (lockstep with ckpt.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Weight = 0,
    Bias = 1,
    Gamma = 2,
    Beta = 3,
    State = 4,
    Momentum = 5,
    Deltas = 6,
}

impl Kind {
    pub fn from_u8(v: u8) -> Result<Kind> {
        Ok(match v {
            0 => Kind::Weight,
            1 => Kind::Bias,
            2 => Kind::Gamma,
            3 => Kind::Beta,
            4 => Kind::State,
            5 => Kind::Momentum,
            6 => Kind::Deltas,
            _ => bail!("unknown tensor kind {v}"),
        })
    }

    pub fn from_name(name: &str) -> Result<Kind> {
        Ok(match name {
            "weight" => Kind::Weight,
            "bias" => Kind::Bias,
            "gamma" => Kind::Gamma,
            "beta" => Kind::Beta,
            "state" => Kind::State,
            "momentum" => Kind::Momentum,
            "deltas" => Kind::Deltas,
            _ => bail!("unknown tensor kind {name:?}"),
        })
    }
}

/// One named tensor in a checkpoint.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub kind: Kind,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

/// A checkpoint: JSON meta + ordered tensor list.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub meta: BTreeMap<String, Json>,
    pub tensors: Vec<Tensor>,
}

impl Checkpoint {
    pub fn read(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: bad checkpoint magic", path.display());
        }
        let meta_len = read_u32(&mut f)? as usize;
        let mut meta_buf = vec![0u8; meta_len];
        f.read_exact(&mut meta_buf)?;
        let meta = match Json::parse(std::str::from_utf8(&meta_buf)?)? {
            Json::Obj(m) => m,
            _ => bail!("checkpoint meta is not an object"),
        };
        let n = read_u32(&mut f)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut name_buf = vec![0u8; name_len];
            f.read_exact(&mut name_buf)?;
            let mut kb = [0u8; 2];
            f.read_exact(&mut kb)?;
            let kind = Kind::from_u8(kb[0])?;
            let ndim = kb[1] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut f)? as usize);
            }
            let numel: usize = dims.iter().product::<usize>().max(1);
            let mut raw = vec![0u8; numel * 4];
            f.read_exact(&mut raw)?;
            let data = raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            tensors.push(Tensor {
                name: String::from_utf8(name_buf)?,
                kind,
                dims,
                data,
            });
        }
        Ok(Checkpoint { meta, tensors })
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        let meta = Json::Obj(self.meta.clone()).to_string();
        f.write_all(&(meta.len() as u32).to_le_bytes())?;
        f.write_all(meta.as_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            let numel: usize = t.dims.iter().product::<usize>().max(1);
            anyhow::ensure!(
                t.data.len() == numel,
                "{}: data len {} != dims {:?}",
                t.name,
                t.data.len(),
                t.dims
            );
            f.write_all(&(t.name.len() as u32).to_le_bytes())?;
            f.write_all(t.name.as_bytes())?;
            f.write_all(&[t.kind as u8, t.dims.len() as u8])?;
            for &d in &t.dims {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            for &v in &t.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn find(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|j| j.str().ok())
    }

    pub fn meta_i64(&self, key: &str) -> Option<i64> {
        self.meta.get(key).and_then(|j| j.int().ok())
    }

    pub fn set_meta(&mut self, key: &str, val: Json) {
        self.meta.insert(key.to_string(), val);
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut meta = BTreeMap::new();
        meta.insert("model".into(), Json::Str("mlp".into()));
        meta.insert("epoch".into(), Json::Num(3.0));
        Checkpoint {
            meta,
            tensors: vec![
                Tensor {
                    name: "a.w".into(),
                    kind: Kind::Weight,
                    dims: vec![2, 3],
                    data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                },
                Tensor {
                    name: "__deltas__".into(),
                    kind: Kind::Deltas,
                    dims: vec![1],
                    data: vec![0.5],
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("symog_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let ck = sample();
        ck.write(&path).unwrap();
        let ck2 = Checkpoint::read(&path).unwrap();
        assert_eq!(ck2.meta_str("model"), Some("mlp"));
        assert_eq!(ck2.meta_i64("epoch"), Some(3));
        assert_eq!(ck2.tensors.len(), 2);
        assert_eq!(ck2.tensors[0].data, ck.tensors[0].data);
        assert_eq!(ck2.tensors[0].kind, Kind::Weight);
        assert_eq!(ck2.tensors[1].dims, vec![1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("symog_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTMAGIC00000000").unwrap();
        assert!(Checkpoint::read(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reads_python_written_ckpt() {
        // aot.py writes init.ckpt for the smoke artifact compiled in CI;
        // if present, verify cross-language compatibility.
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/smoke/init.ckpt");
        if p.exists() {
            let ck = Checkpoint::read(&p).unwrap();
            assert!(ck.find("__deltas__").is_some());
            assert!(ck.tensors.iter().any(|t| t.kind == Kind::Weight));
        }
    }

    #[test]
    fn kind_codes_stable() {
        assert_eq!(Kind::Weight as u8, 0);
        assert_eq!(Kind::Deltas as u8, 6);
        assert_eq!(Kind::from_u8(5).unwrap(), Kind::Momentum);
        assert!(Kind::from_u8(7).is_err());
    }
}
