//! Learning-rate and regularization-parameter schedules (section 3.3).
//!
//! The paper prescribes a linear learning-rate ramp `eta_0 -> eta_E` and an
//! exponentially growing regularization parameter
//! `lambda(e) = lambda_0 * exp(alpha_E * e)` with the recommended setting
//! `[eta_0, eta_E] = [0.01, 0.001]`, `lambda_0 = 10`, `alpha_E = 9 / E`
//! (Algorithm 1, lines 7-8). Linear and constant lambda variants exist for
//! the A2 ablation.

/// Linear learning-rate schedule eta(e) = eta0 - (eta0 - etaE) e / E.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub eta0: f32,
    pub eta_e: f32,
    pub epochs: u32,
}

impl LrSchedule {
    /// Paper-recommended domain [0.01, 0.001].
    pub fn paper(epochs: u32) -> Self {
        LrSchedule { eta0: 0.01, eta_e: 0.001, epochs }
    }

    pub fn at(&self, epoch: u32) -> f32 {
        let e = epoch.min(self.epochs) as f32;
        self.eta0 - (self.eta0 - self.eta_e) * e / self.epochs.max(1) as f32
    }
}

/// Regularization-parameter schedule family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LambdaSchedule {
    /// Paper: lambda0 * exp(alpha * e); alpha defaults to 9/E so that
    /// lambda grows by e^9 (~8100x) over the run.
    Exponential { lambda0: f32, alpha: f32 },
    /// Ablation: linear ramp lambda0 -> lambda0 * growth over E epochs.
    Linear { lambda0: f32, growth: f32, epochs: u32 },
    /// Ablation: constant lambda.
    Constant { lambda0: f32 },
    /// Methods without a regularizer (baseline / bc / twn) or BR's
    /// relaxation coefficient reusing the exponential ramp.
    Off,
}

impl LambdaSchedule {
    /// Paper-recommended: lambda0 = 10, alpha = 9/E.
    pub fn paper(epochs: u32) -> Self {
        LambdaSchedule::Exponential { lambda0: 10.0, alpha: 9.0 / epochs.max(1) as f32 }
    }

    pub fn at(&self, epoch: u32) -> f32 {
        match *self {
            LambdaSchedule::Exponential { lambda0, alpha } => {
                lambda0 * (alpha * epoch as f32).exp()
            }
            LambdaSchedule::Linear { lambda0, growth, epochs } => {
                let frac = epoch as f32 / epochs.max(1) as f32;
                lambda0 * (1.0 + (growth - 1.0) * frac)
            }
            LambdaSchedule::Constant { lambda0 } => lambda0,
            LambdaSchedule::Off => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_endpoints_match_paper() {
        let s = LrSchedule::paper(100);
        assert!((s.at(0) - 0.01).abs() < 1e-8);
        assert!((s.at(100) - 0.001).abs() < 1e-8);
        assert!((s.at(50) - 0.0055).abs() < 1e-7);
    }

    #[test]
    fn lr_is_monotone_decreasing() {
        let s = LrSchedule::paper(40);
        for e in 0..40 {
            assert!(s.at(e) > s.at(e + 1));
        }
    }

    #[test]
    fn lr_clamps_past_end() {
        let s = LrSchedule::paper(10);
        assert_eq!(s.at(25), s.at(10));
    }

    #[test]
    fn lambda_exponential_growth_matches_paper() {
        // lambda(E) / lambda(0) = e^9 with alpha = 9/E
        let s = LambdaSchedule::paper(100);
        let ratio = s.at(100) / s.at(0);
        assert!((ratio - (9f32).exp()).abs() / (9f32).exp() < 1e-4, "ratio {ratio}");
        assert_eq!(s.at(0), 10.0);
    }

    #[test]
    fn paper_schedules_hit_prescribed_checkpoints() {
        // Section 3.3's recommended settings, probed at e = 0, E/2, E for
        // several run lengths: eta ramps 0.01 -> 0.001 linearly, lambda
        // grows 10 -> 10 e^9 exponentially (sqrt(e^9) at the midpoint).
        for epochs in [8u32, 40, 100] {
            let lr = LrSchedule::paper(epochs);
            assert!((lr.at(0) - 0.01).abs() < 1e-8, "E={epochs}");
            assert!((lr.at(epochs / 2) - 0.0055).abs() < 1e-7, "E={epochs}");
            assert!((lr.at(epochs) - 0.001).abs() < 1e-8, "E={epochs}");

            let lam = LambdaSchedule::paper(epochs);
            assert_eq!(lam.at(0), 10.0, "E={epochs}");
            let mid = 10.0 * (4.5f32).exp();
            assert!(
                (lam.at(epochs / 2) - mid).abs() / mid < 1e-5,
                "E={epochs}: lambda(E/2) = {} want {mid}",
                lam.at(epochs / 2)
            );
            let end = 10.0 * (9.0f32).exp();
            assert!(
                (lam.at(epochs) - end).abs() / end < 1e-4,
                "E={epochs}: lambda(E) = {} want {end}",
                lam.at(epochs)
            );
        }
    }

    #[test]
    fn lambda_exponential_is_monotone() {
        let s = LambdaSchedule::paper(50);
        for e in 0..50 {
            assert!(s.at(e + 1) > s.at(e));
        }
    }

    #[test]
    fn lambda_variants() {
        let lin = LambdaSchedule::Linear { lambda0: 2.0, growth: 10.0, epochs: 10 };
        assert_eq!(lin.at(0), 2.0);
        assert!((lin.at(10) - 20.0).abs() < 1e-5);
        let c = LambdaSchedule::Constant { lambda0: 5.0 };
        assert_eq!(c.at(0), 5.0);
        assert_eq!(c.at(99), 5.0);
        assert_eq!(LambdaSchedule::Off.at(3), 0.0);
    }
}
