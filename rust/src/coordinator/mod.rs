//! The L3 coordinator: Algorithm 1 as a Rust training orchestrator.
//!
//! * `backend`    — the `TrainBackend` seam: XLA-artifact vs native substrate
//! * `trainer`    — backend-generic epoch/batch loop, probes, checkpointing
//! * `schedule`   — lr ramp + exponential lambda (section 3.3)
//! * `tracker`    — mode-switch rates (Figure 4)
//! * `histogram`  — weight-distribution probes (Figures 1 and 3)
//! * `checkpoint` — binary checkpoints shared with the Python side
//! * `metrics`    — per-epoch logs, CSV/JSONL

pub mod backend;
pub mod checkpoint;
pub mod histogram;
pub mod metrics;
pub mod schedule;
pub mod tracker;
pub mod trainer;

pub use backend::{StepOut, TrainBackend, XlaBackend};
pub use checkpoint::{Checkpoint, Kind, Tensor};
pub use histogram::{Histogram, HistogramSeries, mode_occupancy};
pub use metrics::{EpochLog, RunLog};
pub use schedule::{LambdaSchedule, LrSchedule};
pub use tracker::ModeTracker;
pub use trainer::{Trainer, TrainOptions, TrainOutcome};
