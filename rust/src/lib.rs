//! SYMOG: symmetric mixture-of-Gaussian-modes fixed-point quantization.
//!
//! Full-stack reproduction of Enderich et al., Neurocomputing 2020:
//! a Rust training coordinator with two backends — AOT-compiled
//! JAX/Pallas compute (HLO via PJRT) and a pure-Rust native trainer
//! (`train::NativeBackend`) — plus a pure integer fixed-point inference
//! engine and a batched multi-model serving layer (`serve`) on its
//! compiled-plan seam.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for results.

pub mod artifact;
pub mod coordinator;
pub mod data;
pub mod bench;
pub mod cli;
pub mod config;
pub mod driver;
pub mod fixedpoint;
pub mod inference;
pub mod kernels;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod train;
pub mod util;
