//! `symog` — the SYMOG training/evaluation coordinator CLI.
//!
//! Subcommands:
//!   train        run one experiment (TOML config and/or flags)
//!   eval         evaluate a checkpoint (float / quantized)
//!   quantize     post-training-quantize a checkpoint (naive PTQ)
//!   stats        per-layer quantization statistics of a checkpoint
//!   infer        run the pure integer inference engine + cost report
//!   serve        expose a model over the TCP serving front-end
//!   fig2         print the 2-bit quantizer transfer curve (paper Fig. 2)
//!   list         list compiled artifacts
//!
//! Benches (`cargo bench`) regenerate Table 1 / Fig 3 / Fig 4; see
//! DESIGN.md's per-experiment index.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use symog::cli::Args;
use symog::config::Experiment;
use symog::coordinator::Checkpoint;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::inference::IntModel;
use symog::report::Table;
use symog::runtime::Runtime;

const SWITCHES: &[&str] = &[
    "quantized", "no-clip", "no-resolve-deltas", "quiet", "track-modes", "augment",
];

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(SWITCHES)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "quantize" => cmd_quantize(&args),
        "pack" => cmd_pack(&args),
        "stats" => cmd_stats(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "fig2" => cmd_fig2(&args),
        "ablate-bits" => cmd_ablate_bits(&args),
        "ablate-lambda" => cmd_ablate_lambda(&args),
        "list" => cmd_list(&args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; run `symog help`"),
    }
}

const HELP: &str = "\
symog — SYMOG fixed-point quantization coordinator

USAGE: symog <subcommand> [flags]

  train     --artifact TAG | --config FILE  [--epochs N --lr0 F --lr-end F
            --lambda0 F --lambda-kind exp|linear|const|off --train-n N
            --test-n N --seed N --steps-per-epoch N --init-from CKPT
            --save CKPT --metrics CSV --track-modes --augment --quiet]
  eval      --artifact TAG --ckpt FILE [--quantized] [--test-n N --seed N]
  quantize  --artifact TAG --ckpt FILE --out FILE
  pack      --artifact TAG --ckpt FILE --out FILE.fxpm   (2-bit packed model)
  stats     --artifact TAG --ckpt FILE
  infer     --artifact TAG --ckpt FILE [--test-n N --seed N --batch N]
  serve     --model vgg7|lenet5|densenet | --fxpa FILE.fxpa
            [--name NAME --bits N --width N --batch N --workers N
            --queue-depth N --seed N --addr HOST:PORT]
            (TCP front-end; length-prefixed binary protocol, see DESIGN.md)
  fig2      [--delta F --bits N]
  ablate-bits    [--epochs N --train-n N --test-n N --seed N]   (A1)
  ablate-lambda  [--epochs N --train-n N --test-n N --seed N]   (A2)
  list      [--root DIR]

Artifacts are searched under $SYMOG_ARTIFACTS (default ./artifacts).
";

/// Build an Experiment from --config and/or flag overrides.
fn experiment_from_args(args: &Args) -> Result<Experiment> {
    let mut exp = match args.str_opt("config") {
        Some(path) => Experiment::from_toml_file(Path::new(&path))?,
        None => Experiment::default(),
    };
    if let Some(a) = args.str_opt("artifact") {
        exp.artifact = a;
    }
    exp.epochs = args.usize_or("epochs", exp.epochs as usize)? as u32;
    exp.lr0 = args.f32_or("lr0", exp.lr0)?;
    exp.lr_end = args.f32_or("lr-end", exp.lr_end)?;
    exp.lambda0 = args.f32_or("lambda0", exp.lambda0)?;
    exp.lambda_kind = args.str_or("lambda-kind", &exp.lambda_kind);
    exp.lambda_growth = args.f32_or("lambda-growth", exp.lambda_growth)?;
    exp.train_n = args.usize_or("train-n", exp.train_n)?;
    exp.test_n = args.usize_or("test-n", exp.test_n)?;
    exp.seed = args.usize_or("seed", exp.seed as usize)? as u64;
    if let Some(s) = args.str_opt("dataset") {
        exp.dataset = Preset::parse(&s).with_context(|| format!("unknown dataset {s}"))?;
    }
    match args.usize_or("steps-per-epoch", exp.steps_per_epoch.unwrap_or(0))? {
        0 => {}
        n => exp.steps_per_epoch = Some(n),
    }
    if let Some(p) = args.str_opt("init-from") {
        exp.init_from = Some(PathBuf::from(p));
    }
    if args.switch("no-resolve-deltas") {
        exp.resolve_deltas = false;
    }
    if args.switch("track-modes") {
        exp.track_modes = true;
    }
    if args.switch("augment") {
        exp.augment = true;
    }
    if args.switch("quiet") {
        exp.verbose = false;
    }
    Ok(exp)
}

fn load_manifest_artifact(args: &Args, rt: &Runtime) -> Result<symog::runtime::XlaArtifact> {
    let tag = args
        .str_opt("artifact")
        .context("--artifact TAG is required")?;
    let dir = artifacts_root().join(tag);
    rt.load_artifact(&dir)
        .with_context(|| format!("loading {} (run `make artifacts`?)", dir.display()))
}

fn cmd_train(args: &Args) -> Result<()> {
    let exp = experiment_from_args(args)?;
    let save = args.str_opt("save");
    let metrics = args.str_opt("metrics");
    args.finish()?;

    let rt = Runtime::cpu()?;
    let artifact = driver::load_artifact(&rt, &exp, &artifacts_root())?;
    let man = &artifact.manifest;
    println!(
        "artifact {} — model {} method {} ({} params, {} quant layers, N={} bits)",
        man.tag, man.model, man.method, symog::report::human_count(man.num_params()),
        man.n_quant, man.n_bits
    );
    let (train, test) = exp.dataset.load(exp.train_n, exp.test_n, exp.seed);
    println!(
        "dataset {} — {} train / {} test, {} classes",
        exp.dataset.name(), train.len(), test.len(), train.classes
    );
    let result = driver::run_experiment(&artifact, &exp, &train, &test)?;
    let last = result.outcome.log.last().context("no epochs ran")?;
    println!(
        "done: best quantized error {:.2}%  (float {:.2}%)  final testq acc {:.3}",
        result.best_q_error * 100.0,
        result.best_f_error * 100.0,
        last.testq_acc
    );
    if let Some(path) = save {
        result.final_ckpt.write(Path::new(&path))?;
        println!("checkpoint -> {path}");
    }
    if let Some(path) = metrics {
        result.outcome.log.save_csv(Path::new(&path))?;
        println!("metrics -> {path}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ckpt_path = args.str_opt("ckpt").context("--ckpt FILE required")?;
    let quantized = args.switch("quantized");
    let test_n = args.usize_or("test-n", 1024)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let rt = Runtime::cpu()?;
    let artifact = load_manifest_artifact(args, &rt)?;
    args.finish()?;

    let ck = Checkpoint::read(Path::new(&ckpt_path))?;
    let trainer = symog::coordinator::Trainer::from_checkpoint(&artifact, &ck, false)?;
    let preset = Preset::parse(&artifact.manifest.dataset).context("unknown dataset")?;
    let (_, test) = preset.load(64, test_n, seed);
    let (loss, acc) = trainer.evaluate(&test, quantized)?;
    println!(
        "{} eval: loss {loss:.4}  acc {acc:.4}  error {:.2}%",
        if quantized { "quantized" } else { "float" },
        (1.0 - acc) * 100.0
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let ckpt_path = args.str_opt("ckpt").context("--ckpt FILE required")?;
    let out = args.str_opt("out").context("--out FILE required")?;
    let rt = Runtime::cpu()?;
    let artifact = load_manifest_artifact(args, &rt)?;
    args.finish()?;
    let ck = Checkpoint::read(Path::new(&ckpt_path))?;
    let qck = symog::quant::quantize_ckpt(&artifact.manifest, &ck)?;
    qck.write(Path::new(&out))?;
    println!("quantized checkpoint -> {out}");
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    let ckpt_path = args.str_opt("ckpt").context("--ckpt FILE required")?;
    let out = args.str_opt("out").context("--out FILE required")?;
    let tag = args.str_opt("artifact").context("--artifact TAG required")?;
    args.finish()?;
    let dir = artifacts_root().join(&tag);
    let man = symog::runtime::Manifest::load(&dir.join("manifest.json"))?;
    let man_json = std::fs::read_to_string(dir.join("manifest.json"))?;
    let ck = Checkpoint::read(Path::new(&ckpt_path))?;
    symog::quant::packed::write_packed(&man, &man_json, &ck, Path::new(&out))?;
    let packed_size = std::fs::metadata(&out)?.len();
    let float_size = std::fs::metadata(&ckpt_path)?.len();
    println!(
        "packed model -> {out} ({} KiB, {:.1}x smaller than the checkpoint)",
        packed_size / 1024,
        float_size as f64 / packed_size as f64
    );
    // verify: load back and confirm it predicts
    let (man2, ck2) = symog::quant::packed::read_packed(Path::new(&out))?;
    let model = IntModel::build(&man2, &ck2)?;
    println!("verified: integer model loads, {} quantized params", model.quant_params);
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let ckpt_path = args.str_opt("ckpt").context("--ckpt FILE required")?;
    let rt = Runtime::cpu()?;
    let artifact = load_manifest_artifact(args, &rt)?;
    args.finish()?;
    let ck = Checkpoint::read(Path::new(&ckpt_path))?;
    let stats = symog::quant::layer_stats(&artifact.manifest, &ck)?;
    let mut t = Table::new(["layer", "numel", "delta", "std", "mse", "occupancy"]);
    for s in stats {
        t.row([
            s.name.clone(),
            s.numel.to_string(),
            format!("{}", s.delta),
            format!("{:.4}", s.std),
            format!("{:.2e}", s.mse),
            s.occupancy.iter().map(|o| format!("{:.2}", o)).collect::<Vec<_>>().join("/"),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let ckpt_path = args.str_opt("ckpt").context("--ckpt FILE required")?;
    let test_n = args.usize_or("test-n", 256)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let batch = args.usize_or("batch", 32)?;
    let rt = Runtime::cpu()?;
    let artifact = load_manifest_artifact(args, &rt)?;
    args.finish()?;

    let ck = Checkpoint::read(Path::new(&ckpt_path))?;
    let model = IntModel::build(&artifact.manifest, &ck)?;
    println!(
        "integer model: {} quantized params, ternary = {}",
        model.quant_params, model.all_ternary
    );
    let preset = Preset::parse(&artifact.manifest.dataset).context("unknown dataset")?;
    let (_, test) = preset.load(64, test_n, seed);
    let t0 = std::time::Instant::now();
    let acc = model.accuracy(&test.images, &test.labels, batch)?;
    let dt = t0.elapsed();
    // compare against the float evalq path
    let trainer = symog::coordinator::Trainer::from_checkpoint(&artifact, &ck, false)?;
    let (_, acc_q) = trainer.evaluate(&test, true)?;
    println!(
        "integer-engine acc {acc:.4} vs evalq {acc_q:.4} (gap {:+.4}) — {} images in {:.2}s",
        acc - acc_q, test.len(), dt.as_secs_f64()
    );
    let report = model.cost_report(1)?;
    println!("{}", report.render());
    Ok(())
}

/// Stand up the TCP serving front-end on one model until killed.
/// The model comes from the deterministic zoo (`--model` + `--seed`, handy
/// for demos and load tests) or from a published `.fxpa` serving artifact
/// (`--fxpa`, the production path).
fn cmd_serve(args: &Args) -> Result<()> {
    use symog::serve::net::TcpFront;
    use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};

    let model_name = args.str_or("model", "lenet5");
    let bits = args.usize_or("bits", 2)? as u32;
    let width = args.usize_or("width", 16)?;
    let batch = args.usize_or("batch", 8)?.max(1);
    let workers = args.usize_or("workers", 0)?;
    let queue_depth = args.usize_or("queue-depth", 0)?;
    let seed = args.usize_or("seed", 0x1453)? as u64;
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let fxpa = args.str_opt("fxpa");
    let name = args.str_or("name", &model_name);
    args.finish()?;

    let opts = RegisterOpts::new().max_batch(batch);
    let mut reg = Registry::new();
    // the in-code model must outlive registration; built in either branch
    let built;
    let key = match &fxpa {
        Some(path) => reg.add(&name, ModelSource::Artifact(Path::new(path)), &opts)?,
        None => {
            let mut rng = symog::util::rng::Rng::new(seed);
            let (man, ck) = match model_name.as_str() {
                "vgg7" => symog::testing::models::vgg7ish(&mut rng, bits, width),
                "lenet5" => symog::testing::models::lenet5ish(&mut rng, bits),
                "densenet" => symog::testing::models::densenetish(&mut rng, bits),
                other => bail!("unknown --model {other:?} (vgg7|lenet5|densenet)"),
            };
            built = IntModel::build(&man, &ck)?;
            reg.add(&name, ModelSource::InCode(&built), &opts)?
        }
    };
    let server = std::sync::Arc::new(Server::new(
        reg,
        ServeConfig::new().workers(workers).queue_depth(queue_depth),
    ));
    let front = TcpFront::bind(std::sync::Arc::clone(&server), &addr)?;
    println!(
        "serving {key} on {}  (micro-batch cap {batch}, queue depth {})",
        front.local_addr(),
        if queue_depth == 0 { "unbounded".to_string() } else { queue_depth.to_string() },
    );
    println!("protocol: length-prefixed binary frames — see DESIGN.md \"Network front-end\"");
    // serve until killed; connections are handled on their own threads
    loop {
        std::thread::park();
    }
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let delta = args.f32_or("delta", 1.0)?;
    let bits = args.usize_or("bits", 2)? as u32;
    args.finish()?;
    let q = symog::fixedpoint::Quantizer::new(bits, delta);
    println!("Q_{bits}(x; Δ={delta}) transfer curve (paper Figure 2):");
    let b = q.clip_bound() * 2.0;
    for i in 0..=20 {
        let x = -b + (2.0 * b) * i as f32 / 20.0;
        let y = q.apply(x);
        let pos = ((y / q.clip_bound() + 1.0) * 15.0) as usize;
        println!("  x={x:+.3}  Q(x)={y:+.3}  {}*", " ".repeat(pos.min(40)));
    }
    Ok(())
}

/// A1 ablation: SYMOG at N in {2, 3, 4, 8} bits on LeNet-5.
fn cmd_ablate_bits(args: &Args) -> Result<()> {
    let epochs = args.usize_or("epochs", 8)? as u32;
    let train_n = args.usize_or("train-n", 2048)?;
    let test_n = args.usize_or("test-n", 512)?;
    let seed = args.usize_or("seed", 0)? as u64;
    args.finish()?;
    let rt = Runtime::cpu()?;
    let (train, test) = Preset::SynthMnist.load(train_n, test_n, seed);
    let mut t = Table::new(["bits", "codebook", "best q-error", "float error"]);
    for (bits, tag) in [
        (2u32, "lenet5-symog-synth-mnist-w1-b2"),
        (3, "lenet5-symog-synth-mnist-w1-b3"),
        (4, "lenet5-symog-synth-mnist-w1-b4"),
        (8, "lenet5-symog-synth-mnist-w1-b8"),
    ] {
        let exp = Experiment {
            name: format!("ablate-b{bits}"),
            artifact: tag.into(),
            dataset: Preset::SynthMnist,
            train_n,
            test_n,
            epochs,
            seed,
            verbose: false,
            ..Default::default()
        };
        let art = match driver::load_artifact(&rt, &exp, &artifacts_root()) {
            Ok(a) => a,
            Err(e) => {
                println!("b{bits}: skipped ({e:#})");
                continue;
            }
        };
        let res = driver::run_experiment(&art, &exp, &train, &test)?;
        println!("N={bits}: q-error {:.2}%", res.best_q_error * 100.0);
        t.row([
            bits.to_string(),
            format!("{} levels", (1usize << bits) - 1),
            format!("{:.2}%", res.best_q_error * 100.0),
            format!("{:.2}%", res.best_f_error * 100.0),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// A2 ablation: exponential (paper) vs linear vs constant lambda schedule.
fn cmd_ablate_lambda(args: &Args) -> Result<()> {
    let epochs = args.usize_or("epochs", 8)? as u32;
    let train_n = args.usize_or("train-n", 2048)?;
    let test_n = args.usize_or("test-n", 512)?;
    let seed = args.usize_or("seed", 0)? as u64;
    args.finish()?;
    let rt = Runtime::cpu()?;
    let (train, test) = Preset::SynthMnist.load(train_n, test_n, seed);
    let exp0 = Experiment {
        name: "ablate-lambda".into(),
        artifact: "lenet5-symog-synth-mnist-w1-b2".into(),
        dataset: Preset::SynthMnist,
        train_n,
        test_n,
        epochs,
        seed,
        verbose: false,
        ..Default::default()
    };
    let art = driver::load_artifact(&rt, &exp0, &artifacts_root())?;
    let mut t = Table::new(["schedule", "lambda(0)", "lambda(E)", "best q-error"]);
    for kind in ["exp", "linear", "const"] {
        let exp = Experiment { lambda_kind: kind.into(), ..exp0.clone() };
        let sched = exp.lambda_schedule();
        let res = driver::run_experiment(&art, &exp, &train, &test)?;
        println!("{kind}: q-error {:.2}%", res.best_q_error * 100.0);
        t.row([
            kind.to_string(),
            format!("{:.1}", sched.at(0)),
            format!("{:.1}", sched.at(epochs)),
            format!("{:.2}%", res.best_q_error * 100.0),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let root = args
        .str_opt("root")
        .map(PathBuf::from)
        .unwrap_or_else(artifacts_root);
    args.finish()?;
    let mut t = Table::new(["tag", "model", "method", "dataset", "batch", "bits", "params"]);
    let mut found = 0;
    if root.exists() {
        let mut entries: Vec<_> = std::fs::read_dir(&root)?.filter_map(|e| e.ok()).collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let mpath = e.path().join("manifest.json");
            if let Ok(man) = symog::runtime::Manifest::load(&mpath) {
                t.row([
                    man.tag.clone(),
                    man.model.clone(),
                    man.method.clone(),
                    man.dataset.clone(),
                    man.batch.to_string(),
                    man.n_bits.to_string(),
                    symog::report::human_count(man.num_params()),
                ]);
                found += 1;
            }
        }
    }
    if found == 0 {
        println!("no artifacts under {} — run `make artifacts`", root.display());
    } else {
        print!("{}", t.render());
    }
    Ok(())
}
