//! Hand-rolled CLI argument parser (clap is not vendored).
//!
//! Grammar: `symog <subcommand> [--flag value | --switch] ...`
//! Every flag is `--kebab-case`; switches take no value. Unknown flags,
//! repeated flags, and a flag whose value looks like another flag (a
//! `--value`) are hard errors so typos never silently change an
//! experiment, and numeric parse failures name the offending flag.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand + flag map.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// flags consumed via accessors — unknown-flag detection
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `argv[1..]`. `switch_names` lists the valueless flags.
    pub fn parse(argv: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            if switch_names.contains(&name) {
                // a repeated switch is as suspicious as a repeated flag:
                // it usually means a line was pasted twice
                if args.switches.iter().any(|s| s == name) {
                    bail!("duplicate switch --{name}");
                }
                args.switches.push(name.to_string());
            } else {
                // a value that itself looks like a flag means the real
                // value was forgotten — consuming it would silently drop
                // the next flag from the command line
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => bail!("flag --{name} requires a value"),
                };
                // last-wins overwrite would let `--seed 1 ... --seed 2`
                // silently change an experiment; make the repeat loud
                if args.flags.insert(name.to_string(), val).is_some() {
                    bail!("duplicate flag --{name}");
                }
            }
        }
        Ok(args)
    }

    pub fn from_env(switch_names: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, switch_names)
    }

    fn mark(&self, name: &str) {
        self.seen.borrow_mut().push(name.to_string());
    }

    pub fn str_opt(&self, name: &str) -> Option<String> {
        self.mark(name);
        self.flags.get(name).cloned()
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        self.mark(name);
        match self.flags.get(name) {
            Some(v) => v.parse().with_context(|| format!("invalid value {v:?} for flag --{name}")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        self.mark(name);
        match self.flags.get(name) {
            Some(v) => v.parse().with_context(|| format!("invalid value {v:?} for flag --{name}")),
            None => Ok(default),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        self.mark(name);
        match self.flags.get(name) {
            Some(v) => v.parse().with_context(|| format!("invalid value {v:?} for flag --{name}")),
            None => Ok(default),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name)
    }

    /// Call after all accessors: errors on any flag nobody consumed.
    pub fn finish(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                bail!("unknown flag --{k} for subcommand {:?}", self.subcommand);
            }
        }
        for s in &self.switches {
            if !seen.contains(s) {
                bail!("unknown switch --{s} for subcommand {:?}", self.subcommand);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &sv(&["train", "--epochs", "10", "--verbose", "--lr0", "0.01"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.usize_or("epochs", 0).unwrap(), 10);
        assert_eq!(a.f32_or("lr0", 0.0).unwrap(), 0.01);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_rejected_at_finish() {
        let a = Args::parse(&sv(&["train", "--oops", "1"]), &[]).unwrap();
        a.usize_or("epochs", 0).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["train", "--epochs"]), &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["x"]), &[]).unwrap();
        assert_eq!(a.str_or("name", "d"), "d");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.u64_or("seed", 9).unwrap(), 9);
    }

    #[test]
    fn u64_parses_large_seeds() {
        let a = Args::parse(&sv(&["x", "--seed", "18446744073709551615"]), &[]).unwrap();
        assert_eq!(a.u64_or("seed", 0).unwrap(), u64::MAX);
        a.finish().unwrap();
    }

    #[test]
    fn positional_after_flags_rejected() {
        assert!(Args::parse(&sv(&["t", "--a", "1", "stray"]), &[]).is_err());
    }

    #[test]
    fn duplicate_flag_is_a_hard_error() {
        // last-wins would make `--seed 1 ... --seed 2` silently run seed 2
        let err = Args::parse(&sv(&["t", "--seed", "1", "--seed", "2"]), &[]).unwrap_err();
        assert!(err.to_string().contains("duplicate flag --seed"), "{err}");
        let err = Args::parse(&sv(&["t", "--quiet", "--quiet"]), &["quiet"]).unwrap_err();
        assert!(err.to_string().contains("duplicate switch --quiet"), "{err}");
    }

    #[test]
    fn omitted_value_does_not_swallow_the_next_flag() {
        // `--deadline-ms --faults x` used to parse deadline-ms = "--faults"
        // and silently drop the faults flag from the command line
        let err =
            Args::parse(&sv(&["t", "--deadline-ms", "--faults", "x"]), &[]).unwrap_err();
        assert!(err.to_string().contains("flag --deadline-ms requires a value"), "{err}");
        // same when the next token is a switch
        let err = Args::parse(&sv(&["t", "--epochs", "--quiet"]), &["quiet"]).unwrap_err();
        assert!(err.to_string().contains("flag --epochs requires a value"), "{err}");
        // a single-dash value (negative number) is still a legal value
        let a = Args::parse(&sv(&["t", "--lr0", "-0.5"]), &[]).unwrap();
        assert_eq!(a.f32_or("lr0", 0.0).unwrap(), -0.5);
        a.finish().unwrap();
    }

    #[test]
    fn numeric_parse_errors_name_the_flag() {
        let a = Args::parse(&sv(&["t", "--queue-depth", "x"]), &[]).unwrap();
        let err = a.usize_or("queue-depth", 0).unwrap_err();
        assert!(
            format!("{err:#}").contains("invalid value \"x\" for flag --queue-depth"),
            "{err:#}"
        );
        let a = Args::parse(&sv(&["t", "--seed", "12e"]), &[]).unwrap();
        let err = a.u64_or("seed", 0).unwrap_err();
        assert!(format!("{err:#}").contains("for flag --seed"), "{err:#}");
        let a = Args::parse(&sv(&["t", "--lr0", "fast"]), &[]).unwrap();
        let err = a.f32_or("lr0", 0.0).unwrap_err();
        assert!(format!("{err:#}").contains("for flag --lr0"), "{err:#}");
    }
}
