//! Hand-rolled CLI argument parser (clap is not vendored).
//!
//! Grammar: `symog <subcommand> [--flag value | --switch] ...`
//! Every flag is `--kebab-case`; switches take no value. Unknown flags are
//! hard errors so typos never silently change an experiment.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand + flag map.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// flags consumed via accessors — unknown-flag detection
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `argv[1..]`. `switch_names` lists the valueless flags.
    pub fn parse(argv: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            if switch_names.contains(&name) {
                args.switches.push(name.to_string());
            } else {
                let val = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("flag --{name} requires a value"))?;
                args.flags.insert(name.to_string(), val.clone());
            }
        }
        Ok(args)
    }

    pub fn from_env(switch_names: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, switch_names)
    }

    fn mark(&self, name: &str) {
        self.seen.borrow_mut().push(name.to_string());
    }

    pub fn str_opt(&self, name: &str) -> Option<String> {
        self.mark(name);
        self.flags.get(name).cloned()
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        self.mark(name);
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        self.mark(name);
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        self.mark(name);
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name)
    }

    /// Call after all accessors: errors on any flag nobody consumed.
    pub fn finish(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                bail!("unknown flag --{k} for subcommand {:?}", self.subcommand);
            }
        }
        for s in &self.switches {
            if !seen.contains(s) {
                bail!("unknown switch --{s} for subcommand {:?}", self.subcommand);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &sv(&["train", "--epochs", "10", "--verbose", "--lr0", "0.01"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.usize_or("epochs", 0).unwrap(), 10);
        assert_eq!(a.f32_or("lr0", 0.0).unwrap(), 0.01);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_rejected_at_finish() {
        let a = Args::parse(&sv(&["train", "--oops", "1"]), &[]).unwrap();
        a.usize_or("epochs", 0).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["train", "--epochs"]), &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["x"]), &[]).unwrap();
        assert_eq!(a.str_or("name", "d"), "d");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.u64_or("seed", 9).unwrap(), 9);
    }

    #[test]
    fn u64_parses_large_seeds() {
        let a = Args::parse(&sv(&["x", "--seed", "18446744073709551615"]), &[]).unwrap();
        assert_eq!(a.u64_or("seed", 0).unwrap(), u64::MAX);
        a.finish().unwrap();
    }

    #[test]
    fn positional_after_flags_rejected() {
        assert!(Args::parse(&sv(&["t", "--a", "1", "stray"]), &[]).is_err());
    }
}
