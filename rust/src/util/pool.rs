//! Persistent parked worker pool for data-parallel host work.
//!
//! rayon is not vendored, so batch assembly / dataset generation, the
//! integer inference GEMM, every fused step inside `ExecPlan::run`, the
//! serve drain's `run_rows` scatter, and the native training
//! forward/backward all fan out through the chunking entry points here:
//! `par_chunks_mut` (one contiguous mutable chunk per worker) and
//! `par_map` (index-ordered results — the training `dw`/`db` reduction
//! cells ride on this).
//!
//! Until PR 8 each call created and joined fresh OS threads via
//! `std::thread::scope` — dozens of spawn/join round-trips per planned
//! forward, per micro-batch, per train step. Dispatch now goes through a
//! **process-wide persistent pool** ([`Pool`]): `default_workers() - 1`
//! threads are spawned once on first use and then park on a condvar; a
//! multi-chunk call pushes one type-erased job onto a shared queue, wakes
//! the workers, claims chunks of its own job alongside them
//! (caller-runs), and blocks until the job's completion counter drains.
//! Steady state performs **zero thread spawns**, observable through
//! [`counters`] and gated by the `pool_dispatch` hotpath-bench section.
//!
//! Three contracts the rest of the system leans on:
//!
//! * **Determinism** — the chunking formula (`ceil(n / workers)`
//!   contiguous chunks, offsets at `i * chunk`) is byte-for-byte the one
//!   the scoped implementation used, chunks write disjoint slices, and
//!   nothing about *which* thread runs a chunk is observable; every
//!   worker-invariance bit-identity suite remains the oracle.
//! * **Reentrancy** — a job chunk that itself fans out (serve drains call
//!   `run_rows`, whose rows run per-step fan-outs) must never wait on the
//!   pool from a pool worker. Nested dispatch *from a worker thread* runs
//!   inline on that worker (`inline_nested` counter); dispatchers
//!   additionally always claim and run every unclaimed chunk of their own
//!   job before blocking, so no thread ever waits on work that only a
//!   blocked thread could run. See DESIGN.md §"Threading model".
//! * **Panic parity** — a panicking chunk is caught on the executing
//!   thread (workers survive), recorded, and re-thrown from the
//!   dispatching call after the job completes — exactly where
//!   `std::thread::scope` would have re-thrown it at join.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

/// Cap on *detected* parallelism (`std::thread::available_parallelism`)
/// when `SYMOG_WORKERS` is unset. The hot paths are memory-bandwidth-
/// bound integer kernels: past ~16 host threads the extra workers mostly
/// contend for the same bandwidth, and on big shared CI/serving hosts an
/// unbounded default would also pin one pool thread per core for a
/// process that may be one tenant among many. Deliberate deployments can
/// go past this with the env override.
pub const DETECTED_WORKERS_CAP: usize = 16;

/// Cap on the explicit `SYMOG_WORKERS` override. Higher than
/// [`DETECTED_WORKERS_CAP`] on purpose: an operator who *asks* for 64
/// workers is sizing for a known machine, so the override is trusted up
/// to this sanity bound (it exists only to keep a typo like
/// `SYMOG_WORKERS=6400` from spawning thousands of parked threads).
pub const ENV_WORKERS_CAP: usize = 64;

/// Number of workers to use for host-side data parallelism. Overridable
/// with `SYMOG_WORKERS`, honored by both the inference and the native
/// training hot paths (serving/CI deployments pin this to their core
/// budget; results never depend on it — only wall-clock does). The env
/// var is read once per process — this sits on per-op hot paths — and
/// the persistent pool sizes itself from the first value returned.
pub fn default_workers() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    *WORKERS.get_or_init(|| {
        if let Some(n) = std::env::var("SYMOG_WORKERS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            if n >= 1 {
                return n.min(ENV_WORKERS_CAP);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(DETECTED_WORKERS_CAP)
    })
}

// --- observability ----------------------------------------------------

static JOBS_DISPATCHED: AtomicU64 = AtomicU64::new(0);
static INLINE_SINGLE: AtomicU64 = AtomicU64::new(0);
static INLINE_NESTED: AtomicU64 = AtomicU64::new(0);
static CALLER_CHUNKS: AtomicU64 = AtomicU64::new(0);
static WORKER_CHUNKS: AtomicU64 = AtomicU64::new(0);
static PARKS: AtomicU64 = AtomicU64::new(0);
static WAKES: AtomicU64 = AtomicU64::new(0);
static THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the pool's lifetime dispatch counters (process-global,
/// monotonic). `threads_spawned` changes only while the pool initializes,
/// so `counters().threads_spawned` being equal across two snapshots that
/// bracket hot-path work *proves* zero OS-thread spawns on that path —
/// the steady-state contract the pool tests and the `pool_dispatch`
/// bench section assert.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    /// multi-chunk jobs dispatched through the queue
    pub jobs_dispatched: u64,
    /// single-chunk calls run inline on the dispatcher (no queue touch)
    pub inline_single: u64,
    /// nested dispatches run inline because the caller was a pool worker
    pub inline_nested: u64,
    /// job chunks executed by their own dispatcher (caller-runs)
    pub caller_chunks: u64,
    /// job chunks executed by parked pool workers
    pub worker_chunks: u64,
    /// times a worker found the queue empty and parked on the condvar
    pub parks: u64,
    /// wake broadcasts issued by dispatchers pushing a job
    pub wakes: u64,
    /// OS threads ever spawned by the pool (fixed after initialization)
    pub threads_spawned: u64,
}

/// Read the current [`PoolCounters`]. Counters are monotonic; take two
/// snapshots and subtract to attribute activity to a code region (other
/// threads may add in between, so assert `>=` on deltas, never `==` —
/// except for `threads_spawned`, which is exact once the pool is warm).
pub fn counters() -> PoolCounters {
    PoolCounters {
        jobs_dispatched: JOBS_DISPATCHED.load(Ordering::Relaxed),
        inline_single: INLINE_SINGLE.load(Ordering::Relaxed),
        inline_nested: INLINE_NESTED.load(Ordering::Relaxed),
        caller_chunks: CALLER_CHUNKS.load(Ordering::Relaxed),
        worker_chunks: WORKER_CHUNKS.load(Ordering::Relaxed),
        parks: PARKS.load(Ordering::Relaxed),
        wakes: WAKES.load(Ordering::Relaxed),
        threads_spawned: THREADS_SPAWNED.load(Ordering::Relaxed),
    }
}

// --- the pool ---------------------------------------------------------

thread_local! {
    /// True on pool worker threads for their whole lifetime: dispatch
    /// from such a thread runs inline (the reentrancy rule).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One dispatched fan-out: a type-erased chunk closure plus the claim
/// and completion state. Chunks are claimed by `fetch_add` on `next`
/// (each index handed out exactly once); `pending` counts chunks not yet
/// *finished* and reaching zero flips `done.finished` under the mutex —
/// the dispatcher blocks on that, never on the queue.
struct Job {
    task: TaskRef,
    n_chunks: usize,
    next: AtomicUsize,
    pending: AtomicUsize,
    done: Mutex<JobDone>,
    done_cv: Condvar,
}

struct JobDone {
    finished: bool,
    /// first chunk panic, re-thrown by the dispatcher after completion
    panic: Option<Box<dyn Any + Send>>,
}

/// Lifetime-erased reference to the dispatcher's chunk closure.
///
/// SAFETY: the `'static` is a lie told to the worker threads; it is
/// sound because [`Pool::run`] does not return until `pending` reaches
/// zero, i.e. until after the last use of this reference on any thread —
/// the same guarantee `std::thread::scope` gives its borrows. Nothing
/// outside this module can observe the reference.
struct TaskRef(&'static (dyn Fn(usize) + Sync));

struct Pool {
    queue: Mutex<VecDeque<Arc<Job>>>,
    work_cv: Condvar,
}

/// The process-wide pool, created (and its workers spawned) on first
/// multi-chunk dispatch.
fn pool() -> &'static Arc<Pool> {
    static POOL: OnceLock<Arc<Pool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let pool = Arc::new(Pool { queue: Mutex::new(VecDeque::new()), work_cv: Condvar::new() });
        // `default_workers() - 1` parked threads: the dispatcher itself is
        // the remaining worker (caller-runs), so a w-way fan-out uses
        // exactly w threads, as the scoped implementation did.
        for wi in 0..default_workers().saturating_sub(1) {
            let p = Arc::clone(&pool);
            let spawned = std::thread::Builder::new()
                .name(format!("symog-pool-{wi}"))
                .spawn(move || worker_loop(&p))
                .is_ok();
            if spawned {
                THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
            }
        }
        pool
    })
}

fn worker_loop(pool: &Pool) {
    IN_POOL_WORKER.with(|f| f.set(true));
    loop {
        let job: Arc<Job> = {
            let mut q = lock(&pool.queue);
            loop {
                // first job with unclaimed chunks; fully-claimed jobs stay
                // queued (their dispatcher removes them on completion) and
                // are skipped here
                let open = q
                    .iter()
                    .find(|j| j.next.load(Ordering::Relaxed) < j.n_chunks)
                    .map(Arc::clone);
                if let Some(j) = open {
                    break j;
                }
                PARKS.fetch_add(1, Ordering::Relaxed);
                q = pool.work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        WORKER_CHUNKS.fetch_add(job.work(), Ordering::Relaxed);
    }
}

impl Job {
    /// Claim and execute chunks until none remain unclaimed; returns how
    /// many this thread ran. Panics inside a chunk are caught (recorded
    /// once, for the dispatcher to re-throw) so the executing thread —
    /// worker or dispatcher — survives and completion still drains.
    fn work(&self) -> u64 {
        let mut ran = 0u64;
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_chunks {
                return ran;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| (self.task.0)(i))) {
                let mut d = lock(&self.done);
                if d.panic.is_none() {
                    d.panic = Some(p);
                }
            }
            ran += 1;
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = lock(&self.done);
                d.finished = true;
                self.done_cv.notify_all();
            }
        }
    }
}

impl Pool {
    /// Dispatch a multi-chunk job: enqueue, wake the parked workers,
    /// claim chunks alongside them, then block until every chunk has
    /// finished. Returns only after all side effects of `f` are visible
    /// to the caller (the completion handshake is the synchronization
    /// edge, like a scope join).
    fn run(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        // SAFETY: see `TaskRef` — this call blocks until the job fully
        // completes, so extending the closure borrow to 'static never
        // lets a worker touch it after `f` is dead.
        let task = TaskRef(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        });
        let job = Arc::new(Job {
            task,
            n_chunks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_chunks),
            done: Mutex::new(JobDone { finished: false, panic: None }),
            done_cv: Condvar::new(),
        });
        JOBS_DISPATCHED.fetch_add(1, Ordering::Relaxed);
        lock(&self.queue).push_back(Arc::clone(&job));
        WAKES.fetch_add(1, Ordering::Relaxed);
        self.work_cv.notify_all();
        // caller-runs: every chunk no worker has claimed runs right here,
        // so progress never depends on pool capacity
        CALLER_CHUNKS.fetch_add(job.work(), Ordering::Relaxed);
        {
            let mut d = lock(&job.done);
            while !d.finished {
                d = job.done_cv.wait(d).unwrap_or_else(|e| e.into_inner());
            }
        }
        // the dispatcher owns its queue entry's removal (workers only
        // skip exhausted jobs), keeping the queue bounded by the number
        // of in-flight dispatchers
        {
            let mut q = lock(&self.queue);
            if let Some(pos) = q.iter().position(|j| Arc::ptr_eq(j, &job)) {
                q.remove(pos);
            }
        }
        let panic = lock(&job.done).panic.take();
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }
}

/// Run `f(chunk_index)` for every index in `0..n_chunks`, each exactly
/// once, returning after all have completed. Single-chunk calls and
/// calls from pool workers (nested fan-outs) run inline.
fn dispatch(n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if IN_POOL_WORKER.with(|c| c.get()) {
        // reentrancy rule: a worker never re-enqueues (and never blocks
        // on another job), it just runs its nested fan-out inline
        INLINE_NESTED.fetch_add(1, Ordering::Relaxed);
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    pool().run(n_chunks, f);
}

/// Pointer wrapper that lets the chunk closure reconstruct disjoint
/// `&mut` sub-slices on whichever thread claims each chunk.
struct SendPtr<T>(*mut T);
// SAFETY: only ever used to rebuild non-overlapping sub-slices of a
// caller-owned `&mut [T]` (one per claimed chunk index), with `T: Send`
// bounds on the public entry points.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `f(offset, chunk)` over contiguous chunks of `data`, `workers`
/// chunks wide, where `offset` is the chunk's starting index within
/// `data` (so callers never re-derive the chunking formula). Chunks are
/// as even as possible; `f` must be Sync. The chunk layout is a pure
/// function of `(data.len(), workers)` — identical to the pre-pool
/// scoped implementation — and chunks land on disjoint slices, so
/// results are bit-identical for any worker count and any pool size.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], workers: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    let chunk = n.div_ceil(workers);
    if chunk >= n {
        // single chunk: run inline — queueing would only add latency
        // (this is the common case for batch-of-1 serving rows)
        INLINE_SINGLE.fetch_add(1, Ordering::Relaxed);
        f(0, data);
        return;
    }
    let n_chunks = n.div_ceil(chunk);
    let base = SendPtr(data.as_mut_ptr());
    dispatch(n_chunks, &|ci: usize| {
        let start = ci * chunk;
        let len = chunk.min(n - start);
        // SAFETY: chunk indices are claimed exactly once, so these
        // reconstructed slices never overlap; `dispatch` returns only
        // after every chunk finished, keeping the borrow of `data` live
        // for as long as any thread touches it.
        let part = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
        f(start, part);
    });
}

/// Parallel-map `f` over `0..n`, collecting results in index order.
pub fn par_map<R: Send, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    let chunk = n.div_ceil(workers.max(1)).max(1);
    if chunk >= n {
        // single chunk: compute inline — no dispatch, no staging slots
        INLINE_SINGLE.fetch_add(1, Ordering::Relaxed);
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    // each slot's global index is its chunk offset plus its position —
    // no staged index vector needed
    par_chunks_mut(&mut out, workers, |off, slots| {
        for (pos, slot) in slots.iter_mut().enumerate() {
            *slot = Some(f(off + pos));
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 7, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn offsets_are_element_starts() {
        let mut v: Vec<usize> = vec![0; 100];
        par_chunks_mut(&mut v, 7, |off, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = off + i;
            }
        });
        assert_eq!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn map_preserves_order() {
        let out = par_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_ok() {
        par_chunks_mut::<u32, _>(&mut [], 4, |_, _| {});
        assert!(par_map::<usize, _>(0, 4, |i| i).is_empty());
    }

    #[test]
    fn single_worker() {
        let out = par_map(10, 1, |i| i + 1);
        assert_eq!(out[9], 10);
    }

    #[test]
    fn nested_dispatch_completes_with_correct_results() {
        // outer fan-out whose chunks fan out again: chunks that land on
        // pool workers take the inline-nested path, chunks run by the
        // dispatcher re-enter the queue — both must yield the same bits
        let want: Vec<u64> = (0..24u64).map(|i| i + (0..32u64).sum::<u64>()).collect();
        for _ in 0..50 {
            let got = par_map(24, 6, |i| {
                let inner = par_map(32, 4, |j| j as u64);
                i as u64 + inner.iter().sum::<u64>()
            });
            assert_eq!(got, want);
        }
    }

    #[test]
    fn deep_nesting_does_not_deadlock() {
        // three levels of fan-out from every chunk; with a small pool
        // this exercises worker-inline, dispatcher re-entry, and
        // oversubscribed queues all at once
        let out = par_map(8, 4, |i| {
            par_map(8, 4, |j| {
                let leaf = par_map(8, 4, |k| (i * 64 + j * 8 + k) as u64);
                leaf.iter().sum::<u64>()
            })
            .iter()
            .sum::<u64>()
        });
        let want: Vec<u64> = (0..8u64)
            .map(|i| (0..64u64).map(|r| i * 64 + r).sum())
            .collect();
        assert_eq!(out, want);
    }

    #[test]
    fn panic_in_chunk_propagates_and_pool_survives() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let mut v = vec![0u32; 64];
            par_chunks_mut(&mut v, 8, |off, _| {
                if off >= 16 {
                    panic!("chunk bomb");
                }
            });
        }));
        assert!(caught.is_err(), "chunk panic must re-throw at the dispatch call");
        // the pool (workers included) keeps serving jobs afterwards
        let out = par_map(64, 8, |i| i * 2);
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn steady_state_dispatch_spawns_no_threads() {
        // warm: first multi-chunk dispatch initializes the pool
        par_map(64, 8, |i| i);
        let c1 = counters();
        for _ in 0..10 {
            let mut v = vec![1u32; 512];
            par_chunks_mut(&mut v, 8, |_, chunk| {
                for x in chunk {
                    *x += 1;
                }
            });
            assert!(v.iter().all(|&x| x == 2));
        }
        let c2 = counters();
        assert_eq!(
            c2.threads_spawned, c1.threads_spawned,
            "steady-state dispatch must not create OS threads"
        );
        assert!(
            c2.jobs_dispatched >= c1.jobs_dispatched + 10,
            "multi-chunk calls must go through the persistent queue"
        );
        // pool size is fixed by default_workers() at init
        assert_eq!(c2.threads_spawned, default_workers().saturating_sub(1) as u64);
    }

    #[test]
    fn single_chunk_calls_stay_inline() {
        let c1 = counters();
        let mut v = vec![0u8; 16];
        par_chunks_mut(&mut v, 1, |_, chunk| chunk.fill(7));
        let _ = par_map(4, 1, |i| i);
        let c2 = counters();
        assert!(v.iter().all(|&x| x == 7));
        assert!(c2.inline_single >= c1.inline_single + 2);
    }

    #[test]
    fn oversubscribed_dispatchers_all_complete() {
        // more concurrent dispatchers than pool threads: caller-runs
        // keeps every job progressing even when no worker is free
        let dispatchers = default_workers() * 3 + 2;
        std::thread::scope(|s| {
            for t in 0..dispatchers {
                s.spawn(move || {
                    for r in 0..20 {
                        let out = par_map(33, 4, move |i| (t * 100_000 + r * 1000 + i) as u64);
                        let want: Vec<u64> =
                            (0..33).map(|i| (t * 100_000 + r * 1000 + i) as u64).collect();
                        assert_eq!(out, want);
                    }
                });
            }
        });
    }
}
