//! Tiny scoped thread pool for data-parallel host work.
//!
//! rayon is not vendored, so batch assembly / dataset generation, the
//! integer inference GEMM, and the native training forward/backward all
//! fan out through `std::thread::scope` chunking here. The entry points
//! are `par_chunks_mut` (one contiguous mutable chunk per worker) and
//! `par_map` (index-ordered results — the training `dw`/`db` reduction
//! cells ride on this).

/// Number of workers to use for host-side data parallelism. Overridable
/// with `SYMOG_WORKERS`, honored by both the inference and the native
/// training hot paths (serving/CI deployments pin this to their core
/// budget; results never depend on it — only wall-clock does). The env
/// var is read once per process — this sits on per-op hot paths.
pub fn default_workers() -> usize {
    static WORKERS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *WORKERS.get_or_init(|| {
        if let Some(n) = std::env::var("SYMOG_WORKERS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            if n >= 1 {
                return n.min(64);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    })
}

/// Run `f(offset, chunk)` over contiguous chunks of `data` on up to
/// `workers` OS threads, where `offset` is the chunk's starting index
/// within `data` (so callers never re-derive the chunking formula).
/// Chunks are as even as possible; `f` must be Sync.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], workers: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    let chunk = n.div_ceil(workers);
    if chunk >= n {
        // single chunk: run inline — a thread spawn would only add latency
        // (this is the common case for batch-of-1 serving rows)
        f(0, data);
        return;
    }
    std::thread::scope(|s| {
        for (i, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(i * chunk, part));
        }
    });
}

/// Parallel-map `f` over `0..n`, collecting results in index order.
pub fn par_map<R: Send, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    let chunk = n.div_ceil(workers.max(1)).max(1);
    if chunk >= n {
        // single chunk: compute inline — no spawn, no staging allocations
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let base: Vec<usize> = (0..n).collect();
    // pair each output slot with its index via chunked ranges
    std::thread::scope(|s| {
        for (slots, idxs) in out.chunks_mut(chunk).zip(base.chunks(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (slot, &i) in slots.iter_mut().zip(idxs) {
                    *slot = Some(f(i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 7, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn offsets_are_element_starts() {
        let mut v: Vec<usize> = vec![0; 100];
        par_chunks_mut(&mut v, 7, |off, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = off + i;
            }
        });
        assert_eq!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn map_preserves_order() {
        let out = par_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_ok() {
        par_chunks_mut::<u32, _>(&mut [], 4, |_, _| {});
        assert!(par_map::<usize, _>(0, 4, |i| i).is_empty());
    }

    #[test]
    fn single_worker() {
        let out = par_map(10, 1, |i| i + 1);
        assert_eq!(out[9], 10);
    }
}
