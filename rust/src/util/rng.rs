//! Deterministic PRNG for the data pipeline and tests.
//!
//! No `rand` crate is vendored in this environment, so we implement
//! xoshiro256++ (Blackman & Vigna) — fast, well-tested statistically, and
//! trivially seedable with SplitMix64 so every dataset / shuffle / test is
//! reproducible from a single u64 seed.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (the reference seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn normal(&mut self) -> f32 {
        // Box–Muller without caching: two u64 draws per value is cheap
        // relative to the trig; avoids carrying interior mutability.
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// N(mu, sigma^2) sample.
    #[inline]
    pub fn normal_scaled(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = sigma * self.normal();
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f32) -> bool {
        self.f32() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn permutation_deterministic_per_seed() {
        assert_eq!(Rng::new(9).permutation(50), Rng::new(9).permutation(50));
        assert_ne!(Rng::new(9).permutation(50), Rng::new(10).permutation(50));
    }
}
