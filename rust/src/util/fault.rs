//! Deterministic, seeded fault injection for the chaos suites.
//!
//! A fault **site** is a named point in production code (the serve drain
//! path, the artifact reader, the swap path) that asks the registry
//! "should I fail here?" via [`fire`]. Each armed site owns its own
//! xoshiro256++ stream ([`crate::util::rng::Rng`]) and a probability, so
//! a fault *schedule* is reproducible from `(site, prob, seed)` — the
//! chaos suites pin three seeds in CI and replay the same storm every
//! run.
//!
//! Arming is either programmatic ([`arm`], [`arm_from_spec`]) or via the
//! `SYMOG_FAULTS` environment variable, parsed once on first use:
//!
//! ```text
//! SYMOG_FAULTS=serve.drain.panic:0.2:7,artifact.payload.corrupt:1:3
//! #            site              prob seed
//! ```
//!
//! **Zero-cost when compiled out.** The real registry exists only under
//! `cfg(any(test, feature = "fault-injection"))`; release builds without
//! the feature get an `#[inline(always)] fn fire(..) -> false` stub, so
//! every `if fault::fire(SITE) { ... }` hook folds away entirely — the
//! hardened serving path carries no probe overhead in production (the
//! `serve_throughput` bench floors gate this).
//!
//! Site names are declared here (not stringly scattered) so the set of
//! injectable failure domains is auditable in one place.

/// Drainer panics mid-batch, after scratch checkout (exercises panic
/// quarantine + scratch-return-on-unwind in `VersionState::run_batch`).
pub const SERVE_DRAIN_PANIC: &str = "serve.drain.panic";
/// `run_rows` reports an injected engine error (the non-unwinding batch
/// failure path; same typed outcome, different recovery route).
pub const SERVE_DRAIN_FAIL: &str = "serve.drain.fail";
/// The pre-install probe row of `Server::swap` fails, so the incoming
/// version is refused and the serving version is untouched.
pub const SERVE_SWAP_PROBE: &str = "serve.swap.probe";
/// One payload byte flips between `artifact::load`'s CRC validation and
/// planning — the re-verify pass must catch it (TOCTOU hardening).
pub const ARTIFACT_PAYLOAD_CORRUPT: &str = "artifact.payload.corrupt";

/// Whether this build carries the real fault registry. Drivers use this
/// to reject `--faults` flags on builds where arming would be a no-op.
pub const ENABLED: bool = cfg!(any(test, feature = "fault-injection"));

#[cfg(any(test, feature = "fault-injection"))]
mod enabled {
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Mutex, MutexGuard, Once, OnceLock};

    use anyhow::{bail, Context, Result};

    use crate::util::rng::Rng;

    struct Site {
        prob: f64,
        rng: Rng,
        draws: u64,
        fired: u64,
    }

    /// Fast-path gate: false whenever the registry is empty, so disarmed
    /// test runs pay one relaxed load per site visit and nothing else.
    static ACTIVE: AtomicBool = AtomicBool::new(false);
    static ENV_INIT: Once = Once::new();

    fn registry() -> &'static Mutex<BTreeMap<String, Site>> {
        static REGISTRY: OnceLock<Mutex<BTreeMap<String, Site>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
    }

    fn lock() -> MutexGuard<'static, BTreeMap<String, Site>> {
        registry().lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Parse `SYMOG_FAULTS` exactly once. A malformed spec panics: a chaos
    /// run that silently ignored its schedule would "pass" by testing
    /// nothing.
    fn init_env() {
        ENV_INIT.call_once(|| {
            if let Ok(spec) = std::env::var("SYMOG_FAULTS") {
                if !spec.trim().is_empty() {
                    arm_from_spec(&spec).expect("invalid SYMOG_FAULTS");
                }
            }
        });
    }

    /// Should the named site fail right now? Draws from the site's seeded
    /// stream; unarmed sites never fire. Counts every draw (see [`stats`]).
    pub fn fire(site: &str) -> bool {
        init_env();
        if !ACTIVE.load(Ordering::Relaxed) {
            return false;
        }
        let mut reg = lock();
        match reg.get_mut(site) {
            Some(s) => {
                s.draws += 1;
                // prob 1.0 always fires: f64() is uniform on [0, 1)
                let hit = s.rng.f64() < s.prob;
                if hit {
                    s.fired += 1;
                }
                hit
            }
            None => false,
        }
    }

    /// Arm (or re-arm, resetting the stream and counters) one site.
    pub fn arm(site: &str, prob: f64, seed: u64) {
        assert!((0.0..=1.0).contains(&prob), "fault probability must be in [0, 1], got {prob}");
        let mut reg = lock();
        reg.insert(site.to_string(), Site { prob, rng: Rng::new(seed), draws: 0, fired: 0 });
        ACTIVE.store(true, Ordering::Relaxed);
    }

    /// Disarm one site (its counters are discarded).
    pub fn disarm(site: &str) {
        let mut reg = lock();
        reg.remove(site);
        if reg.is_empty() {
            ACTIVE.store(false, Ordering::Relaxed);
        }
    }

    /// Disarm every site — chaos tests bracket themselves with this so
    /// schedules never leak across tests sharing the process.
    pub fn disarm_all() {
        let mut reg = lock();
        reg.clear();
        ACTIVE.store(false, Ordering::Relaxed);
    }

    /// `(draws, fired)` for a site since it was (re-)armed.
    pub fn stats(site: &str) -> (u64, u64) {
        let reg = lock();
        reg.get(site).map_or((0, 0), |s| (s.draws, s.fired))
    }

    /// Arm sites from a `site:prob:seed[,site:prob:seed...]` spec — the
    /// `SYMOG_FAULTS` / `--faults` syntax.
    pub fn arm_from_spec(spec: &str) -> Result<()> {
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() != 3 {
                bail!("fault spec {part:?} is not site:prob:seed");
            }
            let prob: f64 = fields[1]
                .parse()
                .with_context(|| format!("fault spec {part:?}: bad probability {:?}", fields[1]))?;
            if !(0.0..=1.0).contains(&prob) {
                bail!("fault spec {part:?}: probability {prob} outside [0, 1]");
            }
            let seed: u64 = fields[2]
                .parse()
                .with_context(|| format!("fault spec {part:?}: bad seed {:?}", fields[2]))?;
            arm(fields[0], prob, seed);
        }
        Ok(())
    }
}

#[cfg(any(test, feature = "fault-injection"))]
pub use enabled::{arm, arm_from_spec, disarm, disarm_all, fire, stats};

/// Stub for builds without the registry: never fires, folds away.
#[cfg(not(any(test, feature = "fault-injection")))]
#[inline(always)]
pub fn fire(_site: &str) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Mutex, MutexGuard};

    /// The registry is process-global; tests in this module serialize on
    /// this lock (and leave the registry empty) so parallel test threads
    /// never see each other's schedules.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn guard() -> MutexGuard<'static, ()> {
        let g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disarm_all();
        g
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let _g = guard();
        for _ in 0..100 {
            assert!(!fire("serve.drain.panic"));
        }
        assert_eq!(stats("serve.drain.panic"), (0, 0));
        disarm_all();
    }

    #[test]
    fn schedules_are_seed_deterministic() {
        let _g = guard();
        let run = |seed: u64| -> Vec<bool> {
            arm(SERVE_DRAIN_PANIC, 0.5, seed);
            let v = (0..64).map(|_| fire(SERVE_DRAIN_PANIC)).collect();
            disarm(SERVE_DRAIN_PANIC);
            v
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert_ne!(a, c, "different seeds must differ (64 draws at p=0.5)");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
        disarm_all();
    }

    #[test]
    fn prob_extremes_and_counters() {
        let _g = guard();
        arm("always", 1.0, 1);
        arm("never", 0.0, 1);
        for _ in 0..20 {
            assert!(fire("always"));
            assert!(!fire("never"));
        }
        assert_eq!(stats("always"), (20, 20));
        assert_eq!(stats("never"), (20, 0));
        disarm_all();
        assert!(!fire("always"), "disarm_all must silence every site");
    }

    #[test]
    fn spec_parsing_accepts_good_and_rejects_bad() {
        let _g = guard();
        arm_from_spec("a:0.25:9, b:1:3 ,").unwrap();
        assert!(fire("b"));
        assert!(arm_from_spec("a:0.5").is_err(), "missing seed");
        assert!(arm_from_spec("a:1.5:2").is_err(), "prob out of range");
        assert!(arm_from_spec("a:x:2").is_err(), "non-numeric prob");
        assert!(arm_from_spec("a:0.5:x").is_err(), "non-numeric seed");
        disarm_all();
    }

    #[test]
    fn this_build_has_the_registry() {
        // cfg(test) builds always carry the real implementation
        assert!(ENABLED);
    }
}
