//! Zero-dependency substrates: PRNG, JSON, thread pool, fault injection,
//! small math helpers.

pub mod fault;
pub mod json;
pub mod pool;
pub mod rng;

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt() as f32
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let idx = ((p / 100.0) * (v.len() - 1) as f32).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118034).abs() < 1e-5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn empty_slices() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
