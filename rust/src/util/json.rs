//! Minimal JSON parser + writer.
//!
//! serde is not vendored in this environment, so the manifest/metrics
//! plumbing uses this self-contained implementation. It supports the full
//! JSON grammar minus exotic number forms; numbers are f64 (adequate for
//! manifests written by aot.py).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing junk at byte {}", p.i);
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn int(&self) -> Result<i64> {
        Ok(self.num()? as i64)
    }

    pub fn usize(&self) -> Result<usize> {
        let n = self.num()?;
        if n < 0.0 {
            bail!("negative where usize expected: {n}");
        }
        Ok(n as usize)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|j| j.usize()).collect()
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // --- writer (via Display; `.to_string()` comes from the blanket impl) --

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        _ => 3,
                    };
                    let start = self.i - 1;
                    self.i += len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"tag":"x","n":2,"ok":true,"xs":[1,2.5,-3e2],
                      "nested":{"a":null},"s":"he\"llo\nworld"}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("tag").unwrap().str().unwrap(), "x");
        assert_eq!(j.get("n").unwrap().int().unwrap(), 2);
        assert!(j.get("ok").unwrap().boolean().unwrap());
        let xs = j.get("xs").unwrap().arr().unwrap();
        assert_eq!(xs[2].num().unwrap(), -300.0);
        assert!(j.get("nested").unwrap().get("a").unwrap().is_null());
        assert_eq!(j.get("s").unwrap().str().unwrap(), "he\"llo\nworld");
    }

    #[test]
    fn rejects_junk() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2,{"b":"c"}],"d":false}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.str().unwrap(), "café ☕");
    }

    #[test]
    fn writer_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
