//! Benchmark harness (criterion is not vendored; this provides the same
//! warmup/measure/report loop with median + p95 statistics).
//!
//! Budget control: `SYMOG_BENCH_BUDGET` in {"smoke", "small", "full"}
//! scales the experiment benches so CI smoke runs finish in minutes while
//! `full` regenerates the paper-scale sweep.

use std::time::Instant;

/// Benchmark budget preset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Budget {
    Smoke,
    Small,
    Full,
}

impl Budget {
    pub fn from_env() -> Budget {
        match std::env::var("SYMOG_BENCH_BUDGET").as_deref() {
            Ok("full") => Budget::Full,
            Ok("small") => Budget::Small,
            Ok("smoke") => Budget::Smoke,
            _ => Budget::Small,
        }
    }

    /// (epochs, train_n, test_n, steps_per_epoch cap)
    ///
    /// `Small` deliberately leaves steps uncapped: the exponential lambda
    /// schedule is *per epoch*, so capping steps compresses the ramp
    /// relative to task progress and over-regularizes (observed on the
    /// synth-cifar100 block — see EXPERIMENTS.md §T1).
    pub fn training_scale(self) -> (u32, usize, usize, Option<usize>) {
        match self {
            Budget::Smoke => (2, 512, 128, Some(4)),
            Budget::Small => (12, 2048, 512, None),
            Budget::Full => (25, 8192, 1024, None),
        }
    }
}

/// Timing statistics over repeated runs.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl Stats {
    pub fn row(&self) -> String {
        format!(
            "{:<40} {:>6} iters  mean {:>10}  median {:>10}  p95 {:>10}  min {:>10}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            fmt_time(self.p95_s),
            fmt_time(self.min_s),
        )
    }

    /// ops/sec at `n` ops per iteration.
    pub fn throughput(&self, n: usize) -> f64 {
        n as f64 / self.median_s
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Measure `f` with `warmup` unrecorded runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    stats_from(name, times)
}

/// Like `bench` but with a time budget: stops after `budget_s` seconds or
/// `max_iters`, whichever first (always >= 1 measured iteration).
pub fn bench_budgeted<F: FnMut()>(
    name: &str,
    warmup: usize,
    budget_s: f64,
    max_iters: usize,
    mut f: F,
) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < max_iters
        && (times.is_empty() || start.elapsed().as_secs_f64() < budget_s)
    {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    stats_from(name, times)
}

fn stats_from(name: &str, mut times: Vec<f64>) -> Stats {
    times.sort_by(|a, b| a.total_cmp(b));
    let n = times.len();
    Stats {
        name: name.to_string(),
        iters: n,
        mean_s: times.iter().sum::<f64>() / n as f64,
        median_s: times[n / 2],
        p95_s: times[((n as f64 * 0.95) as usize).min(n - 1)],
        min_s: times[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_iters() {
        let s = bench("noop", 1, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 10);
        assert!(s.min_s <= s.median_s && s.median_s <= s.p95_s);
    }

    #[test]
    fn budgeted_respects_max() {
        let s = bench_budgeted("noop", 0, 10.0, 5, || {});
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("µs"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with("s"));
    }

    #[test]
    fn budget_presets() {
        assert_eq!(Budget::Smoke.training_scale().0, 2);
        assert!(Budget::Full.training_scale().3.is_none());
    }
}
